//! Data redistribution between block distributions (paper §V-C).
//!
//! When consecutive terms distribute a shared tensor differently, the
//! tensor must move.  The paper derives the per-dimension message
//! matching analytically (Eqs. 19–28): each source block decomposes into
//! at most `k ≤ ceil((B_y − 1)/B_x) + 1` contiguous segments (Eq. 26),
//! each exchanged with exactly one destination block; Eq. 28 bounds the
//! candidate destination processes so matching is O(segments), never
//! O(elements).  Multi-dimensional messages are the Cartesian products of
//! the per-dimension segments (message aggregation: one box = one
//! message).
//!
//! Replication is handled on both sides: the *canonical owner* (lowest
//! replica rank) sends, and every destination replica receives.

use crate::dist::TensorDist;
use crate::error::{Error, Result};
use crate::tensor::{Tensor, ELEM_BYTES};

/// One per-dimension overlap segment between a source and a destination
/// block (Eqs. 25/27 solved as interval intersection).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    /// Source block index `p^(x)` in this dimension.
    pub src_block: usize,
    /// Destination block index `p^(y)`.
    pub dst_block: usize,
    /// Global start coordinate of the overlap.
    pub start: usize,
    /// Overlap length.
    pub len: usize,
}

/// Per-dimension message matching: all (src block, dst block) overlap
/// segments for a dimension of extent `n` split into blocks of `bx`
/// (source) and `by` (destination).
///
/// Implements the Eq. 28 candidate loop: for each source block, only
/// `ceil((p_x B_x + 1)/B_y) − 1 ≤ p_y < ceil(((p_x + 1) B_x)/B_y)`
/// destination blocks can overlap.
pub fn dim_segments(n: usize, bx: usize, by: usize) -> Vec<Segment> {
    let mut out = Vec::new();
    if n == 0 {
        return out;
    }
    let n_src = n.div_ceil(bx);
    for px in 0..n_src {
        let x0 = px * bx;
        let x1 = ((px + 1) * bx).min(n);
        // Eq. 28 candidate range for p^(y).
        let py_lo = (x0 + 1).div_ceil(by).saturating_sub(1);
        let py_hi = x1.div_ceil(by); // exclusive
        for py in py_lo..py_hi {
            let y0 = py * by;
            let y1 = ((py + 1) * by).min(n);
            let s = x0.max(y0);
            let e = x1.min(y1);
            if s < e {
                out.push(Segment { src_block: px, dst_block: py, start: s, len: e - s });
            }
        }
    }
    out
}

/// One aggregated redistribution message: a dense box moved from a source
/// rank's local buffer to a destination rank's local buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    /// Sending rank (canonical owner of the source block).
    pub src: usize,
    /// Receiving rank (one replica of the destination block).
    pub dst: usize,
    /// Box offset inside the source rank's local block.
    pub src_off: Vec<usize>,
    /// Box offset inside the destination rank's local block.
    pub dst_off: Vec<usize>,
    /// Box extents.
    pub size: Vec<usize>,
}

impl Message {
    /// Elements moved.
    pub fn volume(&self) -> usize {
        self.size.iter().product()
    }
    /// Bytes moved (f32).
    pub fn bytes(&self) -> usize {
        self.volume() * ELEM_BYTES
    }
}

/// The full redistribution plan between two distributions of the same
/// tensor (§V-C).  Message count is `Π_d k_d · replicas`, independent of
/// the tensor's element count.
#[derive(Debug, Clone)]
pub struct RedistPlan {
    /// Every point-to-point message, in deterministic rank order.
    pub messages: Vec<Message>,
    /// Total elements moved rank-to-rank (excluding src==dst local copies).
    pub remote_volume: usize,
    /// Elements satisfied locally (src == dst).
    pub local_volume: usize,
}

/// Build the redistribution plan from `src` to `dst` (same tensor
/// extents, possibly different grids/blocks/replication).
pub fn plan(src: &TensorDist, dst: &TensorDist) -> Result<RedistPlan> {
    if src.extents != dst.extents {
        return Err(Error::plan(format!(
            "redistribute extent mismatch: {:?} vs {:?}",
            src.extents, dst.extents
        )));
    }
    let nd = src.extents.len();
    // Per-dim effective (block size, #blocks): replicated => one block.
    let eff = |td: &TensorDist, d: usize| -> usize {
        if td.is_replicated() {
            td.extents[d]
        } else {
            td.dist.block[d]
        }
    };
    // Per-dimension segments.
    let per_dim: Vec<Vec<Segment>> = (0..nd)
        .map(|d| dim_segments(src.extents[d], eff(src, d).max(1), eff(dst, d).max(1)))
        .collect();

    // Cartesian product of segments -> boxes.
    let mut messages = Vec::new();
    let mut remote_volume = 0usize;
    let mut local_volume = 0usize;
    let mut sel = vec![0usize; nd];
    'outer: loop {
        // materialize current box
        let segs: Vec<&Segment> = sel.iter().enumerate().map(|(d, &s)| &per_dim[d][s]).collect();
        let src_block: Vec<usize> = segs.iter().map(|s| s.src_block).collect();
        let dst_block: Vec<usize> = segs.iter().map(|s| s.dst_block).collect();
        let src_coords = if src.is_replicated() { vec![] } else { src_block.clone() };
        let dst_coords = if dst.is_replicated() { vec![] } else { dst_block.clone() };
        let sender = src.owner_of_block(&src_coords);
        let size: Vec<usize> = segs.iter().map(|s| s.len).collect();
        let vol: usize = size.iter().product();
        // Box offsets inside the local blocks (Eq. 27): replicated blocks
        // are the whole tensor, so local offset == global coordinate.
        let src_off: Vec<usize> = if src.is_replicated() {
            (0..nd).map(|d| segs[d].start).collect()
        } else {
            (0..nd).map(|d| segs[d].start - segs[d].src_block * src.dist.block[d]).collect()
        };
        let dst_off: Vec<usize> = if dst.is_replicated() {
            (0..nd).map(|d| segs[d].start).collect()
        } else {
            (0..nd).map(|d| segs[d].start - segs[d].dst_block * dst.dist.block[d]).collect()
        };
        for &receiver in &dst.replicas_of_block(&dst_coords) {
            if receiver == sender {
                local_volume += vol;
            } else {
                remote_volume += vol;
            }
            messages.push(Message {
                src: sender,
                dst: receiver,
                src_off: src_off.clone(),
                dst_off: dst_off.clone(),
                size: size.clone(),
            });
        }
        // odometer
        for d in (0..nd).rev() {
            sel[d] += 1;
            if sel[d] < per_dim[d].len() {
                continue 'outer;
            }
            sel[d] = 0;
            if d == 0 {
                break 'outer;
            }
        }
        if nd == 0 {
            break;
        }
    }
    Ok(RedistPlan { messages, remote_volume, local_volume })
}

/// Move every message box into caller-owned destination buffers (one per
/// rank, shaped `dst.local_dims()`, zeroed by the caller — message boxes
/// only overwrite the regions they cover).  Each box moves with direct
/// strided copies ([`Tensor::copy_box_from`]) — no temporary block
/// tensor per message, and no allocation at all: this is the
/// steady-state redistribution data path under
/// [`crate::sim::Machine::redistribute`].
pub fn execute_into(rp: &RedistPlan, src_bufs: &[Tensor], out: &mut [Tensor]) {
    for m in &rp.messages {
        out[m.dst].copy_box_from(&src_bufs[m.src], &m.src_off, &m.dst_off, &m.size);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::ProcessGrid;

    #[test]
    fn dim_segments_equal_blocks() {
        let segs = dim_segments(8, 4, 4);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0], Segment { src_block: 0, dst_block: 0, start: 0, len: 4 });
        assert_eq!(segs[1], Segment { src_block: 1, dst_block: 1, start: 4, len: 4 });
    }

    #[test]
    fn dim_segments_split_in_two() {
        // 8 elements: src one block of 8, dst two blocks of 4.
        let segs = dim_segments(8, 8, 4);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].dst_block, 0);
        assert_eq!(segs[1].dst_block, 1);
        assert_eq!(segs[1].start, 4);
    }

    #[test]
    fn dim_segments_misaligned() {
        // Eq. 26: k <= ceil((By-1)/Bx)+1 segments per dst block.
        let segs = dim_segments(12, 5, 3);
        // coverage must be exact and disjoint
        let total: usize = segs.iter().map(|s| s.len).sum();
        assert_eq!(total, 12);
        let by = 3;
        for s in &segs {
            // every segment within one dst block
            assert_eq!(s.start / by, s.dst_block);
            assert_eq!((s.start + s.len - 1) / by, s.dst_block);
            // and one src block
            assert_eq!(s.start / 5, s.src_block);
            assert_eq!((s.start + s.len - 1) / 5, s.src_block);
        }
    }

    #[test]
    fn dim_segments_k_bound() {
        // Eq. 26 bound on segments per SOURCE block when By > Bx:
        // a dst block spans at most ceil((By-1)/Bx)+1 src blocks.
        for (n, bx, by) in [(100, 7, 13), (64, 16, 8), (37, 5, 11), (10, 10, 3)] {
            let segs = dim_segments(n, bx, by);
            let k_bound = (by - 1).div_ceil(bx) + 1;
            let n_dst = n.div_ceil(by);
            for py in 0..n_dst {
                let k = segs.iter().filter(|s| s.dst_block == py).count();
                assert!(k <= k_bound, "n={n} bx={bx} by={by}: k={k} > {k_bound}");
            }
            let total: usize = segs.iter().map(|s| s.len).sum();
            assert_eq!(total, n);
        }
    }

    /// Test harness over [`execute_into`]: allocate zeroed destinations
    /// (sized by the larger grid) and move the boxes.
    fn run_execute(
        rp: &RedistPlan,
        src: &TensorDist,
        dst: &TensorDist,
        src_bufs: &[Tensor],
    ) -> Vec<Tensor> {
        assert!(src_bufs.len() >= src.grid.size());
        let p = src.grid.size().max(dst.grid.size());
        let mut out: Vec<Tensor> =
            (0..p).map(|_| Tensor::zeros(&dst.local_dims())).collect();
        execute_into(rp, src_bufs, &mut out);
        out
    }

    fn fill_dist(td: &TensorDist, global: &Tensor) -> Vec<Tensor> {
        (0..td.grid.size())
            .map(|r| {
                let (off, _size) = td.block_for_rank(r);
                global.block(&off, &td.local_dims())
            })
            .collect()
    }

    fn check_dist(td: &TensorDist, bufs: &[Tensor], global: &Tensor) {
        for r in 0..td.grid.size() {
            let (off, size) = td.block_for_rank(r);
            let want = global.block(&off, &size);
            let got = bufs[r].block(&vec![0; size.len()], &size);
            assert!(got.allclose(&want, 0.0, 0.0), "rank {r} mismatch");
        }
    }

    #[test]
    fn roundtrip_1d_resplit() {
        // 2 blocks -> 4 blocks of a 16-vector (paper's t1 redistribution:
        // block over 2 procs -> block over 4 procs).
        let g2 = ProcessGrid::new(&[2, 2]).unwrap();
        let src = TensorDist::new(&[16], &g2, &[0]).unwrap(); // split dim0 over 2, replicated over dim1
        let dst = TensorDist::new(&[16], &g2, &[1]).unwrap(); // now split over the other axis
        let global = Tensor::random(&[16], 5);
        let src_bufs = fill_dist(&src, &global);
        let rp = plan(&src, &dst).unwrap();
        let dst_bufs = run_execute(&rp, &src, &dst, &src_bufs);
        check_dist(&dst, &dst_bufs, &global);
    }

    #[test]
    fn roundtrip_2d_regrid() {
        // (2,2) grid -> (4,1) grid over a 12x12 matrix.
        let ga = ProcessGrid::new(&[2, 2]).unwrap();
        let gb = ProcessGrid::new(&[4, 1]).unwrap();
        let src = TensorDist::new(&[12, 12], &ga, &[0, 1]).unwrap();
        let dst = TensorDist::new(&[12, 12], &gb, &[0, 1]).unwrap();
        let global = Tensor::random(&[12, 12], 6);
        let src_bufs = fill_dist(&src, &global);
        let rp = plan(&src, &dst).unwrap();
        let dst_bufs = run_execute(&rp, &src, &dst, &src_bufs);
        check_dist(&dst, &dst_bufs, &global);
    }

    #[test]
    fn roundtrip_to_replicated() {
        // Allgather-like: split -> replicated everywhere.
        let g = ProcessGrid::new(&[4]).unwrap();
        let src = TensorDist::new(&[10], &g, &[0]).unwrap();
        let dst = TensorDist::replicated(&[10], &g).unwrap();
        let global = Tensor::random(&[10], 7);
        let src_bufs = fill_dist(&src, &global);
        let rp = plan(&src, &dst).unwrap();
        let dst_bufs = run_execute(&rp, &src, &dst, &src_bufs);
        for r in 0..4 {
            assert!(dst_bufs[r].allclose(&global, 0.0, 0.0), "rank {r}");
        }
    }

    #[test]
    fn roundtrip_from_replicated() {
        // Scatter-like: replicated -> split; only owners copy.
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        let src = TensorDist::replicated(&[8, 8], &g).unwrap();
        let dst = TensorDist::new(&[8, 8], &g, &[0, 1]).unwrap();
        let global = Tensor::random(&[8, 8], 8);
        let src_bufs: Vec<Tensor> = (0..4).map(|_| global.clone()).collect();
        let rp = plan(&src, &dst).unwrap();
        let dst_bufs = run_execute(&rp, &src, &dst, &src_bufs);
        check_dist(&dst, &dst_bufs, &global);
    }

    #[test]
    fn misaligned_blocks_roundtrip() {
        // Extent 10 split 3 ways (blocks of 4,4,2) -> split 2 ways (5,5):
        // requires the Eq. 25 step-function segments.
        let g3 = ProcessGrid::new(&[3]).unwrap();
        let g2 = ProcessGrid::new(&[2]).unwrap();
        let src = TensorDist::new(&[10], &g3, &[0]).unwrap();
        let dst = TensorDist::new(&[10], &g2, &[0]).unwrap();
        let global = Tensor::random(&[10], 9);
        let src_bufs = fill_dist(&src, &global);
        let rp = plan(&src, &dst).unwrap();
        // dst rank count (2) < src rank count (3): execute sizes buffers by max grid
        let dst_bufs = run_execute(&rp, &src, &dst, &src_bufs);
        check_dist(&dst, &dst_bufs, &global);
    }

    #[test]
    fn plan_volume_accounting() {
        let g = ProcessGrid::new(&[2]).unwrap();
        let src = TensorDist::new(&[8], &g, &[0]).unwrap();
        let dst = TensorDist::replicated(&[8], &g).unwrap();
        let rp = plan(&src, &dst).unwrap();
        // each rank keeps its half locally (4) and sends it to the peer (4)
        assert_eq!(rp.local_volume, 8);
        assert_eq!(rp.remote_volume, 8);
    }

    #[test]
    fn identical_dists_all_local() {
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        let src = TensorDist::new(&[8, 8], &g, &[0, 1]).unwrap();
        let rp = plan(&src, &src).unwrap();
        assert_eq!(rp.remote_volume, 0);
        assert_eq!(rp.local_volume, 64);
    }

    #[test]
    fn extent_mismatch_rejected() {
        let g = ProcessGrid::new(&[2]).unwrap();
        let a = TensorDist::new(&[8], &g, &[0]).unwrap();
        let b = TensorDist::new(&[9], &g, &[0]).unwrap();
        assert!(plan(&a, &b).is_err());
    }
}
