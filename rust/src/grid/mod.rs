//! Cartesian process grids with MPI `Cart_create` / `Cart_sub` semantics
//! (paper §II-C/D, Fig. 3) and the grid-dimension optimizer that matches
//! grid shape to the SOAP-optimal tile proportions.
//!
//! Ranks are numbered row-major over grid coordinates (MPI's default
//! ordering): the **last** dimension varies fastest.

use crate::error::{Error, Result};

/// An N-dimensional Cartesian process grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProcessGrid {
    dims: Vec<usize>,
}

impl ProcessGrid {
    /// Create a grid with the given per-dimension sizes (all ≥ 1).
    pub fn new(dims: &[usize]) -> Result<Self> {
        if dims.is_empty() || dims.iter().any(|&d| d == 0) {
            return Err(Error::plan(format!("invalid grid dims {dims:?}")));
        }
        Ok(ProcessGrid { dims: dims.to_vec() })
    }

    /// Per-dimension sizes.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Grid dimensionality.
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Total process count `P = Π P_j`.
    pub fn size(&self) -> usize {
        self.dims.iter().product()
    }

    /// Coordinates of `rank` (row-major, last dim fastest).
    pub fn coords(&self, rank: usize) -> Vec<usize> {
        debug_assert!(rank < self.size());
        let mut c = vec![0usize; self.dims.len()];
        let mut rem = rank;
        for d in (0..self.dims.len()).rev() {
            c[d] = rem % self.dims[d];
            rem /= self.dims[d];
        }
        c
    }

    /// Rank of `coords` (inverse of [`coords`](Self::coords)).
    pub fn rank(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.dims.len());
        let mut r = 0usize;
        for (d, &c) in coords.iter().enumerate() {
            debug_assert!(c < self.dims[d]);
            r = r * self.dims[d] + c;
        }
        r
    }

    /// `MPI_Cart_sub`: drop the dimensions where `remain[d]` is false.
    ///
    /// Produces `Π_{!remain} P_d` disjoint sub-grids, each containing
    /// `Π_{remain} P_d` processes (paper Listing 2 / Fig. 3).  The
    /// returned [`SubgridSet`] maps every rank to its group.
    pub fn cart_sub(&self, remain: &[bool]) -> Result<SubgridSet> {
        if remain.len() != self.dims.len() {
            return Err(Error::plan("remain length != grid ndim"));
        }
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut key_of_rank = vec![0usize; self.size()];
        // Group key: coordinates over the DROPPED dims, flattened.
        let dropped: Vec<usize> =
            (0..self.dims.len()).filter(|&d| !remain[d]).collect();
        let mut key_index: std::collections::HashMap<Vec<usize>, usize> =
            std::collections::HashMap::new();
        for r in 0..self.size() {
            let c = self.coords(r);
            let key: Vec<usize> = dropped.iter().map(|&d| c[d]).collect();
            let gid = *key_index.entry(key).or_insert_with(|| {
                groups.push(Vec::new());
                groups.len() - 1
            });
            groups[gid].push(r);
            key_of_rank[r] = gid;
        }
        Ok(SubgridSet { remain: remain.to_vec(), groups, group_of_rank: key_of_rank })
    }
}

/// The result of a `Cart_sub`: disjoint rank groups, one per combination
/// of dropped-dimension coordinates.
#[derive(Debug, Clone)]
pub struct SubgridSet {
    /// Which parent dims the sub-grids keep.
    pub remain: Vec<bool>,
    /// Rank groups (each sorted ascending; index = group id).
    pub groups: Vec<Vec<usize>>,
    /// Group id of every parent rank.
    pub group_of_rank: Vec<usize>,
}

impl SubgridSet {
    /// Number of sub-grids.
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// The group containing `rank`.
    pub fn group(&self, rank: usize) -> &[usize] {
        &self.groups[self.group_of_rank[rank]]
    }

    /// Root (lowest rank) of the group containing `rank`.
    pub fn root(&self, rank: usize) -> usize {
        self.group(rank)[0]
    }
}

/// Choose grid dimensions for `p` processes over `n` iteration-space
/// dimensions, matching the per-dimension *tile counts* `N_d / t_d` the
/// SOAP analysis produced (§II-C: grid shape follows the optimal tiling).
///
/// Enumerates every ordered factorization of `p` (divisor recursion; `p`
/// ≤ thousands in practice) and picks the one minimizing the squared
/// log-distance to the ideal proportions, subject to `P_d ≤ N_d`.
pub fn optimize_grid_dims(p: usize, extents: &[usize], weights: &[f64]) -> Vec<usize> {
    let n = extents.len();
    assert_eq!(weights.len(), n);
    if n == 0 {
        return vec![];
    }
    // Ideal (real-valued) grid: P_d ∝ weights, normalized to product = p,
    // in log space.
    let logsum: f64 = weights.iter().map(|w| w.max(1e-12).ln()).sum();
    let shift = ((p as f64).ln() - logsum) / n as f64;
    let ideal: Vec<f64> = weights.iter().map(|w| w.max(1e-12).ln() + shift).collect();

    let mut best: Option<(Vec<usize>, f64)> = None;
    let mut current = vec![1usize; n];
    factorize_rec(p, 0, n, extents, &ideal, &mut current, &mut best);
    best.map(|(dims, _)| dims).unwrap_or_else(|| {
        // p has a prime factor exceeding every extent: fall back to
        // putting everything in the largest dim.
        let mut dims = vec![1usize; n];
        let dmax = (0..n).max_by_key(|&d| extents[d]).unwrap_or(0);
        dims[dmax] = p;
        dims
    })
}

fn factorize_rec(
    p_left: usize,
    d: usize,
    n: usize,
    extents: &[usize],
    ideal: &[f64],
    current: &mut Vec<usize>,
    best: &mut Option<(Vec<usize>, f64)>,
) {
    if d == n - 1 {
        if p_left > extents[d] {
            return;
        }
        current[d] = p_left;
        let score: f64 = current
            .iter()
            .zip(ideal)
            .map(|(&pd, &id)| {
                let diff = (pd as f64).ln() - id;
                diff * diff
            })
            .sum();
        if best.as_ref().map(|(_, s)| score < *s).unwrap_or(true) {
            *best = Some((current.clone(), score));
        }
        return;
    }
    let mut f = 1usize;
    while f <= p_left && f <= extents[d] {
        if p_left % f == 0 {
            current[d] = f;
            factorize_rec(p_left / f, d + 1, n, extents, ideal, current, best);
        }
        f += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_rank_roundtrip() {
        let g = ProcessGrid::new(&[2, 3, 4]).unwrap();
        assert_eq!(g.size(), 24);
        for r in 0..24 {
            assert_eq!(g.rank(&g.coords(r)), r);
        }
        // row-major, last fastest (MPI order)
        assert_eq!(g.coords(0), vec![0, 0, 0]);
        assert_eq!(g.coords(1), vec![0, 0, 1]);
        assert_eq!(g.coords(4), vec![0, 1, 0]);
        assert_eq!(g.coords(12), vec![1, 0, 0]);
    }

    #[test]
    fn rejects_zero_dims() {
        assert!(ProcessGrid::new(&[2, 0]).is_err());
        assert!(ProcessGrid::new(&[]).is_err());
    }

    #[test]
    fn paper_fig3_subgrid_for_matrix_a() {
        // §II-D Listing 2 / Fig. 3: grid (2,2,2,1) over (i,j,k,a).  The
        // processes replicating one A[j,a]-block differ in their (i,k)
        // coords, so the replication sub-grids keep i and k:
        // remain = {true, false, true, false}.
        let g = ProcessGrid::new(&[2, 2, 2, 1]).unwrap();
        let sub = g.cart_sub(&[true, false, true, false]).unwrap();
        // P_j * P_a = 2 sub-grids, each with P_i * P_k = 4 processes.
        assert_eq!(sub.n_groups(), 2);
        for grp in &sub.groups {
            assert_eq!(grp.len(), 4);
        }
        // Table II: ranks {0,1,4,5} share A[:5,:], ranks {2,3,6,7} share
        // A[5:,:]. Grid (2,2,2,1) coords: rank = i*4 + j*2 + k.
        assert_eq!(sub.group(0).to_vec(), vec![0, 1, 4, 5]);
        assert_eq!(sub.group(2).to_vec(), vec![2, 3, 6, 7]);
    }

    #[test]
    fn cart_sub_all_remain_is_identity() {
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        let sub = g.cart_sub(&[true, true]).unwrap();
        assert_eq!(sub.n_groups(), 1);
        assert_eq!(sub.groups[0], vec![0, 1, 2, 3]);
    }

    #[test]
    fn cart_sub_none_remain_is_singletons() {
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        let sub = g.cart_sub(&[false, false]).unwrap();
        assert_eq!(sub.n_groups(), 4);
        for (r, grp) in sub.groups.iter().enumerate() {
            assert_eq!(grp, &vec![r]);
        }
    }

    #[test]
    fn subgrid_root_is_min_rank() {
        let g = ProcessGrid::new(&[2, 3]).unwrap();
        let sub = g.cart_sub(&[false, true]).unwrap();
        assert_eq!(sub.n_groups(), 2);
        assert_eq!(sub.root(4), 3); // ranks 3,4,5 form the i=1 row
    }

    #[test]
    fn grid_optimizer_balanced_cube() {
        // 8 processes over 3 equal dims -> (2,2,2).
        let dims = optimize_grid_dims(8, &[4096, 4096, 4096], &[1.0, 1.0, 1.0]);
        assert_eq!(dims, vec![2, 2, 2]);
    }

    #[test]
    fn grid_optimizer_respects_weights() {
        // §II-C worked example: MTTKRP term on P=8 with a rank dim whose
        // tile covers the whole extent (weight 1) -> grid (2,2,2,1).
        let dims = optimize_grid_dims(8, &[10, 10, 10, 10], &[2.0, 2.0, 2.0, 1.0]);
        assert_eq!(dims, vec![2, 2, 2, 1]);
    }

    #[test]
    fn grid_optimizer_respects_extent_caps() {
        // A dim of extent 1 can never be split.
        let dims = optimize_grid_dims(16, &[1, 64, 64], &[1.0, 4.0, 4.0]);
        assert_eq!(dims[0], 1);
        assert_eq!(dims.iter().product::<usize>(), 16);
    }

    #[test]
    fn grid_optimizer_total_is_p() {
        for p in [1usize, 2, 4, 6, 8, 12, 32, 512] {
            let dims = optimize_grid_dims(p, &[4096, 4096, 4096], &[1.0, 1.0, 1.0]);
            assert_eq!(dims.iter().product::<usize>(), p, "p={p}");
        }
    }
}
