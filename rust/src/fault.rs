//! Deterministic fault injection: the testing seam behind the serving
//! layer's recovery paths.
//!
//! A serving system's fault tolerance is only as real as its ability to
//! *rehearse* failure: worker panics, hung kernels, and transient
//! runtime errors have to be injectable on demand, deterministically, so
//! every recovery path (supervision restarts, bounded retry, deadline
//! shedding) is exercised by ordinary tests instead of waiting for
//! production to find them.  This module is that seam:
//!
//! - a [`FaultPlan`] maps **named sites** (e.g. `"serve.run"`,
//!   `"serve.worker"`, `"run_plan.term"`, `"engine.gemm"`) to scheduled
//!   [`FaultKind`]s — a panic, an artificial latency, or a transient
//!   typed error ([`crate::error::Error::Transient`]);
//! - schedules are expressed against each site's **invocation counter**
//!   (an atomic tick): either an explicit list of ticks
//!   ([`FaultPlan::panic_at`] and friends) or a periodic stride
//!   ([`FaultPlan::panic_every`]), so a plan's behavior is a pure
//!   function of how often each site is reached — no clocks, no RNG at
//!   check time;
//! - fired faults are **counted per site and kind**
//!   ([`FaultPlan::fired`]), so tests can assert that recovery counters
//!   (restarts, retries, sheds) match the injected plan *exactly*;
//! - [`FaultPlan::from_env`] builds a seeded plan from
//!   `DEINSUM_FAULT_SEED`, enabling a CI chaos leg that runs the whole
//!   serving suite under injected panics and latency with zero code
//!   changes.  The seeded plan only targets the serving-layer sites
//!   (`serve.*`) whose recovery machinery guarantees a closed loop still
//!   completes; direct `Program::run` traffic is never failed by it.
//!
//! The plan is threaded through the stack by handle:
//! [`crate::api::SessionBuilder::fault_plan`] installs it on the
//! [`crate::runtime::KernelEngine`] (whose dispatch methods and the
//! run loop check the `engine.*` / `run_plan.*` sites), and
//! [`crate::serve::ServerBuilder`] inherits the session's plan (or takes
//! its own) for the `serve.*` sites.  A site check against an absent
//! plan is a single branch — production traffic pays nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};

/// What an armed site does when its schedule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the site.  Contained or fatal depending on where the
    /// site sits: `serve.run` panics are caught by per-request
    /// containment, `serve.worker` panics kill the worker incarnation
    /// and exercise the supervisor.
    Panic,
    /// Return a typed [`Error::Transient`] from the site — the retryable
    /// failure class (a flaky interconnect, a transiently-failing PJRT
    /// execute).
    Transient,
    /// Sleep for the given duration at the site, then continue — a hung
    /// or slow kernel, for deadline/timeout coverage.
    Latency(Duration),
}

/// When a rule fires, in site-invocation ticks (0-based).
#[derive(Debug, Clone)]
enum Ticks {
    /// Fire at exactly these ticks.
    At(Vec<u64>),
    /// Fire whenever `tick % stride == offset`.
    Every { stride: u64, offset: u64 },
}

impl Ticks {
    fn fires(&self, tick: u64) -> bool {
        match self {
            Ticks::At(ts) => ts.contains(&tick),
            Ticks::Every { stride, offset } => {
                *stride > 0 && tick % *stride == *offset % *stride
            }
        }
    }
}

#[derive(Debug)]
struct Rule {
    kind: FaultKind,
    ticks: Ticks,
    fired: AtomicU64,
}

#[derive(Debug)]
struct Site {
    name: String,
    tick: AtomicU64,
    rules: Vec<Rule>,
}

/// Per-site totals of faults actually fired (what tests compare
/// recovery counters against).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FiredCounts {
    /// Panics raised at the site.
    pub panics: u64,
    /// Transient errors returned from the site.
    pub transients: u64,
    /// Latency injections slept at the site.
    pub latencies: u64,
}

#[derive(Debug, Default)]
struct Inner {
    sites: Vec<Site>,
}

/// A deterministic fault-injection schedule.  Cheap to clone (shared by
/// `Arc`): the engine, the run loop, and every serving worker hold the
/// same plan, so per-site tick counters are global to the process's view
/// of that plan.  See the [module docs](self).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    inner: Arc<Inner>,
}

/// Builder state: `FaultPlan`'s scheduling methods consume and return
/// the plan, so construction reads as a literal description of the
/// chaos: `FaultPlan::new().panic_at("serve.worker", &[4]).
/// transient_at("serve.run", &[2, 9])`.
impl FaultPlan {
    /// An empty plan (no site ever fires).
    pub fn new() -> Self {
        FaultPlan::default()
    }

    fn add(mut self, site: &str, kind: FaultKind, ticks: Ticks) -> Self {
        // Plans are built before being shared; the Arc is still unique.
        let inner = Arc::get_mut(&mut self.inner)
            .expect("FaultPlan schedules must be added before the plan is shared");
        let rule = Rule { kind, ticks, fired: AtomicU64::new(0) };
        match inner.sites.iter_mut().find(|s| s.name == site) {
            Some(s) => s.rules.push(rule),
            None => inner.sites.push(Site {
                name: site.to_string(),
                tick: AtomicU64::new(0),
                rules: vec![rule],
            }),
        }
        self
    }

    /// Panic at `site` on exactly these invocation ticks (0-based).
    pub fn panic_at(self, site: &str, ticks: &[u64]) -> Self {
        self.add(site, FaultKind::Panic, Ticks::At(ticks.to_vec()))
    }

    /// Return a transient error from `site` on exactly these ticks.
    pub fn transient_at(self, site: &str, ticks: &[u64]) -> Self {
        self.add(site, FaultKind::Transient, Ticks::At(ticks.to_vec()))
    }

    /// Sleep `latency` at `site` on exactly these ticks.
    pub fn latency_at(self, site: &str, latency: Duration, ticks: &[u64]) -> Self {
        self.add(site, FaultKind::Latency(latency), Ticks::At(ticks.to_vec()))
    }

    /// Panic at `site` whenever `tick % stride == offset`.
    pub fn panic_every(self, site: &str, stride: u64, offset: u64) -> Self {
        self.add(site, FaultKind::Panic, Ticks::Every { stride, offset })
    }

    /// Transient error at `site` whenever `tick % stride == offset`.
    pub fn transient_every(self, site: &str, stride: u64, offset: u64) -> Self {
        self.add(site, FaultKind::Transient, Ticks::Every { stride, offset })
    }

    /// Latency at `site` whenever `tick % stride == offset`.
    pub fn latency_every(
        self,
        site: &str,
        latency: Duration,
        stride: u64,
        offset: u64,
    ) -> Self {
        self.add(site, FaultKind::Latency(latency), Ticks::Every { stride, offset })
    }

    /// The seeded chaos plan behind the CI fault leg: reads
    /// `DEINSUM_FAULT_SEED` and, when set, returns
    /// [`seeded`](Self::seeded)`(seed)`.  `None` (no injection at all)
    /// when the variable is unset or unparseable.
    pub fn from_env() -> Option<FaultPlan> {
        let seed = std::env::var("DEINSUM_FAULT_SEED").ok()?.trim().parse::<u64>().ok()?;
        Some(Self::seeded(seed))
    }

    /// A deterministic seeded plan targeting only the serving layer's
    /// *recoverable* sites — transient run errors (retried by the
    /// server), worker-loop panics (restarted by the supervisor), and
    /// small latencies — so a full serving workload under this plan must
    /// still complete every ticket.  Direct `Program::run` paths are
    /// untouched: the seed varies stride offsets, not the target sites.
    pub fn seeded(seed: u64) -> FaultPlan {
        // SplitMix64: decorrelate the offsets from small seeds.
        let mut s = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut next = move || {
            s = s.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        FaultPlan::new()
            .transient_every(site::SERVE_RUN, 7, next() % 7)
            .panic_every(site::SERVE_WORKER, 13, next() % 13)
            .latency_every(
                site::SERVE_WORKER,
                Duration::from_micros(500),
                5,
                next() % 5,
            )
    }

    /// True when at least one rule targets `site` (cheap pre-check for
    /// hot paths that want to skip string work entirely).
    pub fn arms(&self, site: &str) -> bool {
        self.inner.sites.iter().any(|s| s.name == site)
    }

    /// Totals of faults actually fired at `site` so far.
    pub fn fired(&self, site: &str) -> FiredCounts {
        let mut c = FiredCounts::default();
        if let Some(s) = self.inner.sites.iter().find(|s| s.name == site) {
            for r in &s.rules {
                let n = r.fired.load(Ordering::Relaxed);
                match r.kind {
                    FaultKind::Panic => c.panics += n,
                    FaultKind::Transient => c.transients += n,
                    FaultKind::Latency(_) => c.latencies += n,
                }
            }
        }
        c
    }

    /// Times `site` has been checked (the tick counter's current value).
    pub fn hits(&self, site: &str) -> u64 {
        self.inner
            .sites
            .iter()
            .find(|s| s.name == site)
            .map(|s| s.tick.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Evaluate one invocation of `site`: bump its tick, sleep through
    /// any latency rule that fires, then return `Err(Transient)` or
    /// panic if an error rule fires.  Sites that cannot surface a
    /// `Result` use [`check_abort`](Self::check_abort) instead.
    pub fn check(&self, site: &str) -> Result<()> {
        match self.evaluate(site) {
            None => Ok(()),
            Some((tick, FaultKind::Transient)) => Err(Error::transient(format!(
                "injected transient fault at {site} (tick {tick})"
            ))),
            Some((tick, FaultKind::Panic)) => {
                panic!("injected panic at {site} (tick {tick})")
            }
            Some((_, FaultKind::Latency(_))) => unreachable!("latency handled inline"),
        }
    }

    /// [`check`](Self::check) for sites with no error channel: transient
    /// rules escalate to panics too (at an uncontained site like
    /// `serve.worker`, any injected failure means the worker dies).
    pub fn check_abort(&self, site: &str) {
        if let Some((tick, kind)) = self.evaluate(site) {
            panic!("injected {kind:?} at {site} (tick {tick})");
        }
    }

    /// Shared tick-advance + rule walk.  Latency rules fire inline (and
    /// several may fire on one tick); the first error-class rule that
    /// fires is returned for the caller to raise.
    fn evaluate(&self, site: &str) -> Option<(u64, FaultKind)> {
        let s = self.inner.sites.iter().find(|s| s.name == site)?;
        let tick = s.tick.fetch_add(1, Ordering::Relaxed);
        let mut hit: Option<(u64, FaultKind)> = None;
        for r in &s.rules {
            if !r.ticks.fires(tick) {
                continue;
            }
            match r.kind {
                FaultKind::Latency(d) => {
                    r.fired.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(d);
                }
                kind => {
                    if hit.is_none() {
                        r.fired.fetch_add(1, Ordering::Relaxed);
                        hit = Some((tick, kind));
                    }
                }
            }
        }
        hit
    }
}

/// An optional shared fault plan — what the engine and serving layer
/// actually store.  `Faults::none()` checks compile to one branch on a
/// `None`, so the production hot path is unaffected.
#[derive(Debug, Clone, Default)]
pub struct Faults(Option<FaultPlan>);

impl Faults {
    /// No injection (the production default when `DEINSUM_FAULT_SEED` is
    /// unset).
    pub fn none() -> Self {
        Faults(None)
    }

    /// Wrap an explicit plan.
    pub fn plan(plan: FaultPlan) -> Self {
        Faults(Some(plan))
    }

    /// The environment-driven default: `DEINSUM_FAULT_SEED` or nothing.
    pub fn from_env() -> Self {
        Faults(FaultPlan::from_env())
    }

    /// The underlying plan, if any (tests read fired counts off it).
    pub fn get(&self) -> Option<&FaultPlan> {
        self.0.as_ref()
    }

    /// Is any plan installed at all?
    pub fn active(&self) -> bool {
        self.0.is_some()
    }

    /// [`FaultPlan::check`] against the installed plan (no-op without one).
    #[inline]
    pub fn check(&self, site: &str) -> Result<()> {
        match &self.0 {
            None => Ok(()),
            Some(p) => p.check(site),
        }
    }

    /// [`FaultPlan::check_abort`] against the installed plan.
    #[inline]
    pub fn check_abort(&self, site: &str) {
        if let Some(p) = &self.0 {
            p.check_abort(site);
        }
    }
}

/// Canonical site names, so callers and tests never drift on strings.
pub mod site {
    /// Checked by every serving worker once per batch-serve loop,
    /// *outside* per-request panic containment: a panic here kills the
    /// worker incarnation and exercises the supervisor.
    pub const SERVE_WORKER: &str = "serve.worker";
    /// Checked inside per-request containment immediately before the
    /// program runs: panics are contained to the request, transients are
    /// retryable run failures.
    pub const SERVE_RUN: &str = "serve.run";
    /// Checked inside compile containment before a worker instantiates a
    /// program: a panic here costs the request a typed error (compile
    /// failures are deterministic — never retried).
    pub const SERVE_COMPILE: &str = "serve.compile";
    /// Checked by the run loop once per plan term.
    pub const RUN_PLAN_TERM: &str = "run_plan.term";
    /// Checked by the engine's GEMM dispatch.
    pub const ENGINE_GEMM: &str = "engine.gemm";
    /// Checked by the engine's fused-MTTKRP dispatch.
    pub const ENGINE_MTTKRP: &str = "engine.mttkrp";
    /// Checked by the engine's binary-einsum dispatch.
    pub const ENGINE_EINSUM2: &str = "engine.einsum2";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_ticks_fire_exactly_once_each() {
        let plan = FaultPlan::new().transient_at("t.site", &[1, 3]);
        let results: Vec<bool> = (0..6).map(|_| plan.check("t.site").is_err()).collect();
        assert_eq!(results, vec![false, true, false, true, false, false]);
        assert_eq!(plan.fired("t.site").transients, 2);
        assert_eq!(plan.hits("t.site"), 6);
        // Unknown sites never fire and never count.
        assert!(plan.check("other.site").is_ok());
        assert_eq!(plan.hits("other.site"), 0);
    }

    #[test]
    fn stride_schedule_is_periodic() {
        let plan = FaultPlan::new().transient_every("s", 3, 1);
        let errs = (0..9).filter(|_| plan.check("s").is_err()).count();
        assert_eq!(errs, 3, "ticks 1, 4, 7");
    }

    #[test]
    fn panic_rule_panics_and_counts() {
        let plan = FaultPlan::new().panic_at("p", &[0]);
        let p2 = plan.clone();
        let r = std::panic::catch_unwind(move || p2.check("p").unwrap());
        assert!(r.is_err());
        assert_eq!(plan.fired("p").panics, 1);
        assert!(plan.check("p").is_ok(), "tick 1 is clean");
    }

    #[test]
    fn latency_rule_delays_then_succeeds() {
        let plan =
            FaultPlan::new().latency_at("l", Duration::from_millis(5), &[0]);
        let t0 = std::time::Instant::now();
        assert!(plan.check("l").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert_eq!(plan.fired("l").latencies, 1);
    }

    #[test]
    fn transient_error_is_typed_and_retryable() {
        let plan = FaultPlan::new().transient_at("x", &[0]);
        let err = plan.check("x").unwrap_err();
        assert!(matches!(err, Error::Transient(_)));
        assert!(err.is_retryable());
    }

    #[test]
    fn seeded_plan_targets_only_recoverable_serve_sites() {
        let plan = FaultPlan::seeded(42);
        assert!(plan.arms(site::SERVE_RUN));
        assert!(plan.arms(site::SERVE_WORKER));
        for never in
            [site::SERVE_COMPILE, site::RUN_PLAN_TERM, site::ENGINE_GEMM, site::ENGINE_EINSUM2]
        {
            assert!(!plan.arms(never), "{never} must stay clean under the seeded plan");
        }
        // Same seed, same schedule.
        let a = FaultPlan::seeded(7);
        let b = FaultPlan::seeded(7);
        let fire = |p: &FaultPlan| -> Vec<bool> {
            (0..40).map(|_| p.check(site::SERVE_RUN).is_err()).collect()
        };
        assert_eq!(fire(&a), fire(&b));
    }

    #[test]
    fn faults_none_is_inert() {
        let f = Faults::none();
        assert!(!f.active());
        assert!(f.check("anything").is_ok());
        f.check_abort("anything");
    }
}
