//! Block distributions of tensors over Cartesian process grids (paper
//! §II-D, §V-B).
//!
//! A [`TensorDist`] maps every tensor dimension onto one grid dimension
//! (block distribution: dimension `d` of extent `N_d` handled by grid
//! dimension `g` of size `P_g` splits into blocks of `ceil(N_d / P_g)`).
//! Grid dimensions *not* mapped by any tensor dimension replicate the
//! tensor: all ranks sharing the mapped coordinates hold the same block
//! (Fig. 3 / Table II — e.g. A[j,a] on grid (i,j,k,a) is replicated over
//! the (i,k) sub-grids).  The *canonical owner* of a block is the lowest
//! replica rank; redistribution sends from owners and delivers to every
//! replica ([`crate::redist`]).

use crate::error::{Error, Result};
use crate::grid::ProcessGrid;

/// The per-dimension block geometry of a distribution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockDist {
    /// Grid dimension handling each tensor dimension.
    pub grid_dim: Vec<usize>,
    /// Grid extent along each tensor dimension (`P_g` of the handling
    /// grid dim; how many ways the dimension is split).
    pub grid: Vec<usize>,
    /// Nominal block size per tensor dimension: `ceil(N_d / P_g)`.  The
    /// trailing block may be short; ranks whose block starts past the
    /// extent hold an empty (zero-padded) block.
    pub block: Vec<usize>,
}

/// A tensor block-distributed (and possibly replicated) over a grid.
#[derive(Debug, Clone)]
pub struct TensorDist {
    /// Global tensor extents.
    pub extents: Vec<usize>,
    /// The process grid the tensor lives on.
    pub grid: ProcessGrid,
    /// Block geometry (meaningless when fully replicated).
    pub dist: BlockDist,
    /// Fully replicated: every rank holds the whole tensor.
    replicated: bool,
}

impl TensorDist {
    /// Block-distribute `extents` over `grid`, mapping tensor dimension
    /// `d` onto grid dimension `grid_dims[d]`.  Grid dimensions left
    /// unmapped replicate the tensor over their sub-grids.
    pub fn new(extents: &[usize], grid: &ProcessGrid, grid_dims: &[usize]) -> Result<Self> {
        if grid_dims.len() != extents.len() {
            return Err(Error::plan(format!(
                "dist: {} grid dims for {} tensor dims",
                grid_dims.len(),
                extents.len()
            )));
        }
        for (d, &g) in grid_dims.iter().enumerate() {
            if g >= grid.ndim() {
                return Err(Error::plan(format!(
                    "dist: tensor dim {d} mapped to grid dim {g} of {}-d grid",
                    grid.ndim()
                )));
            }
            if grid_dims[..d].contains(&g) {
                return Err(Error::plan(format!(
                    "dist: grid dim {g} handles two tensor dims"
                )));
            }
        }
        let gsizes: Vec<usize> = grid_dims.iter().map(|&g| grid.dims()[g]).collect();
        let block: Vec<usize> = extents
            .iter()
            .zip(&gsizes)
            .map(|(&n, &g)| n.div_ceil(g.max(1)).max(1))
            .collect();
        Ok(TensorDist {
            extents: extents.to_vec(),
            grid: grid.clone(),
            dist: BlockDist { grid_dim: grid_dims.to_vec(), grid: gsizes, block },
            replicated: false,
        })
    }

    /// Fully replicated distribution: every rank holds the whole tensor.
    pub fn replicated(extents: &[usize], grid: &ProcessGrid) -> Result<Self> {
        Ok(TensorDist {
            extents: extents.to_vec(),
            grid: grid.clone(),
            dist: BlockDist {
                grid_dim: Vec::new(),
                grid: vec![1; extents.len()],
                block: extents.to_vec(),
            },
            replicated: true,
        })
    }

    /// True when every rank holds the whole tensor.
    pub fn is_replicated(&self) -> bool {
        self.replicated
    }

    /// Per-rank local buffer shape (the padded nominal block; identical
    /// on all ranks so redistribution offsets are rank-independent).
    pub fn local_dims(&self) -> Vec<usize> {
        if self.replicated {
            self.extents.clone()
        } else {
            self.dist.block.clone()
        }
    }

    /// Number of *real* blocks per tensor dimension (trailing ranks past
    /// `ceil(N_d / block_d)` hold empty blocks).
    pub fn blocks_per_dim(&self) -> Vec<usize> {
        if self.replicated {
            return vec![1; self.extents.len()];
        }
        self.extents
            .iter()
            .zip(&self.dist.block)
            .map(|(&n, &b)| n.div_ceil(b).max(1))
            .collect()
    }

    /// Total number of distinct blocks.
    pub fn n_blocks(&self) -> usize {
        self.blocks_per_dim().iter().product()
    }

    /// All block coordinates (per tensor dimension).  For a replicated
    /// distribution there is a single block with empty coordinates, the
    /// convention [`crate::redist`] uses.
    pub fn block_coords(&self) -> Vec<Vec<usize>> {
        if self.replicated {
            return vec![Vec::new()];
        }
        let per_dim = self.blocks_per_dim();
        let nd = per_dim.len();
        let total: usize = per_dim.iter().product();
        let mut out = Vec::with_capacity(total);
        let mut idx = vec![0usize; nd];
        for _ in 0..total {
            out.push(idx.clone());
            for d in (0..nd).rev() {
                idx[d] += 1;
                if idx[d] < per_dim[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        out
    }

    /// The global (offset, clipped size) of rank `r`'s block.  Ranks past
    /// the real block count get an empty size.
    pub fn block_for_rank(&self, r: usize) -> (Vec<usize>, Vec<usize>) {
        if self.replicated {
            return (vec![0; self.extents.len()], self.extents.clone());
        }
        let coords = self.grid.coords(r);
        let mut off = Vec::with_capacity(self.extents.len());
        let mut size = Vec::with_capacity(self.extents.len());
        for (d, &n) in self.extents.iter().enumerate() {
            let bc = coords[self.dist.grid_dim[d]];
            let o = bc * self.dist.block[d];
            off.push(o);
            size.push(self.dist.block[d].min(n.saturating_sub(o)));
        }
        (off, size)
    }

    /// Canonical owner (lowest replica rank) of the block at `coords`
    /// (per-tensor-dim block coordinates; empty for replicated dists).
    pub fn owner_of_block(&self, coords: &[usize]) -> usize {
        if self.replicated || coords.is_empty() {
            return 0;
        }
        debug_assert_eq!(coords.len(), self.extents.len());
        let mut full = vec![0usize; self.grid.ndim()];
        for (d, &bc) in coords.iter().enumerate() {
            full[self.dist.grid_dim[d]] = bc;
        }
        self.grid.rank(&full)
    }

    /// Every rank holding (a replica of) the block at `coords`.
    pub fn replicas_of_block(&self, coords: &[usize]) -> Vec<usize> {
        if self.replicated || coords.is_empty() {
            return (0..self.grid.size()).collect();
        }
        debug_assert_eq!(coords.len(), self.extents.len());
        let unmapped: Vec<usize> = (0..self.grid.ndim())
            .filter(|g| !self.dist.grid_dim.contains(g))
            .collect();
        let mut base = vec![0usize; self.grid.ndim()];
        for (d, &bc) in coords.iter().enumerate() {
            base[self.dist.grid_dim[d]] = bc;
        }
        if unmapped.is_empty() {
            return vec![self.grid.rank(&base)];
        }
        let dims: Vec<usize> = unmapped.iter().map(|&g| self.grid.dims()[g]).collect();
        let total: usize = dims.iter().product();
        let mut out = Vec::with_capacity(total);
        let mut idx = vec![0usize; unmapped.len()];
        for _ in 0..total {
            let mut full = base.clone();
            for (q, &g) in unmapped.iter().enumerate() {
                full[g] = idx[q];
            }
            out.push(self.grid.rank(&full));
            for q in (0..unmapped.len()).rev() {
                idx[q] += 1;
                if idx[q] < dims[q] {
                    break;
                }
                idx[q] = 0;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_block_split() {
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        let td = TensorDist::new(&[8, 6], &g, &[0, 1]).unwrap();
        assert!(!td.is_replicated());
        assert_eq!(td.local_dims(), vec![4, 3]);
        assert_eq!(td.n_blocks(), 4);
        // rank = i*2 + j over coords (i, j)
        assert_eq!(td.block_for_rank(0), (vec![0, 0], vec![4, 3]));
        assert_eq!(td.block_for_rank(3), (vec![4, 3], vec![4, 3]));
        assert_eq!(td.owner_of_block(&[1, 0]), 2);
        assert_eq!(td.replicas_of_block(&[1, 0]), vec![2]);
    }

    #[test]
    fn partial_replication_over_unmapped_dims() {
        // Fig. 3: A[j,a] on a (2,2,2,1) grid over (i,j,k,a), mapped to
        // grid dims (1, 3) -> replicated over the (i,k) sub-grids.
        let g = ProcessGrid::new(&[2, 2, 2, 1]).unwrap();
        let td = TensorDist::new(&[10, 10], &g, &[1, 3]).unwrap();
        assert_eq!(td.local_dims(), vec![5, 10]);
        // Block (j=0, a=0): replicas are ranks with j-coord 0, any (i,k):
        // ranks {0,1,4,5} (Table II).
        let mut reps = td.replicas_of_block(&[0, 0]);
        reps.sort_unstable();
        assert_eq!(reps, vec![0, 1, 4, 5]);
        assert_eq!(td.owner_of_block(&[0, 0]), 0);
        let mut reps = td.replicas_of_block(&[1, 0]);
        reps.sort_unstable();
        assert_eq!(reps, vec![2, 3, 6, 7]);
        assert_eq!(td.owner_of_block(&[1, 0]), 2);
    }

    #[test]
    fn fully_replicated() {
        let g = ProcessGrid::new(&[4]).unwrap();
        let td = TensorDist::replicated(&[10], &g).unwrap();
        assert!(td.is_replicated());
        assert_eq!(td.local_dims(), vec![10]);
        assert_eq!(td.n_blocks(), 1);
        assert_eq!(td.block_coords(), vec![Vec::<usize>::new()]);
        assert_eq!(td.owner_of_block(&[]), 0);
        assert_eq!(td.replicas_of_block(&[]), vec![0, 1, 2, 3]);
        assert_eq!(td.block_for_rank(2), (vec![0], vec![10]));
    }

    #[test]
    fn uneven_extent_clips_trailing_block() {
        let g = ProcessGrid::new(&[3]).unwrap();
        let td = TensorDist::new(&[10], &g, &[0]).unwrap();
        assert_eq!(td.local_dims(), vec![4]);
        assert_eq!(td.block_for_rank(2), (vec![8], vec![2]));
        assert_eq!(td.blocks_per_dim(), vec![3]);
    }

    #[test]
    fn oversplit_dim_leaves_empty_blocks() {
        // extent 5 over 4 ranks: blocks of 2, only 3 real blocks.
        let g = ProcessGrid::new(&[4]).unwrap();
        let td = TensorDist::new(&[5], &g, &[0]).unwrap();
        assert_eq!(td.blocks_per_dim(), vec![3]);
        let (off, size) = td.block_for_rank(3);
        assert_eq!(off, vec![6]);
        assert_eq!(size, vec![0]);
    }

    #[test]
    fn blocks_cover_every_element_once() {
        let g = ProcessGrid::new(&[2, 3]).unwrap();
        let td = TensorDist::new(&[7, 8], &g, &[0, 1]).unwrap();
        let mut seen = vec![vec![0u32; 8]; 7];
        for bc in td.block_coords() {
            let r = td.owner_of_block(&bc);
            let (off, size) = td.block_for_rank(r);
            for i in off[0]..off[0] + size[0] {
                for j in off[1]..off[1] + size[1] {
                    seen[i][j] += 1;
                }
            }
        }
        assert!(seen.iter().flatten().all(|&c| c == 1));
    }

    #[test]
    fn rejects_bad_mappings() {
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        assert!(TensorDist::new(&[8], &g, &[0, 1]).is_err()); // len mismatch
        assert!(TensorDist::new(&[8, 8], &g, &[0, 2]).is_err()); // dim out of range
        assert!(TensorDist::new(&[8, 8], &g, &[1, 1]).is_err()); // double mapping
    }
}
