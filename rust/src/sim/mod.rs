//! Simulated distributed machine (Piz Daint substitute — see DESIGN.md
//! §Substitutions).
//!
//! P ranks with **real rank-local buffers**: collectives and
//! redistributions move actual bytes between buffers, so distributed
//! numerics are bit-exact versus an MPI run.  *Time* is hybrid:
//!
//! - compute: measured wall-clock of each rank's local kernel (ranks run
//!   sequentially in-process; the simulated parallel time takes the max
//!   over ranks per step);
//! - communication: an α–β (latency–bandwidth) model calibrated to a
//!   Cray-Aries-class interconnect, with tree collectives.
//!
//! The paper's evaluation claims concern communication *volume* and
//! schedule structure; volumes here are exact, and the cost model turns
//! them into the Fig. 5/6 runtime series.

pub mod accel;
pub mod collectives;
pub mod network;

pub use accel::AccelModel;
pub use network::NetworkModel;

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Per-step time breakdown (the blue/pink split of Fig. 5).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct TimeBreakdown {
    /// Max per-rank local compute seconds.
    pub compute: f64,
    /// Modeled communication seconds.
    pub comm: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.comm
    }
}

/// Communication counters (exact volumes, for bound-vs-measured checks).
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    /// Bytes crossing rank boundaries in point-to-point messages.
    pub p2p_bytes: u128,
    /// Point-to-point message count.
    pub p2p_msgs: u64,
    /// Bytes reduced in allreduce calls (payload size × participations).
    pub allreduce_bytes: u128,
    /// Allreduce invocations.
    pub allreduces: u64,
}

/// The simulated machine: rank-local tensor stores + cost accounting.
pub struct Machine {
    ranks: usize,
    net: NetworkModel,
    /// Named per-rank tensors: store[name][rank].
    store: HashMap<String, Vec<Tensor>>,
    /// Accumulated per-rank compute seconds (current step).
    step_compute: Vec<f64>,
    /// Totals.
    pub time: TimeBreakdown,
    pub comm: CommStats,
}

impl Machine {
    /// Create a machine with `ranks` processes and a network model.
    pub fn new(ranks: usize, net: NetworkModel) -> Self {
        Machine {
            ranks,
            net,
            store: HashMap::new(),
            step_compute: vec![0.0; ranks],
            time: TimeBreakdown::default(),
            comm: CommStats::default(),
        }
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    pub fn network(&self) -> &NetworkModel {
        &self.net
    }

    /// Install a per-rank tensor set under `name`.
    pub fn put(&mut self, name: &str, per_rank: Vec<Tensor>) -> Result<()> {
        if per_rank.len() != self.ranks {
            return Err(Error::plan(format!(
                "put {name}: {} tensors for {} ranks",
                per_rank.len(),
                self.ranks
            )));
        }
        self.store.insert(name.to_string(), per_rank);
        Ok(())
    }

    /// Rank-local tensor view.
    pub fn get(&self, name: &str, rank: usize) -> Result<&Tensor> {
        self.store
            .get(name)
            .and_then(|v| v.get(rank))
            .ok_or_else(|| Error::plan(format!("tensor {name} rank {rank} missing")))
    }

    /// All ranks' buffers for `name`.
    pub fn get_all(&self, name: &str) -> Result<&[Tensor]> {
        self.store
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::plan(format!("tensor {name} missing")))
    }

    /// Remove a tensor (free intermediates between terms).
    pub fn drop_tensor(&mut self, name: &str) {
        self.store.remove(name);
    }

    /// Names currently stored (diagnostics).
    pub fn tensor_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.store.keys().cloned().collect();
        v.sort();
        v
    }

    /// Record `seconds` of local compute on `rank` for the current step.
    pub fn charge_compute(&mut self, rank: usize, seconds: f64) {
        self.step_compute[rank] += seconds;
    }

    /// Run `f` as rank-local compute on every rank, writing the results
    /// under `out_name` and charging measured wall-clock per rank.
    pub fn compute_step<F>(&mut self, out_name: &str, mut f: F) -> Result<()>
    where
        F: FnMut(usize, &Machine) -> Result<Tensor>,
    {
        let mut outs = Vec::with_capacity(self.ranks);
        for r in 0..self.ranks {
            let t0 = std::time::Instant::now();
            let out = f(r, self)?;
            let dt = t0.elapsed().as_secs_f64();
            outs.push(out);
            self.step_compute[r] += dt;
        }
        self.store.insert(out_name.to_string(), outs);
        Ok(())
    }

    /// Close the current step: parallel compute time = max over ranks.
    pub fn end_step(&mut self) {
        let max = self.step_compute.iter().cloned().fold(0.0, f64::max);
        self.time.compute += max;
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
    }

    /// Allreduce-sum `name` over each group of ranks (the §II-D partial
    /// result reduction over a sub-grid).  Data: every rank in a group
    /// ends with the elementwise sum.  Time: tree allreduce on the
    /// payload size, charged once (groups reduce concurrently).
    pub fn allreduce_sum(&mut self, name: &str, groups: &[Vec<usize>]) -> Result<()> {
        let bufs = self
            .store
            .get_mut(name)
            .ok_or_else(|| Error::plan(format!("allreduce: {name} missing")))?;
        let mut max_t = 0.0f64;
        for g in groups {
            if g.len() <= 1 {
                continue;
            }
            let len = bufs[g[0]].len();
            for &r in &g[1..] {
                if bufs[r].len() != len {
                    return Err(Error::shape(format!(
                        "allreduce {name}: rank {r} buffer len {} != {len}",
                        bufs[r].len()
                    )));
                }
            }
            // sum into g[0], then broadcast (data path).
            let (first, rest) = {
                let mut sum = bufs[g[0]].clone();
                for &r in &g[1..] {
                    sum.add_assign(&bufs[r]).unwrap();
                }
                (sum, g[1..].to_vec())
            };
            bufs[g[0]] = first.clone();
            for r in rest {
                bufs[r] = first.clone();
            }
            let bytes = (len * 4) as f64;
            let t = self.net.allreduce_time(g.len(), bytes);
            self.comm.allreduce_bytes += (len * 4) as u128 * (g.len() as u128);
            self.comm.allreduces += 1;
            max_t = max_t.max(t);
        }
        self.time.comm += max_t;
        Ok(())
    }

    /// Execute a redistribution plan: move real boxes between rank
    /// buffers, charge the α–β model on the per-rank maximum send/recv
    /// volume (links are parallel across rank pairs).
    pub fn redistribute(
        &mut self,
        src_name: &str,
        dst_name: &str,
        rp: &crate::redist::RedistPlan,
        src_dist: &crate::dist::TensorDist,
        dst_dist: &crate::dist::TensorDist,
    ) -> Result<()> {
        let src_bufs = self
            .store
            .get(src_name)
            .ok_or_else(|| Error::plan(format!("redistribute: {src_name} missing")))?;
        let dst_bufs = crate::redist::execute(rp, src_dist, dst_dist, src_bufs)?;
        let mut dst_bufs = dst_bufs;
        dst_bufs.truncate(self.ranks);
        while dst_bufs.len() < self.ranks {
            dst_bufs.push(Tensor::zeros(&dst_dist.local_dims()));
        }
        // Cost: per-rank send and recv byte totals; time = α·(max #msgs
        // on a rank) + β·(max bytes through any rank).
        let mut sent = vec![0u128; self.ranks];
        let mut recv = vec![0u128; self.ranks];
        let mut msgs = vec![0u64; self.ranks];
        for m in &rp.messages {
            if m.src == m.dst {
                continue;
            }
            let b = m.bytes() as u128;
            sent[m.src] += b;
            recv[m.dst] += b;
            msgs[m.src] += 1;
            self.comm.p2p_bytes += b;
            self.comm.p2p_msgs += 1;
        }
        let max_bytes = sent
            .iter()
            .zip(&recv)
            .map(|(s, r)| s + r)
            .max()
            .unwrap_or(0) as f64;
        let max_msgs = msgs.iter().max().copied().unwrap_or(0) as f64;
        self.time.comm += self.net.p2p_time(max_msgs, max_bytes);
        self.store.insert(dst_name.to_string(), dst_bufs);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::TensorDist;
    use crate::grid::ProcessGrid;

    fn machine(p: usize) -> Machine {
        Machine::new(p, NetworkModel::aries())
    }

    #[test]
    fn put_get_roundtrip() {
        let mut m = machine(2);
        m.put("x", vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])]).unwrap();
        assert_eq!(m.get("x", 1).unwrap().len(), 2);
        assert!(m.get("y", 0).is_err());
        assert!(m.put("z", vec![Tensor::zeros(&[1])]).is_err());
    }

    #[test]
    fn compute_step_records_max_time() {
        let mut m = machine(4);
        m.compute_step("out", |r, _| Ok(Tensor::from_vec(&[1], vec![r as f32]).unwrap()))
            .unwrap();
        m.end_step();
        assert!(m.time.compute > 0.0);
        assert_eq!(m.get("out", 3).unwrap().data()[0], 3.0);
    }

    #[test]
    fn allreduce_sums_groups() {
        let mut m = machine(4);
        let bufs: Vec<Tensor> =
            (0..4).map(|r| Tensor::from_vec(&[2], vec![r as f32, 1.0]).unwrap()).collect();
        m.put("t", bufs).unwrap();
        // two groups: {0,1}, {2,3}
        m.allreduce_sum("t", &[vec![0, 1], vec![2, 3]]).unwrap();
        assert_eq!(m.get("t", 0).unwrap().data(), &[1.0, 2.0]);
        assert_eq!(m.get("t", 1).unwrap().data(), &[1.0, 2.0]);
        assert_eq!(m.get("t", 2).unwrap().data(), &[5.0, 2.0]);
        assert!(m.time.comm > 0.0);
        assert_eq!(m.comm.allreduces, 2);
    }

    #[test]
    fn allreduce_singleton_group_free() {
        let mut m = machine(2);
        m.put("t", vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])]).unwrap();
        m.allreduce_sum("t", &[vec![0], vec![1]]).unwrap();
        assert_eq!(m.time.comm, 0.0);
    }

    #[test]
    fn redistribute_moves_data_and_charges() {
        let g = ProcessGrid::new(&[2]).unwrap();
        let src = TensorDist::new(&[8], &g, &[0]).unwrap();
        let dst = TensorDist::replicated(&[8], &g).unwrap();
        let global = Tensor::random(&[8], 3);
        let mut m = machine(2);
        let bufs: Vec<Tensor> = (0..2)
            .map(|r| {
                let (off, _) = src.block_for_rank(r);
                global.block(&off, &src.local_dims())
            })
            .collect();
        m.put("t", bufs).unwrap();
        let rp = crate::redist::plan(&src, &dst).unwrap();
        m.redistribute("t", "t2", &rp, &src, &dst).unwrap();
        for r in 0..2 {
            assert!(m.get("t2", r).unwrap().allclose(&global, 0.0, 0.0));
        }
        assert!(m.comm.p2p_bytes > 0);
        assert!(m.time.comm > 0.0);
    }

    #[test]
    fn drop_tensor_frees() {
        let mut m = machine(1);
        m.put("x", vec![Tensor::zeros(&[1])]).unwrap();
        m.drop_tensor("x");
        assert!(m.get("x", 0).is_err());
    }
}
