//! Simulated distributed machine (Piz Daint substitute — see DESIGN.md
//! §Substitutions).
//!
//! P ranks with **real rank-local buffers**: collectives and
//! redistributions move actual bytes between buffers, so distributed
//! numerics are bit-exact versus an MPI run.  *Time* is hybrid:
//!
//! - compute: measured wall-clock of each rank's local kernel (ranks run
//!   sequentially in-process; the simulated parallel time takes the max
//!   over ranks per step);
//! - communication: an α–β (latency–bandwidth) model calibrated to a
//!   Cray-Aries-class interconnect, with tree collectives.
//!
//! The paper's evaluation claims concern communication *volume* and
//! schedule structure; volumes here are exact, and the cost model turns
//! them into the Fig. 5/6 runtime series.

pub mod accel;
pub mod collectives;
pub mod network;

pub use accel::AccelModel;
pub use network::NetworkModel;

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::tensor::{Tensor, ELEM_BYTES};

/// Per-step time breakdown (the blue/pink split of Fig. 5).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct TimeBreakdown {
    /// Max per-rank local compute seconds.
    pub compute: f64,
    /// Modeled communication seconds.
    pub comm: f64,
}

impl TimeBreakdown {
    /// Compute plus communication seconds.
    pub fn total(&self) -> f64 {
        self.compute + self.comm
    }
}

/// Communication counters (exact volumes, for bound-vs-measured checks).
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    /// Bytes crossing rank boundaries in point-to-point messages.
    pub p2p_bytes: u128,
    /// Point-to-point message count.
    pub p2p_msgs: u64,
    /// Bytes reduced in allreduce calls (payload size × participations).
    pub allreduce_bytes: u128,
    /// Allreduce invocations.
    pub allreduces: u64,
}

/// Store-buffer recycling counters.  A machine held across coordinator
/// runs recycles every staging and redistribution destination buffer
/// whose name and shape recur; in steady state `dest_allocs` is flat
/// while `dest_reuses` keeps counting (asserted in tests — the
/// coordinator-level analogue of [`crate::tensor::kernel::ScratchStats`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    /// Staging/redistribution destination tensors heap-allocated (first
    /// run, or shape change).
    pub dest_allocs: u64,
    /// Staging/redistribution destination tensors recycled from the
    /// persistent store.
    pub dest_reuses: u64,
    /// Compute-output tensors heap-allocated
    /// ([`Machine::compute_step_into`]: first run, or shape change).
    pub out_allocs: u64,
    /// Compute-output tensors recycled from the persistent store.
    pub out_reuses: u64,
}

/// The simulated machine: rank-local tensor stores + cost accounting.
///
/// The store persists across runs when the machine is held by a
/// [`crate::api::Program`] (or the deprecated coordinator wrapper);
/// [`Machine::begin_run`] resets the per-run time/volume accounting
/// without dropping buffers, so steady-state re-executions of a plan
/// (CP-ALS sweeps, benches) reuse every staging/redistribution
/// destination instead of reallocating.
pub struct Machine {
    ranks: usize,
    net: NetworkModel,
    /// Named per-rank tensors: store[name][rank].
    store: HashMap<String, Vec<Tensor>>,
    /// Accumulated per-rank compute seconds (current step).
    step_compute: Vec<f64>,
    /// Buffer-recycling counters (cumulative across runs).
    store_stats: StoreStats,
    /// Totals.
    pub time: TimeBreakdown,
    /// Cumulative communication-volume counters.
    pub comm: CommStats,
}

impl Machine {
    /// Create a machine with `ranks` processes and a network model.
    pub fn new(ranks: usize, net: NetworkModel) -> Self {
        Machine {
            ranks,
            net,
            store: HashMap::new(),
            step_compute: vec![0.0; ranks],
            store_stats: StoreStats::default(),
            time: TimeBreakdown::default(),
            comm: CommStats::default(),
        }
    }

    /// Number of simulated ranks.
    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// The interconnect cost model collectives are priced with.
    pub fn network(&self) -> &NetworkModel {
        &self.net
    }

    /// Buffer-recycling counters (cumulative across runs).
    pub fn store_stats(&self) -> StoreStats {
        self.store_stats
    }

    /// Start a fresh run on this machine: zero the time and volume
    /// accounting, keep the store (and its recycling counters) so
    /// repeated executions of the same plan allocate nothing.
    pub fn begin_run(&mut self) {
        self.time = TimeBreakdown::default();
        self.comm = CommStats::default();
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
    }

    /// Take `name`'s per-rank buffer set out of the store for in-place
    /// recycling, but only if every buffer matches `dims` (a mismatched
    /// set is dropped and the caller must allocate).  Counter-neutral:
    /// callers record the hit/miss under the right [`StoreStats`] pair.
    fn take_recycled(&mut self, name: &str, dims: &[usize]) -> Option<Vec<Tensor>> {
        match self.store.remove(name) {
            Some(v) if v.len() == self.ranks && v.iter().all(|t| t.dims() == dims) => Some(v),
            _ => None,
        }
    }

    /// [`take_recycled`](Self::take_recycled) for staging/redistribution
    /// destinations, recorded under `dest_allocs`/`dest_reuses`.
    fn recycle_bufs(&mut self, name: &str, dims: &[usize]) -> Option<Vec<Tensor>> {
        match self.take_recycled(name, dims) {
            Some(v) => {
                self.store_stats.dest_reuses += self.ranks as u64;
                Some(v)
            }
            None => {
                self.store_stats.dest_allocs += self.ranks as u64;
                None
            }
        }
    }

    /// Scatter `global` into per-rank blocks under `name` according to
    /// `dist`, recycling the existing store buffers when shapes match
    /// (the coordinator's input staging: zero allocations in steady
    /// state).  Only buffers whose block is clipped at the global edge
    /// are zero-filled before the copy — interior blocks are fully
    /// overwritten — keeping the [`Tensor::block`] zero-pad semantics
    /// without a redundant memset per full block.
    pub fn stage_blocks(
        &mut self,
        name: &str,
        global: &Tensor,
        dist: &crate::dist::TensorDist,
    ) -> Result<()> {
        let ldims = dist.local_dims();
        let mut bufs = self
            .recycle_bufs(name, &ldims)
            .unwrap_or_else(|| (0..self.ranks).map(|_| Tensor::zeros(&ldims)).collect());
        let zero_off = vec![0usize; ldims.len()];
        for (r, buf) in bufs.iter_mut().enumerate() {
            let (off, size) = dist.block_for_rank(r);
            // The copied box overwrites exactly the clipped block; a full
            // (interior) block covers the whole buffer, so only blocks
            // clipped at the global edge need their zero padding
            // re-established before the copy.
            if size != ldims {
                buf.data_mut().fill(0.0);
            }
            buf.copy_box_from(global, &off, &zero_off, &ldims);
        }
        self.store.insert(name.to_string(), bufs);
        Ok(())
    }

    /// Install a per-rank tensor set under `name`.
    pub fn put(&mut self, name: &str, per_rank: Vec<Tensor>) -> Result<()> {
        if per_rank.len() != self.ranks {
            return Err(Error::plan(format!(
                "put {name}: {} tensors for {} ranks",
                per_rank.len(),
                self.ranks
            )));
        }
        self.store.insert(name.to_string(), per_rank);
        Ok(())
    }

    /// Rank-local tensor view.
    pub fn get(&self, name: &str, rank: usize) -> Result<&Tensor> {
        self.store
            .get(name)
            .and_then(|v| v.get(rank))
            .ok_or_else(|| Error::plan(format!("tensor {name} rank {rank} missing")))
    }

    /// All ranks' buffers for `name`.
    pub fn get_all(&self, name: &str) -> Result<&[Tensor]> {
        self.store
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::plan(format!("tensor {name} missing")))
    }

    /// Remove a tensor (free intermediates between terms).
    pub fn drop_tensor(&mut self, name: &str) {
        self.store.remove(name);
    }

    /// Drop every stored tensor set whose name fails `keep`.  The
    /// coordinator prunes names that a run did not touch, so switching
    /// plans on a persistent machine cannot accumulate stale buffer sets
    /// (the current plan's buffers stay resident for recycling).
    pub fn retain_tensors<F: FnMut(&str) -> bool>(&mut self, mut keep: F) {
        self.store.retain(|name, _| keep(name));
    }

    /// Names currently stored (diagnostics).
    pub fn tensor_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.store.keys().cloned().collect();
        v.sort();
        v
    }

    /// Record `seconds` of local compute on `rank` for the current step.
    pub fn charge_compute(&mut self, rank: usize, seconds: f64) {
        self.step_compute[rank] += seconds;
    }

    /// Run `f` as rank-local compute on every rank with **recycled
    /// outputs**: each rank's destination tensor (shape `dims`) is
    /// recycled from the persistent store under `out_name` when the
    /// previous run left a matching buffer set there
    /// ([`StoreStats::out_allocs`] / [`StoreStats::out_reuses`]), and
    /// `f` writes the rank's result through it, charged at measured
    /// wall-clock per rank.  Destination contents are unspecified on
    /// entry — the `*_into` kernels fully overwrite (or zero-initialize)
    /// them.
    pub fn compute_step_into<F>(&mut self, out_name: &str, dims: &[usize], mut f: F) -> Result<()>
    where
        F: FnMut(usize, &Machine, &mut Tensor) -> Result<()>,
    {
        let mut outs = match self.take_recycled(out_name, dims) {
            Some(v) => {
                self.store_stats.out_reuses += self.ranks as u64;
                v
            }
            None => {
                self.store_stats.out_allocs += self.ranks as u64;
                (0..self.ranks).map(|_| Tensor::zeros(dims)).collect()
            }
        };
        for (r, out) in outs.iter_mut().enumerate() {
            let t0 = std::time::Instant::now();
            f(r, self, out)?;
            self.step_compute[r] += t0.elapsed().as_secs_f64();
        }
        self.store.insert(out_name.to_string(), outs);
        Ok(())
    }

    /// Close the current step: parallel compute time = max over ranks.
    pub fn end_step(&mut self) {
        let max = self.step_compute.iter().cloned().fold(0.0, f64::max);
        self.time.compute += max;
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
    }

    /// Allreduce-sum `name` over each group of ranks (the §II-D partial
    /// result reduction over a sub-grid).  Data: every rank in a group
    /// ends with the elementwise sum — accumulated in place into the
    /// group root and broadcast by `copy_from_slice`, so the reduction
    /// allocates nothing.  Time: tree allreduce on the payload size,
    /// charged once (groups reduce concurrently).
    pub fn allreduce_sum(&mut self, name: &str, groups: &[Vec<usize>]) -> Result<()> {
        let bufs = self
            .store
            .get_mut(name)
            .ok_or_else(|| Error::plan(format!("allreduce: {name} missing")))?;
        let mut max_t = 0.0f64;
        for g in groups {
            if g.len() <= 1 {
                continue;
            }
            let root = g[0];
            let len = bufs[root].len();
            // Dims (not just lengths) must agree: equal-element-count
            // blocks of different shapes are a planner bug and must
            // surface as a typed error naming the tensor and ranks, not
            // an elementwise-add panic.
            for &r in &g[1..] {
                if bufs[r].dims() != bufs[root].dims() {
                    return Err(Error::shape(format!(
                        "allreduce {name}: rank {r} block {:?} != rank {root} block {:?}",
                        bufs[r].dims(),
                        bufs[root].dims()
                    )));
                }
            }
            // Reduce into the group root, then broadcast — all in place.
            for &r in &g[1..] {
                let (acc, src) = two_ranks_mut(bufs, root, r);
                acc.add_assign(src)?;
            }
            for &r in &g[1..] {
                let (dst, acc) = two_ranks_mut(bufs, r, root);
                dst.data_mut().copy_from_slice(acc.data());
            }
            let bytes = (len * ELEM_BYTES) as f64;
            let t = self.net.allreduce_time(g.len(), bytes);
            self.comm.allreduce_bytes += (len * ELEM_BYTES) as u128 * (g.len() as u128);
            self.comm.allreduces += 1;
            max_t = max_t.max(t);
        }
        self.time.comm += max_t;
        Ok(())
    }

    /// Execute a redistribution plan: move real boxes between rank
    /// buffers through [`crate::redist::execute_into`], recycling the
    /// destination buffer set from the persistent store when present
    /// (steady-state runs perform zero redistribution allocations);
    /// charge the α–β model on the per-rank maximum send/recv volume
    /// (links are parallel across rank pairs).
    pub fn redistribute(
        &mut self,
        src_name: &str,
        dst_name: &str,
        rp: &crate::redist::RedistPlan,
        src_dist: &crate::dist::TensorDist,
        dst_dist: &crate::dist::TensorDist,
    ) -> Result<()> {
        debug_assert_eq!(src_dist.extents, dst_dist.extents);
        // Guard before touching the destination entry: recycling removes
        // it from the store, which would destroy the source under
        // aliasing or leave the store inconsistent on a missing source.
        if src_name == dst_name {
            return Err(Error::plan(format!(
                "redistribute: in-place aliasing ({src_name}) unsupported"
            )));
        }
        if !self.store.contains_key(src_name) {
            return Err(Error::plan(format!("redistribute: {src_name} missing")));
        }
        if src_dist.grid.size() > self.ranks || dst_dist.grid.size() > self.ranks {
            return Err(Error::plan(format!(
                "redistribute: distribution grid ({} -> {} ranks) exceeds machine ({})",
                src_dist.grid.size(),
                dst_dist.grid.size(),
                self.ranks
            )));
        }
        let ldims = dst_dist.local_dims();
        let mut dst_bufs = match self.recycle_bufs(dst_name, &ldims) {
            Some(mut v) => {
                // Message boxes overwrite the covered region; clear the
                // rest (edge padding) to keep block semantics exact.
                for t in &mut v {
                    t.data_mut().fill(0.0);
                }
                v
            }
            None => (0..self.ranks).map(|_| Tensor::zeros(&ldims)).collect(),
        };
        {
            let src_bufs = self
                .store
                .get(src_name)
                .ok_or_else(|| Error::plan(format!("redistribute: {src_name} missing")))?;
            crate::redist::execute_into(rp, src_bufs, &mut dst_bufs);
        }
        // Cost: per-rank send and recv byte totals; time = α·(max #msgs
        // on a rank) + β·(max bytes through any rank).
        let mut sent = vec![0u128; self.ranks];
        let mut recv = vec![0u128; self.ranks];
        let mut msgs = vec![0u64; self.ranks];
        for m in &rp.messages {
            if m.src == m.dst {
                continue;
            }
            let b = m.bytes() as u128;
            sent[m.src] += b;
            recv[m.dst] += b;
            msgs[m.src] += 1;
            self.comm.p2p_bytes += b;
            self.comm.p2p_msgs += 1;
        }
        let max_bytes = sent
            .iter()
            .zip(&recv)
            .map(|(s, r)| s + r)
            .max()
            .unwrap_or(0) as f64;
        let max_msgs = msgs.iter().max().copied().unwrap_or(0) as f64;
        self.time.comm += self.net.p2p_time(max_msgs, max_bytes);
        self.store.insert(dst_name.to_string(), dst_bufs);
        Ok(())
    }
}

/// Disjoint mutable/shared access to two rank buffers of one tensor set.
fn two_ranks_mut(bufs: &mut [Tensor], target: usize, other: usize) -> (&mut Tensor, &Tensor) {
    debug_assert_ne!(target, other);
    if target < other {
        let (lo, hi) = bufs.split_at_mut(other);
        (&mut lo[target], &hi[0])
    } else {
        let (lo, hi) = bufs.split_at_mut(target);
        (&mut hi[0], &lo[other])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::TensorDist;
    use crate::grid::ProcessGrid;

    fn machine(p: usize) -> Machine {
        Machine::new(p, NetworkModel::aries())
    }

    #[test]
    fn put_get_roundtrip() {
        let mut m = machine(2);
        m.put("x", vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])]).unwrap();
        assert_eq!(m.get("x", 1).unwrap().len(), 2);
        assert!(m.get("y", 0).is_err());
        assert!(m.put("z", vec![Tensor::zeros(&[1])]).is_err());
    }

    #[test]
    fn compute_step_records_max_time() {
        let mut m = machine(4);
        m.compute_step_into("out", &[1], |r, _, dest| {
            dest.data_mut()[0] = r as f32;
            Ok(())
        })
        .unwrap();
        m.end_step();
        assert!(m.time.compute > 0.0);
        assert_eq!(m.get("out", 3).unwrap().data()[0], 3.0);
    }

    #[test]
    fn allreduce_equal_len_different_dims_is_typed_shape_error() {
        // Regression: equal-element-count blocks of different shapes
        // used to reach `add_assign(..).unwrap()` and panic; they must
        // surface as a typed shape error naming the tensor and ranks.
        let mut m = machine(2);
        m.put(
            "t",
            vec![
                Tensor::from_vec(&[2, 3], vec![1.0; 6]).unwrap(),
                Tensor::from_vec(&[3, 2], vec![2.0; 6]).unwrap(),
            ],
        )
        .unwrap();
        match m.allreduce_sum("t", &[vec![0, 1]]) {
            Err(Error::Shape(msg)) => {
                assert!(msg.contains("allreduce t"), "{msg}");
                assert!(msg.contains("rank 1") && msg.contains("rank 0"), "{msg}");
            }
            other => panic!("want Err(Shape), got {other:?}"),
        }
        // Buffers are untouched: the check runs before any accumulation.
        assert_eq!(m.get("t", 0).unwrap().data()[0], 1.0);
        assert_eq!(m.get("t", 1).unwrap().data()[0], 2.0);
    }

    #[test]
    fn allreduce_sums_groups() {
        let mut m = machine(4);
        let bufs: Vec<Tensor> =
            (0..4).map(|r| Tensor::from_vec(&[2], vec![r as f32, 1.0]).unwrap()).collect();
        m.put("t", bufs).unwrap();
        // two groups: {0,1}, {2,3}
        m.allreduce_sum("t", &[vec![0, 1], vec![2, 3]]).unwrap();
        assert_eq!(m.get("t", 0).unwrap().data(), &[1.0, 2.0]);
        assert_eq!(m.get("t", 1).unwrap().data(), &[1.0, 2.0]);
        assert_eq!(m.get("t", 2).unwrap().data(), &[5.0, 2.0]);
        assert!(m.time.comm > 0.0);
        assert_eq!(m.comm.allreduces, 2);
    }

    #[test]
    fn allreduce_singleton_group_free() {
        let mut m = machine(2);
        m.put("t", vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])]).unwrap();
        m.allreduce_sum("t", &[vec![0], vec![1]]).unwrap();
        assert_eq!(m.time.comm, 0.0);
    }

    #[test]
    fn redistribute_moves_data_and_charges() {
        let g = ProcessGrid::new(&[2]).unwrap();
        let src = TensorDist::new(&[8], &g, &[0]).unwrap();
        let dst = TensorDist::replicated(&[8], &g).unwrap();
        let global = Tensor::random(&[8], 3);
        let mut m = machine(2);
        let bufs: Vec<Tensor> = (0..2)
            .map(|r| {
                let (off, _) = src.block_for_rank(r);
                global.block(&off, &src.local_dims())
            })
            .collect();
        m.put("t", bufs).unwrap();
        let rp = crate::redist::plan(&src, &dst).unwrap();
        m.redistribute("t", "t2", &rp, &src, &dst).unwrap();
        for r in 0..2 {
            assert!(m.get("t2", r).unwrap().allclose(&global, 0.0, 0.0));
        }
        assert!(m.comm.p2p_bytes > 0);
        assert!(m.time.comm > 0.0);
    }

    #[test]
    fn stage_blocks_recycles_buffers() {
        let g = ProcessGrid::new(&[2]).unwrap();
        let dist = TensorDist::new(&[10], &g, &[0]).unwrap();
        let mut m = machine(2);
        let global = Tensor::random(&[10], 4);
        m.stage_blocks("x", &global, &dist).unwrap();
        let s1 = m.store_stats();
        assert_eq!(s1.dest_allocs, 2, "first staging allocates per rank");
        // Same name + shape: buffers recycled, contents refreshed.
        let global2 = Tensor::random(&[10], 5);
        m.stage_blocks("x", &global2, &dist).unwrap();
        let s2 = m.store_stats();
        assert_eq!(s2.dest_allocs, 2, "steady-state staging must not allocate");
        assert_eq!(s2.dest_reuses, 2);
        for r in 0..2 {
            let (off, size) = dist.block_for_rank(r);
            let want = global2.block(&off, &size);
            let got = m.get("x", r).unwrap().block(&vec![0; 1], &size);
            assert!(got.allclose(&want, 0.0, 0.0), "rank {r} stale after recycle");
        }
    }

    #[test]
    fn compute_step_into_recycles_outputs() {
        let mut m = machine(2);
        for run in 0..3usize {
            m.compute_step_into("out", &[2], |r, _, dest| {
                dest.data_mut().fill((run * 10 + r) as f32);
                Ok(())
            })
            .unwrap();
            m.end_step();
        }
        let s = m.store_stats();
        assert_eq!(s.out_allocs, 2, "only the first step may allocate outputs");
        assert_eq!(s.out_reuses, 4, "later steps must recycle the store buffers");
        assert_eq!(m.get("out", 1).unwrap().data(), &[21.0, 21.0]);
        // A shape change re-allocates (and the counters say so).
        m.compute_step_into("out", &[3], |_, _, dest| {
            dest.data_mut().fill(0.0);
            Ok(())
        })
        .unwrap();
        assert_eq!(m.store_stats().out_allocs, 4);
    }

    #[test]
    fn compute_step_into_reads_inputs_from_store() {
        let mut m = machine(2);
        m.put("x", vec![Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap(),
                        Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap()])
            .unwrap();
        m.compute_step_into("y", &[2], |r, mm, dest| {
            let x = mm.get("x", r)?;
            for (d, s) in dest.data_mut().iter_mut().zip(x.data()) {
                *d = s * 2.0;
            }
            Ok(())
        })
        .unwrap();
        m.end_step();
        assert_eq!(m.get("y", 1).unwrap().data(), &[6.0, 8.0]);
        assert!(m.time.compute > 0.0);
    }

    #[test]
    fn stage_blocks_edge_rank_zero_padding_survives_recycling() {
        // Extent 10 over 4 ranks: blocks of 3, rank 3 holds [9..10) — a
        // clipped block whose tail must stay zero-padded even when the
        // buffer is recycled with stale nonzero contents.
        let g = ProcessGrid::new(&[4]).unwrap();
        let dist = TensorDist::new(&[10], &g, &[0]).unwrap();
        let mut m = machine(4);
        let global = Tensor::random(&[10], 11);
        m.stage_blocks("x", &global, &dist).unwrap();
        // Dirty every stored buffer, then restage: interior ranks are
        // fully overwritten without a zero-fill; the clipped edge rank
        // must be re-padded.
        for buf in m.store.get_mut("x").unwrap() {
            buf.data_mut().fill(7.5);
        }
        let global2 = Tensor::random(&[10], 12);
        m.stage_blocks("x", &global2, &dist).unwrap();
        assert_eq!(m.store_stats().dest_reuses, 4, "restaging must recycle");
        for r in 0..4 {
            let got = m.get("x", r).unwrap();
            let (off, size) = dist.block_for_rank(r);
            let want = global2.block(&off, &[3]);
            assert!(got.allclose(&want, 0.0, 0.0), "rank {r} (size {size:?})");
        }
        // The edge rank's padding positions are exact zeros again.
        assert_eq!(m.get("x", 3).unwrap().data()[1..], [0.0, 0.0]);
    }

    #[test]
    fn redistribute_recycles_destinations_across_runs() {
        let g = ProcessGrid::new(&[2]).unwrap();
        let src = TensorDist::new(&[8], &g, &[0]).unwrap();
        let dst = TensorDist::replicated(&[8], &g).unwrap();
        let rp = crate::redist::plan(&src, &dst).unwrap();
        let mut m = machine(2);
        let global = Tensor::random(&[8], 6);
        m.stage_blocks("t", &global, &src).unwrap();
        m.redistribute("t", "t2", &rp, &src, &dst).unwrap();
        let warm = m.store_stats().dest_allocs;
        for _ in 0..3 {
            m.redistribute("t", "t2", &rp, &src, &dst).unwrap();
        }
        assert_eq!(
            m.store_stats().dest_allocs,
            warm,
            "steady-state redistribution must not allocate destinations"
        );
        for r in 0..2 {
            assert!(m.get("t2", r).unwrap().allclose(&global, 0.0, 0.0), "rank {r}");
        }
    }

    #[test]
    fn begin_run_resets_accounting_but_keeps_store() {
        let mut m = machine(2);
        m.put("x", vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])]).unwrap();
        m.allreduce_sum("x", &[vec![0, 1]]).unwrap();
        assert!(m.time.comm > 0.0);
        m.begin_run();
        assert_eq!(m.time.comm, 0.0);
        assert_eq!(m.comm.allreduces, 0);
        assert!(m.get("x", 0).is_ok(), "store survives begin_run");
    }

    #[test]
    fn drop_tensor_frees() {
        let mut m = machine(1);
        m.put("x", vec![Tensor::zeros(&[1])]).unwrap();
        m.drop_tensor("x");
        assert!(m.get("x", 0).is_err());
    }
}
