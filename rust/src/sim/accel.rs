//! Accelerator execution model for the Fig. 6 reproduction.
//!
//! The paper runs the same schedules on P100 GPUs (cuTENSOR locals) and
//! distinguishes (a) *accelerator mode* — inputs/outputs live in host
//! memory, so every benchmark pays H2D/D2H copies — from (b)
//! *GPU-resident* mode where data never leaves device memory.  CTF only
//! supports (a).  We model the device with a compute-speedup factor over
//! the measured CPU kernels plus a PCIe copy cost; the Fig. 6 message
//! (copy overhead dominates at small node counts and shrinks relative to
//! compute as weak scaling grows the problem) is structural and survives
//! the substitution (DESIGN.md §Substitutions).

/// GPU execution model: scaled compute + explicit host<->device copies.
#[derive(Debug, Clone, Copy)]
pub struct AccelModel {
    /// Device compute speedup over the measured CPU kernel time.
    pub speedup: f64,
    /// PCIe effective bandwidth, bytes/s (per direction).
    pub pcie_bw: f64,
    /// Per-transfer latency, seconds.
    pub latency: f64,
}

impl AccelModel {
    /// P100-over-Xeon defaults: ~8× on contraction kernels, 12 GB/s
    /// effective PCIe gen3 x16, 10 µs per transfer.
    pub fn p100() -> Self {
        AccelModel { speedup: 8.0, pcie_bw: 12e9, latency: 10e-6 }
    }

    /// Device-side compute time for a measured CPU time.
    pub fn compute_time(&self, cpu_seconds: f64) -> f64 {
        cpu_seconds / self.speedup
    }

    /// One-way copy time for `bytes`.
    pub fn copy_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.pcie_bw
    }

    /// Accelerator-mode overhead for a step with the given host-side
    /// input/output footprints (bytes): copy in + copy out.
    pub fn h2d_d2h_time(&self, in_bytes: f64, out_bytes: f64) -> f64 {
        self.copy_time(in_bytes) + self.copy_time(out_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_applies() {
        let a = AccelModel::p100();
        assert!((a.compute_time(8.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn copies_cost() {
        let a = AccelModel::p100();
        let t = a.h2d_d2h_time(12e9, 0.0);
        assert!(t > 1.0); // 12 GB over 12 GB/s + latencies
        assert!(a.copy_time(0.0) == a.latency);
    }

    #[test]
    fn resident_mode_skips_copies() {
        // GPU-resident mode is modeled by simply not charging
        // h2d_d2h_time; sanity-check relative magnitudes.
        let a = AccelModel::p100();
        let compute = a.compute_time(0.08);
        let copies = a.h2d_d2h_time(1e9, 1e8);
        assert!(copies > compute * 5.0);
    }
}
