//! α–β (latency–bandwidth) interconnect cost model.
//!
//! Calibrated to a Cray-Aries-class network (Piz Daint, §VI-A): per-hop
//! latency ~1.5 µs, per-node injection bandwidth ~10 GB/s.  The paper's
//! comparisons depend on communication *volumes* (which the simulator
//! counts exactly); this model only converts volumes to the seconds
//! plotted in Fig. 5/6.

/// Latency–bandwidth network model.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1 / bandwidth).
    pub beta: f64,
}

impl NetworkModel {
    /// Cray Aries defaults: α = 1.5 µs, 10 GB/s injection bandwidth.
    pub fn aries() -> Self {
        NetworkModel { alpha: 1.5e-6, beta: 1.0 / 10e9 }
    }

    /// An ideal network (zero cost) — for compute-only measurements
    /// (the paper's blue bars are produced exactly this way: "a version
    /// of the code stripped of any inter-node communication", §VI-B).
    pub fn ideal() -> Self {
        NetworkModel { alpha: 0.0, beta: 0.0 }
    }

    /// Point-to-point phase: `msgs` sequential message setups plus
    /// `bytes` through the bottleneck link.
    pub fn p2p_time(&self, msgs: f64, bytes: f64) -> f64 {
        self.alpha * msgs + self.beta * bytes
    }

    /// Tree allreduce over `p` ranks with an `m`-byte payload:
    /// reduce + broadcast, `2·ceil(log2 p)` rounds of `(α + β·m)`.
    /// (§VI-B observes exactly this `log2` depth dependence: the MM
    /// overhead steps up whenever the reduction grid dim doubles.)
    pub fn allreduce_time(&self, p: usize, m: f64) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let rounds = (p as f64).log2().ceil();
        2.0 * rounds * (self.alpha + self.beta * m)
    }

    /// Broadcast over `p` ranks (binomial tree).
    pub fn bcast_time(&self, p: usize, m: f64) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p as f64).log2().ceil() * (self.alpha + self.beta * m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_free() {
        let n = NetworkModel::ideal();
        assert_eq!(n.p2p_time(10.0, 1e9), 0.0);
        assert_eq!(n.allreduce_time(512, 1e9), 0.0);
    }

    #[test]
    fn allreduce_scales_log2() {
        let n = NetworkModel::aries();
        let t4 = n.allreduce_time(4, 1e6);
        let t16 = n.allreduce_time(16, 1e6);
        assert!((t16 / t4 - 2.0).abs() < 1e-9); // log2 16 / log2 4 = 2
        assert_eq!(n.allreduce_time(1, 1e6), 0.0);
    }

    #[test]
    fn allreduce_doubling_depth_steps() {
        // §VI-B: doubling the reduction dim increases allreduce depth by
        // one round — the staircase in Fig. 5's MM plots.
        let n = NetworkModel::aries();
        let t8 = n.allreduce_time(8, 1e6);
        let t16 = n.allreduce_time(16, 1e6);
        let extra = t16 - t8;
        assert!((extra - 2.0 * (n.alpha + n.beta * 1e6)).abs() < 1e-12);
    }

    #[test]
    fn p2p_linear() {
        let n = NetworkModel::aries();
        assert!((n.p2p_time(0.0, 10e9) - 1.0).abs() < 1e-9);
        assert!((n.p2p_time(2.0, 0.0) - 3e-6).abs() < 1e-12);
    }
}
