//! Collective-communication helpers shared by the coordinator and the
//! baseline: group construction from sub-grids and cost helpers.
//!
//! Data movement itself happens in [`crate::sim::Machine`]; this module
//! keeps the pure logic testable without a machine instance.

use crate::grid::{ProcessGrid, SubgridSet};

/// Build allreduce groups for reducing a term's partial outputs: one
/// group per combination of the *kept* (output) dims, each containing the
/// ranks that differ only in the *reduced* dims (paper §II-D: the output
/// sub-grids produced by dropping the non-output dimensions).
pub fn reduction_groups(grid: &ProcessGrid, reduced_dims: &[usize]) -> Vec<Vec<usize>> {
    let remain: Vec<bool> =
        (0..grid.ndim()).map(|d| reduced_dims.contains(&d)).collect();
    // cart_sub groups ranks by the coords of the DROPPED dims; here the
    // groups must share output coords and span the reduced dims, so we
    // keep exactly the reduced dims.
    let sub: SubgridSet = grid.cart_sub(&remain).expect("valid remain");
    sub.groups
}

/// Total ranks across groups must equal the grid size and groups must be
/// disjoint — invariant helper used in tests and debug assertions.
pub fn groups_partition_ranks(groups: &[Vec<usize>], p: usize) -> bool {
    let mut seen = vec![false; p];
    for g in groups {
        for &r in g {
            if r >= p || std::mem::replace(&mut seen[r], true) {
                return false;
            }
        }
    }
    seen.iter().all(|&b| b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_groups_for_paper_grid() {
        // Worked example, MM term grid (2,2,2) over (i,l,a): reducing 'a'
        // (dim 2) groups ranks differing only in a-coord: P_i*P_l = 4
        // groups of 2 (§II-E's grid1_out Cart_sub(remain=[F,F,T])).
        let g = ProcessGrid::new(&[2, 2, 2]).unwrap();
        let groups = reduction_groups(&g, &[2]);
        assert_eq!(groups.len(), 4);
        for grp in &groups {
            assert_eq!(grp.len(), 2);
            let c0 = g.coords(grp[0]);
            let c1 = g.coords(grp[1]);
            assert_eq!(c0[0], c1[0]);
            assert_eq!(c0[1], c1[1]);
            assert_ne!(c0[2], c1[2]);
        }
        assert!(groups_partition_ranks(&groups, 8));
    }

    #[test]
    fn no_reduction_dims_gives_singletons() {
        let g = ProcessGrid::new(&[2, 2]).unwrap();
        let groups = reduction_groups(&g, &[]);
        assert_eq!(groups.len(), 4);
        assert!(groups.iter().all(|g| g.len() == 1));
    }

    #[test]
    fn all_dims_reduced_gives_one_group() {
        let g = ProcessGrid::new(&[2, 4]).unwrap();
        let groups = reduction_groups(&g, &[0, 1]);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 8);
    }

    #[test]
    fn partition_checker_catches_overlap() {
        assert!(!groups_partition_ranks(&[vec![0, 1], vec![1]], 2));
        assert!(!groups_partition_ranks(&[vec![0]], 2));
        assert!(groups_partition_ranks(&[vec![0], vec![1]], 2));
    }
}
