//! The distributed run loop: execute a [`Plan`] through a pluggable
//! [`Executor`] backend (paper §II-D/E).
//!
//! For every term, in order:
//!
//! 1. **Distribute** program inputs (block + replication per the term's
//!    [`TensorDist`]s) or **Redistribute** intermediates produced by
//!    earlier terms (§V-C message matching);
//! 2. **Local compute** on every rank — the fused MTTKRP Pallas/PJRT
//!    kernel, or the generic folded-GEMM binary-op sequence — resolved
//!    once per term into a backend-agnostic
//!    [`ComputeStep`](crate::exec::ComputeStep) and executed by the
//!    backend with measured per-rank wall-clock;
//! 3. **Allreduce** partial outputs over the reduction sub-grids (§II-D).
//!
//! Numerics are exact (real bytes move between rank buffers); time is
//! measured compute + α–β-modeled communication, reported per term for
//! the Fig. 5/6 blue/pink split.
//!
//! The execution core is `run_plan` over an `ExecState` — a backend
//! selection plus the persistent [`Executor`] it lazily builds — owned
//! by [`crate::api::Program`] (the public front door: one compiled
//! program, one persistent state).  The run loop itself holds no
//! machine-specific state: the simulated machine
//! ([`crate::exec::ExecBackend::Sim`]) and the message-passing thread
//! sites ([`crate::exec::ExecBackend::Mp`]) sit behind the same seam,
//! and a plan executes bitwise identically on either.
//!
//! Repeated executions of a plan (CP-ALS sweeps, benches) recycle every
//! staging and redistribution destination buffer, every compute output,
//! the Seq kernel's per-op intermediates, its pre-reduction buffers, and
//! the MTTKRP/gather permute staging from the previous run — the
//! backend's [`StoreStats`] and [`LocalScratchStats`] counters assert a
//! zero-allocation steady state on the simulated backend.  Each term
//! also reconfigures the [`KernelEngine`] with its SOAP-derived tile
//! sizes ([`crate::planner::TermPlan::kernel_config`] via
//! [`KernelEngine::configure_for_term`]); backends replay the same
//! config on their own compute threads.
//!
//! [`TensorDist`]: crate::dist::TensorDist

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::exec::{self, ComputeStep, ExecBackend, ExecTuning, Executor};
use crate::planner::Plan;
use crate::runtime::KernelEngine;
use crate::sim::collectives::reduction_groups;
use crate::sim::{AccelModel, CommStats, NetworkModel, StoreStats, TimeBreakdown};
use crate::tensor::{Tensor, ELEM_BYTES};

pub use crate::exec::LocalScratchStats;

/// Per-term execution statistics.
#[derive(Debug, Clone, Default)]
pub struct TermStats {
    /// The term's name in the schedule (e.g. `"T0"`).
    pub name: String,
    /// Max per-rank local compute seconds.
    pub compute: f64,
    /// Modeled communication seconds (redistribution + allreduce).
    pub comm: f64,
    /// Per-rank local input footprint (bytes, max over ranks).
    pub local_in_bytes: usize,
    /// Per-rank local output footprint (bytes).
    pub local_out_bytes: usize,
}

/// Time/volume accounting of one run, without the gathered output — what
/// [`crate::api::Program::run_into`] returns (the output lands in the
/// caller's recycled tensor instead).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Total simulated time.
    pub time: TimeBreakdown,
    /// Exact communication volumes.
    pub comm: CommStats,
    /// Per-term breakdown.
    pub per_term: Vec<TermStats>,
}

/// The result of a distributed run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// The assembled global output (gathered off the last term's dist).
    pub output: Tensor,
    /// Total simulated time.
    pub time: TimeBreakdown,
    /// Exact communication volumes.
    pub comm: CommStats,
    /// Per-term breakdown.
    pub per_term: Vec<TermStats>,
}

impl RunReport {
    pub(crate) fn from_parts(output: Tensor, m: RunMetrics) -> Self {
        RunReport { output, time: m.time, comm: m.comm, per_term: m.per_term }
    }

    /// Fig. 6 time model: device compute = measured/speedup; in
    /// *accelerator mode* every term also pays H2D/D2H copies of its
    /// local footprints; *GPU-resident* mode skips the copies.  Network
    /// time is unchanged (CUDA-aware MPI in the paper).
    pub fn gpu_time(&self, accel: &AccelModel, resident: bool) -> TimeBreakdown {
        let mut compute = 0.0;
        let mut comm = self.time.comm;
        for t in &self.per_term {
            compute += accel.compute_time(t.compute);
            if !resident {
                comm += accel
                    .h2d_d2h_time(t.local_in_bytes as f64, t.local_out_bytes as f64);
            }
        }
        TimeBreakdown { compute, comm }
    }
}

/// Persistent execution state for one compiled program: the backend
/// selection plus the [`Executor`] it lazily builds on the first run
/// (and rebuilds on a rank-count change, a backend change, or after a
/// fatal protocol failure poisoned it).  Owned exclusively by one
/// [`crate::api::Program`] — which is what lets programs of a shared
/// session execute on concurrent threads: all mutable run state is
/// program-private, and the shared [`KernelEngine`] is `Sync`.
pub(crate) struct ExecState {
    pub(crate) backend: ExecBackend,
    /// Transport tuning for the distributed backends (peer deadline,
    /// pre-existing rank listeners), resolved once per session.
    pub(crate) tuning: ExecTuning,
    pub(crate) exec: Option<Box<dyn Executor>>,
}

impl Default for ExecState {
    fn default() -> Self {
        ExecState {
            backend: ExecBackend::from_env(),
            tuning: ExecTuning::default(),
            exec: None,
        }
    }
}

impl ExecState {
    /// State pinned to an explicit backend and transport tuning
    /// ([`crate::api::SessionBuilder::backend`] and friends).
    pub(crate) fn with_backend(backend: ExecBackend, tuning: ExecTuning) -> Self {
        ExecState { backend, tuning, exec: None }
    }

    /// Buffer-recycling counters of the persistent executor (defaults
    /// until the first run).
    pub(crate) fn store_stats(&self) -> StoreStats {
        self.exec.as_ref().map(|e| e.store_stats()).unwrap_or_default()
    }

    /// Allocation counters of the executor's local scratch.
    pub(crate) fn local_scratch_stats(&self) -> LocalScratchStats {
        self.exec.as_ref().map(|e| e.scratch_stats()).unwrap_or_default()
    }
}

/// Execute `plan` on `state` against `engine`, staging the global
/// `inputs` (one per program operand, in einsum order).  Initial
/// distribution is not charged (the paper's weak-scaling timings start
/// from distributed data).  With `dest = Some(t)` the gathered output is
/// written through `t` (shape-checked against the spec's output dims;
/// recycled permute staging keeps the path allocation-free in steady
/// state) and the returned output is `None`; with `dest = None` a fresh
/// output tensor is returned.
pub(crate) fn run_plan(
    engine: &Arc<KernelEngine>,
    network: NetworkModel,
    state: &mut ExecState,
    plan: &Plan,
    inputs: &[Tensor],
    dest: Option<&mut Tensor>,
) -> Result<(Option<Tensor>, RunMetrics)> {
    /// Drop guard: the thread-local per-term override must not leak past
    /// the run — including when a kernel panics and a caller (the
    /// serving worker's per-request containment) catches the unwind.
    struct ResetConfig<'e>(&'e KernelEngine);
    impl Drop for ResetConfig<'_> {
        fn drop(&mut self) {
            self.0.reset_config();
        }
    }
    let _reset = ResetConfig(engine);
    run_plan_inner(engine, network, state, plan, inputs, dest)
}

fn run_plan_inner(
    engine: &Arc<KernelEngine>,
    network: NetworkModel,
    state: &mut ExecState,
    plan: &Plan,
    inputs: &[Tensor],
    dest: Option<&mut Tensor>,
) -> Result<(Option<Tensor>, RunMetrics)> {
    if inputs.len() != plan.path.n_inputs {
        return Err(Error::plan(format!(
            "plan needs {} inputs, got {}",
            plan.path.n_inputs,
            inputs.len()
        )));
    }
    for (op, t) in plan.spec.inputs.iter().zip(inputs) {
        let want: Vec<usize> = op.iter().map(|c| plan.spec.extents[c]).collect();
        if t.dims() != want {
            return Err(Error::shape(format!(
                "input dims {:?} != spec {:?}",
                t.dims(),
                want
            )));
        }
    }
    if let Some(d) = dest.as_deref() {
        let want: Vec<usize> =
            plan.spec.output.iter().map(|c| plan.spec.extents[c]).collect();
        if d.dims() != want {
            return Err(Error::shape(format!(
                "run_into: dest dims {:?} != output dims {want:?}",
                d.dims()
            )));
        }
    }

    let backend = state.backend;
    // Reuse the persistent executor (and its stores) when the rank count
    // and backend match and it is still healthy; only the accounting is
    // reset per run.  A poisoned message-passing executor (fatal
    // protocol failure) is torn down and rebuilt here.
    let rebuild = match state.exec.as_ref() {
        Some(e) => e.ranks() != plan.p || e.backend() != backend || !e.healthy(),
        None => true,
    };
    if rebuild {
        state.exec =
            Some(exec::make(backend, plan.p, network, Arc::clone(engine), &state.tuning));
    }
    let exec = state
        .exec
        .as_mut()
        .ok_or_else(|| Error::plan("executor initialization failed"))?;
    exec.begin_run()?;
    let mut per_term: Vec<TermStats> = Vec::new();
    // Every store name this run touches; anything else is a stale buffer
    // set from a previously-run plan and is pruned at the end (the
    // persistent buffers must not grow across plan switches).
    let mut live_names: BTreeSet<String> = BTreeSet::new();

    for (ti, term) in plan.terms.iter().enumerate() {
        let mut stats = TermStats { name: term.name.clone(), ..Default::default() };
        let comm_before = exec.time().comm;
        // Retarget the engine's cache blocking to this term's
        // SOAP-derived tiles (§IV: the local kernel blocks along the
        // same proportions the I/O analysis assumed).  Backends replay
        // the same config on their own compute threads via the step's
        // [`ComputeStep`] payload.
        engine.configure_for_term(term);
        engine.faults().check(crate::fault::site::RUN_PLAN_TERM)?;

        // --- stage inputs -------------------------------------------------
        let mut in_names: Vec<String> = Vec::with_capacity(term.inputs.len());
        for (slot, tin) in term.inputs.iter().enumerate() {
            let name = format!("t{}@{}", tin.id, term.name);
            if tin.id < plan.path.n_inputs {
                // Program input: scatter blocks into recycled store
                // buffers (uncharged staging).
                exec.stage_blocks(&name, &inputs[tin.id], &tin.dist)?;
            } else {
                // Intermediate: redistribute from the producing term.
                let mv = plan
                    .moves
                    .iter()
                    .find(|m| m.to_term == ti && m.to_slot == slot)
                    .ok_or_else(|| {
                        Error::malformed_plan(
                            &term.name,
                            format!("no move for t{} into slot {slot}", tin.id),
                        )
                    })?;
                let from = plan.terms.get(mv.from_term).ok_or_else(|| {
                    Error::malformed_plan(
                        &term.name,
                        format!("move from_term {} out of range", mv.from_term),
                    )
                })?;
                let src_name = format!("t{}@{}", tin.id, from.name);
                exec.redistribute(&src_name, &name, &mv.plan, &mv.src, &mv.dst)?;
            }
            stats.local_in_bytes +=
                tin.dist.local_dims().iter().product::<usize>() * ELEM_BYTES;
            live_names.insert(name.clone());
            in_names.push(name);
        }

        // --- local compute ------------------------------------------------
        let out_name = format!("t{}@{}", term.output_id, term.name);
        live_names.insert(out_name.clone());
        // Resolve the term against the plan once (validation, shapes,
        // names, per-term kernel config) and hand the backend the
        // self-contained step; every backend runs it through the same
        // per-rank interpreter, which is the bitwise-identity guarantee.
        let step =
            ComputeStep::build(term, ti, &in_names, out_name.clone(), engine.base_config())?;
        exec.compute_step_into(&step)?;
        exec.end_step();
        stats.local_out_bytes =
            term.output_dist.local_dims().iter().product::<usize>() * ELEM_BYTES;

        // --- reduce partials over sub-grids -------------------------------
        if !term.reduced_grid_dims.is_empty() {
            let groups = reduction_groups(&term.grid, &term.reduced_grid_dims);
            exec.allreduce_sum(&out_name, &groups)?;
        }

        stats.comm = exec.time().comm - comm_before;
        stats.compute =
            exec.time().compute - per_term.iter().map(|t| t.compute).sum::<f64>();
        per_term.push(stats);
    }

    // --- gather the result ------------------------------------------------
    let last = plan.terms.last().ok_or_else(|| Error::plan("empty plan"))?;
    let out_name = format!("t{}@{}", last.output_id, last.name);
    let dist = &last.output_dist;
    let perm: Option<Vec<usize>> = if last.output_indices == plan.spec.output {
        None
    } else {
        Some(
            plan.spec
                .output
                .iter()
                .map(|c| {
                    last.output_indices.iter().position(|d| d == c).ok_or_else(|| {
                        Error::malformed_plan(
                            &last.name,
                            format!("output index '{c}' missing"),
                        )
                    })
                })
                .collect::<Result<_>>()?,
        )
    };
    let output = match dest {
        Some(d) => {
            // Dims were checked against the spec before the run started.
            exec.gather_into(&out_name, dist, perm.as_deref(), d)?;
            None
        }
        None => {
            // Only the escaping output is fresh; the backend's permute
            // staging recycles underneath.
            let dims: Vec<usize> = match &perm {
                Some(p) => p.iter().map(|&i| dist.extents[i]).collect(),
                None => dist.extents.clone(),
            };
            let mut out = Tensor::zeros(&dims);
            exec.gather_into(&out_name, dist, perm.as_deref(), &mut out)?;
            Some(out)
        }
    };

    // Prune buffer sets a previous plan staged under names this run
    // never touched (keeps the persistent buffers bounded by the current
    // plan's footprint; the backend prunes its scratch the same way).
    exec.end_run(&live_names)?;

    let metrics = RunMetrics {
        time: exec.time(),
        comm: exec.comm(),
        per_term,
    };
    Ok((output, metrics))
}

/// One member of a fused batch execution: the member's program inputs
/// plus the recycled destination its gathered output is written through.
/// [`crate::api::Program::run_batch_into`] callers build one per
/// coalesced request from disjoint per-request borrows.
#[derive(Debug)]
pub struct BatchRun<'a> {
    /// Program inputs, one per operand in einsum order.
    pub inputs: &'a [Tensor],
    /// Output destination — dims must match the program's output dims;
    /// overwritten on success.
    pub dest: &'a mut Tensor,
}

impl<'a> BatchRun<'a> {
    /// Pair one request's inputs with its recycled destination.
    pub fn new(inputs: &'a [Tensor], dest: &'a mut Tensor) -> Self {
        BatchRun { inputs, dest }
    }
}

/// Per-member admission check (input count/dims, dest dims) — the same
/// validation [`run_plan`] applies up front, but scoped to one member so
/// a shape-invalid member fails typed without poisoning its batch-mates.
fn validate_member(plan: &Plan, m: &BatchRun<'_>) -> Result<()> {
    if m.inputs.len() != plan.path.n_inputs {
        return Err(Error::plan(format!(
            "plan needs {} inputs, got {}",
            plan.path.n_inputs,
            m.inputs.len()
        )));
    }
    for (op, t) in plan.spec.inputs.iter().zip(m.inputs) {
        let want: Vec<usize> = op.iter().map(|c| plan.spec.extents[c]).collect();
        if t.dims() != want {
            return Err(Error::shape(format!(
                "input dims {:?} != spec {:?}",
                t.dims(),
                want
            )));
        }
    }
    let want: Vec<usize> = plan.spec.output.iter().map(|c| plan.spec.extents[c]).collect();
    if m.dest.dims() != want {
        return Err(Error::shape(format!(
            "run_batch_into: dest dims {:?} != output dims {want:?}",
            m.dest.dims()
        )));
    }
    Ok(())
}

/// Store-name suffix for batch member `k`.  Member 0 uses the unsuffixed
/// serial names, so a batch of one touches byte-for-byte the same store
/// entries as [`run_plan`] and the two paths share warm buffers; members
/// `k >= 1` get a stable `#b{k}` suffix, so same-shape batches recycle
/// the same buffer sets run after run (the zero-steady-state-allocation
/// invariant extends to the batched path).
fn member_suffix(k: usize) -> String {
    if k == 0 {
        String::new()
    } else {
        format!("#b{k}")
    }
}

/// Execute `plan` once for every member of a coalesced batch through one
/// executor pass: per term, the engine is configured (and the fault site
/// checked) **once**, then each member's operands are staged under
/// member-suffixed store names and driven through the same
/// [`ComputeStep`] interpreter as [`run_plan`] — so every member's
/// kernel-call sequence, and therefore its output bytes, is identical to
/// a serial back-to-back run on every backend and at every thread count.
///
/// Program inputs that share one underlying buffer across members (the
/// serving layer's coalesced requests usually share one
/// `Arc<Vec<Tensor>>`) are staged once and referenced by every member,
/// which is where the batch's staging saving comes from.
///
/// The outer `Result` is a batch-level infrastructure failure (executor
/// build, protocol violation, injected per-term fault): no member
/// completed, and the caller retries or fails the batch as a unit.  The
/// inner per-member `Result`s carry each member's own admission errors
/// (excluded from execution, batch-mates unaffected) or its
/// [`RunMetrics`] (time/comm attributed per member via counter deltas).
pub(crate) fn run_plan_batch(
    engine: &Arc<KernelEngine>,
    network: NetworkModel,
    state: &mut ExecState,
    plan: &Plan,
    members: &mut [BatchRun<'_>],
) -> Result<Vec<Result<RunMetrics>>> {
    struct ResetConfig<'e>(&'e KernelEngine);
    impl Drop for ResetConfig<'_> {
        fn drop(&mut self) {
            self.0.reset_config();
        }
    }
    let _reset = ResetConfig(engine);
    run_plan_batch_inner(engine, network, state, plan, members)
}

fn run_plan_batch_inner(
    engine: &Arc<KernelEngine>,
    network: NetworkModel,
    state: &mut ExecState,
    plan: &Plan,
    members: &mut [BatchRun<'_>],
) -> Result<Vec<Result<RunMetrics>>> {
    let mut results: Vec<Result<RunMetrics>> = members
        .iter()
        .map(|m| validate_member(plan, m).map(|()| RunMetrics::default()))
        .collect();
    let valid: Vec<usize> =
        results.iter().enumerate().filter(|(_, r)| r.is_ok()).map(|(i, _)| i).collect();
    if valid.is_empty() {
        return Ok(results);
    }

    let backend = state.backend;
    let rebuild = match state.exec.as_ref() {
        Some(e) => e.ranks() != plan.p || e.backend() != backend || !e.healthy(),
        None => true,
    };
    if rebuild {
        state.exec =
            Some(exec::make(backend, plan.p, network, Arc::clone(engine), &state.tuning));
    }
    let exec = state
        .exec
        .as_mut()
        .ok_or_else(|| Error::plan("executor initialization failed"))?;
    exec.begin_run()?;
    let mut live_names: BTreeSet<String> = BTreeSet::new();
    // Program inputs staged this term, keyed by (operand id, buffer
    // address): a member whose operand aliases an already-staged buffer
    // references that member's store entry instead of staging again.
    let mut staged: std::collections::BTreeMap<(usize, usize), String> =
        std::collections::BTreeMap::new();

    for (ti, term) in plan.terms.iter().enumerate() {
        // One per-term configuration + fault check for the whole batch —
        // the amortization the batched entry point exists for.
        engine.configure_for_term(term);
        engine.faults().check(crate::fault::site::RUN_PLAN_TERM)?;
        staged.clear();

        for &k in &valid {
            let time0 = exec.time();
            let comm0 = exec.comm();
            let sfx = member_suffix(k);
            let mut stats = TermStats { name: term.name.clone(), ..Default::default() };

            let mut in_names: Vec<String> = Vec::with_capacity(term.inputs.len());
            for (slot, tin) in term.inputs.iter().enumerate() {
                let name = if tin.id < plan.path.n_inputs {
                    let input = &members[k].inputs[tin.id];
                    let key = (tin.id, input.data().as_ptr() as usize);
                    match staged.get(&key) {
                        Some(n) => n.clone(),
                        None => {
                            let n = format!("t{}@{}{}", tin.id, term.name, sfx);
                            exec.stage_blocks(&n, input, &tin.dist)?;
                            staged.insert(key, n.clone());
                            n
                        }
                    }
                } else {
                    let name = format!("t{}@{}{}", tin.id, term.name, sfx);
                    let mv = plan
                        .moves
                        .iter()
                        .find(|m| m.to_term == ti && m.to_slot == slot)
                        .ok_or_else(|| {
                            Error::malformed_plan(
                                &term.name,
                                format!("no move for t{} into slot {slot}", tin.id),
                            )
                        })?;
                    let from = plan.terms.get(mv.from_term).ok_or_else(|| {
                        Error::malformed_plan(
                            &term.name,
                            format!("move from_term {} out of range", mv.from_term),
                        )
                    })?;
                    let src_name = format!("t{}@{}{}", tin.id, from.name, sfx);
                    exec.redistribute(&src_name, &name, &mv.plan, &mv.src, &mv.dst)?;
                    name
                };
                stats.local_in_bytes +=
                    tin.dist.local_dims().iter().product::<usize>() * ELEM_BYTES;
                live_names.insert(name.clone());
                in_names.push(name);
            }

            let out_name = format!("t{}@{}{}", term.output_id, term.name, sfx);
            live_names.insert(out_name.clone());
            let step = ComputeStep::build(
                term,
                ti,
                &in_names,
                out_name.clone(),
                engine.base_config(),
            )?;
            exec.compute_step_into(&step)?;
            exec.end_step();
            stats.local_out_bytes =
                term.output_dist.local_dims().iter().product::<usize>() * ELEM_BYTES;

            if !term.reduced_grid_dims.is_empty() {
                let groups = reduction_groups(&term.grid, &term.reduced_grid_dims);
                exec.allreduce_sum(&out_name, &groups)?;
            }

            let time1 = exec.time();
            let comm1 = exec.comm();
            stats.compute = time1.compute - time0.compute;
            stats.comm = time1.comm - time0.comm;
            if let Ok(m) = &mut results[k] {
                m.time.compute += time1.compute - time0.compute;
                m.time.comm += time1.comm - time0.comm;
                add_comm_delta(&mut m.comm, &comm0, &comm1);
                m.per_term.push(stats);
            }
        }
    }

    // --- gather each member's result --------------------------------------
    let last = plan.terms.last().ok_or_else(|| Error::plan("empty plan"))?;
    let dist = &last.output_dist;
    let perm: Option<Vec<usize>> = if last.output_indices == plan.spec.output {
        None
    } else {
        Some(
            plan.spec
                .output
                .iter()
                .map(|c| {
                    last.output_indices.iter().position(|d| d == c).ok_or_else(|| {
                        Error::malformed_plan(
                            &last.name,
                            format!("output index '{c}' missing"),
                        )
                    })
                })
                .collect::<Result<_>>()?,
        )
    };
    for &k in &valid {
        let time0 = exec.time();
        let comm0 = exec.comm();
        let out_name = format!("t{}@{}{}", last.output_id, last.name, member_suffix(k));
        exec.gather_into(&out_name, dist, perm.as_deref(), members[k].dest)?;
        let time1 = exec.time();
        let comm1 = exec.comm();
        if let Ok(m) = &mut results[k] {
            m.time.compute += time1.compute - time0.compute;
            m.time.comm += time1.comm - time0.comm;
            add_comm_delta(&mut m.comm, &comm0, &comm1);
        }
    }

    exec.end_run(&live_names)?;
    Ok(results)
}

/// Accumulate the `before -> after` change of the executor's cumulative
/// communication counters into one member's share.
fn add_comm_delta(acc: &mut CommStats, before: &CommStats, after: &CommStats) {
    acc.p2p_bytes += after.p2p_bytes - before.p2p_bytes;
    acc.p2p_msgs += after.p2p_msgs - before.p2p_msgs;
    acc.allreduce_bytes += after.allreduce_bytes - before.allreduce_bytes;
    acc.allreduces += after.allreduces - before.allreduces;
}

/// Unary local op: permutation, possibly with summed-away indices
/// (allocating wrapper over the run loop's
/// [`crate::exec::step::unary_local_into`], kept as the oracle in tests
/// — the run loop itself only uses the `_into` variant).
#[cfg(test)]
fn unary_local(a: &Tensor, a_idx: &[char], out_idx: &[char]) -> Result<Tensor> {
    let dims: Vec<usize> = out_idx
        .iter()
        .map(|c| {
            a_idx
                .iter()
                .position(|d| d == c)
                .map(|d| a.dims()[d])
                .ok_or_else(|| Error::shape(format!("unary: index '{c}' missing")))
        })
        .collect::<Result<_>>()?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    let mut out = Tensor::zeros(&dims);
    crate::exec::step::unary_local_into(a, a_idx, out_idx, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Session;
    use crate::einsum::EinsumSpec;
    use crate::planner::{LocalKernel, PlannerConfig};
    use crate::tensor::{contract, KernelConfig};

    fn run_einsum(
        expr: &str,
        shapes: &[Vec<usize>],
        p: usize,
        cfg: &PlannerConfig,
    ) -> (RunReport, Vec<Tensor>, EinsumSpec) {
        let spec = EinsumSpec::parse(expr, shapes).unwrap();
        let inputs: Vec<Tensor> = (0..shapes.len())
            .map(|i| Tensor::random(&shapes[i], 1000 + i as u64))
            .collect();
        let session = Session::builder().ranks(p).planner(*cfg).build().unwrap();
        let mut prog = session.compile(expr, shapes).unwrap();
        let rep = prog.run(&inputs).unwrap();
        (rep, inputs, spec)
    }

    /// Serial oracle: evaluate the einsum by running the same path ops
    /// globally with einsum2.
    fn oracle(spec: &EinsumSpec, inputs: &[Tensor]) -> Tensor {
        let path = crate::contraction::optimize(spec).unwrap();
        let mut table: std::collections::BTreeMap<usize, (Tensor, Vec<char>)> =
            std::collections::BTreeMap::new();
        for (i, t) in inputs.iter().enumerate() {
            table.insert(i, (t.clone(), spec.inputs[i].clone()));
        }
        let mut last = 0;
        for op in &path.ops {
            let out = if op.input_ids.len() == 2 {
                let (a, ai) = table[&op.input_ids[0]].clone();
                let (b, bi) = table[&op.input_ids[1]].clone();
                contract::einsum2(&a, &ai, &b, &bi, &op.output).unwrap()
            } else {
                let (a, ai) = table[&op.input_ids[0]].clone();
                super::unary_local(&a, &ai, &op.output).unwrap()
            };
            table.insert(op.output_id, (out, op.output.clone()));
            last = op.output_id;
        }
        let (t, idx) = table[&last].clone();
        if idx == spec.output {
            t
        } else {
            let perm: Vec<usize> = spec
                .output
                .iter()
                .map(|c| idx.iter().position(|d| d == c).unwrap())
                .collect();
            t.permute(&perm)
        }
    }

    #[test]
    fn gemm_distributed_matches_oracle() {
        for p in [1, 2, 4, 8] {
            let (rep, inputs, spec) = run_einsum(
                "ij,jk->ik",
                &[vec![24, 20], vec![20, 16]],
                p,
                &PlannerConfig::default(),
            );
            let want = oracle(&spec, &inputs);
            assert!(
                rep.output.allclose(&want, 1e-4, 1e-4),
                "P={p}: rel err {}",
                rep.output.rel_error(&want)
            );
        }
    }

    #[test]
    fn mttkrp3_distributed_matches_oracle() {
        for p in [1, 2, 4, 8, 6] {
            let (rep, inputs, spec) = run_einsum(
                "ijk,ja,ka->ia",
                &[vec![16, 20, 12], vec![20, 6], vec![12, 6]],
                p,
                &PlannerConfig::default(),
            );
            let want = oracle(&spec, &inputs);
            assert!(
                rep.output.allclose(&want, 1e-3, 1e-3),
                "P={p}: rel err {}",
                rep.output.rel_error(&want)
            );
        }
    }

    #[test]
    fn worked_example_distributed_matches_oracle() {
        // §II: ijk,ja,ka,al->il with P=8 (the Tables I/II setup).  At the
        // illustrative N=10 the model fuses all ops into one term (the
        // whole problem fits in fast memory) — numerics must still match.
        let (rep, inputs, spec) = run_einsum(
            "ijk,ja,ka,al->il",
            &[vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]],
            8,
            &PlannerConfig::default(),
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
        assert!(!rep.per_term.is_empty());
    }

    #[test]
    fn worked_example_two_term_split_at_scale() {
        // Forcing a small analysis S reproduces the paper's two-term
        // [MTTKRP, MM] structure even at the illustrative N=10, and the
        // distributed numerics survive the redistribution between terms.
        let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let (rep, inputs, spec) = run_einsum(
            "ijk,ja,ka,al->il",
            &[vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]],
            8,
            &cfg,
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn mttkrp_other_modes_match() {
        let ext = |c: char| match c {
            'i' => 12usize,
            'j' => 14,
            'k' => 10,
            'a' => 5,
            _ => unreachable!(),
        };
        for expr in ["ijk,ia,ka->ja", "ijk,ia,ja->ka"] {
            let lhs = expr.split("->").next().unwrap();
            let shapes: Vec<Vec<usize>> =
                lhs.split(',').map(|s| s.chars().map(ext).collect()).collect();
            let (rep, inputs, spec) =
                run_einsum(expr, &shapes, 4, &PlannerConfig::default());
            let want = oracle(&spec, &inputs);
            assert!(rep.output.allclose(&want, 1e-3, 1e-3), "{expr}");
        }
    }

    #[test]
    fn order5_mttkrp_distributed() {
        let (rep, inputs, spec) = run_einsum(
            "ijklm,ja,ka,la,ma->ia",
            &[
                vec![8, 6, 4, 6, 4],
                vec![6, 5],
                vec![4, 5],
                vec![6, 5],
                vec![4, 5],
            ],
            8,
            &PlannerConfig::default(),
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn ttmc_distributed() {
        let (rep, inputs, spec) = run_einsum(
            "ijklm,jb,kc,ld,me->ibcde",
            &[
                vec![8, 6, 6, 6, 6],
                vec![6, 3],
                vec![6, 3],
                vec![6, 3],
                vec![6, 3],
            ],
            4,
            &PlannerConfig::default(),
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn baseline_unfused_matches_oracle() {
        let base = PlannerConfig { fuse: false, soap_grids: false, ..Default::default() };
        let (rep, inputs, spec) = run_einsum(
            "ijk,ja,ka->ia",
            &[vec![12, 10, 8], vec![10, 4], vec![8, 4]],
            4,
            &base,
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn mm_chain_2mm_3mm() {
        for (expr, shapes) in [
            ("ij,jk,kl->il", vec![vec![12, 10], vec![10, 14], vec![14, 8]]),
            (
                "ij,jk,kl,lm->im",
                vec![vec![8, 10], vec![10, 12], vec![12, 6], vec![6, 9]],
            ),
        ] {
            let (rep, inputs, spec) =
                run_einsum(expr, &shapes, 4, &PlannerConfig::default());
            let want = oracle(&spec, &inputs);
            assert!(rep.output.allclose(&want, 1e-3, 1e-3), "{expr}");
        }
    }

    #[test]
    fn report_has_comm_when_split() {
        let (rep, _, _) = run_einsum(
            "ijk,ja,ka,al->il",
            &[vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]],
            8,
            &PlannerConfig::default(),
        );
        // the intermediate must be redistributed: nonzero p2p or allreduce
        assert!(rep.comm.p2p_bytes > 0 || rep.comm.allreduce_bytes > 0);
        assert!(rep.time.total() > 0.0);
    }

    #[test]
    fn steady_state_runs_reuse_engine_scratch() {
        // The zero-alloc invariant on the hot path: once the engine's
        // scratch pool is warm, repeated program executions (e.g. CP-ALS
        // sweeps) take every packing/fold buffer from the pool instead
        // of the heap.
        let shapes = [vec![24, 20, 16], vec![20, 8], vec![16, 8]];
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[24, 20, 16], 1),
            Tensor::random(&[20, 8], 2),
            Tensor::random(&[16, 8], 3),
        ];
        let session = Session::builder().ranks(4).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka->ia", &shapes).unwrap();
        // Warmup populates the pool to its high-water mark.
        for _ in 0..2 {
            prog.run(&inputs).unwrap();
        }
        let warm = prog.stats().engine_scratch;
        for _ in 0..3 {
            prog.run(&inputs).unwrap();
        }
        let after = prog.stats().engine_scratch;
        // Engine-scratch flatness is deterministic only on the
        // sequential simulated backend — mp rank threads hit the shared
        // pool concurrently, so its high-water mark can wander.
        if ExecBackend::from_env() == ExecBackend::Sim {
            assert_eq!(
                after.allocs, warm.allocs,
                "steady-state steps allocated scratch ({warm:?} -> {after:?})"
            );
        }
        assert!(after.takes > warm.takes, "steps must route buffers through the pool");
    }

    #[test]
    fn steady_state_coordinator_is_allocation_free() {
        // The tentpole invariant: across consecutive runs of the same
        // multi-step plan, the engine's scratch pool (packing/fold) AND
        // the persistent backend's staging/redistribution destinations
        // stop allocating, and the per-term kernel-config override is
        // restored after every run.
        let shapes = [vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]];
        // A small analysis S forces the two-term [MTTKRP, MM] split, so
        // the plan includes an inter-term redistribution.
        let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let session = Session::builder().ranks(8).planner(cfg).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka,al->il", &shapes).unwrap();
        assert!(
            !prog.plan().moves.is_empty(),
            "want a multi-step plan with redistribution"
        );
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[16, 16, 16], 1),
            Tensor::random(&[16, 8], 2),
            Tensor::random(&[16, 8], 3),
            Tensor::random(&[8, 16], 4),
        ];
        let base = session.engine().config();
        let first = prog.run(&inputs).unwrap();
        prog.run(&inputs).unwrap();
        let warm = prog.stats();
        assert!(warm.store.dest_allocs > 0, "first run must have allocated destinations");
        assert!(warm.store.out_allocs > 0, "first run must have allocated compute outputs");
        for _ in 0..2 {
            let rep = prog.run(&inputs).unwrap();
            assert!(rep.output.allclose(&first.output, 0.0, 0.0), "reruns must be bitwise stable");
        }
        let after = prog.stats();
        // Engine scratch is only deterministic on the sequential sim
        // backend (see steady_state_runs_reuse_engine_scratch).
        if ExecBackend::from_env() == ExecBackend::Sim {
            assert_eq!(
                after.engine_scratch.allocs, warm.engine_scratch.allocs,
                "steady-state packing/fold allocated ({warm:?} -> {after:?})"
            );
        }
        assert_eq!(
            after.store.dest_allocs, warm.store.dest_allocs,
            "steady-state staging/redistribution allocated ({warm:?} -> {after:?})"
        );
        assert_eq!(
            after.store.out_allocs, warm.store.out_allocs,
            "steady-state compute outputs allocated ({warm:?} -> {after:?})"
        );
        assert_eq!(
            after.local_scratch.allocs, warm.local_scratch.allocs,
            "steady-state Seq intermediates/permutes allocated ({warm:?} -> {after:?})"
        );
        assert!(
            after.store.dest_reuses > warm.store.dest_reuses,
            "reruns must recycle store buffers"
        );
        assert!(
            after.store.out_reuses > warm.store.out_reuses,
            "reruns must recycle compute-output buffers"
        );
        assert_eq!(session.engine().config(), base, "per-term config override must be reset");
    }

    #[test]
    fn steady_state_holds_across_thread_counts_with_identical_outputs() {
        // The acceptance invariant: the recycled-output path is
        // allocation-free after warmup AND bitwise identical between a
        // serial and an 8-thread engine.
        let shapes = [vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]];
        let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[16, 16, 16], 1),
            Tensor::random(&[16, 8], 2),
            Tensor::random(&[16, 8], 3),
            Tensor::random(&[8, 16], 4),
        ];
        let mut outputs = Vec::new();
        for threads in [1usize, 8] {
            let session = Session::builder()
                .ranks(8)
                .planner(cfg)
                .kernel_config(KernelConfig::default().with_threads(threads))
                .build()
                .unwrap();
            let mut prog = session.compile("ijk,ja,ka,al->il", &shapes).unwrap();
            for _ in 0..2 {
                prog.run(&inputs).unwrap();
            }
            let warm = prog.stats();
            let rep = prog.run(&inputs).unwrap();
            let after = prog.stats();
            assert_eq!(after.store.dest_allocs, warm.store.dest_allocs, "{threads}t dest");
            assert_eq!(after.store.out_allocs, warm.store.out_allocs, "{threads}t out");
            assert_eq!(
                after.local_scratch.allocs, warm.local_scratch.allocs,
                "{threads}t local scratch"
            );
            outputs.push(rep.output);
        }
        assert!(
            outputs[0].allclose(&outputs[1], 0.0, 0.0),
            "1t vs 8t outputs must be bitwise identical"
        );
    }

    #[test]
    fn mttkrp_permuted_output_recycles_and_matches_oracle() {
        // Regression: the MTTKRP output-order permute used to allocate
        // plan.p fresh tensors on every run.  Output order 'ai' differs
        // from the kernel's natural (mode, r) = 'ia', forcing the
        // permute path; counters must stay flat across reruns.
        let shapes = [vec![16, 20, 12], vec![20, 6], vec![12, 6]];
        let spec = EinsumSpec::parse("ijk,ja,ka->ai", &shapes).unwrap();
        let session = Session::builder().ranks(4).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka->ai", &shapes).unwrap();
        let term = prog.plan().terms.last().unwrap();
        assert!(
            matches!(prog.plan().terms[0].kernel, LocalKernel::Mttkrp { .. }),
            "plan must use the fused MTTKRP kernel"
        );
        assert_eq!(term.output_indices, vec!['a', 'i'], "output must be permuted");
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[16, 20, 12], 5),
            Tensor::random(&[20, 6], 6),
            Tensor::random(&[12, 6], 7),
        ];
        let first = prog.run(&inputs).unwrap();
        let want = oracle(&spec, &inputs);
        assert!(first.output.allclose(&want, 1e-3, 1e-3));
        prog.run(&inputs).unwrap();
        let warm = prog.stats();
        assert!(warm.local_scratch.reuses > 0, "second run must recycle permute buffers");
        for _ in 0..3 {
            let rep = prog.run(&inputs).unwrap();
            assert!(rep.output.allclose(&first.output, 0.0, 0.0));
        }
        let after = prog.stats();
        assert_eq!(after.store.dest_allocs, warm.store.dest_allocs);
        assert_eq!(
            after.store.out_allocs, warm.store.out_allocs,
            "permuted MTTKRP outputs must recycle ({warm:?} -> {after:?})"
        );
        assert!(after.store.out_reuses > warm.store.out_reuses);
        assert_eq!(
            after.local_scratch.allocs, warm.local_scratch.allocs,
            "permute scratch must recycle ({warm:?} -> {after:?})"
        );
    }

    #[test]
    fn malformed_plan_surfaces_as_typed_error_not_panic() {
        // A fused-MTTKRP plan whose output index string is corrupted
        // after planning: execution must return Error::MalformedPlan,
        // not panic on an unwrap mid-run.  (Moved here from the
        // integration suite when the deprecated Coordinator wrapper —
        // the last public way to execute a hand-edited Plan — was
        // removed in 0.6.0.)
        let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
        let spec = EinsumSpec::parse("ijk,ja,ka->ia", &shapes).unwrap();
        let mut pl =
            crate::planner::plan(&spec, 4, &PlannerConfig::default()).unwrap();
        let last = pl.terms.len() - 1;
        pl.terms[last].output_indices = vec!['a', 'q'];
        let inputs: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, 500 + i as u64))
            .collect();
        let engine = Arc::new(KernelEngine::native());
        let mut state = ExecState::default();
        match run_plan(&engine, NetworkModel::aries(), &mut state, &pl, &inputs, None) {
            Err(Error::MalformedPlan { term, detail }) => {
                assert!(!term.is_empty());
                assert!(detail.contains('q'), "detail should name the bad index: {detail}");
            }
            other => panic!("want Err(MalformedPlan), got {:?}", other.err()),
        }
        // The error formats with its term context.
        let e = Error::malformed_plan("term0", "boom");
        assert_eq!(e.to_string(), "malformed plan (term term0): boom");
    }

    #[test]
    fn gpu_time_modes() {
        let (rep, _, _) = run_einsum(
            "ij,jk->ik",
            &[vec![32, 32], vec![32, 32]],
            4,
            &PlannerConfig::default(),
        );
        let accel = AccelModel::p100();
        let resident = rep.gpu_time(&accel, true);
        let offload = rep.gpu_time(&accel, false);
        assert!(offload.total() > resident.total());
        assert!(resident.compute < rep.time.compute + 1e-12);
    }
}
