//! The distributed run loop: execute a [`Plan`] on the simulated machine
//! (paper §II-D/E).
//!
//! For every term, in order:
//!
//! 1. **Distribute** program inputs (block + replication per the term's
//!    [`TensorDist`]s) or **Redistribute** intermediates produced by
//!    earlier terms (§V-C message matching);
//! 2. **Local compute** on every rank — the fused MTTKRP Pallas/PJRT
//!    kernel, or the generic folded-GEMM binary-op sequence — with
//!    measured per-rank wall-clock;
//! 3. **Allreduce** partial outputs over the reduction sub-grids (§II-D).
//!
//! Numerics are exact (real bytes move between rank buffers); time is
//! measured compute + α–β-modeled communication, reported per term for
//! the Fig. 5/6 blue/pink split.
//!
//! The execution core is `run_plan` over an `ExecState` — the
//! persistent [`Machine`] plus the recycled local scratch table — owned
//! by [`crate::api::Program`] (the public front door: one compiled
//! program, one persistent state; the deprecated `Coordinator` wrapper
//! was removed in 0.6.0 at the end of its one-release migration
//! window).  Repeated executions of a plan
//! (CP-ALS sweeps, benches) recycle every staging and redistribution
//! destination buffer from the previous run ([`Machine::store_stats`]
//! counters) — and, through the `*_into` kernel family, every **compute
//! output** as well: [`Machine::compute_step_into`] hands each rank a
//! destination recycled from the store, the Seq kernel's per-op
//! intermediates, its pre-reduction buffers for indices private to one
//! operand ([`contract::reduce_modes_into`]), and the MTTKRP
//! output-order permute recycle through a per-`(term, slot)`
//! [`LocalScratchStats`]-counted scratch table, and local inputs are
//! borrowed from the store rather than deep-copied.  In steady state the
//! whole run loop performs zero tensor allocations (asserted in tests).
//! Each term also reconfigures the [`KernelEngine`] with its
//! SOAP-derived tile sizes ([`crate::planner::TermPlan::kernel_config`]
//! via [`KernelEngine::configure_for_term`]).
//!
//! [`TensorDist`]: crate::dist::TensorDist

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::einsum::BinaryOp;
use crate::error::{Error, Result};
use crate::planner::{LocalKernel, Plan, TermInput, TermPlan};
use crate::runtime::KernelEngine;
use crate::sim::collectives::reduction_groups;
use crate::sim::{AccelModel, CommStats, Machine, NetworkModel, StoreStats, TimeBreakdown};
use crate::tensor::{contract, Tensor, ELEM_BYTES};

/// Allocation counters for the run loop's local scratch table (Seq
/// intermediates, pre-reduction buffers, MTTKRP permute buffers, the
/// gather's permute staging).  Steady-state invariant: `allocs` stops
/// growing after the first run of a plan while `reuses` keeps counting.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LocalScratchStats {
    /// Whole local tensors heap-allocated (first run, or shape change).
    pub allocs: u64,
    /// Whole local tensors recycled across runs.
    pub reuses: u64,
}

/// Recycled per-rank buffers for the per-term local compute, keyed by
/// `(term, slot)`: Seq-kernel intermediates at `(term, op)`,
/// pre-reduction buffers at `(term, REDUCE_BASE + 2·op + operand)`, the
/// MTTKRP output-order permute at `(term, PERMUTE_SLOT)`, and the final
/// gather's permute staging at [`GATHER_KEY`].  The run-loop analogue of
/// the engine's [`crate::tensor::kernel::ScratchPool`], but holding
/// whole tensors.
#[derive(Debug, Default)]
pub(crate) struct LocalScratch {
    bufs: HashMap<(usize, usize), Vec<Tensor>>,
    stats: LocalScratchStats,
}

/// Scratch key of a term's MTTKRP permute buffers (never a real op id).
const PERMUTE_SLOT: usize = usize::MAX;

/// Base of the scratch-key slot range holding pre-reduction buffers
/// (`slot = REDUCE_BASE + 2·op + operand`); far above any real op count
/// and below [`PERMUTE_SLOT`].
const REDUCE_BASE: usize = usize::MAX / 2;

/// Scratch key of the gather stage's permute staging buffer (the term
/// index `usize::MAX` is never a real term).
const GATHER_KEY: (usize, usize) = (usize::MAX, 0);

impl LocalScratch {
    /// Take the buffer set for `key` (recycled when `p` tensors of shape
    /// `dims` are present, freshly allocated otherwise).
    fn take(&mut self, key: (usize, usize), p: usize, dims: &[usize]) -> Vec<Tensor> {
        match self.bufs.remove(&key) {
            Some(v) if v.len() == p && v.iter().all(|t| t.dims() == dims) => {
                self.stats.reuses += p as u64;
                v
            }
            _ => {
                self.stats.allocs += p as u64;
                (0..p).map(|_| Tensor::zeros(dims)).collect()
            }
        }
    }

    /// Return a buffer set for recycling by the next run.
    fn put(&mut self, key: (usize, usize), bufs: Vec<Tensor>) {
        self.bufs.insert(key, bufs);
    }
}

/// Per-term execution statistics.
#[derive(Debug, Clone, Default)]
pub struct TermStats {
    pub name: String,
    /// Max per-rank local compute seconds.
    pub compute: f64,
    /// Modeled communication seconds (redistribution + allreduce).
    pub comm: f64,
    /// Per-rank local input footprint (bytes, max over ranks).
    pub local_in_bytes: usize,
    /// Per-rank local output footprint (bytes).
    pub local_out_bytes: usize,
}

/// Time/volume accounting of one run, without the gathered output — what
/// [`crate::api::Program::run_into`] returns (the output lands in the
/// caller's recycled tensor instead).
#[derive(Debug, Clone)]
pub struct RunMetrics {
    /// Total simulated time.
    pub time: TimeBreakdown,
    /// Exact communication volumes.
    pub comm: CommStats,
    /// Per-term breakdown.
    pub per_term: Vec<TermStats>,
}

/// The result of a distributed run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// The assembled global output (gathered off the last term's dist).
    pub output: Tensor,
    /// Total simulated time.
    pub time: TimeBreakdown,
    /// Exact communication volumes.
    pub comm: CommStats,
    /// Per-term breakdown.
    pub per_term: Vec<TermStats>,
}

impl RunReport {
    pub(crate) fn from_parts(output: Tensor, m: RunMetrics) -> Self {
        RunReport { output, time: m.time, comm: m.comm, per_term: m.per_term }
    }

    /// Fig. 6 time model: device compute = measured/speedup; in
    /// *accelerator mode* every term also pays H2D/D2H copies of its
    /// local footprints; *GPU-resident* mode skips the copies.  Network
    /// time is unchanged (CUDA-aware MPI in the paper).
    pub fn gpu_time(&self, accel: &AccelModel, resident: bool) -> TimeBreakdown {
        let mut compute = 0.0;
        let mut comm = self.time.comm;
        for t in &self.per_term {
            compute += accel.compute_time(t.compute);
            if !resident {
                comm += accel
                    .h2d_d2h_time(t.local_in_bytes as f64, t.local_out_bytes as f64);
            }
        }
        TimeBreakdown { compute, comm }
    }
}

/// Persistent execution state for one compiled program: the simulated
/// [`Machine`] (rank-local stores, recycled staging/redistribution/
/// compute-output buffers) and the [`LocalScratch`] table.  Owned
/// exclusively by one [`crate::api::Program`] — which is what lets
/// programs of a shared session execute on concurrent threads: all
/// mutable run state is program-private, and the shared
/// [`KernelEngine`] is `Sync`.
#[derive(Default)]
pub(crate) struct ExecState {
    pub(crate) machine: Option<Machine>,
    pub(crate) scratch: LocalScratch,
}

impl ExecState {
    /// Buffer-recycling counters of the persistent machine (defaults
    /// until the first run).
    pub(crate) fn store_stats(&self) -> StoreStats {
        self.machine.as_ref().map(|m| m.store_stats()).unwrap_or_default()
    }

    /// Allocation counters of the local scratch table.
    pub(crate) fn local_scratch_stats(&self) -> LocalScratchStats {
        self.scratch.stats
    }
}

/// Execute `plan` on `state` against `engine`, staging the global
/// `inputs` (one per program operand, in einsum order).  Initial
/// distribution is not charged (the paper's weak-scaling timings start
/// from distributed data).  With `dest = Some(t)` the gathered output is
/// written through `t` (shape-checked against the spec's output dims;
/// recycled permute staging keeps the path allocation-free in steady
/// state) and the returned output is `None`; with `dest = None` a fresh
/// output tensor is returned.
pub(crate) fn run_plan(
    engine: &KernelEngine,
    network: NetworkModel,
    state: &mut ExecState,
    plan: &Plan,
    inputs: &[Tensor],
    dest: Option<&mut Tensor>,
) -> Result<(Option<Tensor>, RunMetrics)> {
    /// Drop guard: the thread-local per-term override must not leak past
    /// the run — including when a kernel panics and a caller (the
    /// serving worker's per-request containment) catches the unwind.
    struct ResetConfig<'e>(&'e KernelEngine);
    impl Drop for ResetConfig<'_> {
        fn drop(&mut self) {
            self.0.reset_config();
        }
    }
    let _reset = ResetConfig(engine);
    run_plan_inner(engine, network, state, plan, inputs, dest)
}

fn run_plan_inner(
    engine: &KernelEngine,
    network: NetworkModel,
    state: &mut ExecState,
    plan: &Plan,
    inputs: &[Tensor],
    dest: Option<&mut Tensor>,
) -> Result<(Option<Tensor>, RunMetrics)> {
    if inputs.len() != plan.path.n_inputs {
        return Err(Error::plan(format!(
            "plan needs {} inputs, got {}",
            plan.path.n_inputs,
            inputs.len()
        )));
    }
    for (op, t) in plan.spec.inputs.iter().zip(inputs) {
        let want: Vec<usize> = op.iter().map(|c| plan.spec.extents[c]).collect();
        if t.dims() != want {
            return Err(Error::shape(format!(
                "input dims {:?} != spec {:?}",
                t.dims(),
                want
            )));
        }
    }
    if let Some(d) = dest.as_deref() {
        let want: Vec<usize> =
            plan.spec.output.iter().map(|c| plan.spec.extents[c]).collect();
        if d.dims() != want {
            return Err(Error::shape(format!(
                "run_into: dest dims {:?} != output dims {want:?}",
                d.dims()
            )));
        }
    }

    let ExecState { machine: machine_slot, scratch } = state;
    // Reuse the persistent machine (and its store) when the rank count
    // matches; only the accounting is reset per run.
    if !matches!(machine_slot.as_ref(), Some(m) if m.ranks() == plan.p) {
        *machine_slot = Some(Machine::new(plan.p, network));
    }
    let machine = machine_slot
        .as_mut()
        .ok_or_else(|| Error::plan("machine initialization failed"))?;
    machine.begin_run();
    let mut per_term: Vec<TermStats> = Vec::new();
    // Every store name / scratch key this run touches; anything else is
    // a stale buffer set from a previously-run plan and is pruned at the
    // end (the persistent buffers must not grow across plan switches).
    let mut live_names: BTreeSet<String> = BTreeSet::new();
    let mut live_scratch: BTreeSet<(usize, usize)> = BTreeSet::new();

    for (ti, term) in plan.terms.iter().enumerate() {
        let mut stats = TermStats { name: term.name.clone(), ..Default::default() };
        let comm_before = machine.time.comm;
        // Retarget the engine's cache blocking to this term's
        // SOAP-derived tiles (§IV: the local kernel blocks along the
        // same proportions the I/O analysis assumed).
        engine.configure_for_term(term);
        engine.faults().check(crate::fault::site::RUN_PLAN_TERM)?;

        // --- stage inputs -------------------------------------------------
        let mut in_names: Vec<String> = Vec::with_capacity(term.inputs.len());
        for (slot, tin) in term.inputs.iter().enumerate() {
            let name = format!("t{}@{}", tin.id, term.name);
            if tin.id < plan.path.n_inputs {
                // Program input: scatter blocks into recycled store
                // buffers (uncharged staging).
                machine.stage_blocks(&name, &inputs[tin.id], &tin.dist)?;
            } else {
                // Intermediate: redistribute from the producing term.
                let mv = plan
                    .moves
                    .iter()
                    .find(|m| m.to_term == ti && m.to_slot == slot)
                    .ok_or_else(|| {
                        Error::malformed_plan(
                            &term.name,
                            format!("no move for t{} into slot {slot}", tin.id),
                        )
                    })?;
                let from = plan.terms.get(mv.from_term).ok_or_else(|| {
                    Error::malformed_plan(
                        &term.name,
                        format!("move from_term {} out of range", mv.from_term),
                    )
                })?;
                let src_name = format!("t{}@{}", tin.id, from.name);
                machine.redistribute(&src_name, &name, &mv.plan, &mv.src, &mv.dst)?;
            }
            stats.local_in_bytes +=
                tin.dist.local_dims().iter().product::<usize>() * ELEM_BYTES;
            live_names.insert(name.clone());
            in_names.push(name);
        }

        // --- local compute ------------------------------------------------
        let out_name = format!("t{}@{}", term.output_id, term.name);
        live_names.insert(out_name.clone());
        match &term.kernel {
            LocalKernel::Mttkrp { x_input, mode, factor_inputs } => {
                if factor_inputs.is_empty() {
                    return Err(Error::malformed_plan(&term.name, "mttkrp with no factors"));
                }
                // Every slot index comes from the plan: range-check them
                // all so a corrupted plan is an Err, never a panic
                // (in_names is index-aligned with term.inputs).
                let x_in = term.inputs.get(*x_input).ok_or_else(|| {
                    Error::malformed_plan(
                        &term.name,
                        format!("mttkrp x slot {x_input} out of range"),
                    )
                })?;
                let x_name = in_names[*x_input].as_str();
                let f_names: Vec<&str> = factor_inputs
                    .iter()
                    .map(|&s| {
                        in_names.get(s).map(String::as_str).ok_or_else(|| {
                            Error::malformed_plan(
                                &term.name,
                                format!("mttkrp factor slot {s} out of range"),
                            )
                        })
                    })
                    .collect::<Result<_>>()?;
                let order = x_in.indices.len();
                let mode = *mode;
                // Local kernel output shape: (local mode extent, local R).
                let x_ldims = x_in.dist.local_dims();
                let mode_extent = x_ldims.get(mode).copied().ok_or_else(|| {
                    Error::malformed_plan(
                        &term.name,
                        format!("mttkrp mode {mode} out of range for order {order}"),
                    )
                })?;
                let r_local = term.inputs[factor_inputs[0]]
                    .dist
                    .local_dims()
                    .get(1)
                    .copied()
                    .ok_or_else(|| {
                        Error::malformed_plan(&term.name, "mttkrp factor is not a matrix")
                    })?;
                let natural_dims = [mode_extent, r_local];
                // Kernel output order is (mode_idx, r); a differing
                // term output order takes the recycled permute path.
                let x_idx = &x_in.indices;
                let r_char = term
                    .output_indices
                    .iter()
                    .copied()
                    .find(|c| !x_idx.contains(c))
                    .ok_or_else(|| {
                        Error::malformed_plan(&term.name, "mttkrp: no rank index")
                    })?;
                let mode_char = x_idx[mode];
                let natural = vec![mode_char, r_char];
                if term.output_indices == natural {
                    // Kernel writes straight into the store-recycled
                    // per-rank destinations.
                    machine.compute_step_into(&out_name, &natural_dims, |r, m, dest| {
                        mttkrp_rank_into(
                            engine, m, r, &term.name, x_name, &f_names, order, mode, dest,
                        )
                    })?;
                } else {
                    let perm: Vec<usize> = term
                        .output_indices
                        .iter()
                        .map(|c| {
                            natural.iter().position(|d| d == c).ok_or_else(|| {
                                Error::malformed_plan(
                                    &term.name,
                                    format!(
                                        "mttkrp output index '{c}' not in natural \
                                         layout {natural:?}"
                                    ),
                                )
                            })
                        })
                        .collect::<Result<_>>()?;
                    let permuted_dims: Vec<usize> =
                        perm.iter().map(|&p| natural_dims[p]).collect();
                    // Natural-layout kernel outputs land in scratch
                    // buffers recycled across runs...
                    let key = (ti, PERMUTE_SLOT);
                    live_scratch.insert(key);
                    let mut nat = scratch.take(key, plan.p, &natural_dims);
                    for (r, buf) in nat.iter_mut().enumerate() {
                        let t0 = std::time::Instant::now();
                        mttkrp_rank_into(
                            engine, machine, r, &term.name, x_name, &f_names, order, mode,
                            buf,
                        )?;
                        machine.charge_compute(r, t0.elapsed().as_secs_f64());
                    }
                    // ...then permute into the store-recycled
                    // destinations (no allocation on either side).  The
                    // scratch goes back before error propagation so a
                    // recovered run stays allocation-free.
                    let step = machine.compute_step_into(&out_name, &permuted_dims, |r, _m, dest| {
                        nat[r].permute_into(&perm, dest)
                    });
                    scratch.put(key, nat);
                    step?;
                }
            }
            LocalKernel::Seq => {
                // Local output extents per index char: inputs are
                // staged at their distribution's padded local dims,
                // so every op's local output shape is fixed by the
                // chars it keeps — known before any kernel runs,
                // which is what lets the destinations be recycled.
                let mut local_ext: BTreeMap<char, usize> = BTreeMap::new();
                for tin in &term.inputs {
                    for (c, e) in tin.indices.iter().zip(tin.dist.local_dims()) {
                        local_ext.insert(*c, e);
                    }
                }
                let op_dims: Vec<Vec<usize>> = term
                    .ops
                    .iter()
                    .map(|op| {
                        let d: Vec<usize> = op
                            .output
                            .iter()
                            .map(|c| {
                                local_ext.get(c).copied().ok_or_else(|| {
                                    Error::malformed_plan(
                                        &term.name,
                                        format!("seq: unknown index '{c}'"),
                                    )
                                })
                            })
                            .collect::<Result<_>>()?;
                        Ok(if d.is_empty() { vec![1] } else { d })
                    })
                    .collect::<Result<_>>()?;
                let n_ops = term.ops.len();
                if n_ops == 0 {
                    return Err(Error::malformed_plan(&term.name, "empty term"));
                }
                if term.ops[n_ops - 1].output_id != term.output_id {
                    return Err(Error::malformed_plan(
                        &term.name,
                        "last op does not produce the term output",
                    ));
                }
                // Tensor-id table: term inputs are *borrowed* from
                // the store (never deep-copied); intermediates live
                // in scratch buffers recycled across runs.  The
                // final op writes the store-recycled destination.
                let mut src_of: BTreeMap<usize, SeqSrc> = BTreeMap::new();
                for (slot, tin) in term.inputs.iter().enumerate() {
                    src_of.insert(tin.id, SeqSrc::Input(slot));
                }
                for (j, op) in term.ops.iter().enumerate() {
                    src_of.insert(op.output_id, SeqSrc::Op(j));
                }
                // Pre-reduction table: operands carrying indices private
                // to themselves and absent from the op output are summed
                // away *before* the engine sees them, through recycled
                // scratch buffers ([`contract::reduce_modes_into`]) — so
                // `einsum2`'s internal pre-reduction (which allocates)
                // stays off the hot path.
                let mut red = build_reduce_slots(
                    term, ti, plan.p, &src_of, &local_ext, scratch, &mut live_scratch,
                )?;
                let mut opbufs: Vec<Vec<Tensor>> = (0..n_ops - 1)
                    .map(|j| {
                        live_scratch.insert((ti, j));
                        scratch.take((ti, j), plan.p, &op_dims[j])
                    })
                    .collect();
                let ops = &term.ops;
                let term_inputs = &term.inputs;
                // Bound (not `?`d) so the recycled buffer sets return to
                // the scratch table even when a kernel errors mid-step —
                // a caller that recovers keeps its flat alloc counters.
                let step = machine.compute_step_into(&out_name, &op_dims[n_ops - 1], |r, m, dest| {
                    for (j, op) in ops.iter().enumerate() {
                        // Ops run in order: everything before `j` is
                        // readable, `j`'s buffer (or the final
                        // destination) is writable.
                        if op.input_ids.is_empty() {
                            return Err(Error::malformed_plan(
                                &term.name,
                                "0-ary local op unsupported",
                            ));
                        }
                        let (done, rest) = opbufs.split_at_mut(j.min(n_ops - 1));
                        let dst: &mut Tensor =
                            if j == n_ops - 1 { &mut *dest } else { &mut rest[0][r] };
                        let (ra, rai) = seq_operand(
                            op.input_ids[0],
                            j,
                            &src_of,
                            m,
                            r,
                            &in_names,
                            term_inputs,
                            done,
                            ops,
                        )?;
                        if let Some(rs) = red[2 * j].as_mut() {
                            contract::reduce_modes_into(ra, &rs.drop, &mut rs.bufs[r])?;
                        }
                        match op.input_ids.len() {
                            2 => {
                                let (rb, rbi) = seq_operand(
                                    op.input_ids[1],
                                    j,
                                    &src_of,
                                    m,
                                    r,
                                    &in_names,
                                    term_inputs,
                                    done,
                                    ops,
                                )?;
                                if let Some(rs) = red[2 * j + 1].as_mut() {
                                    contract::reduce_modes_into(
                                        rb, &rs.drop, &mut rs.bufs[r],
                                    )?;
                                }
                                let (a, ai) = match red[2 * j].as_ref() {
                                    Some(rs) => (&rs.bufs[r], rs.idx.as_slice()),
                                    None => (ra, rai),
                                };
                                let (b, bi) = match red[2 * j + 1].as_ref() {
                                    Some(rs) => (&rs.bufs[r], rs.idx.as_slice()),
                                    None => (rb, rbi),
                                };
                                engine.einsum2_into(a, ai, b, bi, &op.output, dst)?;
                            }
                            1 => {
                                let (a, ai) = match red[2 * j].as_ref() {
                                    Some(rs) => (&rs.bufs[r], rs.idx.as_slice()),
                                    None => (ra, rai),
                                };
                                unary_local_into(a, ai, &op.output, dst)?;
                            }
                            n => {
                                return Err(Error::malformed_plan(
                                    &term.name,
                                    format!("{n}-ary local op unsupported"),
                                ))
                            }
                        }
                    }
                    Ok(())
                });
                for (j, v) in opbufs.into_iter().enumerate() {
                    scratch.put((ti, j), v);
                }
                for (slot, rs) in red.into_iter().enumerate() {
                    if let Some(rs) = rs {
                        scratch.put((ti, REDUCE_BASE + slot), rs.bufs);
                    }
                }
                step?;
            }
        }
        machine.end_step();
        stats.local_out_bytes =
            term.output_dist.local_dims().iter().product::<usize>() * ELEM_BYTES;

        // --- reduce partials over sub-grids -------------------------------
        if !term.reduced_grid_dims.is_empty() {
            let groups = reduction_groups(&term.grid, &term.reduced_grid_dims);
            machine.allreduce_sum(&out_name, &groups)?;
        }

        stats.comm = machine.time.comm - comm_before;
        stats.compute = machine.time.compute
            - per_term.iter().map(|t| t.compute).sum::<f64>();
        per_term.push(stats);
    }

    // --- gather the result ------------------------------------------------
    let last = plan.terms.last().ok_or_else(|| Error::plan("empty plan"))?;
    let out_name = format!("t{}@{}", last.output_id, last.name);
    let dist = &last.output_dist;
    let perm: Option<Vec<usize>> = if last.output_indices == plan.spec.output {
        None
    } else {
        Some(
            plan.spec
                .output
                .iter()
                .map(|c| {
                    last.output_indices.iter().position(|d| d == c).ok_or_else(|| {
                        Error::malformed_plan(
                            &last.name,
                            format!("output index '{c}' missing"),
                        )
                    })
                })
                .collect::<Result<_>>()?,
        )
    };
    // Assemble the last term's distributed blocks into `target` (term
    // output order) by direct strided copies out of the owners' local
    // buffers — no temporary block tensor per block.
    let zero_off = vec![0usize; dist.extents.len()];
    let assemble = |target: &mut Tensor| -> Result<()> {
        for bc in dist.block_coords() {
            let owner = dist.owner_of_block(&bc);
            let (off, size) = dist.block_for_rank(owner);
            target.copy_box_from(machine.get(&out_name, owner)?, &zero_off, &off, &size);
        }
        Ok(())
    };
    let output = match (dest, perm) {
        (Some(d), perm) => {
            // Dims were checked against the spec before the run started.
            match perm {
                // Assemble into recycled staging, permute into the
                // caller's buffer: zero allocations in steady state.
                Some(p) => {
                    live_scratch.insert(GATHER_KEY);
                    let mut g = scratch.take(GATHER_KEY, 1, &dist.extents);
                    assemble(&mut g[0])?;
                    g[0].permute_into(&p, d)?;
                    scratch.put(GATHER_KEY, g);
                }
                None => assemble(d)?,
            }
            None
        }
        (None, Some(p)) => {
            // The assembled (pre-permute) staging recycles even on the
            // allocating path; only the escaping output is fresh.
            live_scratch.insert(GATHER_KEY);
            let mut g = scratch.take(GATHER_KEY, 1, &dist.extents);
            assemble(&mut g[0])?;
            let out = g[0].permute(&p);
            scratch.put(GATHER_KEY, g);
            Some(out)
        }
        (None, None) => {
            let mut out = Tensor::zeros(&dist.extents);
            assemble(&mut out)?;
            Some(out)
        }
    };

    // Prune buffer sets a previous plan staged under names (or scratch
    // keys) this run never touched (keeps the persistent buffers bounded
    // by the current plan's footprint).
    machine.retain_tensors(|n| live_names.contains(n));
    scratch.bufs.retain(|k, _| live_scratch.contains(k));

    let metrics = RunMetrics {
        time: machine.time,
        comm: machine.comm.clone(),
        per_term,
    };
    Ok((output, metrics))
}

/// One operand's pre-reduction slot: the dropped mode positions in the
/// operand's original index string, the surviving index string, and the
/// per-rank recycled destination buffers.
struct RedSlot {
    idx: Vec<char>,
    drop: Vec<usize>,
    bufs: Vec<Tensor>,
}

/// Index string of Seq-local tensor `id` (term input or earlier op
/// output).
fn seq_idx_of<'t>(
    id: usize,
    src_of: &BTreeMap<usize, SeqSrc>,
    term: &'t TermPlan,
) -> Result<&'t [char]> {
    match src_of.get(&id) {
        Some(SeqSrc::Input(slot)) => Ok(term.inputs[*slot].indices.as_slice()),
        Some(SeqSrc::Op(i)) => Ok(term.ops[*i].output.as_slice()),
        None => Err(Error::malformed_plan(
            &term.name,
            format!("seq: operand t{id} never produced"),
        )),
    }
}

/// Build the pre-reduction table for a Seq term: entry `2·op + operand`
/// is `Some` when that operand carries indices private to itself and
/// absent from the op output (they are summed away into recycled,
/// [`LocalScratchStats`]-counted buffers before the engine runs).  A
/// fully-summed binary operand becomes the `[1]`-shaped synthetic
/// singleton (`'\u{1}'`) `einsum2` itself uses for the already-reduced
/// state, so even that degenerate case stays allocation-free.
#[allow(clippy::too_many_arguments)]
fn build_reduce_slots(
    term: &TermPlan,
    ti: usize,
    p: usize,
    src_of: &BTreeMap<usize, SeqSrc>,
    local_ext: &BTreeMap<char, usize>,
    scratch: &mut LocalScratch,
    live_scratch: &mut BTreeSet<(usize, usize)>,
) -> Result<Vec<Option<RedSlot>>> {
    let mut red: Vec<Option<RedSlot>> = Vec::with_capacity(term.ops.len() * 2);
    for (j, op) in term.ops.iter().enumerate() {
        for q in 0..2 {
            if q >= op.input_ids.len() {
                red.push(None);
                continue;
            }
            let idx = seq_idx_of(op.input_ids[q], src_of, term)?;
            let other: Option<&[char]> = if op.input_ids.len() == 2 {
                Some(seq_idx_of(op.input_ids[1 - q], src_of, term)?)
            } else {
                None
            };
            let drop: Vec<usize> = idx
                .iter()
                .enumerate()
                .filter(|&(_, c)| {
                    if op.output.contains(c) {
                        return false;
                    }
                    match other {
                        Some(o) => !o.contains(c),
                        None => true,
                    }
                })
                .map(|(d, _)| d)
                .collect();
            if drop.is_empty() {
                red.push(None);
                continue;
            }
            let mut kept: Vec<char> = idx
                .iter()
                .enumerate()
                .filter(|(d, _)| !drop.contains(d))
                .map(|(_, &c)| c)
                .collect();
            let dims: Vec<usize> = if kept.is_empty() {
                if op.input_ids.len() == 2 {
                    // Fully-summed binary operand: hand einsum2 the
                    // synthetic already-reduced singleton it would have
                    // built itself (unary ops take the empty-index copy
                    // path instead).
                    kept.push('\u{1}');
                }
                vec![1]
            } else {
                kept.iter()
                    .map(|c| {
                        local_ext.get(c).copied().ok_or_else(|| {
                            Error::malformed_plan(
                                &term.name,
                                format!("seq: unknown index '{c}'"),
                            )
                        })
                    })
                    .collect::<Result<_>>()?
            };
            let key = (ti, REDUCE_BASE + 2 * j + q);
            live_scratch.insert(key);
            red.push(Some(RedSlot { idx: kept, drop, bufs: scratch.take(key, p, &dims) }));
        }
    }
    Ok(red)
}

/// Where a Seq-local tensor id lives during a rank's execution: borrowed
/// from the machine store (term input slot) or from a recycled scratch
/// buffer (output of an earlier op of the same term).
enum SeqSrc {
    Input(usize),
    Op(usize),
}

/// Resolve operand `id` of op `j` to a borrowed tensor + index string —
/// the replacement for the old per-rank clone-everything local table.
#[allow(clippy::too_many_arguments)]
fn seq_operand<'a>(
    id: usize,
    j: usize,
    src_of: &BTreeMap<usize, SeqSrc>,
    m: &'a Machine,
    r: usize,
    in_names: &'a [String],
    inputs: &'a [TermInput],
    done: &'a [Vec<Tensor>],
    ops: &'a [BinaryOp],
) -> Result<(&'a Tensor, &'a [char])> {
    match src_of.get(&id) {
        Some(SeqSrc::Input(slot)) => {
            Ok((m.get(&in_names[*slot], r)?, inputs[*slot].indices.as_slice()))
        }
        Some(SeqSrc::Op(i)) if *i < j => Ok((&done[*i][r], ops[*i].output.as_slice())),
        _ => Err(Error::plan(format!("seq: operand t{id} not available at op {j}"))),
    }
}

/// One rank's fused-MTTKRP local kernel through the recycled-output
/// engine path (`slots` layout: `order` entries, the `mode` slot is a
/// placeholder the kernel ignores).
#[allow(clippy::too_many_arguments)]
fn mttkrp_rank_into(
    engine: &KernelEngine,
    m: &Machine,
    r: usize,
    term_name: &str,
    x_name: &str,
    f_names: &[&str],
    order: usize,
    mode: usize,
    dest: &mut Tensor,
) -> Result<()> {
    let x = m.get(x_name, r)?;
    let fs: Vec<&Tensor> = f_names.iter().map(|n| m.get(n, r)).collect::<Result<_>>()?;
    let mut slots: Vec<&Tensor> = Vec::with_capacity(order);
    let mut fi = fs.iter();
    for mm in 0..order {
        if mm == mode {
            slots.push(x); // placeholder, ignored
        } else {
            slots.push(fi.next().ok_or_else(|| {
                Error::malformed_plan(
                    term_name,
                    format!(
                        "mttkrp factor count mismatch: {} factors for order {order}",
                        f_names.len()
                    ),
                )
            })?);
        }
    }
    engine.mttkrp_into(x, &slots, mode, dest)
}

/// Unary local op: permutation, possibly with summed-away indices
/// (allocating wrapper over [`unary_local_into`], kept as the oracle in
/// tests — the run loop itself only uses the `_into` variant).
#[cfg(test)]
fn unary_local(a: &Tensor, a_idx: &[char], out_idx: &[char]) -> Result<Tensor> {
    let dims: Vec<usize> = out_idx
        .iter()
        .map(|c| {
            a_idx
                .iter()
                .position(|d| d == c)
                .map(|d| a.dims()[d])
                .ok_or_else(|| Error::shape(format!("unary: index '{c}' missing")))
        })
        .collect::<Result<_>>()?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    let mut out = Tensor::zeros(&dims);
    unary_local_into(a, a_idx, out_idx, &mut out)?;
    Ok(out)
}

/// `unary_local` writing through a recycled destination: the final
/// permutation (the common case — pure mode reorder) lands directly in
/// `dest` with zero allocations.  Summed-away indices are normally gone
/// by the time this runs (the Seq loop pre-reduces them through recycled
/// scratch); the allocating [`contract::reduce_mode`] fallback remains
/// for direct callers.
fn unary_local_into(
    a: &Tensor,
    a_idx: &[char],
    out_idx: &[char],
    dest: &mut Tensor,
) -> Result<()> {
    let mut owned: Option<Tensor> = None;
    let mut idx = a_idx.to_vec();
    // reduce dropped indices
    while let Some(d) = idx.iter().position(|c| !out_idx.contains(c)) {
        let cur = owned.as_ref().unwrap_or(a);
        owned = Some(contract::reduce_mode(cur, d));
        idx.remove(d);
    }
    let t = owned.as_ref().unwrap_or(a);
    if idx == out_idx || idx.is_empty() {
        return dest.copy_from(t);
    }
    let perm: Vec<usize> = out_idx
        .iter()
        .map(|c| {
            idx.iter()
                .position(|d| d == c)
                .ok_or_else(|| Error::shape(format!("unary: index '{c}' missing")))
        })
        .collect::<Result<_>>()?;
    t.permute_into(&perm, dest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Session;
    use crate::einsum::EinsumSpec;
    use crate::planner::PlannerConfig;
    use crate::tensor::KernelConfig;

    fn run_einsum(
        expr: &str,
        shapes: &[Vec<usize>],
        p: usize,
        cfg: &PlannerConfig,
    ) -> (RunReport, Vec<Tensor>, EinsumSpec) {
        let spec = EinsumSpec::parse(expr, shapes).unwrap();
        let inputs: Vec<Tensor> = (0..shapes.len())
            .map(|i| Tensor::random(&shapes[i], 1000 + i as u64))
            .collect();
        let session = Session::builder().ranks(p).planner(*cfg).build().unwrap();
        let mut prog = session.compile(expr, shapes).unwrap();
        let rep = prog.run(&inputs).unwrap();
        (rep, inputs, spec)
    }

    /// Serial oracle: evaluate the einsum by running the same path ops
    /// globally with einsum2.
    fn oracle(spec: &EinsumSpec, inputs: &[Tensor]) -> Tensor {
        let path = crate::contraction::optimize(spec).unwrap();
        let mut table: std::collections::BTreeMap<usize, (Tensor, Vec<char>)> =
            std::collections::BTreeMap::new();
        for (i, t) in inputs.iter().enumerate() {
            table.insert(i, (t.clone(), spec.inputs[i].clone()));
        }
        let mut last = 0;
        for op in &path.ops {
            let out = if op.input_ids.len() == 2 {
                let (a, ai) = table[&op.input_ids[0]].clone();
                let (b, bi) = table[&op.input_ids[1]].clone();
                contract::einsum2(&a, &ai, &b, &bi, &op.output).unwrap()
            } else {
                let (a, ai) = table[&op.input_ids[0]].clone();
                super::unary_local(&a, &ai, &op.output).unwrap()
            };
            table.insert(op.output_id, (out, op.output.clone()));
            last = op.output_id;
        }
        let (t, idx) = table[&last].clone();
        if idx == spec.output {
            t
        } else {
            let perm: Vec<usize> = spec
                .output
                .iter()
                .map(|c| idx.iter().position(|d| d == c).unwrap())
                .collect();
            t.permute(&perm)
        }
    }

    #[test]
    fn gemm_distributed_matches_oracle() {
        for p in [1, 2, 4, 8] {
            let (rep, inputs, spec) = run_einsum(
                "ij,jk->ik",
                &[vec![24, 20], vec![20, 16]],
                p,
                &PlannerConfig::default(),
            );
            let want = oracle(&spec, &inputs);
            assert!(
                rep.output.allclose(&want, 1e-4, 1e-4),
                "P={p}: rel err {}",
                rep.output.rel_error(&want)
            );
        }
    }

    #[test]
    fn mttkrp3_distributed_matches_oracle() {
        for p in [1, 2, 4, 8, 6] {
            let (rep, inputs, spec) = run_einsum(
                "ijk,ja,ka->ia",
                &[vec![16, 20, 12], vec![20, 6], vec![12, 6]],
                p,
                &PlannerConfig::default(),
            );
            let want = oracle(&spec, &inputs);
            assert!(
                rep.output.allclose(&want, 1e-3, 1e-3),
                "P={p}: rel err {}",
                rep.output.rel_error(&want)
            );
        }
    }

    #[test]
    fn worked_example_distributed_matches_oracle() {
        // §II: ijk,ja,ka,al->il with P=8 (the Tables I/II setup).  At the
        // illustrative N=10 the model fuses all ops into one term (the
        // whole problem fits in fast memory) — numerics must still match.
        let (rep, inputs, spec) = run_einsum(
            "ijk,ja,ka,al->il",
            &[vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]],
            8,
            &PlannerConfig::default(),
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
        assert!(!rep.per_term.is_empty());
    }

    #[test]
    fn worked_example_two_term_split_at_scale() {
        // Forcing a small analysis S reproduces the paper's two-term
        // [MTTKRP, MM] structure even at the illustrative N=10, and the
        // distributed numerics survive the redistribution between terms.
        let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let (rep, inputs, spec) = run_einsum(
            "ijk,ja,ka,al->il",
            &[vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]],
            8,
            &cfg,
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn mttkrp_other_modes_match() {
        let ext = |c: char| match c {
            'i' => 12usize,
            'j' => 14,
            'k' => 10,
            'a' => 5,
            _ => unreachable!(),
        };
        for expr in ["ijk,ia,ka->ja", "ijk,ia,ja->ka"] {
            let lhs = expr.split("->").next().unwrap();
            let shapes: Vec<Vec<usize>> =
                lhs.split(',').map(|s| s.chars().map(ext).collect()).collect();
            let (rep, inputs, spec) =
                run_einsum(expr, &shapes, 4, &PlannerConfig::default());
            let want = oracle(&spec, &inputs);
            assert!(rep.output.allclose(&want, 1e-3, 1e-3), "{expr}");
        }
    }

    #[test]
    fn order5_mttkrp_distributed() {
        let (rep, inputs, spec) = run_einsum(
            "ijklm,ja,ka,la,ma->ia",
            &[
                vec![8, 6, 4, 6, 4],
                vec![6, 5],
                vec![4, 5],
                vec![6, 5],
                vec![4, 5],
            ],
            8,
            &PlannerConfig::default(),
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn ttmc_distributed() {
        let (rep, inputs, spec) = run_einsum(
            "ijklm,jb,kc,ld,me->ibcde",
            &[
                vec![8, 6, 6, 6, 6],
                vec![6, 3],
                vec![6, 3],
                vec![6, 3],
                vec![6, 3],
            ],
            4,
            &PlannerConfig::default(),
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn baseline_unfused_matches_oracle() {
        let base = PlannerConfig { fuse: false, soap_grids: false, ..Default::default() };
        let (rep, inputs, spec) = run_einsum(
            "ijk,ja,ka->ia",
            &[vec![12, 10, 8], vec![10, 4], vec![8, 4]],
            4,
            &base,
        );
        let want = oracle(&spec, &inputs);
        assert!(rep.output.allclose(&want, 1e-3, 1e-3));
    }

    #[test]
    fn mm_chain_2mm_3mm() {
        for (expr, shapes) in [
            ("ij,jk,kl->il", vec![vec![12, 10], vec![10, 14], vec![14, 8]]),
            (
                "ij,jk,kl,lm->im",
                vec![vec![8, 10], vec![10, 12], vec![12, 6], vec![6, 9]],
            ),
        ] {
            let (rep, inputs, spec) =
                run_einsum(expr, &shapes, 4, &PlannerConfig::default());
            let want = oracle(&spec, &inputs);
            assert!(rep.output.allclose(&want, 1e-3, 1e-3), "{expr}");
        }
    }

    #[test]
    fn report_has_comm_when_split() {
        let (rep, _, _) = run_einsum(
            "ijk,ja,ka,al->il",
            &[vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]],
            8,
            &PlannerConfig::default(),
        );
        // the intermediate must be redistributed: nonzero p2p or allreduce
        assert!(rep.comm.p2p_bytes > 0 || rep.comm.allreduce_bytes > 0);
        assert!(rep.time.total() > 0.0);
    }

    #[test]
    fn steady_state_runs_reuse_engine_scratch() {
        // The zero-alloc invariant on the hot path: once the engine's
        // scratch pool is warm, repeated program executions (e.g. CP-ALS
        // sweeps) take every packing/fold buffer from the pool instead
        // of the heap.
        let shapes = [vec![24, 20, 16], vec![20, 8], vec![16, 8]];
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[24, 20, 16], 1),
            Tensor::random(&[20, 8], 2),
            Tensor::random(&[16, 8], 3),
        ];
        let session = Session::builder().ranks(4).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka->ia", &shapes).unwrap();
        // Warmup populates the pool to its high-water mark.
        for _ in 0..2 {
            prog.run(&inputs).unwrap();
        }
        let warm = prog.stats().engine_scratch;
        for _ in 0..3 {
            prog.run(&inputs).unwrap();
        }
        let after = prog.stats().engine_scratch;
        assert_eq!(
            after.allocs, warm.allocs,
            "steady-state steps allocated scratch ({warm:?} -> {after:?})"
        );
        assert!(after.takes > warm.takes, "steps must route buffers through the pool");
    }

    #[test]
    fn steady_state_coordinator_is_allocation_free() {
        // The tentpole invariant: across consecutive runs of the same
        // multi-step plan, the engine's scratch pool (packing/fold) AND
        // the persistent machine's staging/redistribution destinations
        // stop allocating, and the per-term kernel-config override is
        // restored after every run.
        let shapes = [vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]];
        // A small analysis S forces the two-term [MTTKRP, MM] split, so
        // the plan includes an inter-term redistribution.
        let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let session = Session::builder().ranks(8).planner(cfg).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka,al->il", &shapes).unwrap();
        assert!(
            !prog.plan().moves.is_empty(),
            "want a multi-step plan with redistribution"
        );
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[16, 16, 16], 1),
            Tensor::random(&[16, 8], 2),
            Tensor::random(&[16, 8], 3),
            Tensor::random(&[8, 16], 4),
        ];
        let base = session.engine().config();
        let first = prog.run(&inputs).unwrap();
        prog.run(&inputs).unwrap();
        let warm = prog.stats();
        assert!(warm.store.dest_allocs > 0, "first run must have allocated destinations");
        assert!(warm.store.out_allocs > 0, "first run must have allocated compute outputs");
        for _ in 0..2 {
            let rep = prog.run(&inputs).unwrap();
            assert!(rep.output.allclose(&first.output, 0.0, 0.0), "reruns must be bitwise stable");
        }
        let after = prog.stats();
        assert_eq!(
            after.engine_scratch.allocs, warm.engine_scratch.allocs,
            "steady-state packing/fold allocated ({warm:?} -> {after:?})"
        );
        assert_eq!(
            after.store.dest_allocs, warm.store.dest_allocs,
            "steady-state staging/redistribution allocated ({warm:?} -> {after:?})"
        );
        assert_eq!(
            after.store.out_allocs, warm.store.out_allocs,
            "steady-state compute outputs allocated ({warm:?} -> {after:?})"
        );
        assert_eq!(
            after.local_scratch.allocs, warm.local_scratch.allocs,
            "steady-state Seq intermediates/permutes allocated ({warm:?} -> {after:?})"
        );
        assert!(
            after.store.dest_reuses > warm.store.dest_reuses,
            "reruns must recycle store buffers"
        );
        assert!(
            after.store.out_reuses > warm.store.out_reuses,
            "reruns must recycle compute-output buffers"
        );
        assert_eq!(session.engine().config(), base, "per-term config override must be reset");
    }

    #[test]
    fn steady_state_holds_across_thread_counts_with_identical_outputs() {
        // The acceptance invariant: the recycled-output path is
        // allocation-free after warmup AND bitwise identical between a
        // serial and an 8-thread engine.
        let shapes = [vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]];
        let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[16, 16, 16], 1),
            Tensor::random(&[16, 8], 2),
            Tensor::random(&[16, 8], 3),
            Tensor::random(&[8, 16], 4),
        ];
        let mut outputs = Vec::new();
        for threads in [1usize, 8] {
            let session = Session::builder()
                .ranks(8)
                .planner(cfg)
                .kernel_config(KernelConfig::default().with_threads(threads))
                .build()
                .unwrap();
            let mut prog = session.compile("ijk,ja,ka,al->il", &shapes).unwrap();
            for _ in 0..2 {
                prog.run(&inputs).unwrap();
            }
            let warm = prog.stats();
            let rep = prog.run(&inputs).unwrap();
            let after = prog.stats();
            assert_eq!(after.store.dest_allocs, warm.store.dest_allocs, "{threads}t dest");
            assert_eq!(after.store.out_allocs, warm.store.out_allocs, "{threads}t out");
            assert_eq!(
                after.local_scratch.allocs, warm.local_scratch.allocs,
                "{threads}t local scratch"
            );
            outputs.push(rep.output);
        }
        assert!(
            outputs[0].allclose(&outputs[1], 0.0, 0.0),
            "1t vs 8t outputs must be bitwise identical"
        );
    }

    #[test]
    fn mttkrp_permuted_output_recycles_and_matches_oracle() {
        // Regression: the MTTKRP output-order permute used to allocate
        // plan.p fresh tensors on every run.  Output order 'ai' differs
        // from the kernel's natural (mode, r) = 'ia', forcing the
        // permute path; counters must stay flat across reruns.
        let shapes = [vec![16, 20, 12], vec![20, 6], vec![12, 6]];
        let spec = EinsumSpec::parse("ijk,ja,ka->ai", &shapes).unwrap();
        let session = Session::builder().ranks(4).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka->ai", &shapes).unwrap();
        let term = prog.plan().terms.last().unwrap();
        assert!(
            matches!(prog.plan().terms[0].kernel, LocalKernel::Mttkrp { .. }),
            "plan must use the fused MTTKRP kernel"
        );
        assert_eq!(term.output_indices, vec!['a', 'i'], "output must be permuted");
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[16, 20, 12], 5),
            Tensor::random(&[20, 6], 6),
            Tensor::random(&[12, 6], 7),
        ];
        let first = prog.run(&inputs).unwrap();
        let want = oracle(&spec, &inputs);
        assert!(first.output.allclose(&want, 1e-3, 1e-3));
        prog.run(&inputs).unwrap();
        let warm = prog.stats();
        assert!(warm.local_scratch.reuses > 0, "second run must recycle permute buffers");
        for _ in 0..3 {
            let rep = prog.run(&inputs).unwrap();
            assert!(rep.output.allclose(&first.output, 0.0, 0.0));
        }
        let after = prog.stats();
        assert_eq!(after.store.dest_allocs, warm.store.dest_allocs);
        assert_eq!(
            after.store.out_allocs, warm.store.out_allocs,
            "permuted MTTKRP outputs must recycle ({warm:?} -> {after:?})"
        );
        assert!(after.store.out_reuses > warm.store.out_reuses);
        assert_eq!(
            after.local_scratch.allocs, warm.local_scratch.allocs,
            "permute scratch must recycle ({warm:?} -> {after:?})"
        );
    }

    #[test]
    fn malformed_plan_surfaces_as_typed_error_not_panic() {
        // A fused-MTTKRP plan whose output index string is corrupted
        // after planning: execution must return Error::MalformedPlan,
        // not panic on an unwrap mid-run.  (Moved here from the
        // integration suite when the deprecated Coordinator wrapper —
        // the last public way to execute a hand-edited Plan — was
        // removed in 0.6.0.)
        let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
        let spec = EinsumSpec::parse("ijk,ja,ka->ia", &shapes).unwrap();
        let mut pl =
            crate::planner::plan(&spec, 4, &PlannerConfig::default()).unwrap();
        let last = pl.terms.len() - 1;
        pl.terms[last].output_indices = vec!['a', 'q'];
        let inputs: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, 500 + i as u64))
            .collect();
        let engine = KernelEngine::native();
        let mut state = ExecState::default();
        match run_plan(&engine, NetworkModel::aries(), &mut state, &pl, &inputs, None) {
            Err(Error::MalformedPlan { term, detail }) => {
                assert!(!term.is_empty());
                assert!(detail.contains('q'), "detail should name the bad index: {detail}");
            }
            other => panic!("want Err(MalformedPlan), got {:?}", other.err()),
        }
        // The error formats with its term context.
        let e = Error::malformed_plan("term0", "boom");
        assert_eq!(e.to_string(), "malformed plan (term term0): boom");
    }

    #[test]
    fn gpu_time_modes() {
        let (rep, _, _) = run_einsum(
            "ij,jk->ik",
            &[vec![32, 32], vec![32, 32]],
            4,
            &PlannerConfig::default(),
        );
        let accel = AccelModel::p100();
        let resident = rep.gpu_time(&accel, true);
        let offload = rep.gpu_time(&accel, false);
        assert!(offload.total() > resident.total());
        assert!(resident.compute < rep.time.compute + 1e-12);
    }
}
