//! Decomposition of n-ary einsums into FLOP-minimizing binary operations
//! (paper §II-A, §IV-C — the opt_einsum role).
//!
//! Exploiting associativity, an n-ary multilinear operation is broken into
//! a sequence of binary contractions; the order changes the arithmetic
//! complexity asymptotically (the §II example drops from `4·N_i N_j N_k
//! N_l N_a` to `2·N_i N_a (N_k (1 + N_j) + N_l)` FLOPs).  Finding the
//! optimal order is NP-hard in general [Chi-Chung et al.], but exhaustive
//! enumeration is exact for the operand counts that occur in practice; we
//! enumerate exhaustively up to [`EXHAUSTIVE_LIMIT`] operands and fall
//! back to the standard greedy heuristic above that.

use std::collections::BTreeSet;

use crate::einsum::{BinaryOp, EinsumSpec};
use crate::error::{Error, Result};

/// Max operand count for exhaustive (provably FLOP-optimal) search.
pub const EXHAUSTIVE_LIMIT: usize = 6;

/// A contraction path: the binary-op sequence plus its total FLOP count.
#[derive(Debug, Clone)]
pub struct Path {
    /// Binary ops in execution order; `output_id`s are allocated after the
    /// program inputs (ids `0..n_inputs`).
    pub ops: Vec<BinaryOp>,
    /// Total multiply-add FLOPs (2 * iteration-space per op, summed).
    pub flops: u128,
    /// Number of program inputs.
    pub n_inputs: usize,
}

impl Path {
    /// Id of the tensor holding the final result.
    pub fn result_id(&self) -> usize {
        self.ops.last().map(|op| op.output_id).unwrap_or(0)
    }

    /// Render the path as einsum fragments (mirrors §II-A's bullet list).
    pub fn render(&self) -> String {
        self.ops
            .iter()
            .map(|op| op.einsum())
            .collect::<Vec<_>>()
            .join("; ")
    }
}

/// One operand during search: its index set and tensor-table id.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Operand {
    idx: Vec<char>, // ordered indices (output ordering of the producing op)
    id: usize,
}

#[allow(dead_code)]
fn index_set(ops: &[Operand]) -> BTreeSet<char> {
    ops.iter().flat_map(|o| o.idx.iter().copied()).collect()
}

/// Indices the contraction of `a` and `b` must keep: those appearing in
/// any *other* operand or in the program output.  The final op (no other
/// operands left) uses the program's requested output ordering so no
/// trailing transpose is needed.
fn kept_indices(
    a: &Operand,
    b: &Operand,
    others: &[&Operand],
    output: &[char],
) -> Vec<char> {
    if others.is_empty() {
        return output.to_vec();
    }
    let mut needed: BTreeSet<char> = output.iter().copied().collect();
    for o in others {
        needed.extend(o.idx.iter().copied());
    }
    let mut all: Vec<char> = a.idx.clone();
    for &c in &b.idx {
        if !all.contains(&c) {
            all.push(c);
        }
    }
    all.retain(|c| needed.contains(c));
    all
}

fn op_cost(a: &Operand, b: &Operand, spec: &EinsumSpec) -> u128 {
    let mut all: BTreeSet<char> = a.idx.iter().copied().collect();
    all.extend(b.idx.iter().copied());
    2 * all.iter().map(|c| spec.extents[c] as u128).product::<u128>()
}

/// Compute the FLOP-optimal contraction path for `spec`.
pub fn optimize(spec: &EinsumSpec) -> Result<Path> {
    let n = spec.inputs.len();
    if n == 0 {
        return Err(Error::plan("einsum with no operands"));
    }
    let operands: Vec<Operand> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, idx)| Operand { idx: idx.clone(), id: i })
        .collect();

    if n == 1 {
        // Unary program (permute / partial reduction): a single op.
        let op = BinaryOp {
            inputs: vec![operands[0].idx.clone()],
            input_ids: vec![0],
            output: spec.output.clone(),
            output_id: 1,
        };
        let flops = op.flops(&spec.extents);
        return Ok(Path { ops: vec![op], flops, n_inputs: 1 });
    }

    let mut next_id = n;
    let (ops, flops) = if n <= EXHAUSTIVE_LIMIT {
        let mut best: Option<(Vec<BinaryOp>, u128, Vec<u128>)> = None;
        exhaustive(&operands, spec, &mut Vec::new(), &mut Vec::new(), 0, &mut best, n);
        best.map(|(ops, flops, _)| (ops, flops))
            .ok_or_else(|| Error::plan("no contraction path found"))?
    } else {
        greedy(operands, spec, &mut next_id)
    };
    Ok(Path { ops, flops, n_inputs: n })
}

/// Exhaustive recursion: try every pair at every step.  Operand counts are
/// tiny (≤ 6 ⇒ ≤ 2700 leaves), so no memoization is needed.
///
/// Ties in total FLOPs are broken lexicographically on the per-op cost
/// sequence, preferring paths whose early ops are cheap.  This makes the
/// result deterministic and recovers the paper's §II-A decomposition
/// (KRP first, then TDOT) among the FLOP-equal alternatives.
fn exhaustive(
    operands: &[Operand],
    spec: &EinsumSpec,
    prefix: &mut Vec<BinaryOp>,
    costs: &mut Vec<u128>,
    cost_so_far: u128,
    best: &mut Option<(Vec<BinaryOp>, u128, Vec<u128>)>,
    n_inputs: usize,
) {
    if operands.len() == 1 {
        // Final operand must match the requested output (possibly via a
        // free transpose, which the planner handles; cost-equivalent).
        let final_set: BTreeSet<char> = operands[0].idx.iter().copied().collect();
        let out_set: BTreeSet<char> = spec.output.iter().copied().collect();
        if final_set != out_set {
            return; // kept_indices guarantees this never happens
        }
        let better = match best {
            None => true,
            Some((_, c, seq)) => {
                cost_so_far < *c || (cost_so_far == *c && costs.as_slice() < seq.as_slice())
            }
        };
        if better {
            *best = Some((prefix.clone(), cost_so_far, costs.clone()));
        }
        return;
    }
    if let Some((_, c, _)) = best {
        if cost_so_far > *c {
            return; // branch-and-bound prune (keep == for tie-breaking)
        }
    }
    for i in 0..operands.len() {
        for j in i + 1..operands.len() {
            let a = &operands[i];
            let b = &operands[j];
            let others: Vec<&Operand> = operands
                .iter()
                .enumerate()
                .filter(|(q, _)| *q != i && *q != j)
                .map(|(_, o)| o)
                .collect();
            let out_idx = kept_indices(a, b, &others, &spec.output);
            let cost = op_cost(a, b, spec);
            let new_id = n_inputs + prefix.len();
            let op = BinaryOp {
                inputs: vec![a.idx.clone(), b.idx.clone()],
                input_ids: vec![a.id, b.id],
                output: out_idx.clone(),
                output_id: new_id,
            };
            let mut rest: Vec<Operand> =
                others.iter().map(|&o| o.clone()).collect();
            rest.push(Operand { idx: out_idx, id: new_id });
            prefix.push(op);
            costs.push(cost);
            exhaustive(&rest, spec, prefix, costs, cost_so_far + cost, best, n_inputs);
            costs.pop();
            prefix.pop();
        }
    }
}

/// Greedy heuristic for > EXHAUSTIVE_LIMIT operands: repeatedly contract
/// the cheapest pair (opt_einsum's `greedy` strategy).
fn greedy(
    mut operands: Vec<Operand>,
    spec: &EinsumSpec,
    next_id: &mut usize,
) -> (Vec<BinaryOp>, u128) {
    let mut ops = Vec::new();
    let mut total = 0u128;
    while operands.len() > 1 {
        let mut best: Option<(usize, usize, u128)> = None;
        for i in 0..operands.len() {
            for j in i + 1..operands.len() {
                let c = op_cost(&operands[i], &operands[j], spec);
                if best.map(|(_, _, bc)| c < bc).unwrap_or(true) {
                    best = Some((i, j, c));
                }
            }
        }
        let (i, j, cost) = best.unwrap();
        let b = operands.remove(j);
        let a = operands.remove(i);
        let others: Vec<&Operand> = operands.iter().collect();
        let out_idx = kept_indices(&a, &b, &others, &spec.output);
        let op = BinaryOp {
            inputs: vec![a.idx.clone(), b.idx.clone()],
            input_ids: vec![a.id, b.id],
            output: out_idx.clone(),
            output_id: *next_id,
        };
        operands.push(Operand { idx: out_idx, id: *next_id });
        *next_id += 1;
        total += cost;
        ops.push(op);
    }
    (ops, total)
}

/// FLOPs of the paper's §II-A reference decomposition of the worked
/// example, used as a regression anchor in tests:
/// `2 N_i N_a (N_k (1 + N_j) + N_l)`.
pub fn paper_example_flops(ni: u128, nj: u128, nk: u128, nl: u128, na: u128) -> u128 {
    2 * nj * nk * na        // ja,ka->jka   (KRP)
        + 2 * ni * nj * nk * na // ijk,jka->ia  (TDOT)
        + 2 * ni * na * nl      // ia,al->il    (GEMM)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(expr: &str, shapes: &[Vec<usize>]) -> EinsumSpec {
        EinsumSpec::parse(expr, shapes).unwrap()
    }

    #[test]
    fn single_matmul_is_one_op() {
        let s = spec("ij,jk->ik", &[vec![8, 9], vec![9, 10]]);
        let p = optimize(&s).unwrap();
        assert_eq!(p.ops.len(), 1);
        assert_eq!(p.flops, 2 * 8 * 9 * 10);
        assert_eq!(p.ops[0].output, vec!['i', 'k']);
    }

    #[test]
    fn paper_worked_example_cost() {
        // §II-A: ijk,ja,ka,al->il with the KRP→TDOT→GEMM decomposition.
        let (ni, nj, nk, nl, na) = (100, 100, 100, 100, 24);
        let s = spec(
            "ijk,ja,ka,al->il",
            &[vec![ni, nj, nk], vec![nj, na], vec![nk, na], vec![na, nl]],
        );
        let p = optimize(&s).unwrap();
        let reference = paper_example_flops(
            ni as u128,
            nj as u128,
            nk as u128,
            nl as u128,
            na as u128,
        );
        assert!(
            p.flops <= reference,
            "optimal path {} must not exceed paper's reference {}",
            p.flops,
            reference
        );
        // And it must beat the naive 5-deep loop nest by a wide margin.
        assert!(p.flops < s.naive_flops() / 10);
    }

    #[test]
    fn paper_example_structure() {
        // With square extents the optimal path is exactly the paper's:
        // KRP (ja,ka->jka), TDOT (ijk,jka->ia), GEMM (ia,al->il).
        let s = spec(
            "ijk,ja,ka,al->il",
            &[vec![64, 64, 64], vec![64, 8], vec![64, 8], vec![8, 64]],
        );
        let p = optimize(&s).unwrap();
        assert_eq!(p.ops.len(), 3);
        let rendered = p.render();
        assert!(rendered.contains("->ia"), "TDOT producing ia: {rendered}");
        assert!(rendered.ends_with("->il") || rendered.contains("->il"));
    }

    #[test]
    fn mttkrp3_path_is_krp_then_tdot() {
        let s = spec(
            "ijk,ja,ka->ia",
            &[vec![128, 128, 128], vec![128, 24], vec![128, 24]],
        );
        let p = optimize(&s).unwrap();
        assert_eq!(p.ops.len(), 2);
        // First op must be the KRP of the two factor matrices (contracting
        // X with a factor first would cost 2*I*J*K*A instead of 2*J*K*A);
        // a KRP contracts nothing.
        assert_eq!(p.ops[0].input_ids, vec![1, 2]);
        assert!(p.ops[0].contracted().is_empty(), "{}", p.ops[0].einsum());
        let krp_out: std::collections::BTreeSet<char> =
            p.ops[0].output.iter().copied().collect();
        assert_eq!(krp_out, ['a', 'j', 'k'].into_iter().collect());
        // Second op is the TDOT contracting j, k.
        assert_eq!(p.ops[1].contracted(), vec!['j', 'k']);
    }

    #[test]
    fn mm_chain_association_matters() {
        // (A·B)·C vs A·(B·C): extents force a unique optimum.
        let s = spec(
            "ij,jk,kl->il",
            &[vec![1000, 10], vec![10, 1000], vec![1000, 10]],
        );
        let p = optimize(&s).unwrap();
        // optimal: B·C first (10x1000x10), then A·(BC) (1000x10x10)
        let bc_first = 2 * (10 * 1000 * 10) + 2 * (1000 * 10 * 10);
        assert_eq!(p.flops, bc_first as u128);
    }

    #[test]
    fn path_ids_are_consistent() {
        let s = spec(
            "ijk,ja,ka,al->il",
            &[vec![16, 16, 16], vec![16, 4], vec![16, 4], vec![4, 16]],
        );
        let p = optimize(&s).unwrap();
        let n = p.n_inputs;
        for (q, op) in p.ops.iter().enumerate() {
            assert_eq!(op.output_id, n + q);
            for &id in &op.input_ids {
                assert!(id < n + q, "op {q} consumes not-yet-produced tensor {id}");
            }
        }
        assert_eq!(p.result_id(), n + p.ops.len() - 1);
    }

    #[test]
    fn greedy_handles_many_operands() {
        // 8 operands force the greedy path.
        let shapes: Vec<Vec<usize>> = (0..8).map(|_| vec![8, 8]).collect();
        let s = spec(
            "ab,bc,cd,de,ef,fg,gh,hi->ai",
            &shapes,
        );
        let p = optimize(&s).unwrap();
        assert_eq!(p.ops.len(), 7);
        assert!(p.flops > 0);
    }

    #[test]
    fn unary_program() {
        let s = spec("ij->ji", &[vec![3, 4]]);
        let p = optimize(&s).unwrap();
        assert_eq!(p.ops.len(), 1);
        assert_eq!(p.ops[0].inputs.len(), 1);
    }

    #[test]
    fn ttmc_order5_path_length() {
        // ijklm,jb,kc,ld,me->ibcde: 4 TTMs, so 4 binary ops.
        let s = spec(
            "ijklm,jb,kc,ld,me->ibcde",
            &[
                vec![16, 16, 16, 16, 16],
                vec![16, 4],
                vec![16, 4],
                vec![16, 4],
                vec![16, 4],
            ],
        );
        let p = optimize(&s).unwrap();
        assert_eq!(p.ops.len(), 4);
        // Each op contracts exactly one tensor dim (a TTM).
        for op in &p.ops {
            assert_eq!(op.contracted().len(), 1, "{}", op.einsum());
        }
    }
}
