//! Einsum parsing and program specification (paper §II, §III-A).
//!
//! An einsum string like `ijk,ja,ka,al->il` describes a multilinear
//! program: one loop per distinct index, one input tensor per index
//! string before the arrow, implicit summation over indices absent from
//! the output.  [`EinsumSpec`] carries the parsed structure plus the
//! extent of every index, which is all downstream analysis needs.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed, shape-bound einsum program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EinsumSpec {
    /// Index string per input operand (e.g. `['i','j','k']`).
    pub inputs: Vec<Vec<char>>,
    /// Output index string.
    pub output: Vec<char>,
    /// Extent of every index, keyed by its character.
    pub extents: BTreeMap<char, usize>,
}

impl EinsumSpec {
    /// Parse an einsum string and bind it to operand shapes.
    ///
    /// Rules enforced (paper §III-A):
    /// - explicit output (`->`) required;
    /// - every operand carries at least one index (no scalar operands —
    ///   `,j->j` and trailing commas are rejected);
    /// - every output index must appear in some input;
    /// - repeated indices must agree on extent across operands;
    /// - no index repetition *within* one operand (no traces) — the SOAP
    ///   model assumes simple overlap access (§IV-B);
    /// - index characters are single ASCII letters, at most 26 distinct
    ///   indices per program (one loop dimension per letter).
    pub fn parse(expr: &str, shapes: &[Vec<usize>]) -> Result<Self> {
        let expr: String = expr.chars().filter(|c| !c.is_whitespace()).collect();
        let (lhs, rhs) = expr
            .split_once("->")
            .ok_or_else(|| Error::parse("missing '->' (implicit output unsupported)"))?;
        let inputs: Vec<Vec<char>> = lhs.split(',').map(|s| s.chars().collect()).collect();
        let output: Vec<char> = rhs.chars().collect();

        if inputs.len() != shapes.len() {
            return Err(Error::parse(format!(
                "{} operands in string but {} shapes given",
                inputs.len(),
                shapes.len()
            )));
        }
        if let Some(op) = inputs.iter().position(|ops| ops.is_empty()) {
            return Err(Error::parse(format!(
                "operand {op} is empty (scalar operands / stray ',' unsupported)"
            )));
        }
        let mut extents = BTreeMap::new();
        for (ops, shape) in inputs.iter().zip(shapes) {
            if ops.len() != shape.len() {
                return Err(Error::parse(format!(
                    "operand '{}' has {} indices but shape {:?}",
                    ops.iter().collect::<String>(),
                    ops.len(),
                    shape
                )));
            }
            let mut seen = Vec::new();
            for (&c, &ext) in ops.iter().zip(shape) {
                if !c.is_ascii_alphabetic() {
                    return Err(Error::parse(format!("invalid index char '{c}'")));
                }
                if seen.contains(&c) {
                    return Err(Error::parse(format!(
                        "repeated index '{c}' within one operand (traces unsupported)"
                    )));
                }
                seen.push(c);
                match extents.insert(c, ext) {
                    Some(prev) if prev != ext => {
                        return Err(Error::parse(format!(
                            "index '{c}' bound to both {prev} and {ext}"
                        )));
                    }
                    _ => {}
                }
            }
        }
        if extents.len() > 26 {
            return Err(Error::parse(format!(
                "{} distinct indices (max 26, one ASCII letter each)",
                extents.len()
            )));
        }
        let mut out_seen = Vec::new();
        for &c in &output {
            if !extents.contains_key(&c) {
                return Err(Error::parse(format!("output index '{c}' not in any input")));
            }
            if out_seen.contains(&c) {
                return Err(Error::parse(format!("repeated output index '{c}'")));
            }
            out_seen.push(c);
        }
        Ok(EinsumSpec { inputs, output, extents })
    }

    /// All distinct indices, sorted (the program's loop nest, §II).
    pub fn indices(&self) -> Vec<char> {
        self.extents.keys().copied().collect()
    }

    /// Indices summed over (present in inputs, absent from output).
    pub fn contracted(&self) -> Vec<char> {
        self.extents.keys().copied().filter(|c| !self.output.contains(c)).collect()
    }

    /// Size of the full iteration space `|I| = prod extents` (§II).
    pub fn iteration_space(&self) -> u128 {
        self.extents.values().map(|&e| e as u128).product()
    }

    /// Shape of operand `op`.
    pub fn input_shape(&self, op: usize) -> Vec<usize> {
        self.inputs[op].iter().map(|c| self.extents[c]).collect()
    }

    /// Shape of the output.
    pub fn output_shape(&self) -> Vec<usize> {
        self.output.iter().map(|c| self.extents[c]).collect()
    }

    /// FLOPs of the naive (un-decomposed) evaluation: one multiply-add
    /// chain of length `inputs` per iteration-space point (§II-A).
    pub fn naive_flops(&self) -> u128 {
        self.iteration_space() * (self.inputs.len() as u128)
    }
}

/// A single *binary* (or unary) tensor operation produced by the
/// contraction-path decomposition — the unit the SOAP model analyzes and
/// the planner distributes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BinaryOp {
    /// Operand index strings (1 or 2 entries).
    pub inputs: Vec<Vec<char>>,
    /// IDs of the operands in the program's tensor table.
    pub input_ids: Vec<usize>,
    /// Output index string.
    pub output: Vec<char>,
    /// Output tensor id.
    pub output_id: usize,
}

impl BinaryOp {
    /// Indices contracted away by this op.
    pub fn contracted(&self) -> Vec<char> {
        let mut c: Vec<char> = self
            .inputs
            .iter()
            .flatten()
            .copied()
            .filter(|i| !self.output.contains(i))
            .collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// All distinct indices touched by this op.
    pub fn all_indices(&self) -> Vec<char> {
        let mut c: Vec<char> = self.inputs.iter().flatten().copied().collect();
        c.extend(self.output.iter().copied());
        c.sort_unstable();
        c.dedup();
        c
    }

    /// Multiply-add FLOPs given index extents: 2 * prod over all indices.
    pub fn flops(&self, extents: &BTreeMap<char, usize>) -> u128 {
        2 * self.all_indices().iter().map(|c| extents[c] as u128).product::<u128>()
    }

    /// Render as an einsum fragment, e.g. `ja,ka->jka`.
    pub fn einsum(&self) -> String {
        let ins: Vec<String> =
            self.inputs.iter().map(|v| v.iter().collect::<String>()).collect();
        format!("{}->{}", ins.join(","), self.output.iter().collect::<String>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_example() -> EinsumSpec {
        // §II worked example: ijk,ja,ka,al->il
        EinsumSpec::parse(
            "ijk,ja,ka,al->il",
            &[vec![10, 11, 12], vec![11, 13], vec![12, 13], vec![13, 14]],
        )
        .unwrap()
    }

    #[test]
    fn parses_paper_example() {
        let s = paper_example();
        assert_eq!(s.inputs.len(), 4);
        assert_eq!(s.output, vec!['i', 'l']);
        assert_eq!(s.extents[&'i'], 10);
        assert_eq!(s.extents[&'a'], 13);
        assert_eq!(s.indices(), vec!['a', 'i', 'j', 'k', 'l']);
        assert_eq!(s.contracted(), vec!['a', 'j', 'k']);
    }

    #[test]
    fn iteration_space_and_flops() {
        let s = paper_example();
        assert_eq!(s.iteration_space(), 10 * 11 * 12 * 13 * 14);
        // §II-A: naive cost is 4 * |I| multiply ops (4 operands).
        assert_eq!(s.naive_flops(), 4 * s.iteration_space());
    }

    #[test]
    fn shapes() {
        let s = paper_example();
        assert_eq!(s.input_shape(0), vec![10, 11, 12]);
        assert_eq!(s.input_shape(3), vec![13, 14]);
        assert_eq!(s.output_shape(), vec![10, 14]);
    }

    #[test]
    fn whitespace_tolerated() {
        let s = EinsumSpec::parse("ij, jk -> ik", &[vec![2, 3], vec![3, 4]]).unwrap();
        assert_eq!(s.output, vec!['i', 'k']);
    }

    #[test]
    fn rejects_missing_arrow() {
        assert!(EinsumSpec::parse("ij,jk", &[vec![2, 3], vec![3, 4]]).is_err());
    }

    #[test]
    fn rejects_extent_mismatch() {
        assert!(EinsumSpec::parse("ij,jk->ik", &[vec![2, 3], vec![4, 5]]).is_err());
    }

    #[test]
    fn rejects_rank_mismatch() {
        assert!(EinsumSpec::parse("ij,jk->ik", &[vec![2, 3, 7], vec![3, 4]]).is_err());
    }

    #[test]
    fn rejects_unknown_output_index() {
        assert!(EinsumSpec::parse("ij,jk->iz", &[vec![2, 3], vec![3, 4]]).is_err());
    }

    #[test]
    fn rejects_trace() {
        assert!(EinsumSpec::parse("ii->i", &[vec![3, 3]]).is_err());
    }

    #[test]
    fn rejects_operand_count_mismatch() {
        assert!(EinsumSpec::parse("ij,jk->ik", &[vec![2, 3]]).is_err());
    }

    /// Every hostile rejection is a typed [`Error::Parse`], never a
    /// panic, and never burns serve retry budget.
    fn assert_parse_reject(expr: &str, shapes: &[Vec<usize>]) {
        match EinsumSpec::parse(expr, shapes) {
            Err(e @ Error::Parse(_)) => assert!(!e.is_retryable(), "{expr}"),
            Err(e) => panic!("{expr}: expected Parse error, got {e:?}"),
            Ok(_) => panic!("{expr}: expected rejection"),
        }
    }

    #[test]
    fn rejects_empty_operand_string() {
        // Leading, middle, and trailing empty operands (stray commas).
        assert_parse_reject(",j->j", &[vec![], vec![3]]);
        assert_parse_reject("i,,j->j", &[vec![2], vec![], vec![3]]);
        assert_parse_reject("i,->", &[vec![2], vec![]]);
        assert_parse_reject("->", &[vec![]]);
    }

    #[test]
    fn rejects_more_than_26_distinct_indices() {
        // 27 distinct single-letter indices across two operands.
        let lhs_a: String = ('a'..='z').collect();
        let expr = format!("{lhs_a},A->A");
        let shapes = vec![vec![1usize; 26], vec![1usize]];
        assert_parse_reject(&expr, &shapes);
        // Exactly 26 is still fine.
        let expr26 = format!("{lhs_a}->a");
        assert!(EinsumSpec::parse(&expr26, &[vec![1usize; 26]]).is_ok());
    }

    #[test]
    fn rejects_non_ascii_and_multibyte_index_chars() {
        assert_parse_reject("iμ->i", &[vec![2, 3]]);
        assert_parse_reject("ij,j\u{4e16}->i", &[vec![2, 3], vec![3, 4]]);
        assert_parse_reject("i2->i", &[vec![2, 3]]);
        assert_parse_reject("i_->i", &[vec![2, 3]]);
    }

    #[test]
    fn mttkrp_benchmarks_parse() {
        // Table IV einsum strings.
        for (expr, nshapes) in [
            ("ijk,ja,ka->ia", 3),
            ("ijk,ia,ka->ja", 3),
            ("ijk,ia,ja->ka", 3),
            ("ijklm,ja,ka,la,ma->ia", 5),
            ("ijklm,ia,ja,la,ma->ka", 5),
            ("ijklm,ia,ja,ka,la->ma", 5),
            ("ijklm,jb,kc,ld,me->ibcde", 5),
        ] {
            let mut extents = BTreeMap::new();
            let (lhs, _) = expr.split_once("->").unwrap();
            let inputs: Vec<&str> = lhs.split(',').collect();
            for c in expr.chars().filter(|c| c.is_ascii_alphabetic()) {
                let e = 4 + (c as usize % 5);
                extents.entry(c).or_insert(e);
            }
            let shapes: Vec<Vec<usize>> = inputs
                .iter()
                .map(|s| s.chars().map(|c| extents[&c]).collect())
                .collect();
            assert_eq!(shapes.len(), nshapes);
            assert!(EinsumSpec::parse(expr, &shapes).is_ok(), "{expr}");
        }
    }

    #[test]
    fn binary_op_helpers() {
        let op = BinaryOp {
            inputs: vec![vec!['j', 'a'], vec!['k', 'a']],
            input_ids: vec![1, 2],
            output: vec!['j', 'k', 'a'],
            output_id: 4,
        };
        assert_eq!(op.contracted(), Vec::<char>::new());
        assert_eq!(op.all_indices(), vec!['a', 'j', 'k']);
        assert_eq!(op.einsum(), "ja,ka->jka");
        let mut ext = BTreeMap::new();
        ext.insert('j', 3);
        ext.insert('k', 4);
        ext.insert('a', 5);
        assert_eq!(op.flops(&ext), 2 * 60);
    }
}
