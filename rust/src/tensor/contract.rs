//! Native local contraction kernels (exact-shape fallback for the PJRT
//! artifacts, and the oracle in integration tests).
//!
//! Everything lowers the way the paper's Sec. III-B describes: TDOT/TTM
//! fold to GEMM after a mode permutation; MTTKRP has a dedicated *fused*
//! kernel (KRP tile formed on the fly, never materialized) mirroring the
//! L1 Pallas kernel's structure; the two-step MTTKRP used by the CTF-like
//! baseline is also provided.
//!
//! All GEMM-shaped work runs on the packed engine in [`super::kernel`]
//! (BLIS-style MC×KC / KC×NC packing, 8×8 register microkernel, row-band
//! threading); the fused MTTKRP parallelizes over row bands of the
//! matricized tensor with each worker forming its own bounded KRP tile.
//! Every `*_with` variant takes an explicit [`KernelConfig`] +
//! [`ScratchPool`] so the coordinator's steady-state steps reuse packing
//! and fold buffers across steps; the plain-named entry points use the
//! process-global config/pool.

use super::kernel::{self, KernelConfig, ScratchPool};
use super::transpose::{self, dematricize, matricize};
use super::Tensor;
use crate::error::{Error, Result};

/// Packed GEMM: `C[m,n] = A[m,k] * B[k,n]`.
pub fn gemm(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    gemm_with(&KernelConfig::global(), kernel::global_pool(), a, b)
}

/// [`gemm`] with an explicit engine config and scratch pool.
pub fn gemm_with(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    a: &Tensor,
    b: &Tensor,
) -> Result<Tensor> {
    let (m, k) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        return Err(Error::shape(format!("gemm: inner dims {k} != {k2}")));
    }
    let mut c = vec![0.0f32; m * n];
    kernel::gemm_into_with(cfg, pool, a.data(), b.data(), &mut c, m, k, n);
    Tensor::from_vec(&[m, n], c)
}

/// GEMM into a preallocated accumulator (`c += a * b`). Raw-slice API so
/// the coordinator's hot path can reuse buffers.  Runs on the packed
/// engine with the process-global config/pool; see
/// [`kernel::gemm_into_with`] for the explicit-handles variant.
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    kernel::gemm_into_with(&KernelConfig::global(), kernel::global_pool(), a, b, c, m, k, n);
}

/// The seed's scalar i-k-j kernel, kept as the perf baseline and test
/// oracle.  Note: **no** `aik == 0.0` skip — that branch defeated
/// vectorization on dense inputs and is exactly what the packed engine
/// replaced (the zero-handling semantics are identical either way, which
/// `gemm_zero_rich_inputs_match_oracle` pins down).
pub fn gemm_scalar_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert!(a.len() >= m * k);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= m * n);
    const MC: usize = 64;
    const KC: usize = 256;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MC).min(m);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let c_row = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = a[i * k + kk];
                    let b_row = &b[kk * n..kk * n + n];
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += aik * bv;
                    }
                }
            }
            k0 = k1;
        }
        i0 = i1;
    }
}

fn mat_dims(t: &Tensor) -> Result<(usize, usize)> {
    if t.order() != 2 {
        return Err(Error::shape(format!("expected matrix, got order {}", t.order())));
    }
    Ok((t.dims()[0], t.dims()[1]))
}

/// Tensor dot product over paired axes (numpy `tensordot` semantics):
/// fold both operands so the contracted axes are adjacent, GEMM, unfold.
pub fn tdot(x: &Tensor, y: &Tensor, axes_x: &[usize], axes_y: &[usize]) -> Result<Tensor> {
    if axes_x.len() != axes_y.len() {
        return Err(Error::shape("tdot: axes length mismatch"));
    }
    for (&ax, &ay) in axes_x.iter().zip(axes_y) {
        if x.dims()[ax] != y.dims()[ay] {
            return Err(Error::shape(format!(
                "tdot: contracted extents differ: x[{ax}]={} y[{ay}]={}",
                x.dims()[ax],
                y.dims()[ay]
            )));
        }
    }
    let free_x: Vec<usize> = (0..x.order()).filter(|d| !axes_x.contains(d)).collect();
    let free_y: Vec<usize> = (0..y.order()).filter(|d| !axes_y.contains(d)).collect();

    let perm_x: Vec<usize> = free_x.iter().chain(axes_x.iter()).copied().collect();
    let perm_y: Vec<usize> = axes_y.iter().chain(free_y.iter()).copied().collect();
    let xp = x.permute(&perm_x);
    let yp = y.permute(&perm_y);

    let m: usize = free_x.iter().map(|&d| x.dims()[d]).product();
    let kk: usize = axes_x.iter().map(|&d| x.dims()[d]).product();
    let n: usize = free_y.iter().map(|&d| y.dims()[d]).product();

    let mut c = vec![0.0f32; m * n];
    gemm_into(xp.data(), yp.data(), &mut c, m, kk, n);

    let mut out_dims: Vec<usize> = free_x.iter().map(|&d| x.dims()[d]).collect();
    out_dims.extend(free_y.iter().map(|&d| y.dims()[d]));
    if out_dims.is_empty() {
        out_dims.push(1);
    }
    Tensor::from_vec(&out_dims, c)
}

/// Tensor-times-matrix in `mode`: contract X's mode-`mode` fibers with
/// `U[I_mode, R]`, placing R in that mode.
pub fn ttm(x: &Tensor, u: &Tensor, mode: usize) -> Result<Tensor> {
    let (i_mode, r) = mat_dims(u)?;
    if x.dims()[mode] != i_mode {
        return Err(Error::shape(format!(
            "ttm: mode {mode} extent {} != U rows {}",
            x.dims()[mode],
            i_mode
        )));
    }
    // fold: (I_mode, rest) = matricize; U^T * that is (R, rest); unfold.
    let xm = matricize(x, mode); // (I_mode, rest)
    let ut = u.permute(&[1, 0]); // (R, I_mode)
    let mut c = vec![0.0f32; r * xm.dims()[1]];
    gemm_into(ut.data(), xm.data(), &mut c, r, i_mode, xm.dims()[1]);
    let folded = Tensor::from_vec(&[r, xm.dims()[1]], c)?;
    let mut out_dims = x.dims().to_vec();
    out_dims[mode] = r;
    Ok(dematricize(&folded, &out_dims, mode))
}

/// Mode-`mode` TTM chain (Table IV TTMc): apply every factor but `mode`'s.
/// `factors[mode]` is ignored and may be any placeholder.
pub fn ttmc(x: &Tensor, factors: &[&Tensor], mode: usize) -> Result<Tensor> {
    let mut out = x.clone();
    for m in 0..x.order() {
        if m == mode {
            continue;
        }
        out = ttm(&out, factors[m], m)?;
    }
    Ok(out)
}

/// Khatri-Rao product chain, unflattened: `(I_0, ..., I_{q-1}, R)`.
pub fn krp_chain(factors: &[&Tensor]) -> Result<Tensor> {
    if factors.is_empty() {
        return Err(Error::shape("krp_chain: no factors"));
    }
    let r = factors[0].dims()[1];
    let mut out = factors[0].clone();
    for f in &factors[1..] {
        if f.dims()[1] != r {
            return Err(Error::shape("krp_chain: rank mismatch"));
        }
        let rows_out: usize = out.len() / r.max(1);
        let rows_f = f.dims()[0];
        let mut data = vec![0.0f32; rows_out * rows_f * r];
        for i in 0..rows_out {
            let o_row = &out.data()[i * r..(i + 1) * r];
            for j in 0..rows_f {
                let f_row = &f.data()[j * r..(j + 1) * r];
                let dst = &mut data[(i * rows_f + j) * r..(i * rows_f + j + 1) * r];
                for c in 0..r {
                    dst[c] = o_row[c] * f_row[c];
                }
            }
        }
        let mut dims: Vec<usize> = out.dims()[..out.order() - 1].to_vec();
        dims.push(rows_f);
        dims.push(r);
        out = Tensor::from_vec(&dims, data)?;
    }
    Ok(out)
}

/// Fused mode-`mode` MTTKRP (paper Sec. IV-E tiling structure): the KRP
/// row is formed on the fly per reduction index and contracted
/// immediately — the KRP never hits memory beyond a bounded tile, exactly
/// like the L1 Pallas kernel.  `factors[mode]` is ignored.
pub fn mttkrp(x: &Tensor, factors: &[&Tensor], mode: usize) -> Result<Tensor> {
    mttkrp_with(&KernelConfig::global(), kernel::global_pool(), x, factors, mode)
}

/// Maximum tensor order the fused MTTKRP path handles (odometer digit
/// buffers are fixed-size so the hot loop allocates nothing).
const MAX_MTTKRP_ORDER: usize = 16;

/// [`mttkrp`] with explicit engine config + scratch pool: allocates the
/// `(I_mode, R)` output and runs [`mttkrp_with_into`].
pub fn mttkrp_with(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    x: &Tensor,
    factors: &[&Tensor],
    mode: usize,
) -> Result<Tensor> {
    let (_, n_rows, r) = mttkrp_validate(x, factors, mode)?;
    let mut out = Tensor::zeros(&[n_rows, r]);
    mttkrp_with_into(cfg, pool, x, factors, mode, &mut out)?;
    Ok(out)
}

/// [`mttkrp`] writing through a caller-provided `(I_mode, R)` output
/// with the process-global config/pool (the recycled-output hot path).
pub fn mttkrp_into(
    x: &Tensor,
    factors: &[&Tensor],
    mode: usize,
    dest: &mut Tensor,
) -> Result<()> {
    mttkrp_with_into(&KernelConfig::global(), kernel::global_pool(), x, factors, mode, dest)
}

/// Shared argument validation: returns `(rest modes, I_mode, R)`.
fn mttkrp_validate(
    x: &Tensor,
    factors: &[&Tensor],
    mode: usize,
) -> Result<(Vec<usize>, usize, usize)> {
    let order = x.order();
    if factors.len() != order {
        return Err(Error::shape(format!(
            "mttkrp: need {order} factors (mode slot ignored), got {}",
            factors.len()
        )));
    }
    let rest: Vec<usize> = (0..order).filter(|&m| m != mode).collect();
    if rest.is_empty() || order > MAX_MTTKRP_ORDER {
        return Err(Error::shape(format!("mttkrp: unsupported order {order}")));
    }
    let r = factors[rest[0]].dims()[1];
    for &m in &rest {
        if factors[m].dims() != [x.dims()[m], r] {
            return Err(Error::shape(format!(
                "mttkrp: factor {m} dims {:?} != [{}, {r}]",
                factors[m].dims(),
                x.dims()[m]
            )));
        }
    }
    Ok((rest, x.dims()[mode], r))
}

/// The fused-MTTKRP engine proper, writing through a caller-provided
/// destination (shape-checked `(I_mode, R)`; contents overwritten).  The
/// macro loop mirrors the shared-packing GEMM: the KC×R KRP tile — this
/// kernel's "B panel" — is formed **once** per column tile in shared
/// pool scratch (PR 1 built it redundantly per worker), then the
/// matricized tensor's rows are contracted against it as stealable
/// pool-task bands (disjoint output slices), each through the strided
/// packed GEMM with no panel gather.  The column-tile loop is serial and
/// each row's reduction order is fixed by it, so results are bitwise
/// identical across thread counts — and identical to the allocating
/// [`mttkrp_with`], which is now a thin wrapper over this.
pub fn mttkrp_with_into(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    x: &Tensor,
    factors: &[&Tensor],
    mode: usize,
    dest: &mut Tensor,
) -> Result<()> {
    let (rest, n_rows, r) = mttkrp_validate(x, factors, mode)?;
    if dest.dims() != [n_rows, r] {
        return Err(Error::shape(format!(
            "mttkrp_into: dest dims {:?} != [{n_rows}, {r}]",
            dest.dims()
        )));
    }
    let cfg = cfg.normalized();
    let n_cols = x.len() / n_rows.max(1);
    let out: &mut [f32] = dest.data_mut();
    out.fill(0.0);
    if n_rows == 0 || n_cols == 0 || r == 0 {
        return Ok(());
    }

    // Matricize X with `mode` leading.  Mode 0 is already that layout —
    // borrow it; otherwise permute into pool scratch (HPTT's role).
    let xm_guard = if mode == 0 {
        None
    } else {
        let mut perm = Vec::with_capacity(x.order());
        perm.push(mode);
        perm.extend(rest.iter().copied());
        let mut buf = pool.take(x.len());
        transpose::permute_into(&cfg, x.data(), x.dims(), &perm, &mut buf);
        Some(buf)
    };
    let xm: &[f32] = match &xm_guard {
        Some(b) => b,
        None => x.data(),
    };

    let rest_dims: Vec<usize> = rest.iter().map(|&m| x.dims()[m]).collect();
    let fdata: Vec<&[f32]> = rest.iter().map(|&m| factors[m].data()).collect();
    let kc_tile = cfg.kc.max(64); // KRP tile rows resident in "fast memory"

    // Same multiply-add cutoff and MR-aligned band split as the packed
    // GEMM (kernel::parallel_row_bands — one partitioning scheme for the
    // whole engine).
    let madds = n_rows.saturating_mul(n_cols).saturating_mul(r);
    let threads =
        if madds < kernel::PARALLEL_FLOP_CUTOFF { 1 } else { cfg.threads.min(n_rows) };
    let serial = cfg.serial();
    let mut krp = pool.take(kc_tile * r);
    let mut col0 = 0usize;
    while col0 < n_cols {
        let tile = kc_tile.min(n_cols - col0);
        // Shared KRP tile: formed once per column tile (the reduction
        // order every row sees is fixed by this serial loop).
        fill_krp_tile(&mut krp, col0, tile, &fdata, &rest_dims, r);
        let krp_tile: &[f32] = &krp[..tile * r];
        // out[rows, :] += X[rows, col0..col0+tile] @ krp — strided A
        // view (no gather), disjoint output bands, stealable tasks.
        kernel::parallel_row_bands(threads, n_rows, r, &mut *out, |row0, rows, out_band| {
            kernel::gemm_strided(
                &serial,
                pool,
                &xm[row0 * n_cols + col0..],
                n_cols,
                krp_tile,
                r,
                out_band,
                r,
                rows,
                tile,
                r,
            );
        });
        col0 += tile;
    }
    Ok(())
}

/// Form rows `col0..col0+tile` of the Khatri-Rao product into `krp`
/// (product of factor rows under the mixed-radix odometer over
/// `rest_dims`, last digit fastest).  The KRP never hits memory beyond
/// this bounded tile.
fn fill_krp_tile(
    krp: &mut [f32],
    col0: usize,
    tile: usize,
    fdata: &[&[f32]],
    rest_dims: &[usize],
    r: usize,
) {
    let q_rest = rest_dims.len();
    let mut idx = [0usize; MAX_MTTKRP_ORDER];
    // Mixed-radix digits of col0 over rest_dims (last fastest).
    let mut rem = col0;
    for q in (0..q_rest).rev() {
        idx[q] = rem % rest_dims[q];
        rem /= rest_dims[q];
    }
    for t in 0..tile {
        let dst = &mut krp[t * r..(t + 1) * r];
        dst.copy_from_slice(&fdata[0][idx[0] * r..idx[0] * r + r]);
        for q in 1..q_rest {
            let row = &fdata[q][idx[q] * r..idx[q] * r + r];
            for c in 0..r {
                dst[c] *= row[c];
            }
        }
        for q in (0..q_rest).rev() {
            idx[q] += 1;
            if idx[q] < rest_dims[q] {
                break;
            }
            idx[q] = 0;
        }
    }
}

/// Sum a tensor over one mode (used to eliminate indices that appear in
/// one operand only and not in the output).
pub fn reduce_mode(x: &Tensor, mode: usize) -> Tensor {
    let out_dims: Vec<usize> = x
        .dims()
        .iter()
        .enumerate()
        .filter(|(d, _)| *d != mode)
        .map(|(_, &e)| e)
        .collect();
    let out_dims = if out_dims.is_empty() { vec![1] } else { out_dims };
    let mut out = Tensor::zeros(&out_dims);
    reduce_modes_into(x, &[mode], &mut out).expect("dims derived from x");
    out
}

/// Tensor order up to which [`reduce_modes_into`]'s odometer lives on
/// the stack (far above the order-5 tensors of the benchmark suite);
/// higher orders fall back to a heap odometer rather than failing.
const REDUCE_MAX_ORDER: usize = 16;

/// Sum `x` over every mode listed in `drop` into `dest`, with **zero
/// allocations** up to order [`REDUCE_MAX_ORDER`]: a single linear pass
/// over `x` accumulating into the kept-dims layout.  `dest` must already
/// have the kept dims (`[1]` when every mode is dropped); its contents
/// are overwritten.  Per output element the dropped indices are visited
/// in ascending order, so a single-mode call is bitwise identical to
/// [`reduce_mode`].
///
/// This is the coordinator's pre-reduction hot path for indices private
/// to one operand: destinations come from its recycled local scratch
/// table, closing what used to be the last documented steady-state
/// allocation exception.
pub fn reduce_modes_into(x: &Tensor, drop: &[usize], dest: &mut Tensor) -> Result<()> {
    let dims = x.dims();
    let n = dims.len();
    if drop.iter().any(|&d| d >= n) {
        return Err(Error::shape(format!("reduce: mode out of range for order {n}")));
    }
    let want: Vec<usize> =
        (0..n).filter(|d| !drop.contains(d)).map(|d| dims[d]).collect();
    let want = if want.is_empty() { vec![1] } else { want };
    if dest.dims() != want {
        return Err(Error::shape(format!(
            "reduce: dest dims {:?} != kept dims {want:?}",
            dest.dims()
        )));
    }
    // Destination stride per source dim (0 for dropped dims); the linear
    // walk over `x` advances the destination offset with a plain
    // odometer carry.  On-stack for every realistic order; exotic orders
    // pay one heap odometer instead of erroring.
    let mut dstride_arr = [0usize; REDUCE_MAX_ORDER];
    let mut idx_arr = [0usize; REDUCE_MAX_ORDER];
    let mut dstride_heap: Vec<usize>;
    let mut idx_heap: Vec<usize>;
    let (dstride, idx): (&mut [usize], &mut [usize]) = if n <= REDUCE_MAX_ORDER {
        (&mut dstride_arr[..n], &mut idx_arr[..n])
    } else {
        dstride_heap = vec![0usize; n];
        idx_heap = vec![0usize; n];
        (&mut dstride_heap[..], &mut idx_heap[..])
    };
    let mut s = 1usize;
    for d in (0..n).rev() {
        if !drop.contains(&d) {
            dstride[d] = s;
            s *= dims[d];
        }
    }
    let out = dest.data_mut();
    out.fill(0.0);
    let mut off = 0usize;
    for &v in x.data() {
        out[off] += v;
        for d in (0..n).rev() {
            idx[d] += 1;
            if idx[d] < dims[d] {
                off += dstride[d];
                break;
            }
            idx[d] = 0;
            off -= dstride[d] * (dims[d] - 1);
        }
    }
    Ok(())
}

/// General binary einsum: `out[out_idx] = Σ x[x_idx] * y[y_idx]` with
/// batch (shared & kept), contracted (shared & dropped) and free indices.
/// This is the local-tile workhorse for arbitrary fused-group ops: folds
/// both operands into `(batch, free, contracted)` layout and runs one
/// GEMM per batch slice.
pub fn einsum2(
    x: &Tensor,
    x_idx: &[char],
    y: &Tensor,
    y_idx: &[char],
    out_idx: &[char],
) -> Result<Tensor> {
    einsum2_with(&KernelConfig::global(), kernel::global_pool(), x, x_idx, y, y_idx, out_idx)
}

/// [`einsum2`] writing through a caller-provided output tensor with the
/// process-global config/pool (the recycled-output hot path).  `dest`
/// must already have the result dims; its contents are overwritten.
pub fn einsum2_into(
    x: &Tensor,
    x_idx: &[char],
    y: &Tensor,
    y_idx: &[char],
    out_idx: &[char],
    dest: &mut Tensor,
) -> Result<()> {
    einsum2_into_with(
        &KernelConfig::global(),
        kernel::global_pool(),
        x,
        x_idx,
        y,
        y_idx,
        out_idx,
        dest,
    )
}

/// [`einsum2`] with explicit engine config + scratch pool: the mode
/// folds and (when the output order needs a final permute) the GEMM
/// accumulator land in pool scratch, so steady-state steps allocate only
/// the escaping output buffer.  Exception: the rare pre-reduction of
/// indices private to one operand ([`reduce_mode`]) still allocates its
/// intermediates.
pub fn einsum2_with(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    x: &Tensor,
    x_idx: &[char],
    y: &Tensor,
    y_idx: &[char],
    out_idx: &[char],
) -> Result<Tensor> {
    let out = einsum2_dispatch(cfg, pool, x, x_idx, y, y_idx, out_idx, None)?;
    Ok(out.expect("einsum2_dispatch without dest returns a tensor"))
}

/// [`einsum2_with`] writing through a caller-provided output: nothing on
/// the path allocates except pool misses, so a warm pool plus a recycled
/// `dest` makes the whole binary contraction allocation-free.  Results
/// are bitwise identical to [`einsum2_with`] (same dispatch, same
/// arithmetic order).
#[allow(clippy::too_many_arguments)]
pub fn einsum2_into_with(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    x: &Tensor,
    x_idx: &[char],
    y: &Tensor,
    y_idx: &[char],
    out_idx: &[char],
    dest: &mut Tensor,
) -> Result<()> {
    einsum2_dispatch(cfg, pool, x, x_idx, y, y_idx, out_idx, Some(dest))?;
    Ok(())
}

/// The einsum2 engine: with `dest` the result is written through it
/// (shape-checked, returns `None`); without, a fresh tensor is returned.
#[allow(clippy::too_many_arguments)]
fn einsum2_dispatch(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    x: &Tensor,
    x_idx: &[char],
    y: &Tensor,
    y_idx: &[char],
    out_idx: &[char],
    mut dest: Option<&mut Tensor>,
) -> Result<Option<Tensor>> {
    if x.order() != x_idx.len() || y.order() != y_idx.len() {
        return Err(Error::shape("einsum2: index/rank mismatch"));
    }
    // Pre-reduce indices private to one operand and absent from output
    // (copy-on-write: the common all-indices-used case never clones).
    let mut x_owned: Option<Tensor> = None;
    let mut x_idx: Vec<char> = x_idx.to_vec();
    loop {
        // The synthetic singleton is never a victim (it marks an operand
        // already fully reduced — re-selecting it would loop forever).
        let victim = x_idx
            .iter()
            .position(|c| *c != '\u{1}' && !y_idx.contains(c) && !out_idx.contains(c));
        match victim {
            Some(d) => {
                let cur = x_owned.as_ref().unwrap_or(x);
                x_owned = Some(reduce_mode(cur, d));
                x_idx.remove(d);
                if x_idx.is_empty() {
                    x_idx.push('\u{1}'); // synthetic singleton
                }
            }
            None => break,
        }
    }
    let x: &Tensor = x_owned.as_ref().unwrap_or(x);
    let mut y_owned: Option<Tensor> = None;
    let mut y_idx: Vec<char> = y_idx.to_vec();
    loop {
        let victim = y_idx
            .iter()
            .position(|c| *c != '\u{1}' && !x_idx.contains(c) && !out_idx.contains(c));
        match victim {
            Some(d) => {
                let cur = y_owned.as_ref().unwrap_or(y);
                y_owned = Some(reduce_mode(cur, d));
                y_idx.remove(d);
                if y_idx.is_empty() {
                    y_idx.push('\u{1}');
                }
            }
            None => break,
        }
    }
    let y: &Tensor = y_owned.as_ref().unwrap_or(y);

    let batch: Vec<char> = x_idx
        .iter()
        .copied()
        .filter(|c| y_idx.contains(c) && out_idx.contains(c))
        .collect();
    let contracted: Vec<char> = x_idx
        .iter()
        .copied()
        .filter(|c| y_idx.contains(c) && !out_idx.contains(c))
        .collect();
    let free_x: Vec<char> = x_idx
        .iter()
        .copied()
        .filter(|c| !y_idx.contains(c) && *c != '\u{1}')
        .collect();
    let free_y: Vec<char> = y_idx
        .iter()
        .copied()
        .filter(|c| !x_idx.contains(c) && *c != '\u{1}')
        .collect();

    let pos = |idx: &[char], c: char| idx.iter().position(|&i| i == c).unwrap();
    let ext_x = |c: char| x.dims()[pos(&x_idx, c)];
    let ext_y = |c: char| y.dims()[pos(&y_idx, c)];
    for &c in &batch {
        if ext_x(c) != ext_y(c) {
            return Err(Error::shape(format!("einsum2: batch extent mismatch '{c}'")));
        }
    }
    for &c in &contracted {
        if ext_x(c) != ext_y(c) {
            return Err(Error::shape(format!("einsum2: contracted extent mismatch '{c}'")));
        }
    }

    // Fold x -> (B, M, K), y -> (B, K, N).
    let perm_x: Vec<usize> = batch
        .iter()
        .chain(free_x.iter())
        .chain(contracted.iter())
        .map(|&c| pos(&x_idx, c))
        .chain(x_idx.iter().enumerate().filter(|(_, &c)| c == '\u{1}').map(|(d, _)| d))
        .collect();
    let perm_y: Vec<usize> = batch
        .iter()
        .chain(contracted.iter())
        .chain(free_y.iter())
        .map(|&c| pos(&y_idx, c))
        .chain(y_idx.iter().enumerate().filter(|(_, &c)| c == '\u{1}').map(|(d, _)| d))
        .collect();
    // Identity permutations fold for free: borrow the original data.
    // Non-identity folds land in pool scratch (freed on return).
    let is_identity = |p: &[usize]| p.iter().enumerate().all(|(i, &q)| i == q);
    let xp_guard = if is_identity(&perm_x) {
        None
    } else {
        let mut buf = pool.take(x.len());
        transpose::permute_into(cfg, x.data(), x.dims(), &perm_x, &mut buf);
        Some(buf)
    };
    let xp_data: &[f32] = match &xp_guard {
        Some(b) => b,
        None => x.data(),
    };
    let yp_guard = if is_identity(&perm_y) {
        None
    } else {
        let mut buf = pool.take(y.len());
        transpose::permute_into(cfg, y.data(), y.dims(), &perm_y, &mut buf);
        Some(buf)
    };
    let yp_data: &[f32] = match &yp_guard {
        Some(b) => b,
        None => y.data(),
    };
    let b: usize = batch.iter().map(|&c| ext_x(c)).product();
    let m: usize = free_x.iter().map(|&c| ext_x(c)).product();
    let kk: usize = contracted.iter().map(|&c| ext_x(c)).product();
    let n: usize = free_y.iter().map(|&c| ext_y(c)).product();

    // Result layout after the batched GEMMs: (batch..., free_x...,
    // free_y...); resolve the output permutation up front so the
    // accumulator can live in pool scratch when a final permute is
    // needed (only the escaping buffer is ever heap-allocated).
    let natural: Vec<char> = batch
        .iter()
        .chain(free_x.iter())
        .chain(free_y.iter())
        .copied()
        .collect();
    let nat_dims: Vec<usize> = natural
        .iter()
        .map(|&c| if free_y.contains(&c) { ext_y(c) } else { ext_x(c) })
        .collect();
    let nat_dims = if nat_dims.is_empty() { vec![1] } else { nat_dims };
    let needs_perm = !natural.is_empty() && natural != out_idx;
    if needs_perm {
        let out_set: std::collections::BTreeSet<char> = out_idx.iter().copied().collect();
        let nat_set: std::collections::BTreeSet<char> = natural.iter().copied().collect();
        if out_set != nat_set {
            return Err(Error::shape(format!(
                "einsum2: output indices {:?} != computed {:?}",
                out_idx, natural
            )));
        }
    }

    if !needs_perm {
        // Result lands in natural layout: accumulate directly into the
        // destination (recycled or freshly owned).
        let mut owned: Vec<f32> = Vec::new();
        let c_data: &mut [f32] = match dest.as_deref_mut() {
            Some(d) => {
                if d.dims() != &nat_dims[..] {
                    return Err(Error::shape(format!(
                        "einsum2_into: dest dims {:?} != result dims {:?}",
                        d.dims(),
                        nat_dims
                    )));
                }
                let s = d.data_mut();
                s.fill(0.0);
                s
            }
            None => {
                owned = vec![0.0f32; b * m * n];
                &mut owned
            }
        };
        for bi in 0..b {
            let xs = &xp_data[bi * m * kk..(bi + 1) * m * kk];
            let ys = &yp_data[bi * kk * n..(bi + 1) * kk * n];
            let cs = &mut c_data[bi * m * n..(bi + 1) * m * n];
            kernel::gemm_into_with(cfg, pool, xs, ys, cs, m, kk, n);
        }
        return match dest {
            Some(_) => Ok(None),
            None => Ok(Some(Tensor::from_vec(&nat_dims, owned)?)),
        };
    }

    // Non-identity output order: accumulate in scratch, permute straight
    // into the escaping (or recycled) buffer.  Validate the destination
    // *before* burning the batched GEMMs on a bad call.
    let perm: Vec<usize> = out_idx
        .iter()
        .map(|&c| natural.iter().position(|&d| d == c).unwrap())
        .collect();
    let out_dims: Vec<usize> = perm.iter().map(|&p| nat_dims[p]).collect();
    if let Some(d) = dest.as_deref_mut() {
        if d.dims() != &out_dims[..] {
            return Err(Error::shape(format!(
                "einsum2_into: dest dims {:?} != result dims {:?}",
                d.dims(),
                out_dims
            )));
        }
    }
    let mut c_scratch = pool.take_zeroed(b * m * n);
    for bi in 0..b {
        let xs = &xp_data[bi * m * kk..(bi + 1) * m * kk];
        let ys = &yp_data[bi * kk * n..(bi + 1) * kk * n];
        let cs = &mut c_scratch[bi * m * n..(bi + 1) * m * n];
        kernel::gemm_into_with(cfg, pool, xs, ys, cs, m, kk, n);
    }
    match dest {
        Some(d) => {
            // The permutation writes every element: no zeroing needed.
            transpose::permute_into(cfg, &c_scratch, &nat_dims, &perm, d.data_mut());
            Ok(None)
        }
        None => {
            let mut out_data = vec![0.0f32; b * m * n];
            transpose::permute_into(cfg, &c_scratch, &nat_dims, &perm, &mut out_data);
            Ok(Some(Tensor::from_vec(&out_dims, out_data)?))
        }
    }
}

/// Two-step MTTKRP (explicit KRP then GEMM) — the communication-suboptimal
/// formulation the CTF-like baseline uses (paper Sec. IV-E).
pub fn mttkrp_two_step(x: &Tensor, factors: &[&Tensor], mode: usize) -> Result<Tensor> {
    let order = x.order();
    let rest: Vec<usize> = (0..order).filter(|&m| m != mode).collect();
    let krp = krp_chain(&rest.iter().map(|&m| factors[m]).collect::<Vec<_>>())?;
    let r = krp.dims()[krp.order() - 1];
    let krp_mat = krp.reshape(&[krp.len() / r, r])?;
    let xm = matricize(x, mode);
    gemm(&xm, &krp_mat)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(dims: &[usize], seed: u64) -> Tensor {
        Tensor::random(dims, seed)
    }

    /// Naive triple-loop GEMM oracle.
    fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = b.dims()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at(&[i, kk]) * b.at(&[kk, j]);
                }
                *c.at_mut(&[i, j]) = s;
            }
        }
        c
    }

    /// Naive elementwise MTTKRP oracle straight from the einsum.
    fn mttkrp_naive(x: &Tensor, factors: &[&Tensor], mode: usize) -> Tensor {
        let order = x.order();
        let rest: Vec<usize> = (0..order).filter(|&m| m != mode).collect();
        let r = factors[rest[0]].dims()[1];
        let mut out = Tensor::zeros(&[x.dims()[mode], r]);
        let dims = x.dims().to_vec();
        let total: usize = dims.iter().product();
        let strides = super::super::strides_of(&dims);
        for flat in 0..total {
            let mut rem = flat;
            let mut idx = vec![0usize; order];
            for d in 0..order {
                idx[d] = rem / strides[d];
                rem %= strides[d];
            }
            for c in 0..r {
                let mut v = x.data()[flat];
                for &m in &rest {
                    v *= factors[m].at(&[idx[m], c]);
                }
                *out.at_mut(&[idx[mode], c]) += v;
            }
        }
        out
    }

    #[test]
    fn gemm_matches_naive() {
        let a = randn(&[17, 23], 1);
        let b = randn(&[23, 9], 2);
        let got = gemm(&a, &b).unwrap();
        assert!(got.allclose(&gemm_naive(&a, &b), 1e-4, 1e-5));
    }

    #[test]
    fn gemm_blocked_path() {
        let a = randn(&[130, 300], 3);
        let b = randn(&[300, 70], 4);
        let got = gemm(&a, &b).unwrap();
        assert!(got.allclose(&gemm_naive(&a, &b), 1e-3, 1e-3));
    }

    #[test]
    fn gemm_packed_matches_scalar_kernel() {
        for (m, k, n) in [(33usize, 65usize, 29usize), (128, 128, 128), (7, 513, 3)] {
            let a = randn(&[m, k], 5);
            let b = randn(&[k, n], 6);
            let mut packed = vec![0.0f32; m * n];
            gemm_into(a.data(), b.data(), &mut packed, m, k, n);
            let mut scalar = vec![0.0f32; m * n];
            gemm_scalar_into(a.data(), b.data(), &mut scalar, m, k, n);
            let got = Tensor::from_vec(&[m, n], packed).unwrap();
            let want = Tensor::from_vec(&[m, n], scalar).unwrap();
            assert!(got.allclose(&want, 1e-3, 1e-3), "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_zero_rich_inputs_match_oracle() {
        // Invariant pinned by the removal of the `aik == 0.0` skip: exact
        // zeros in A (entire rows/cols of them) change nothing.
        let mut a = randn(&[40, 48], 7);
        for (i, v) in a.data_mut().iter_mut().enumerate() {
            if i % 3 == 0 || (i / 48) % 5 == 0 {
                *v = 0.0;
            }
        }
        let b = randn(&[48, 31], 8);
        let got = gemm(&a, &b).unwrap();
        assert!(got.allclose(&gemm_naive(&a, &b), 1e-4, 1e-4));
        let mut scalar = vec![0.0f32; 40 * 31];
        gemm_scalar_into(a.data(), b.data(), &mut scalar, 40, 48, 31);
        let want = Tensor::from_vec(&[40, 31], scalar).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn gemm_rejects_mismatch() {
        let a = randn(&[3, 4], 1);
        let b = randn(&[5, 2], 2);
        assert!(gemm(&a, &b).is_err());
    }

    #[test]
    fn tdot_matches_paper_example() {
        // ijk,jka->ia == tensordot(X, t0, axes=([1,2],[0,1])) (Sec. II-A)
        let x = randn(&[5, 6, 7], 10);
        let t0 = randn(&[6, 7, 4], 11);
        let got = tdot(&x, &t0, &[1, 2], &[0, 1]).unwrap();
        assert_eq!(got.dims(), &[5, 4]);
        // oracle via full loops
        let mut want = Tensor::zeros(&[5, 4]);
        for i in 0..5 {
            for j in 0..6 {
                for k in 0..7 {
                    for a in 0..4 {
                        *want.at_mut(&[i, a]) += x.at(&[i, j, k]) * t0.at(&[j, k, a]);
                    }
                }
            }
        }
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn tdot_full_contraction_scalar() {
        let x = randn(&[3, 4], 20);
        let y = randn(&[3, 4], 21);
        let got = tdot(&x, &y, &[0, 1], &[0, 1]).unwrap();
        assert_eq!(got.dims(), &[1]);
        let want: f32 = x.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        assert!((got.data()[0] - want).abs() < 1e-3);
    }

    #[test]
    fn ttm_all_modes() {
        let x = randn(&[4, 5, 6], 30);
        for mode in 0..3 {
            let u = randn(&[x.dims()[mode], 3], 31 + mode as u64);
            let got = ttm(&x, &u, mode).unwrap();
            let mut want_dims = x.dims().to_vec();
            want_dims[mode] = 3;
            assert_eq!(got.dims(), &want_dims[..]);
            // oracle
            let mut want = Tensor::zeros(&want_dims);
            for i in 0..4 {
                for j in 0..5 {
                    for k in 0..6 {
                        let idx = [i, j, k];
                        for rr in 0..3 {
                            let mut o = idx.to_vec();
                            o[mode] = rr;
                            *want.at_mut(&o) += x.at(&idx) * u.at(&[idx[mode], rr]);
                        }
                    }
                }
            }
            assert!(got.allclose(&want, 1e-4, 1e-4), "mode {mode}");
        }
    }

    #[test]
    fn ttmc_order3() {
        let x = randn(&[4, 5, 6], 40);
        let u0 = randn(&[4, 2], 41);
        let u1 = randn(&[5, 3], 42);
        let u2 = randn(&[6, 2], 43);
        let got = ttmc(&x, &[&u0, &u1, &u2], 1).unwrap();
        assert_eq!(got.dims(), &[2, 5, 2]);
        let step1 = ttm(&x, &u0, 0).unwrap();
        let want = ttm(&step1, &u2, 2).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn krp_chain_shape_and_values() {
        let u0 = randn(&[3, 4], 50);
        let u1 = randn(&[5, 4], 51);
        let k = krp_chain(&[&u0, &u1]).unwrap();
        assert_eq!(k.dims(), &[3, 5, 4]);
        for i in 0..3 {
            for j in 0..5 {
                for c in 0..4 {
                    let want = u0.at(&[i, c]) * u1.at(&[j, c]);
                    assert!((k.at(&[i, j, c]) - want).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn mttkrp_order3_all_modes() {
        let x = randn(&[5, 6, 7], 60);
        let fs: Vec<Tensor> =
            (0..3).map(|m| randn(&[x.dims()[m], 4], 61 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..3 {
            let got = mttkrp(&x, &frefs, mode).unwrap();
            let want = mttkrp_naive(&x, &frefs, mode);
            assert!(got.allclose(&want, 1e-3, 1e-4), "mode {mode}");
        }
    }

    #[test]
    fn mttkrp_order5() {
        let x = randn(&[3, 4, 2, 4, 3], 70);
        let fs: Vec<Tensor> =
            (0..5).map(|m| randn(&[x.dims()[m], 3], 71 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in [0usize, 2, 4] {
            let got = mttkrp(&x, &frefs, mode).unwrap();
            let want = mttkrp_naive(&x, &frefs, mode);
            assert!(got.allclose(&want, 1e-3, 1e-4), "mode {mode}");
        }
    }

    #[test]
    fn mttkrp_parallel_matches_serial() {
        // Big enough to engage the threaded band path.
        let x = randn(&[96, 48, 32], 75);
        let fs: Vec<Tensor> =
            (0..3).map(|m| randn(&[x.dims()[m], 24], 76 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        let pool = ScratchPool::new();
        let cfg1 = KernelConfig::default().serial();
        let cfg4 = KernelConfig::default().with_threads(4);
        for mode in 0..3 {
            let a = mttkrp_with(&cfg1, &pool, &x, &frefs, mode).unwrap();
            let b = mttkrp_with(&cfg4, &pool, &x, &frefs, mode).unwrap();
            assert!(a.allclose(&b, 1e-5, 1e-5), "mode {mode}");
            let want = mttkrp_naive(&x, &frefs, mode);
            assert!(a.allclose(&want, 1e-2, 1e-3), "mode {mode} vs naive");
        }
    }

    #[test]
    fn mttkrp_degenerate_extent_one_dims() {
        let x = randn(&[1, 4, 3], 77);
        let fs: Vec<Tensor> =
            (0..3).map(|m| randn(&[x.dims()[m], 2], 78 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..3 {
            let got = mttkrp(&x, &frefs, mode).unwrap();
            let want = mttkrp_naive(&x, &frefs, mode);
            assert!(got.allclose(&want, 1e-4, 1e-4), "mode {mode}");
        }
    }

    /// Naive einsum2 oracle via full index iteration.
    fn einsum2_naive(
        x: &Tensor,
        x_idx: &[char],
        y: &Tensor,
        y_idx: &[char],
        out_idx: &[char],
    ) -> Tensor {
        use std::collections::BTreeMap;
        let mut ext: BTreeMap<char, usize> = BTreeMap::new();
        for (d, &c) in x_idx.iter().enumerate() {
            ext.insert(c, x.dims()[d]);
        }
        for (d, &c) in y_idx.iter().enumerate() {
            ext.insert(c, y.dims()[d]);
        }
        let all: Vec<char> = ext.keys().copied().collect();
        let out_dims: Vec<usize> = out_idx.iter().map(|c| ext[c]).collect();
        let out_dims = if out_dims.is_empty() { vec![1] } else { out_dims };
        let mut out = Tensor::zeros(&out_dims);
        let total: usize = all.iter().map(|c| ext[c]).product();
        for flat in 0..total {
            let mut rem = flat;
            let mut asn: BTreeMap<char, usize> = BTreeMap::new();
            for &c in all.iter().rev() {
                asn.insert(c, rem % ext[&c]);
                rem /= ext[&c];
            }
            let xi: Vec<usize> = x_idx.iter().map(|c| asn[c]).collect();
            let yi: Vec<usize> = y_idx.iter().map(|c| asn[c]).collect();
            let oi: Vec<usize> = if out_idx.is_empty() {
                vec![0]
            } else {
                out_idx.iter().map(|c| asn[c]).collect()
            };
            *out.at_mut(&oi) += x.at(&xi) * y.at(&yi);
        }
        out
    }

    #[test]
    fn einsum2_pure_matmul() {
        let a = randn(&[7, 9], 100);
        let b = randn(&[9, 5], 101);
        let got = einsum2(&a, &['i', 'j'], &b, &['j', 'k'], &['i', 'k']).unwrap();
        let want = einsum2_naive(&a, &['i', 'j'], &b, &['j', 'k'], &['i', 'k']);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn einsum2_krp_batched_outer() {
        // ja,ka->jka: 'a' is a batch dim, nothing contracted.
        let a = randn(&[6, 4], 102);
        let b = randn(&[5, 4], 103);
        let got = einsum2(&a, &['j', 'a'], &b, &['k', 'a'], &['j', 'k', 'a']).unwrap();
        let want = einsum2_naive(&a, &['j', 'a'], &b, &['k', 'a'], &['j', 'k', 'a']);
        assert_eq!(got.dims(), &[6, 5, 4]);
        assert!(got.allclose(&want, 1e-4, 1e-5));
    }

    #[test]
    fn einsum2_tdot_paper() {
        // ijk,jka->ia
        let x = randn(&[5, 6, 7], 104);
        let t0 = randn(&[6, 7, 4], 105);
        let got =
            einsum2(&x, &['i', 'j', 'k'], &t0, &['j', 'k', 'a'], &['i', 'a']).unwrap();
        let want = einsum2_naive(&x, &['i', 'j', 'k'], &t0, &['j', 'k', 'a'], &['i', 'a']);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn einsum2_output_permutation() {
        let a = randn(&[3, 4], 106);
        let b = randn(&[4, 5], 107);
        let got = einsum2(&a, &['i', 'j'], &b, &['j', 'k'], &['k', 'i']).unwrap();
        let want = einsum2_naive(&a, &['i', 'j'], &b, &['j', 'k'], &['k', 'i']);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn einsum2_private_index_reduced() {
        // ijx,jk->ik: x is private to the left operand and reduced.
        let a = randn(&[3, 4, 5], 108);
        let b = randn(&[4, 6], 109);
        let got = einsum2(&a, &['i', 'j', 'x'], &b, &['j', 'k'], &['i', 'k']).unwrap();
        let want = einsum2_naive(&a, &['i', 'j', 'x'], &b, &['j', 'k'], &['i', 'k']);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn einsum2_full_contraction() {
        let a = randn(&[3, 4], 110);
        let b = randn(&[3, 4], 111);
        let got = einsum2(&a, &['i', 'j'], &b, &['i', 'j'], &[]).unwrap();
        let want: f32 = a.data().iter().zip(b.data()).map(|(x, y)| x * y).sum();
        assert!((got.data()[0] - want).abs() < 1e-3);
    }

    #[test]
    fn einsum2_batched_matmul() {
        // bij,bjk->bik
        let a = randn(&[2, 3, 4], 112);
        let b = randn(&[2, 4, 5], 113);
        let got =
            einsum2(&a, &['b', 'i', 'j'], &b, &['b', 'j', 'k'], &['b', 'i', 'k']).unwrap();
        let want =
            einsum2_naive(&a, &['b', 'i', 'j'], &b, &['b', 'j', 'k'], &['b', 'i', 'k']);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn einsum2_steady_state_uses_pool() {
        // The folds and packing of a repeated einsum2 must stop
        // allocating once the pool is warm.
        let pool = ScratchPool::new();
        let cfg = KernelConfig::default().serial();
        let x = randn(&[24, 18, 12], 114);
        let t0 = randn(&[18, 12, 8], 115);
        for _ in 0..2 {
            let _ = einsum2_with(&cfg, &pool, &x, &['i', 'j', 'k'], &t0, &['j', 'k', 'a'], &['i', 'a'])
                .unwrap();
        }
        let warm = pool.stats().allocs;
        for _ in 0..5 {
            let _ = einsum2_with(&cfg, &pool, &x, &['i', 'j', 'k'], &t0, &['j', 'k', 'a'], &['i', 'a'])
                .unwrap();
        }
        assert_eq!(pool.stats().allocs, warm, "einsum2 steady state allocated");
    }

    #[test]
    #[allow(clippy::type_complexity)]
    fn einsum2_into_bitwise_matches_allocating() {
        // Same dispatch, same arithmetic: the recycled-output variant
        // must be bitwise identical, including the permuted-output path,
        // and must fully overwrite a dirty destination.
        let cases: &[(&[usize], &[char], &[usize], &[char], &[char])] = &[
            (&[7, 9], &['i', 'j'], &[9, 5], &['j', 'k'], &['i', 'k']),
            (&[3, 4], &['i', 'j'], &[4, 5], &['j', 'k'], &['k', 'i']),
            (&[6, 4], &['j', 'a'], &[5, 4], &['k', 'a'], &['j', 'k', 'a']),
            (&[5, 6, 7], &['i', 'j', 'k'], &[6, 7, 4], &['j', 'k', 'a'], &['i', 'a']),
            (&[3, 4], &['i', 'j'], &[3, 4], &['i', 'j'], &[]),
        ];
        for (xd, xi, yd, yi, oi) in cases {
            let x = randn(xd, 300);
            let y = randn(yd, 301);
            let want = einsum2(&x, xi, &y, yi, oi).unwrap();
            let mut dest = randn(want.dims(), 302); // dirty
            einsum2_into(&x, xi, &y, yi, oi, &mut dest).unwrap();
            assert_eq!(dest, want, "{xi:?},{yi:?}->{oi:?}");
        }
    }

    #[test]
    fn einsum2_into_rejects_wrong_dest_dims() {
        let x = randn(&[3, 4], 310);
        let y = randn(&[4, 5], 311);
        let mut bad = Tensor::zeros(&[4, 4]);
        assert!(einsum2_into(&x, &['i', 'j'], &y, &['j', 'k'], &['i', 'k'], &mut bad).is_err());
        let mut bad_perm = Tensor::zeros(&[3, 5]); // permuted result is [5, 3]
        assert!(
            einsum2_into(&x, &['i', 'j'], &y, &['j', 'k'], &['k', 'i'], &mut bad_perm).is_err()
        );
    }

    #[test]
    fn mttkrp_into_bitwise_matches_allocating() {
        let x = randn(&[6, 5, 4], 320);
        let fs: Vec<Tensor> =
            (0..3).map(|m| randn(&[x.dims()[m], 5], 321 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..3 {
            let want = mttkrp(&x, &frefs, mode).unwrap();
            let mut dest = randn(want.dims(), 330); // dirty
            mttkrp_into(&x, &frefs, mode, &mut dest).unwrap();
            assert_eq!(dest, want, "mode {mode}");
        }
        let mut bad = Tensor::zeros(&[6, 6]);
        assert!(mttkrp_into(&x, &frefs, 0, &mut bad).is_err());
    }

    #[test]
    fn reduce_mode_sums() {
        let t = randn(&[3, 4, 5], 114);
        let r = reduce_mode(&t, 1);
        assert_eq!(r.dims(), &[3, 5]);
        let mut want = Tensor::zeros(&[3, 5]);
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    *want.at_mut(&[i, k]) += t.at(&[i, j, k]);
                }
            }
        }
        assert!(r.allclose(&want, 1e-5, 1e-5));
    }

    #[test]
    fn einsum2_fully_summed_operand_terminates_and_scales() {
        // Regression: an operand whose indices are ALL summed away
        // collapses to the synthetic singleton ('\u{1}'); the victim
        // search used to re-select that singleton forever (hang).  The
        // result is the other operand scaled by the full sum.
        let x = randn(&[4, 3], 140);
        let y = randn(&[2, 5], 141);
        let s: f32 = y.data().iter().sum();
        let mut want = x.clone();
        for v in want.data_mut().iter_mut() {
            *v *= s;
        }
        let got = einsum2(&x, &['i', 'j'], &y, &['k', 'l'], &['i', 'j']).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4), "rel {}", got.rel_error(&want));
        // Symmetric: the singleton on the x side.
        let got2 = einsum2(&y, &['k', 'l'], &x, &['i', 'j'], &['i', 'j']).unwrap();
        assert!(got2.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn reduce_modes_into_matches_chained_reduce_mode() {
        // Multi-mode single-pass sum vs the chained one-mode oracle, on
        // every drop subset of an order-4 tensor, writing through a
        // dirty recycled-style destination.
        let t = randn(&[3, 4, 2, 5], 117);
        for drop_mask in 1u32..(1 << 4) {
            let drop: Vec<usize> = (0..4).filter(|d| drop_mask & (1 << d) != 0).collect();
            // Oracle: drop modes one at a time (descending so positions
            // stay valid).
            let mut want = t.clone();
            for &d in drop.iter().rev() {
                want = reduce_mode(&want, d);
            }
            let mut dest = randn(want.dims(), 118); // dirty
            reduce_modes_into(&t, &drop, &mut dest).unwrap();
            assert!(
                dest.allclose(&want, 1e-4, 1e-4),
                "drop {drop:?}: max diff {}",
                dest.max_abs_diff(&want)
            );
        }
        // Shape mismatch is a typed error, not a panic.
        let mut bad = Tensor::zeros(&[3, 4]);
        assert!(reduce_modes_into(&t, &[0], &mut bad).is_err());
    }

    #[test]
    fn fused_equals_two_step() {
        let x = randn(&[6, 5, 4], 80);
        let fs: Vec<Tensor> =
            (0..3).map(|m| randn(&[x.dims()[m], 5], 81 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..3 {
            let fused = mttkrp(&x, &frefs, mode).unwrap();
            let two = mttkrp_two_step(&x, &frefs, mode).unwrap();
            assert!(fused.allclose(&two, 1e-3, 1e-4), "mode {mode}");
        }
    }
}
