//! Dense tensor substrate: row-major `f32` tensors, strided block access,
//! HPTT-lite transposition, and native contraction kernels.
//!
//! This is the local-compute substrate under the coordinator: the PJRT
//! runtime handles bucketed tile shapes, and these native kernels are the
//! exact-shape fallback (and the oracle used in integration tests).
//!
//! The paper evaluates in `C^n` on Piz Daint with MKL/cuTENSOR locals; we
//! standardize on `f32` (the artifacts' dtype) — the data-movement
//! analysis is dtype-agnostic.

pub mod contract;
pub mod kernel;
pub mod transpose;

pub use kernel::{KernelConfig, ScratchPool, ScratchStats};

use crate::error::{Error, Result};

/// Bytes per tensor element (`f32`).  The single constant every byte
/// accounting in the crate derives from (communication volumes, α–β
/// costs, per-rank footprints) — the dtype appears in exactly one place.
pub const ELEM_BYTES: usize = std::mem::size_of::<f32>();

/// Dense row-major tensor of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

/// Row-major strides for `dims`.
pub fn strides_of(dims: &[usize]) -> Vec<usize> {
    let n = dims.len();
    let mut s = vec![1usize; n];
    for i in (0..n.saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

impl Tensor {
    /// All-zeros tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        let len = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![0.0; len] }
    }

    /// Build from raw row-major data.
    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Result<Self> {
        let len: usize = dims.iter().product();
        if data.len() != len {
            return Err(Error::shape(format!(
                "data length {} != product of dims {:?}",
                data.len(),
                dims
            )));
        }
        Ok(Tensor { dims: dims.to_vec(), data })
    }

    /// Deterministic pseudo-random tensor in [-1, 1) (splitmix64-seeded
    /// xorshift; no rand dependency, reproducible across platforms).
    ///
    /// The seed goes through two splitmix64 avalanche rounds: xorshift is
    /// GF(2)-linear, so *raw* sequential seeds (1, 2, 3, ...) would
    /// produce linearly-related — i.e. statistically correlated —
    /// streams, which breaks downstream consumers like the CP-ALS
    /// example (near-collinear factors stall the decomposition).
    pub fn random(dims: &[usize], seed: u64) -> Self {
        fn splitmix(mut z: u64) -> u64 {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        }
        let len: usize = dims.iter().product();
        let mut state = splitmix(splitmix(seed)) | 1;
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // map to [-1, 1)
            data.push(((state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32);
        }
        Tensor { dims: dims.to_vec(), data }
    }

    /// Tensor order (number of modes).
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Mode extents.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into raw data.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Element accessor (debug/test convenience; not a hot path).
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.dims.len());
        let s = strides_of(&self.dims);
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        self.data[off]
    }

    /// Mutable element accessor.
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let s = strides_of(&self.dims);
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        &mut self.data[off]
    }

    /// Reinterpret with new dims of equal product (row-major reshape).
    pub fn reshape(&self, dims: &[usize]) -> Result<Tensor> {
        let len: usize = dims.iter().product();
        if len != self.data.len() {
            return Err(Error::shape(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Tensor { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Extract the block `[off[d] .. off[d]+size[d])` in every dim.
    /// Out-of-range tails are zero-padded (bucketed PJRT dispatch relies on
    /// this: padding with zeros is exact for multiply-add contractions).
    pub fn block(&self, off: &[usize], size: &[usize]) -> Tensor {
        debug_assert_eq!(off.len(), self.dims.len());
        let mut out = Tensor::zeros(size);
        out.copy_box_from(self, off, &vec![0; size.len()], size);
        out
    }

    /// Write `blk` into this tensor at offset `off` (inverse of `block`;
    /// clips to bounds so padded buckets round-trip).
    pub fn set_block(&mut self, off: &[usize], blk: &Tensor) {
        debug_assert_eq!(off.len(), self.dims.len());
        debug_assert_eq!(blk.dims.len(), self.dims.len());
        self.copy_box_from(blk, &vec![0; blk.dims.len()], off, &blk.dims);
    }

    /// Permute modes (out-of-place, cache-blocked, multithreaded; see
    /// [`transpose`]).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        transpose::permute(self, perm)
    }

    /// [`permute`](Self::permute) into a caller-provided destination
    /// whose dims must equal the permuted dims — the recycled-buffer
    /// variant the coordinator's steady state uses (a permutation writes
    /// every destination element, so `out` needs no zeroing).
    pub fn permute_into(&self, perm: &[usize], out: &mut Tensor) -> Result<()> {
        let n = self.dims.len();
        if perm.len() != n {
            return Err(Error::shape(format!(
                "permute_into: perm length {} != order {n}",
                perm.len()
            )));
        }
        let mut seen = vec![false; n];
        for &p in perm {
            if p >= n || std::mem::replace(&mut seen[p], true) {
                return Err(Error::shape(format!("permute_into: bad perm {perm:?}")));
            }
        }
        let want: Vec<usize> = perm.iter().map(|&p| self.dims[p]).collect();
        if out.dims != want {
            return Err(Error::shape(format!(
                "permute_into: dest dims {:?} != permuted dims {:?}",
                out.dims, want
            )));
        }
        transpose::permute_into(
            &KernelConfig::global(),
            &self.data,
            &self.dims,
            perm,
            &mut out.data,
        );
        Ok(())
    }

    /// Shape-checked whole-tensor copy from `src` (recycled-buffer
    /// helper: refresh a destination without reallocating it).
    pub fn copy_from(&mut self, src: &Tensor) -> Result<()> {
        if self.dims != src.dims {
            return Err(Error::shape(format!(
                "copy_from: dest dims {:?} != src dims {:?}",
                self.dims, src.dims
            )));
        }
        self.data.copy_from_slice(&src.data);
        Ok(())
    }

    /// Copy the box `src[src_off .. src_off+size]` into
    /// `self[dst_off .. dst_off+size]` directly — the redistribution data
    /// path (one contiguous memcpy per innermost run, no temporary block
    /// tensor).  Out-of-range spans on either side are clipped, matching
    /// `block`/`set_block` zero-pad semantics when the destination starts
    /// zeroed.
    pub fn copy_box_from(
        &mut self,
        src: &Tensor,
        src_off: &[usize],
        dst_off: &[usize],
        size: &[usize],
    ) {
        let n = self.dims.len();
        debug_assert_eq!(src.dims.len(), n);
        debug_assert_eq!(src_off.len(), n);
        debug_assert_eq!(dst_off.len(), n);
        debug_assert_eq!(size.len(), n);
        if n == 0 {
            return;
        }
        let inner = size[n - 1]
            .min(src.dims[n - 1].saturating_sub(src_off[n - 1]))
            .min(self.dims[n - 1].saturating_sub(dst_off[n - 1]));
        if inner == 0 {
            return;
        }
        let src_strides = strides_of(&src.dims);
        let dst_strides = strides_of(&self.dims);
        let outer_dims = &size[..n - 1];
        let total_outer: usize = outer_dims.iter().product();
        let mut idx = vec![0usize; n - 1];
        for _ in 0..total_outer {
            let mut in_range = true;
            let mut s = src_off[n - 1];
            let mut d = dst_off[n - 1];
            for q in 0..n - 1 {
                let si = src_off[q] + idx[q];
                let di = dst_off[q] + idx[q];
                if si >= src.dims[q] || di >= self.dims[q] {
                    in_range = false;
                    break;
                }
                s += si * src_strides[q];
                d += di * dst_strides[q];
            }
            if in_range {
                self.data[d..d + inner].copy_from_slice(&src.data[s..s + inner]);
            }
            for q in (0..n - 1).rev() {
                idx[q] += 1;
                if idx[q] < outer_dims[q] {
                    break;
                }
                idx[q] = 0;
            }
        }
    }

    /// In-place accumulate: `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.dims != other.dims {
            return Err(Error::shape(format!(
                "add_assign {:?} += {:?}",
                self.dims, other.dims
            )));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius error ||a - b|| / ||b||.
    pub fn rel_error(&self, other: &Tensor) -> f64 {
        let mut num = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a as f64) - (*b as f64);
            num += d * d;
        }
        let den = other.norm().max(1e-30);
        num.sqrt() / den
    }

    /// Approximate equality within atol + rtol*|b| per element.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.dims == other.dims
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_of(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_of(&[5]), vec![1]);
        assert_eq!(strides_of(&[]), Vec::<usize>::new());
    }

    #[test]
    fn zeros_and_len() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 5]).is_err());
    }

    #[test]
    fn at_indexing() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn random_is_deterministic() {
        let a = Tensor::random(&[4, 4], 7);
        let b = Tensor::random(&[4, 4], 7);
        let c = Tensor::random(&[4, 4], 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.data().iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn block_interior() {
        let t = Tensor::from_vec(&[4, 4], (0..16).map(|x| x as f32).collect()).unwrap();
        let b = t.block(&[1, 1], &[2, 2]);
        assert_eq!(b.data(), &[5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn block_zero_pads_tail() {
        let t = Tensor::from_vec(&[3, 3], (0..9).map(|x| x as f32).collect()).unwrap();
        let b = t.block(&[2, 2], &[2, 2]);
        assert_eq!(b.data(), &[8.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn copy_box_from_matches_block_set_block() {
        let src = Tensor::from_vec(&[4, 6], (0..24).map(|x| x as f32).collect()).unwrap();
        // direct path
        let mut direct = Tensor::zeros(&[5, 5]);
        direct.copy_box_from(&src, &[1, 2], &[2, 1], &[2, 3]);
        // temp-block path
        let mut via_block = Tensor::zeros(&[5, 5]);
        via_block.set_block(&[2, 1], &src.block(&[1, 2], &[2, 3]));
        assert_eq!(direct, via_block);
        // clipping on both sides
        let mut clipped = Tensor::zeros(&[3, 3]);
        clipped.copy_box_from(&src, &[3, 4], &[2, 2], &[2, 3]);
        assert_eq!(clipped.at(&[2, 2]), src.at(&[3, 4]));
        assert_eq!(clipped.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn block_set_block_roundtrip() {
        let mut t = Tensor::zeros(&[4, 6]);
        let blk = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        t.set_block(&[2, 3], &blk);
        let back = t.block(&[2, 3], &[2, 3]);
        assert_eq!(back, blk);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[2, 3]), 1.0);
        assert_eq!(t.at(&[3, 5]), 6.0);
    }

    #[test]
    fn set_block_clips() {
        let mut t = Tensor::zeros(&[3, 3]);
        let blk = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        t.set_block(&[2, 2], &blk); // only [2,2] in range
        assert_eq!(t.at(&[2, 2]), 1.0);
        assert_eq!(t.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn block_order3() {
        let t = Tensor::from_vec(&[2, 3, 4], (0..24).map(|x| x as f32).collect()).unwrap();
        let b = t.block(&[1, 1, 2], &[1, 2, 2]);
        assert_eq!(b.dims(), &[1, 2, 2]);
        assert_eq!(b.at(&[0, 0, 0]), t.at(&[1, 1, 2]));
        assert_eq!(b.at(&[0, 1, 1]), t.at(&[1, 2, 3]));
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|x| x as f32).collect()).unwrap();
        let r = t.reshape(&[3, 4]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn add_assign_and_norm() {
        let mut a = Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![0.0, 0.0]).unwrap();
        a.add_assign(&b).unwrap();
        assert!((a.norm() - 5.0).abs() < 1e-9);
        let c = Tensor::zeros(&[3]);
        assert!(a.add_assign(&c).is_err());
    }

    #[test]
    fn permute_into_matches_permute_and_checks_shapes() {
        let t = Tensor::random(&[3, 4, 5], 11);
        let perm = [2, 0, 1];
        let want = t.permute(&perm);
        // Dirty destination: permute_into must fully overwrite it.
        let mut out = Tensor::random(&[5, 3, 4], 12);
        t.permute_into(&perm, &mut out).unwrap();
        assert_eq!(out, want);
        let mut bad = Tensor::zeros(&[3, 4, 5]);
        assert!(t.permute_into(&perm, &mut bad).is_err(), "wrong dest dims");
        assert!(t.permute_into(&[0, 1], &mut out).is_err(), "wrong perm length");
        assert!(t.permute_into(&[0, 0, 1], &mut out).is_err(), "duplicate perm entry");
    }

    #[test]
    fn copy_from_checks_shape() {
        let src = Tensor::random(&[2, 3], 13);
        let mut dst = Tensor::zeros(&[2, 3]);
        dst.copy_from(&src).unwrap();
        assert_eq!(dst, src);
        let mut bad = Tensor::zeros(&[3, 2]);
        assert!(bad.copy_from(&src).is_err());
    }

    #[test]
    fn allclose_and_rel_error() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let mut b = a.clone();
        assert!(a.allclose(&b, 1e-6, 1e-6));
        b.data_mut()[0] += 1e-3;
        assert!(!a.allclose(&b, 1e-6, 1e-6));
        assert!(a.rel_error(&b) < 1e-2);
    }
}
