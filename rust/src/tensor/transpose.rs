//! HPTT-lite: blocked, multithreaded out-of-place tensor transposition.
//!
//! The paper links both Deinsum and CTF against HPTT for out-of-place mode
//! permutations (Sec. VI-A); every fold-to-GEMM lowering needs one.  This
//! is a compact reimplementation on the engine's blocking/threading scheme
//! ([`super::kernel`]): odometer iteration over all-but-two modes, a
//! cache-blocked 2D kernel over (src-innermost, dst-innermost) so one side
//! always streams contiguously, and the work units (rest-index × a-block)
//! submitted to the persistent work-stealing pool
//! ([`crate::runtime::pool`]) as stealable chunks — no thread spawns per
//! permutation, and bitwise-identical output for any thread count.
//! A permutation writes every destination
//! element exactly once, so any partition of the unit space has disjoint
//! writes — the parallel path shares the output through a raw pointer
//! under that invariant.

use super::kernel::{parallel_units, KernelConfig, SendMutPtr};
use super::{strides_of, Tensor};

/// Cache block edge for the 2D transpose microkernel (f32: 32x32 = 4 KiB
/// per tile side, comfortably L1-resident).
const BLOCK: usize = 32;

/// Tensors below this element count transpose serially (thread spawn
/// costs more than the copy).
const PARALLEL_ELEM_CUTOFF: usize = 1 << 15;

/// Permute tensor modes: `out[i_{perm[0]}, ..., i_{perm[n-1]}] = in[i_0, ..., i_{n-1}]`.
///
/// `perm[d]` is the source mode that lands in destination mode `d`
/// (numpy's `transpose` convention).
pub fn permute(t: &Tensor, perm: &[usize]) -> Tensor {
    permute_with(&KernelConfig::global(), t, perm)
}

/// [`permute`] with an explicit engine config (benches compare serial vs
/// threaded through this).
pub fn permute_with(cfg: &KernelConfig, t: &Tensor, perm: &[usize]) -> Tensor {
    let src_dims = t.dims();
    let dst_dims: Vec<usize> = perm.iter().map(|&p| src_dims[p]).collect();
    let mut out = vec![0.0f32; t.len()];
    permute_into(cfg, t.data(), src_dims, perm, &mut out);
    Tensor::from_vec(&dst_dims, out).unwrap()
}

/// Core permutation into a caller-provided buffer (the coordinator's hot
/// path feeds pool-backed scratch here so mode folds allocate nothing).
/// `out.len()` must be at least the element count.
pub fn permute_into(
    cfg: &KernelConfig,
    src: &[f32],
    src_dims: &[usize],
    perm: &[usize],
    out: &mut [f32],
) {
    let n = src_dims.len();
    assert_eq!(perm.len(), n, "perm length mismatch");
    debug_assert!({
        let mut seen = vec![false; n];
        perm.iter().all(|&p| p < n && !std::mem::replace(&mut seen[p], true))
    });
    let total: usize = src_dims.iter().product();
    debug_assert!(src.len() >= total && out.len() >= total);
    if total == 0 {
        return;
    }
    if n <= 1 || perm.iter().enumerate().all(|(i, &p)| i == p) {
        out[..total].copy_from_slice(&src[..total]);
        return;
    }

    let src_strides = strides_of(src_dims);
    let dst_dims: Vec<usize> = perm.iter().map(|&p| src_dims[p]).collect();
    let dst_strides = strides_of(&dst_dims);
    // Stride of each *source* mode in the destination layout.
    let mut dst_stride_of_src = vec![0usize; n];
    for (d, &p) in perm.iter().enumerate() {
        dst_stride_of_src[p] = dst_strides[d];
    }

    let threads = if total < PARALLEL_ELEM_CUTOFF { 1 } else { cfg.threads };
    let ptr = SendMutPtr(out.as_mut_ptr());

    // The two "fast" modes: source innermost (contiguous reads) and the
    // source mode that is destination-innermost (contiguous writes).
    let src_inner = n - 1;
    let dst_inner_src_mode = perm[n - 1];

    if dst_inner_src_mode == src_inner {
        // Innermost mode unchanged: copy contiguous runs.  Units are the
        // outer odometer positions; each unit owns one disjoint run.
        let run = src_dims[src_inner];
        let outer = total / run.max(1);
        let outer_dims = &src_dims[..n - 1];
        parallel_units(threads, outer, 64, |u0, u1| {
            for u in u0..u1 {
                let mut rem = u;
                let mut s = 0usize;
                let mut d = 0usize;
                for m in (0..n - 1).rev() {
                    let c = rem % outer_dims[m];
                    rem /= outer_dims[m];
                    s += c * src_strides[m];
                    d += c * dst_stride_of_src[m];
                }
                // SAFETY: distinct units have distinct outer coords, so
                // their destination runs are disjoint (permutation).
                unsafe {
                    std::ptr::copy_nonoverlapping(src.as_ptr().add(s), ptr.0.add(d), run);
                }
            }
        });
        return;
    }

    // General case: 2D blocked kernel over (a, b) = (dst-inner source
    // mode, src-inner mode); units are (rest odometer position, a-block).
    let a_mode = dst_inner_src_mode;
    let b_mode = src_inner;
    let na = src_dims[a_mode];
    let nb = src_dims[b_mode];
    let sa_src = src_strides[a_mode];
    // b is src innermost: stride 1 in src. a is dst innermost: stride 1 in dst.
    let sb_dst = dst_stride_of_src[b_mode];

    let rest: Vec<usize> = (0..n).filter(|&m| m != a_mode && m != b_mode).collect();
    let rest_dims: Vec<usize> = rest.iter().map(|&m| src_dims[m]).collect();
    let rest_total: usize = rest_dims.iter().product::<usize>().max(1);
    let n_ablocks = na.div_ceil(BLOCK);
    let units = rest_total * n_ablocks;

    parallel_units(threads, units, 4, |u0, u1| {
        for u in u0..u1 {
            let rest_idx = u / n_ablocks;
            let ab = u % n_ablocks;
            let a0 = ab * BLOCK;
            let a1 = (a0 + BLOCK).min(na);
            let mut rem = rest_idx;
            let mut base_s = 0usize;
            let mut base_d = 0usize;
            for q in (0..rest.len()).rev() {
                let c = rem % rest_dims[q];
                rem /= rest_dims[q];
                base_s += c * src_strides[rest[q]];
                base_d += c * dst_stride_of_src[rest[q]];
            }
            // Blocked 2D transpose: src[a*sa_src + b], dst[b*sb_dst + a].
            // Inner loop runs over `a` so the *writes* are contiguous.
            let mut b0 = 0usize;
            while b0 < nb {
                let b1 = (b0 + BLOCK).min(nb);
                for b in b0..b1 {
                    let d_row = base_d + b * sb_dst;
                    let s_col = base_s + b;
                    for a in a0..a1 {
                        // SAFETY: (rest, a, b) ↦ d_row + a is injective
                        // over the whole iteration space (permutation),
                        // and units partition (rest, a-block) disjointly.
                        unsafe {
                            *ptr.0.add(d_row + a) = src[s_col + a * sa_src];
                        }
                    }
                }
                b0 = b1;
            }
        }
    });
}

/// Mode-n matricization (paper Sec. III-B): permute so `mode` leads, then
/// flatten the rest — returns an (I_mode, prod rest) matrix.
pub fn matricize(t: &Tensor, mode: usize) -> Tensor {
    let n = t.order();
    let mut perm = Vec::with_capacity(n);
    perm.push(mode);
    perm.extend((0..n).filter(|&m| m != mode));
    let p = permute(t, &perm);
    let rows = t.dims()[mode];
    let cols = t.len() / rows.max(1);
    p.reshape(&[rows, cols]).unwrap()
}

/// Inverse of [`matricize`]: fold an (I_mode, prod rest) matrix back into
/// a tensor with extents `dims`, placing rows in `mode`.
pub fn dematricize(m: &Tensor, dims: &[usize], mode: usize) -> Tensor {
    let n = dims.len();
    let mut permuted_dims = Vec::with_capacity(n);
    permuted_dims.push(dims[mode]);
    permuted_dims.extend((0..n).filter(|&d| d != mode).map(|d| dims[d]));
    let t = m.reshape(&permuted_dims).unwrap();
    // inverse permutation of [mode, rest...]
    let fwd: Vec<usize> = std::iter::once(mode).chain((0..n).filter(|&d| d != mode)).collect();
    let mut inv = vec![0usize; n];
    for (pos, &d) in fwd.iter().enumerate() {
        inv[d] = pos;
    }
    permute(&t, &inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(dims: &[usize]) -> Tensor {
        let len: usize = dims.iter().product();
        Tensor::from_vec(dims, (0..len).map(|x| x as f32).collect()).unwrap()
    }

    /// Elementwise oracle for permute.
    fn permute_naive(t: &Tensor, perm: &[usize]) -> Tensor {
        let src_dims = t.dims();
        let dst_dims: Vec<usize> = perm.iter().map(|&p| src_dims[p]).collect();
        let mut out = Tensor::zeros(&dst_dims);
        let n = src_dims.len();
        let total = t.len();
        let src_strides = strides_of(src_dims);
        for flat in 0..total {
            let mut rem = flat;
            let mut idx = vec![0usize; n];
            for d in 0..n {
                idx[d] = rem / src_strides[d];
                rem %= src_strides[d];
            }
            let dst_idx: Vec<usize> = perm.iter().map(|&p| idx[p]).collect();
            *out.at_mut(&dst_idx) = t.data()[flat];
        }
        out
    }

    #[test]
    fn matrix_transpose() {
        let t = seq(&[3, 5]);
        let tt = permute(&t, &[1, 0]);
        assert_eq!(tt.dims(), &[5, 3]);
        for i in 0..3 {
            for j in 0..5 {
                assert_eq!(tt.at(&[j, i]), t.at(&[i, j]));
            }
        }
    }

    #[test]
    fn identity_perm_is_copy() {
        let t = seq(&[4, 6]);
        assert_eq!(permute(&t, &[0, 1]), t);
    }

    #[test]
    fn all_order3_perms_match_naive() {
        let t = seq(&[3, 4, 5]);
        for perm in [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]] {
            assert_eq!(permute(&t, &perm), permute_naive(&t, &perm), "{perm:?}");
        }
    }

    #[test]
    fn order4_blocked_path() {
        let t = seq(&[6, 40, 5, 36]); // > BLOCK in two modes
        let perm = [3, 1, 0, 2];
        assert_eq!(permute(&t, &perm), permute_naive(&t, &perm));
    }

    #[test]
    fn innermost_fixed_fast_path() {
        let t = seq(&[7, 8, 33]);
        let perm = [1, 0, 2];
        assert_eq!(permute(&t, &perm), permute_naive(&t, &perm));
    }

    #[test]
    fn large_blocked_transpose() {
        let t = seq(&[65, 70]);
        assert_eq!(permute(&t, &[1, 0]), permute_naive(&t, &[1, 0]));
    }

    #[test]
    fn parallel_matches_serial_above_cutoff() {
        // Big enough to engage the threaded paths in both kernels.
        let cfg1 = KernelConfig::default().serial();
        let cfg4 = KernelConfig::default().with_threads(4);
        for (dims, perm) in [
            (vec![96usize, 64, 48], vec![2usize, 1, 0]), // blocked path
            (vec![96, 64, 48], vec![1, 0, 2]),           // inner-fixed path
            (vec![512, 600], vec![1, 0]),                // matrix transpose
            (vec![3, 4, 7, 9, 11, 5], vec![5, 3, 1, 4, 2, 0]), // high order
        ] {
            let t = Tensor::random(&dims, 99);
            let a = permute_with(&cfg1, &t, &perm);
            let b = permute_with(&cfg4, &t, &perm);
            assert_eq!(a, b, "{dims:?} {perm:?}");
            assert_eq!(a, permute_naive(&t, &perm), "{dims:?} {perm:?} vs naive");
        }
    }

    #[test]
    fn degenerate_extents() {
        for dims in [vec![1usize, 5, 1], vec![1, 1, 1], vec![5, 1, 3]] {
            let t = seq(&dims);
            let perm = [2, 0, 1];
            assert_eq!(permute(&t, &perm), permute_naive(&t, &perm), "{dims:?}");
        }
    }

    #[test]
    fn matricize_mode0_is_reshape() {
        let t = seq(&[3, 4, 5]);
        let m = matricize(&t, 0);
        assert_eq!(m.dims(), &[3, 20]);
        assert_eq!(m.data(), t.data());
    }

    #[test]
    fn matricize_mode1() {
        let t = seq(&[3, 4, 5]);
        let m = matricize(&t, 1);
        assert_eq!(m.dims(), &[4, 15]);
        assert_eq!(m.at(&[2, 7]), t.at(&[1, 2, 2])); // col 7 = (i=1, k=2)
    }

    #[test]
    fn matricize_dematricize_roundtrip() {
        let t = seq(&[3, 4, 5]);
        for mode in 0..3 {
            let m = matricize(&t, mode);
            let back = dematricize(&m, t.dims(), mode);
            assert_eq!(back, t, "mode {mode}");
        }
    }
}
