//! HPTT-lite: blocked out-of-place tensor transposition.
//!
//! The paper links both Deinsum and CTF against HPTT for out-of-place mode
//! permutations (Sec. VI-A); every fold-to-GEMM lowering needs one.  This
//! is a compact reimplementation: odometer iteration over all-but-two
//! modes, with a cache-blocked 2D kernel over (src-innermost,
//! dst-innermost) so one side always streams contiguously.

use super::{strides_of, Tensor};

/// Cache block edge for the 2D transpose microkernel (f32: 32x32 = 4 KiB
/// per tile side, comfortably L1-resident).
const BLOCK: usize = 32;

/// Permute tensor modes: `out[i_{perm[0]}, ..., i_{perm[n-1]}] = in[i_0, ..., i_{n-1}]`.
///
/// `perm[d]` is the source mode that lands in destination mode `d`
/// (numpy's `transpose` convention).
pub fn permute(t: &Tensor, perm: &[usize]) -> Tensor {
    let n = t.order();
    assert_eq!(perm.len(), n, "perm length mismatch");
    debug_assert!({
        let mut seen = vec![false; n];
        perm.iter().all(|&p| p < n && !std::mem::replace(&mut seen[p], true))
    });

    let src_dims = t.dims();
    let dst_dims: Vec<usize> = perm.iter().map(|&p| src_dims[p]).collect();
    if n <= 1 || perm.iter().enumerate().all(|(i, &p)| i == p) {
        return Tensor::from_vec(&dst_dims, t.data().to_vec()).unwrap();
    }

    let src_strides = strides_of(src_dims);
    let dst_strides = strides_of(&dst_dims);
    // Stride of each *source* mode in the destination layout.
    let mut dst_stride_of_src = vec![0usize; n];
    for (d, &p) in perm.iter().enumerate() {
        dst_stride_of_src[p] = dst_strides[d];
    }

    let mut out = vec![0.0f32; t.len()];
    let src = t.data();

    // The two "fast" modes: source innermost (contiguous reads) and the
    // source mode that is destination-innermost (contiguous writes).
    let src_inner = n - 1;
    let dst_inner_src_mode = perm[n - 1];

    if dst_inner_src_mode == src_inner {
        // Innermost mode unchanged: copy contiguous runs.
        let run = src_dims[src_inner];
        let outer: usize = t.len() / run.max(1);
        let mut idx = vec![0usize; n - 1];
        for _ in 0..outer {
            let mut s = 0usize;
            let mut d = 0usize;
            for m in 0..n - 1 {
                s += idx[m] * src_strides[m];
                d += idx[m] * dst_stride_of_src[m];
            }
            out[d..d + run].copy_from_slice(&src[s..s + run]);
            for m in (0..n - 1).rev() {
                idx[m] += 1;
                if idx[m] < src_dims[m] {
                    break;
                }
                idx[m] = 0;
            }
        }
        return Tensor::from_vec(&dst_dims, out).unwrap();
    }

    // General case: 2D blocked kernel over (a, b) = (dst-inner source
    // mode, src-inner mode); odometer over the remaining modes.
    let a_mode = dst_inner_src_mode;
    let b_mode = src_inner;
    let na = src_dims[a_mode];
    let nb = src_dims[b_mode];
    let sa_src = src_strides[a_mode];
    // b is src innermost: stride 1 in src. a is dst innermost: stride 1 in dst.
    let sb_dst = dst_stride_of_src[b_mode];

    let rest: Vec<usize> = (0..n).filter(|&m| m != a_mode && m != b_mode).collect();
    let rest_dims: Vec<usize> = rest.iter().map(|&m| src_dims[m]).collect();
    let rest_total: usize = rest_dims.iter().product();
    let mut idx = vec![0usize; rest.len()];

    for _ in 0..rest_total.max(1) {
        let mut base_s = 0usize;
        let mut base_d = 0usize;
        for (r, &m) in rest.iter().enumerate() {
            base_s += idx[r] * src_strides[m];
            base_d += idx[r] * dst_stride_of_src[m];
        }
        // Blocked 2D transpose: src[a*sa_src + b], dst[b*sb_dst + a].
        // Inner loop runs over `a` so the *writes* are contiguous (the
        // destination is written exactly once, while the strided reads
        // overlap via hardware prefetch across the block's rows).
        let mut a0 = 0;
        while a0 < na {
            let a1 = (a0 + BLOCK).min(na);
            let mut b0 = 0;
            while b0 < nb {
                let b1 = (b0 + BLOCK).min(nb);
                for b in b0..b1 {
                    let d_row = base_d + b * sb_dst;
                    let s_col = base_s + b;
                    for a in a0..a1 {
                        out[d_row + a] = src[s_col + a * sa_src];
                    }
                }
                b0 = b1;
            }
            a0 = a1;
        }
        for r in (0..rest.len()).rev() {
            idx[r] += 1;
            if idx[r] < rest_dims[r] {
                break;
            }
            idx[r] = 0;
        }
    }
    Tensor::from_vec(&dst_dims, out).unwrap()
}

/// Mode-n matricization (paper Sec. III-B): permute so `mode` leads, then
/// flatten the rest — returns an (I_mode, prod rest) matrix.
pub fn matricize(t: &Tensor, mode: usize) -> Tensor {
    let n = t.order();
    let mut perm = Vec::with_capacity(n);
    perm.push(mode);
    perm.extend((0..n).filter(|&m| m != mode));
    let p = permute(t, &perm);
    let rows = t.dims()[mode];
    let cols = t.len() / rows.max(1);
    p.reshape(&[rows, cols]).unwrap()
}

/// Inverse of [`matricize`]: fold an (I_mode, prod rest) matrix back into
/// a tensor with extents `dims`, placing rows in `mode`.
pub fn dematricize(m: &Tensor, dims: &[usize], mode: usize) -> Tensor {
    let n = dims.len();
    let mut permuted_dims = Vec::with_capacity(n);
    permuted_dims.push(dims[mode]);
    permuted_dims.extend((0..n).filter(|&d| d != mode).map(|d| dims[d]));
    let t = m.reshape(&permuted_dims).unwrap();
    // inverse permutation of [mode, rest...]
    let fwd: Vec<usize> = std::iter::once(mode).chain((0..n).filter(|&d| d != mode)).collect();
    let mut inv = vec![0usize; n];
    for (pos, &d) in fwd.iter().enumerate() {
        inv[d] = pos;
    }
    permute(&t, &inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(dims: &[usize]) -> Tensor {
        let len: usize = dims.iter().product();
        Tensor::from_vec(dims, (0..len).map(|x| x as f32).collect()).unwrap()
    }

    /// Elementwise oracle for permute.
    fn permute_naive(t: &Tensor, perm: &[usize]) -> Tensor {
        let src_dims = t.dims();
        let dst_dims: Vec<usize> = perm.iter().map(|&p| src_dims[p]).collect();
        let mut out = Tensor::zeros(&dst_dims);
        let n = src_dims.len();
        let total = t.len();
        let src_strides = strides_of(src_dims);
        for flat in 0..total {
            let mut rem = flat;
            let mut idx = vec![0usize; n];
            for d in 0..n {
                idx[d] = rem / src_strides[d];
                rem %= src_strides[d];
            }
            let dst_idx: Vec<usize> = perm.iter().map(|&p| idx[p]).collect();
            *out.at_mut(&dst_idx) = t.data()[flat];
        }
        out
    }

    #[test]
    fn matrix_transpose() {
        let t = seq(&[3, 5]);
        let tt = permute(&t, &[1, 0]);
        assert_eq!(tt.dims(), &[5, 3]);
        for i in 0..3 {
            for j in 0..5 {
                assert_eq!(tt.at(&[j, i]), t.at(&[i, j]));
            }
        }
    }

    #[test]
    fn identity_perm_is_copy() {
        let t = seq(&[4, 6]);
        assert_eq!(permute(&t, &[0, 1]), t);
    }

    #[test]
    fn all_order3_perms_match_naive() {
        let t = seq(&[3, 4, 5]);
        for perm in [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]] {
            assert_eq!(permute(&t, &perm), permute_naive(&t, &perm), "{perm:?}");
        }
    }

    #[test]
    fn order4_blocked_path() {
        let t = seq(&[6, 40, 5, 36]); // > BLOCK in two modes
        let perm = [3, 1, 0, 2];
        assert_eq!(permute(&t, &perm), permute_naive(&t, &perm));
    }

    #[test]
    fn innermost_fixed_fast_path() {
        let t = seq(&[7, 8, 33]);
        let perm = [1, 0, 2];
        assert_eq!(permute(&t, &perm), permute_naive(&t, &perm));
    }

    #[test]
    fn large_blocked_transpose() {
        let t = seq(&[65, 70]);
        assert_eq!(permute(&t, &[1, 0]), permute_naive(&t, &[1, 0]));
    }

    #[test]
    fn matricize_mode0_is_reshape() {
        let t = seq(&[3, 4, 5]);
        let m = matricize(&t, 0);
        assert_eq!(m.dims(), &[3, 20]);
        assert_eq!(m.data(), t.data());
    }

    #[test]
    fn matricize_mode1() {
        let t = seq(&[3, 4, 5]);
        let m = matricize(&t, 1);
        assert_eq!(m.dims(), &[4, 15]);
        assert_eq!(m.at(&[2, 7]), t.at(&[1, 2, 2])); // col 7 = (i=1, k=2)
    }

    #[test]
    fn matricize_dematricize_roundtrip() {
        let t = seq(&[3, 4, 5]);
        for mode in 0..3 {
            let m = matricize(&t, mode);
            let back = dematricize(&m, t.dims(), mode);
            assert_eq!(back, t, "mode {mode}");
        }
    }
}
