//! The packed compute engine: BLIS-style cache blocking, an 8×8
//! register-tiled microkernel, persistent-pool macro-loop parallelism
//! with shared B-panel packing, and a reusable scratch-buffer pool.
//!
//! Layout follows Goto/BLIS: `A` is packed into `MC×KC` panels of
//! [`MR`]-row strips, `B` into `KC×NC` panels of [`NR`]-column strips, and
//! the microkernel keeps an `MR×NR` accumulator block in registers across
//! the full `KC` reduction (no branches in the inner loop, so `-O3`
//! auto-vectorizes it).  Edge tiles are zero-padded *inside the packed
//! panels*, which keeps the microkernel branch-free for ragged shapes.
//!
//! Parallelism runs on the persistent work-stealing pool
//! ([`crate::runtime::pool`]) instead of per-step `thread::scope`
//! spawns.  The GEMM macro loop keeps the `jc → pc` panel walk serial
//! and, per `KC×NC` panel, dispatches two pool regions: a cooperative
//! **shared pack** of the B panel (one copy in shared scratch, NR-strip
//! tasks; the pool's job-completion protocol is the publish/consume
//! fence), then a grid of **A-panel × macro-tile tasks** — each task
//! packs its own `MC×KC` A panel and drives the microkernel over an
//! `MC × NC/jr_split` column chunk.  The jr split widens the task grid
//! when M is skinny, so wide-N and tall-M shapes both load-balance by
//! stealing; B is packed exactly once per panel either way (PR 1 packed
//! it redundantly per row band).  Thread count and block sizes come from
//! a [`KernelConfig`], which the planner derives from SOAP tile sizes
//! ([`KernelConfig::from_tiles`]) and the coordinator feeds per term;
//! env overrides: `RAYON_NUM_THREADS` / `DEINSUM_NUM_THREADS`,
//! `DEINSUM_MC/KC/NC`.
//!
//! Determinism: the per-element accumulation order (`jc`, `pc` ascending,
//! full-`kcb` register accumulation) is independent of the thread count
//! and of which worker claims a tile, so `threads = 1` and `threads = 8`
//! produce bitwise-identical results (pinned by tests).
//!
//! All packing buffers come from a [`ScratchPool`]: size-classed
//! free-lists with **one lock per size class**, so steady-state kernel
//! invocations perform zero heap allocations (verified by
//! [`ScratchPool::stats`] in tests) and concurrent submitter threads —
//! the multi-tenant serving layer runs many programs against one shared
//! engine pool — only contend when they want the exact same class at the
//! exact same instant.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Microkernel rows (M-direction register tile).
pub const MR: usize = 8;
/// Microkernel columns (N-direction register tile).
pub const NR: usize = 8;

/// Problems below this many multiply-adds run single-threaded (thread
/// spawn + pool traffic would dominate).  Shared by the packed GEMM and
/// the fused MTTKRP so their serial/parallel crossover stays aligned.
pub(crate) const PARALLEL_FLOP_CUTOFF: usize = 1 << 18;

/// Cache-blocking and threading knobs for the local compute engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// A-panel rows per pack (L2-resident; rounded up to a multiple of [`MR`]).
    pub mc: usize,
    /// Reduction depth per pack (shared by GEMM and the MTTKRP KRP tile).
    pub kc: usize,
    /// B-panel columns per pack (rounded up to a multiple of [`NR`]).
    pub nc: usize,
    /// Worker threads for the macro loops (1 = fully serial).
    pub threads: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig { mc: 128, kc: 256, nc: 512, threads: detected_threads() }.normalized()
    }
}

/// Thread count: `RAYON_NUM_THREADS` (the convention distributed-BLAS
/// users already set) or `DEINSUM_NUM_THREADS`, else all cores.  Probed
/// once per process — config derivation sits on the planner path.
fn detected_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        for var in ["RAYON_NUM_THREADS", "DEINSUM_NUM_THREADS"] {
            if let Ok(v) = std::env::var(var) {
                if let Ok(n) = v.trim().parse::<usize>() {
                    if n >= 1 {
                        return n;
                    }
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

fn env_block(var: &str, default: usize) -> usize {
    std::env::var(var)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

impl KernelConfig {
    /// Defaults with environment overrides (`DEINSUM_MC`, `DEINSUM_KC`,
    /// `DEINSUM_NC`, `RAYON_NUM_THREADS`/`DEINSUM_NUM_THREADS`).
    pub fn from_env() -> Self {
        let d = KernelConfig::default();
        KernelConfig {
            mc: env_block("DEINSUM_MC", d.mc),
            kc: env_block("DEINSUM_KC", d.kc),
            nc: env_block("DEINSUM_NC", d.nc),
            threads: d.threads,
        }
        .normalized()
    }

    /// Clamp blocks to the microkernel grid (mc, nc multiples of MR/NR).
    pub fn normalized(mut self) -> Self {
        self.mc = self.mc.max(MR).div_ceil(MR) * MR;
        self.nc = self.nc.max(NR).div_ceil(NR) * NR;
        self.kc = self.kc.max(8);
        self.threads = self.threads.max(1);
        self
    }

    /// Same blocks, explicit thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Shrink blocks to an `m × k × n` problem so packing scratch stays
    /// proportional to the work (SOAP-derived configs can carry blocks
    /// far larger than a small local tile).  Loop bounds — and therefore
    /// results, bitwise — are unchanged: a block larger than an extent
    /// already behaves as the extent.
    pub(crate) fn clamp_to(mut self, m: usize, k: usize, n: usize) -> Self {
        self.mc = self.mc.min(m.max(1).div_ceil(MR) * MR);
        self.kc = self.kc.min(k.max(8));
        self.nc = self.nc.min(n.max(1).div_ceil(NR) * NR);
        self
    }

    /// Same blocks, single-threaded (used inside already-parallel bands).
    pub fn serial(self) -> Self {
        self.with_threads(1)
    }

    /// Build cache blocks from SOAP-optimal tile extents (paper §IV):
    /// `(t_m, t_k, t_n)` are the per-dimension tile sizes the I/O
    /// analysis found; they clamp into the packing panels so the local
    /// kernel blocks along the same proportions the schedule assumed.
    pub fn from_tiles(tm: f64, tk: f64, tn: f64) -> Self {
        fn clamp(t: f64, lo: usize, hi: usize) -> usize {
            if !t.is_finite() || t < lo as f64 {
                lo
            } else if t > hi as f64 {
                hi
            } else {
                t.round() as usize
            }
        }
        KernelConfig {
            mc: clamp(tm, MR, 1024),
            kc: clamp(tk, 8, 2048),
            nc: clamp(tn, NR, 4096),
            threads: detected_threads(),
        }
        .normalized()
    }

    /// The process-wide config used by the convenience entry points
    /// (`contract::gemm_into` etc.).
    pub fn global() -> KernelConfig {
        *crate::sync::lock(global_config())
    }

    /// Replace the process-wide config.
    pub fn install_global(cfg: KernelConfig) {
        *crate::sync::lock(global_config()) = cfg.normalized();
    }
}

fn global_config() -> &'static Mutex<KernelConfig> {
    static CFG: OnceLock<Mutex<KernelConfig>> = OnceLock::new();
    CFG.get_or_init(|| Mutex::new(KernelConfig::from_env()))
}

/// The process-wide scratch pool behind the convenience entry points.
pub fn global_pool() -> &'static ScratchPool {
    static POOL: OnceLock<ScratchPool> = OnceLock::new();
    POOL.get_or_init(ScratchPool::new)
}

/// Allocation counters (steady-state invariant: `allocs` stops growing
/// after warmup while `takes` keeps counting reuses).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchStats {
    /// Buffers actually heap-allocated (pool misses).
    pub allocs: u64,
    /// Total take() calls (hits + misses).
    pub takes: u64,
}

/// Smallest size class: 256 elements (1 KiB), so tiny requests of
/// different sizes share one class.
const CLASS_MIN_SHIFT: u32 = 8;

/// Number of size classes: powers of two from 2^8 up to 2^39 elements
/// (2 TiB of f32) — far past any realistic packing buffer; larger
/// requests clamp into the top class.
const N_CLASSES: usize = 32;

/// Size-classed free lists of `f32` buffers.  `Sync`: workers inside the
/// parallel macro loops — and, since the serving layer, multiple
/// submitter threads running different programs against one shared
/// engine — take and return buffers directly.  Each size class has its
/// own lock, so concurrent takes only serialize when they race for the
/// same class; the free lists themselves stay process-wide (no
/// per-thread sharding), which keeps the steady-state `allocs`-flat
/// invariant independent of which worker thread happens to claim a task.
#[derive(Debug)]
pub struct ScratchPool {
    free: [Mutex<Vec<Vec<f32>>>; N_CLASSES],
    allocs: AtomicU64,
    takes: AtomicU64,
}

impl Default for ScratchPool {
    fn default() -> Self {
        ScratchPool::new()
    }
}

impl ScratchPool {
    /// An empty pool: every size class starts with no cached buffers.
    pub fn new() -> Self {
        ScratchPool {
            free: std::array::from_fn(|_| Mutex::new(Vec::new())),
            allocs: AtomicU64::new(0),
            takes: AtomicU64::new(0),
        }
    }

    /// Size class: next power of two, floored at 256 elements.
    fn class_of(len: usize) -> usize {
        len.max(1 << CLASS_MIN_SHIFT).next_power_of_two()
    }

    /// Free-list index of a class value (a power of two ≥ 2^8).
    fn class_index(class: usize) -> usize {
        (class.trailing_zeros().saturating_sub(CLASS_MIN_SHIFT) as usize).min(N_CLASSES - 1)
    }

    /// Borrow a buffer of at least `len` elements.  Contents are
    /// unspecified (callers fully overwrite or [`ScratchBuf::fill`]).
    pub fn take(&self, len: usize) -> ScratchBuf<'_> {
        self.takes.fetch_add(1, Ordering::Relaxed);
        let class = Self::class_of(len);
        let reused = {
            let mut list = crate::sync::lock(&self.free[Self::class_index(class)]);
            match list.pop() {
                // Only the clamped top class can mix sizes; everywhere
                // else buffers sit at exactly their class size.
                Some(b) if b.len() >= class => Some(b),
                Some(b) => {
                    list.push(b);
                    None
                }
                None => None,
            }
        };
        let buf = match reused {
            Some(b) => b,
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; class]
            }
        };
        ScratchBuf { pool: self, buf, len }
    }

    /// [`take`](Self::take), zero-filled.
    pub fn take_zeroed(&self, len: usize) -> ScratchBuf<'_> {
        let mut b = self.take(len);
        b.fill(0.0);
        b
    }

    /// Reuse counters: `allocs` must stay flat once warm (the
    /// steady-state invariant tests and the bench gate assert).
    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            allocs: self.allocs.load(Ordering::Relaxed),
            takes: self.takes.load(Ordering::Relaxed),
        }
    }

    /// Drop every pooled buffer (frees memory; counters keep their values).
    pub fn clear(&self) {
        for list in &self.free {
            crate::sync::lock(list).clear();
        }
    }
}

/// RAII scratch buffer: derefs to `[f32; len]`, returns to the pool on drop.
pub struct ScratchBuf<'p> {
    pool: &'p ScratchPool,
    buf: Vec<f32>,
    len: usize,
}

impl Deref for ScratchBuf<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf[..self.len]
    }
}

impl DerefMut for ScratchBuf<'_> {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf[..self.len]
    }
}

impl Drop for ScratchBuf<'_> {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() == 0 {
            return;
        }
        // Buffers are allocated at exactly their class size and never
        // resized, so buf.len() is the class value.
        let idx = ScratchPool::class_index(buf.len());
        crate::sync::lock(&self.pool.free[idx]).push(buf);
    }
}

/// `C[m×n] += A[m×k] · B[k×n]`, all row-major and dense.
pub fn gemm_into_with(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert!(a.len() >= m * k);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= m * n);
    gemm_strided(cfg, pool, a, k, b, n, c, n, m, k, n);
}

/// Strided-operand packed GEMM: `C[m×n] += A[m×k] · B[k×n]` with leading
/// dimensions `lda`/`ldb`/`ldc` (row-major views into larger buffers; the
/// fused MTTKRP uses this to contract column panels of the matricized
/// tensor without gathering them first).  Requires `c.len() == m * ldc`.
pub fn gemm_strided(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let cfg = cfg.normalized().clamp_to(m, k, n);
    let threads = if m.saturating_mul(n).saturating_mul(k) < PARALLEL_FLOP_CUTOFF {
        1
    } else {
        cfg.threads
    };
    if threads <= 1 {
        serial_gemm(cfg, pool, a, lda, b, ldb, c, ldc, m, k, n);
    } else {
        shared_pack_gemm(cfg, pool, threads, a, lda, b, ldb, c, ldc, m, k, n);
    }
}

/// One member of a shared-B batched GEMM: its `A` operand and the `C`
/// buffer it accumulates into (row-major `m×k` / `m×n`, shapes shared by
/// the whole batch).
pub struct GemmBatchMember<'a> {
    /// Row-major `A[m×k]`.
    pub a: &'a [f32],
    /// Row-major `C[m×n]`, accumulated into in place.
    pub c: &'a mut [f32],
}

/// Batched `C_i += A_i · B` over one **shared** `B[k×n]`: each `KC×NC`
/// panel of `B` is packed exactly once and reused by every batch member,
/// so a batch of `B` members pays `1/B`-th of the back-to-back path's
/// B-packing traffic.  This is the kernel-level lever behind the serving
/// layer's fused same-key batches, applicable whenever the batch shares
/// the stationary operand (coalesced serving requests submitting one
/// `Arc`'d input set, CP-ALS sweeps re-contracting one factor).
///
/// Bitwise identical to calling [`gemm_into_with`] once per member: the
/// macro-loop walk (`jc → pc → ic`, ascending), the packed panel bytes,
/// and the full-`kcb` register accumulation are exactly the serial
/// path's — and that path's per-element accumulation order is
/// thread-count independent — so hoisting the B pack out of the member
/// loop cannot change any member's bytes (pinned in tests).
pub fn gemm_batch_shared_b_with(
    cfg: &KernelConfig,
    pool: &ScratchPool,
    members: &mut [GemmBatchMember<'_>],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || n == 0 || k == 0 || members.is_empty() {
        return;
    }
    debug_assert!(b.len() >= k * n);
    let cfg = cfg.normalized().clamp_to(m, k, n);
    let mut apack = pool.take(cfg.mc * cfg.kc);
    let mut bpack = pool.take(cfg.kc * cfg.nc);
    let mut jc = 0usize;
    while jc < n {
        let ncb = cfg.nc.min(n - jc);
        let mut pc = 0usize;
        while pc < k {
            let kcb = cfg.kc.min(k - pc);
            // The batch's saving: one B pack serves every member.
            pack_b_strips(b, n, pc, kcb, jc, ncb, 0, ncb.div_ceil(NR), &mut bpack);
            for member in members.iter_mut() {
                debug_assert!(member.a.len() >= m * k);
                debug_assert!(member.c.len() >= m * n);
                let cptr = member.c.as_mut_ptr();
                let mut ic = 0usize;
                while ic < m {
                    let mcb = cfg.mc.min(m - ic);
                    pack_a(member.a, k, ic, mcb, pc, kcb, &mut apack);
                    // SAFETY: serial — this call exclusively owns all of
                    // this member's C.
                    unsafe {
                        macro_tile(&apack, &bpack, cptr, n, ic, mcb, jc, kcb, 0, ncb);
                    }
                    ic += mcb;
                }
            }
            pc += kcb;
        }
        jc += ncb;
    }
}

/// The serial macro-loop nest (jc → pc → ic, the Goto loop order: B
/// panels stream through L3, A panels sit in L2).  Also the retained
/// oracle the pool-parallel path must match bitwise.
fn serial_gemm(
    cfg: KernelConfig,
    pool: &ScratchPool,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let mut apack = pool.take(cfg.mc * cfg.kc);
    let mut bpack = pool.take(cfg.kc * cfg.nc);
    let cptr = c.as_mut_ptr();
    let mut jc = 0usize;
    while jc < n {
        let ncb = cfg.nc.min(n - jc);
        let mut pc = 0usize;
        while pc < k {
            let kcb = cfg.kc.min(k - pc);
            pack_b_strips(b, ldb, pc, kcb, jc, ncb, 0, ncb.div_ceil(NR), &mut bpack);
            let mut ic = 0usize;
            while ic < m {
                let mcb = cfg.mc.min(m - ic);
                pack_a(a, lda, ic, mcb, pc, kcb, &mut apack);
                // SAFETY: serial — this call exclusively owns all of C.
                unsafe {
                    macro_tile(&apack, &bpack, cptr, ldc, ic, mcb, jc, kcb, 0, ncb);
                }
                ic += mcb;
            }
            pc += kcb;
        }
        jc += ncb;
    }
}

/// The pool-parallel macro loop with *shared* B-panel packing (ROADMAP
/// "shared rather than per-worker B packing with a work-stealing macro
/// loop").  Per `KC×NC` panel:
///
/// 1. **Cooperative pack** — the panel's NR strips are packed once into
///    shared scratch by a pool region (disjoint strip ranges per task);
///    the job's completion protocol publishes the packed bytes to the
///    next region's workers.
/// 2. **A-panel × macro-tile tasks** — a `m_tiles × jr_split` task grid;
///    each task packs its own `MC×KC` A panel from pool scratch and runs
///    the microkernel over its `MC × (NC/jr_split)` column chunk of C.
///    `jr_split > 1` only when M alone cannot feed every worker, so
///    skinny-M/wide-N shapes still load-balance; the cost is re-packing
///    A once per column chunk, the cheap redundancy (an `MC×KC` panel vs
///    PR 1's per-band `KC×NC` B panel).
fn shared_pack_gemm(
    cfg: KernelConfig,
    pool: &ScratchPool,
    threads: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let mut bpack = pool.take(cfg.kc * cfg.nc);
    let m_tiles = m.div_ceil(cfg.mc);
    let cptr = SendMutPtr(c.as_mut_ptr());
    let mut jc = 0usize;
    while jc < n {
        let ncb = cfg.nc.min(n - jc);
        let strips = ncb.div_ceil(NR);
        let mut pc = 0usize;
        while pc < k {
            let kcb = cfg.kc.min(k - pc);
            // Phase 1: shared B pack, one NR-strip range per task.
            {
                let bptr = SendMutPtr(bpack.as_mut_ptr());
                let strip_chunk = strips.div_ceil(threads * 2).max(1);
                let pack_tasks = strips.div_ceil(strip_chunk);
                crate::runtime::pool::global().run(threads, pack_tasks, &|t| {
                    let s0 = t * strip_chunk;
                    let s1 = (s0 + strip_chunk).min(strips);
                    // SAFETY: strip ranges are disjoint, so the slices
                    // carved out of the shared pack never overlap.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            bptr.0.add(s0 * kcb * NR),
                            (s1 - s0) * kcb * NR,
                        )
                    };
                    pack_b_strips(b, ldb, pc, kcb, jc, ncb, s0, s1, dst);
                });
            }
            // Phase 2: consume the shared panel from macro-tile tasks.
            let bshared: &[f32] = &bpack;
            let jr_split = (threads * 2).div_ceil(m_tiles).clamp(1, strips);
            let jr_per = strips.div_ceil(jr_split) * NR;
            crate::runtime::pool::global().run(threads, m_tiles * jr_split, &|t| {
                let ic = (t / jr_split) * cfg.mc;
                let jr0 = (t % jr_split) * jr_per;
                if jr0 >= ncb {
                    return;
                }
                let jr1 = (jr0 + jr_per).min(ncb);
                let mcb = cfg.mc.min(m - ic);
                let mut apack = pool.take(cfg.mc * cfg.kc);
                pack_a(a, lda, ic, mcb, pc, kcb, &mut apack);
                // SAFETY: tasks own disjoint (row-tile, column-chunk)
                // rectangles of C — `ic` ranges are disjoint across
                // `t / jr_split`, `jr` ranges across `t % jr_split`.
                unsafe {
                    macro_tile(&apack, bshared, cptr.0, ldc, ic, mcb, jc, kcb, jr0, jr1);
                }
            });
            pc += kcb;
        }
        jc += ncb;
    }
}

/// Split `out` (`rows × row_elems`, row-major) into disjoint MR-aligned
/// row bands and run `work(row0, band_rows, band_out)` as stealable pool
/// tasks (`threads <= 1` runs inline).  The single band-split used by
/// both the packed GEMM driver and the fused MTTKRP, so their
/// partitioning can never diverge.
pub(crate) fn parallel_row_bands<F>(
    threads: usize,
    rows: usize,
    row_elems: usize,
    out: &mut [f32],
    work: F,
) where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    if rows == 0 {
        return;
    }
    let threads = threads.max(1).min(rows.div_ceil(MR));
    if threads <= 1 {
        work(0, rows, out);
        return;
    }
    // Bands finer than the thread count so stealing can rebalance
    // ragged per-row costs.
    let band = rows.div_ceil(threads * 2).div_ceil(MR) * MR;
    let n_bands = rows.div_ceil(band);
    let ptr = SendMutPtr(out.as_mut_ptr());
    crate::runtime::pool::global().run(threads, n_bands, &|t| {
        let row0 = t * band;
        let take = band.min(rows - row0);
        // SAFETY: bands are disjoint row ranges of `out`, so the carved
        // slices never overlap across tasks.
        let band_out = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(row0 * row_elems), take * row_elems)
        };
        work(row0, take, band_out);
    });
}

/// Pack `A[ic..ic+mcb, pc..pc+kcb]` into MR-row strips:
/// `out[s*kcb*MR + p*MR + i] = A[ic + s*MR + i, pc + p]` (zeros past mcb).
fn pack_a(a: &[f32], lda: usize, ic: usize, mcb: usize, pc: usize, kcb: usize, out: &mut [f32]) {
    let strips = mcb.div_ceil(MR);
    for s in 0..strips {
        let base = s * kcb * MR;
        let r0 = ic + s * MR;
        let rows = MR.min(ic + mcb - r0);
        for p in 0..kcb {
            let dst = &mut out[base + p * MR..base + (p + 1) * MR];
            for (i, d) in dst.iter_mut().enumerate().take(rows) {
                *d = a[(r0 + i) * lda + pc + p];
            }
            for d in dst.iter_mut().skip(rows) {
                *d = 0.0;
            }
        }
    }
}

/// Pack the NR-column strips `s0..s1` of `B[pc..pc+kcb, jc..jc+ncb]`:
/// `out[(s-s0)*kcb*NR + p*NR + j] = B[pc + p, jc + s*NR + j]` (zeros past
/// ncb).  The full-panel pack is `s0 = 0, s1 = ncb.div_ceil(NR)`; the
/// shared-pack phase hands each pool task a disjoint strip range.
fn pack_b_strips(
    b: &[f32],
    ldb: usize,
    pc: usize,
    kcb: usize,
    jc: usize,
    ncb: usize,
    s0: usize,
    s1: usize,
    out: &mut [f32],
) {
    for s in s0..s1 {
        let base = (s - s0) * kcb * NR;
        let c0 = jc + s * NR;
        let cols = NR.min(jc + ncb - c0);
        for p in 0..kcb {
            let src = (pc + p) * ldb + c0;
            let dst = &mut out[base + p * NR..base + (p + 1) * NR];
            if cols == NR {
                dst.copy_from_slice(&b[src..src + NR]);
            } else {
                for (j, d) in dst.iter_mut().enumerate().take(cols) {
                    *d = b[src + j];
                }
                for d in dst.iter_mut().skip(cols) {
                    *d = 0.0;
                }
            }
        }
    }
}

/// Drive the microkernel over the column chunk `jr0..jr1` (NR-aligned
/// start) of one packed macro tile, writing through a raw C pointer.
///
/// # Safety
///
/// The caller must guarantee exclusive ownership of the C rectangle
/// `rows [ic, ic+mcb) × cols [jc+jr0, jc+jr1)` under leading dimension
/// `ldc`, and that `c` points at a live allocation covering it.  The
/// parallel macro loops partition C into such disjoint rectangles.
unsafe fn macro_tile(
    apack: &[f32],
    bpack: &[f32],
    c: *mut f32,
    ldc: usize,
    ic: usize,
    mcb: usize,
    jc: usize,
    kcb: usize,
    jr0: usize,
    jr1: usize,
) {
    debug_assert_eq!(jr0 % NR, 0);
    let mut jr = jr0;
    while jr < jr1 {
        let nr_eff = NR.min(jr1 - jr);
        let bstrip = &bpack[(jr / NR) * kcb * NR..][..kcb * NR];
        let mut ir = 0usize;
        while ir < mcb {
            let mr_eff = MR.min(mcb - ir);
            let astrip = &apack[(ir / MR) * kcb * MR..][..kcb * MR];
            micro_kernel(
                kcb,
                astrip,
                bstrip,
                c.add((ic + ir) * ldc + jc + jr),
                ldc,
                mr_eff,
                nr_eff,
            );
            ir += MR;
        }
        jr += NR;
    }
}

/// The 8×8 register-tiled microkernel: `acc[MR][NR] += a_strip ⊗ b_strip`
/// over the full `kc` reduction, then a single accumulate into C.  No
/// data-dependent branches in the reduction loop (the seed kernel's
/// `aik == 0.0` skip is gone: it broke vectorization on dense inputs).
///
/// # Safety
///
/// `c` must point at an exclusively-owned `mr × nr` tile under leading
/// dimension `ldc` (see [`macro_tile`]).
#[inline]
unsafe fn micro_kernel(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let av: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        let bv: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        for i in 0..MR {
            let aik = av[i];
            for j in 0..NR {
                acc[i][j] += aik * bv[j];
            }
        }
    }
    if mr == MR && nr == NR {
        for (i, acc_row) in acc.iter().enumerate() {
            let row = c.add(i * ldc);
            for (j, &v) in acc_row.iter().enumerate() {
                *row.add(j) += v;
            }
        }
    } else {
        for (i, acc_row) in acc.iter().enumerate().take(mr) {
            let row = c.add(i * ldc);
            for (j, &v) in acc_row.iter().enumerate().take(nr) {
                *row.add(j) += v;
            }
        }
    }
}

/// Run `work(lo, hi)` over `0..units` as stealable pool tasks (chunks
/// finer than the thread count so ragged unit costs rebalance); callers
/// guarantee at least `min_per_thread` units per participant.  Used by
/// the transpose macro loop.
pub(crate) fn parallel_units<F>(threads: usize, units: usize, min_per_thread: usize, work: F)
where
    F: Fn(usize, usize) + Sync,
{
    if units == 0 {
        return;
    }
    let threads = threads.max(1).min(units / min_per_thread.max(1)).max(1);
    if threads <= 1 {
        work(0, units);
        return;
    }
    let chunk = units.div_ceil(threads * 4).max(min_per_thread.max(1));
    let n_tasks = units.div_ceil(chunk);
    crate::runtime::pool::global().run(threads, n_tasks, &|t| {
        let u0 = t * chunk;
        let u1 = (u0 + chunk).min(units);
        work(u0, u1);
    });
}

/// Raw mutable pointer that crosses scoped-thread boundaries.  Safety
/// contract: every worker writes a disjoint index set (the transpose
/// writes each destination element exactly once — it is a bijection).
#[derive(Clone, Copy)]
pub(crate) struct SendMutPtr(pub *mut f32);
unsafe impl Send for SendMutPtr {}
unsafe impl Sync for SendMutPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unblocked triple-loop oracle.
    fn gemm_oracle(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let aik = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += aik * b[p * n + j];
                }
            }
        }
        c
    }

    fn randv(len: usize, seed: u64) -> Vec<f32> {
        crate::tensor::Tensor::random(&[len.max(1)], seed).into_data()[..len].to_vec()
    }

    fn check_shape(m: usize, k: usize, n: usize, cfg: KernelConfig) {
        let pool = ScratchPool::new();
        let a = randv(m * k, 1 + (m * 31 + k * 7 + n) as u64);
        let b = randv(k * n, 2 + (m + k + n) as u64);
        let want = gemm_oracle(&a, &b, m, k, n);
        let mut c = vec![0.0f32; m * n];
        gemm_into_with(&cfg, &pool, &a, &b, &mut c, m, k, n);
        for (i, (&g, &w)) in c.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 + 1e-3 * w.abs(),
                "({m},{k},{n}) cfg {cfg:?} elem {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn packed_gemm_matches_oracle_odd_shapes() {
        let base = KernelConfig { mc: 16, kc: 24, nc: 16, threads: 1 }.normalized();
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (7, 1, 9),
            (1, 64, 1),
            (8, 8, 8),
            (17, 23, 9),
            (33, 65, 29),
            (64, 64, 64),
            (100, 3, 50),
        ] {
            check_shape(m, k, n, base);
            check_shape(m, k, n, base.with_threads(4));
        }
    }

    #[test]
    fn packed_gemm_parallel_matches_serial_exactly() {
        // Same cfg => same blocking => identical FP order per element.
        let pool = ScratchPool::new();
        let cfg = KernelConfig { mc: 32, kc: 32, nc: 32, threads: 1 }.normalized();
        let (m, k, n) = (150, 70, 90);
        let a = randv(m * k, 11);
        let b = randv(k * n, 12);
        let mut c1 = vec![0.0f32; m * n];
        gemm_into_with(&cfg, &pool, &a, &b, &mut c1, m, k, n);
        let mut c4 = vec![0.0f32; m * n];
        gemm_into_with(&cfg.with_threads(4), &pool, &a, &b, &mut c4, m, k, n);
        // Thread split changes which band a row falls into but not the
        // per-row reduction order, so results match to roundoff exactly.
        assert_eq!(c1, c4);
    }

    #[test]
    fn batched_shared_b_matches_per_member_serial_bitwise() {
        // The whole point of the batched entry: hoisting the B pack out
        // of the member loop must not change a single bit of any member.
        let pool = ScratchPool::new();
        let cfg = KernelConfig { mc: 16, kc: 24, nc: 16, threads: 1 }.normalized();
        for &(m, k, n) in &[(7usize, 5usize, 9usize), (17, 23, 9), (33, 65, 29)] {
            let b = randv(k * n, 99);
            let a_list: Vec<Vec<f32>> =
                (0..3u64).map(|i| randv(m * k, 200 + i)).collect();
            let want: Vec<Vec<f32>> = a_list
                .iter()
                .map(|a| {
                    let mut c = vec![0.0f32; m * n];
                    gemm_into_with(&cfg, &pool, a, &b, &mut c, m, k, n);
                    c
                })
                .collect();
            let mut c_list: Vec<Vec<f32>> = vec![vec![0.0f32; m * n]; a_list.len()];
            let mut members: Vec<GemmBatchMember> = a_list
                .iter()
                .zip(c_list.iter_mut())
                .map(|(a, c)| GemmBatchMember { a, c })
                .collect();
            gemm_batch_shared_b_with(&cfg, &pool, &mut members, &b, m, k, n);
            drop(members);
            assert_eq!(c_list, want, "({m},{k},{n}) batched != serial");
        }
    }

    #[test]
    fn batched_shared_b_accumulates_and_handles_degenerates() {
        let pool = ScratchPool::new();
        let cfg = KernelConfig::default().serial();
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        let mut c0 = vec![10.0f32; 4];
        let mut c1 = vec![0.0f32; 4];
        {
            let mut members = vec![
                GemmBatchMember { a: &a, c: &mut c0 },
                GemmBatchMember { a: &a, c: &mut c1 },
            ];
            gemm_batch_shared_b_with(&cfg, &pool, &mut members, &b, 2, 2, 2);
        }
        assert_eq!(c0, vec![12.0; 4], "accumulates like gemm_into_with");
        assert_eq!(c1, vec![2.0; 4]);
        // Empty batches and degenerate dims are no-ops.
        gemm_batch_shared_b_with(&cfg, &pool, &mut [], &b, 2, 2, 2);
        let mut c = vec![1.0f32; 4];
        {
            let mut members = vec![GemmBatchMember { a: &a, c: &mut c }];
            gemm_batch_shared_b_with(&cfg, &pool, &mut members, &b, 0, 2, 2);
        }
        assert_eq!(c, vec![1.0; 4]);
    }

    #[test]
    fn batched_shared_b_steady_state_is_alloc_free() {
        let pool = ScratchPool::new();
        let cfg = KernelConfig { mc: 16, kc: 16, nc: 16, threads: 1 }.normalized();
        let (m, k, n) = (24usize, 24usize, 24usize);
        let b = randv(k * n, 5);
        let a0 = randv(m * k, 6);
        let a1 = randv(m * k, 7);
        let mut c0 = vec![0.0f32; m * n];
        let mut c1 = vec![0.0f32; m * n];
        let run = |pool: &ScratchPool, c0: &mut [f32], c1: &mut [f32]| {
            let mut members = vec![
                GemmBatchMember { a: &a0, c: c0 },
                GemmBatchMember { a: &a1, c: c1 },
            ];
            gemm_batch_shared_b_with(&cfg, pool, &mut members, &b, m, k, n);
        };
        run(&pool, &mut c0, &mut c1); // warmup populates the pool
        let warm = pool.stats().allocs;
        for _ in 0..5 {
            run(&pool, &mut c0, &mut c1);
        }
        assert_eq!(pool.stats().allocs, warm, "batched gemm steady state allocated");
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let pool = ScratchPool::new();
        let cfg = KernelConfig::default().serial();
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        let mut c = vec![10.0f32; 4];
        gemm_into_with(&cfg, &pool, &a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![12.0; 4]);
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let pool = ScratchPool::new();
        let cfg = KernelConfig::default();
        let mut c = vec![1.0f32; 6];
        gemm_into_with(&cfg, &pool, &[], &[], &mut c, 0, 0, 0);
        gemm_into_with(&cfg, &pool, &[], &[1.0, 2.0], &mut c, 2, 0, 3);
        assert_eq!(c, vec![1.0; 6]);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let pool = ScratchPool::new();
        {
            let _a = pool.take(1000);
            let _b = pool.take(1000);
        }
        let after_warmup = pool.stats();
        assert_eq!(after_warmup.allocs, 2);
        for _ in 0..10 {
            let _a = pool.take(1000);
            let _b = pool.take(900); // same 1024 class
        }
        let s = pool.stats();
        assert_eq!(s.allocs, after_warmup.allocs, "steady state must not allocate");
        assert_eq!(s.takes, after_warmup.takes + 20);
    }

    #[test]
    fn scratch_class_index_covers_all_sizes() {
        assert_eq!(ScratchPool::class_of(1), 256);
        assert_eq!(ScratchPool::class_of(256), 256);
        assert_eq!(ScratchPool::class_of(257), 512);
        assert_eq!(ScratchPool::class_index(256), 0);
        assert_eq!(ScratchPool::class_index(512), 1);
        // The top class clamps instead of indexing out of bounds.
        assert!(ScratchPool::class_index(1usize << (usize::BITS - 1)) < N_CLASSES);
        // A buffer returned into the clamped class never serves a
        // request it is too small for.
        let pool = ScratchPool::new();
        {
            let _small = pool.take(300); // class 512
        }
        let big = pool.take(400); // same class, fits
        assert!(big.len() >= 400);
    }

    #[test]
    fn scratch_pool_is_safe_under_concurrent_takes() {
        // The serving layer's shape of pool traffic: several submitter
        // threads taking/returning concurrently.  Buffers returned by
        // any thread are visible to every other (process-wide free
        // lists), so total allocations are bounded by the concurrent
        // high-water mark, not by thread count × rounds.
        let pool = ScratchPool::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let pool = &pool;
                s.spawn(move || {
                    for i in 0..50 {
                        let mut b = pool.take(1000 + (t * 13 + i) % 24);
                        b.fill(t as f32);
                    }
                });
            }
        });
        let stats = pool.stats();
        assert_eq!(stats.takes, 200);
        assert!(stats.allocs <= 4, "at most one live buffer per thread: {stats:?}");
        // Warm pool: a serial sweep allocates nothing new.
        let before = pool.stats().allocs;
        for _ in 0..10 {
            let _ = pool.take(1001);
        }
        assert_eq!(pool.stats().allocs, before);
    }

    #[test]
    fn steady_state_gemm_is_alloc_free() {
        let pool = ScratchPool::new();
        let cfg = KernelConfig { mc: 32, kc: 32, nc: 32, threads: 2 }.normalized();
        // Pre-seed the pool to its high-water mark (2 workers × 2 panels,
        // all in the same size class here), so the runs below must be
        // served entirely from the free list regardless of scheduling.
        {
            let _bufs: Vec<ScratchBuf> =
                (0..4).map(|_| pool.take(cfg.mc * cfg.kc)).collect();
        }
        let a = randv(64 * 64, 3);
        let b = randv(64 * 64, 4);
        let mut c = vec![0.0f32; 64 * 64];
        let warm = pool.stats().allocs;
        for _ in 0..5 {
            gemm_into_with(&cfg, &pool, &a, &b, &mut c, 64, 64, 64);
        }
        assert_eq!(pool.stats().allocs, warm, "gemm steady state allocated");
    }

    #[test]
    fn config_normalization_and_env_shape() {
        let c = KernelConfig { mc: 1, kc: 1, nc: 1, threads: 0 }.normalized();
        assert_eq!(c.mc % MR, 0);
        assert_eq!(c.nc % NR, 0);
        assert!(c.kc >= 8 && c.threads >= 1);
        let t = KernelConfig::from_tiles(100.0, 300.0, 24.0);
        assert_eq!(t.mc % MR, 0);
        assert_eq!(t.nc % NR, 0);
        assert!(t.kc >= 8);
        let huge = KernelConfig::from_tiles(1e18, f64::NAN, -5.0);
        assert!(huge.mc <= 1024 && huge.kc >= 8 && huge.nc >= NR);
    }
}
