//! The paper's benchmark suite: Table IV (kernels) and Table V
//! (weak-scaling sizes), plus the harness that produces the Fig. 5/6
//! rows.
//!
//! Base problem sizes are scaled down from the paper's (Piz Daint had 64
//! GB/node; all our simulated ranks share one address space), controlled
//! by `size_factor` — the *shape* of every comparison (who wins, where
//! the crossovers are) is size-stable; EXPERIMENTS.md records the
//! mapping.

use std::collections::BTreeMap;

use crate::api::Session;
use crate::coordinator::RunReport;
use crate::einsum::EinsumSpec;
use crate::error::Result;
use crate::sim::TimeBreakdown;
use crate::tensor::Tensor;

/// One Table IV benchmark.
#[derive(Debug, Clone)]
pub struct BenchDef {
    /// Paper name, e.g. `MTTKRP-03-M1`.
    pub name: String,
    /// Einsum string (Table IV column 4).
    pub expr: String,
    /// Base extent of every index at P = 1 (Table V column 2).
    pub base: BTreeMap<char, usize>,
    /// Indices that weak-scale with P (the `I^n`; ranks stay fixed).
    pub scaled: Vec<char>,
    /// Scaling exponent root: extent × P^(1/root) (Table V column 3).
    pub root: u32,
}

impl BenchDef {
    fn new(
        name: &str,
        expr: &str,
        base: &[(char, usize)],
        scaled: &[char],
        root: u32,
    ) -> Self {
        BenchDef {
            name: name.to_string(),
            expr: expr.to_string(),
            base: base.iter().copied().collect(),
            scaled: scaled.to_vec(),
            root,
        }
    }

    /// Index extents at `p` ranks (weak scaling, Table V).
    pub fn extents_at(&self, p: usize) -> BTreeMap<char, usize> {
        let f = (p as f64).powf(1.0 / self.root as f64);
        self.base
            .iter()
            .map(|(&c, &n)| {
                let n = if self.scaled.contains(&c) {
                    ((n as f64) * f).round() as usize
                } else {
                    n
                };
                (c, n.max(1))
            })
            .collect()
    }

    /// Operand shapes at `p` ranks.
    pub fn shapes_at(&self, p: usize) -> Vec<Vec<usize>> {
        let ext = self.extents_at(p);
        let lhs = self.expr.split("->").next().unwrap();
        lhs.split(',')
            .map(|ops| ops.chars().map(|c| ext[&c]).collect())
            .collect()
    }

    /// Parsed spec at `p` ranks.
    pub fn spec_at(&self, p: usize) -> Result<EinsumSpec> {
        EinsumSpec::parse(&self.expr, &self.shapes_at(p))
    }

    /// Total input elements at `p` (memory sanity checks in harnesses).
    pub fn input_elements(&self, p: usize) -> usize {
        self.shapes_at(p).iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// The full Table IV suite, with base sizes divided by `size_factor`
/// (1 = paper sizes; the default harness uses 8–16).
pub fn suite(size_factor: usize) -> Vec<BenchDef> {
    let sf = size_factor.max(1);
    let mm = (4096 / sf).max(8);
    let m3 = (1024 / sf).max(8);
    let m5 = (1024 / (sf * sf)).max(4); // order-5 tensors grow fast
    let t5 = (60 / sf.min(4)).max(8);
    let r = 24;
    vec![
        BenchDef::new(
            "1MM",
            "ij,jk->ik",
            &[('i', mm), ('j', mm), ('k', mm)],
            &['i', 'j', 'k'],
            3,
        ),
        BenchDef::new(
            "2MM",
            "ij,jk,kl->il",
            &[('i', mm), ('j', mm), ('k', mm), ('l', mm)],
            &['i', 'j', 'k', 'l'],
            3,
        ),
        BenchDef::new(
            "3MM",
            "ij,jk,kl,lm->im",
            &[('i', mm), ('j', mm), ('k', mm), ('l', mm), ('m', mm)],
            &['i', 'j', 'k', 'l', 'm'],
            3,
        ),
        BenchDef::new(
            "MTTKRP-03-M0",
            "ijk,ja,ka->ia",
            &[('i', m3), ('j', m3), ('k', m3), ('a', r)],
            &['i', 'j', 'k'],
            4,
        ),
        BenchDef::new(
            "MTTKRP-03-M1",
            "ijk,ia,ka->ja",
            &[('i', m3), ('j', m3), ('k', m3), ('a', r)],
            &['i', 'j', 'k'],
            4,
        ),
        BenchDef::new(
            "MTTKRP-03-M2",
            "ijk,ia,ja->ka",
            &[('i', m3), ('j', m3), ('k', m3), ('a', r)],
            &['i', 'j', 'k'],
            4,
        ),
        BenchDef::new(
            "MTTKRP-05-M0",
            "ijklm,ja,ka,la,ma->ia",
            &[('i', m5), ('j', m5), ('k', m5), ('l', m5), ('m', m5), ('a', r)],
            &['i', 'j', 'k', 'l', 'm'],
            6,
        ),
        BenchDef::new(
            "MTTKRP-05-M2",
            "ijklm,ia,ja,la,ma->ka",
            &[('i', m5), ('j', m5), ('k', m5), ('l', m5), ('m', m5), ('a', r)],
            &['i', 'j', 'k', 'l', 'm'],
            6,
        ),
        BenchDef::new(
            "MTTKRP-05-M4",
            "ijklm,ia,ja,ka,la->ma",
            &[('i', m5), ('j', m5), ('k', m5), ('l', m5), ('m', m5), ('a', r)],
            &['i', 'j', 'k', 'l', 'm'],
            6,
        ),
        BenchDef::new(
            "TTMc-05-M0",
            "ijklm,jb,kc,ld,me->ibcde",
            &[
                ('i', t5),
                ('j', t5),
                ('k', t5),
                ('l', t5),
                ('m', t5),
                ('b', r),
                ('c', r),
                ('d', r),
                ('e', r),
            ],
            &['i', 'j', 'k', 'l', 'm'],
            6,
        ),
    ]
}

/// Deinsum-vs-baseline measurement at one (benchmark, P) point.
#[derive(Debug, Clone)]
pub struct BenchPoint {
    /// Benchmark name (Table IV row).
    pub name: String,
    /// Rank count of this weak-scaling point.
    pub p: usize,
    /// Deinsum's modeled compute/communication split.
    pub deinsum: TimeBreakdown,
    /// The CTF-like baseline's split on the same inputs.
    pub baseline: TimeBreakdown,
    /// Exact communication volumes (bytes) for both schedulers.
    pub deinsum_comm_bytes: u128,
    /// The baseline's exact communication volume in bytes.
    pub baseline_comm_bytes: u128,
    /// Baseline total time over deinsum total time.
    pub speedup: f64,
}

/// Run one benchmark point: both schedulers, same inputs, numerics
/// cross-checked.  Returns the reports too (for Fig. 6 GPU modeling).
/// Plans come through the session's cache, so weak-scaling sweeps that
/// revisit a `(benchmark, P)` point skip re-planning.
pub fn run_point(
    def: &BenchDef,
    p: usize,
    session: &Session,
) -> Result<(BenchPoint, RunReport, RunReport)> {
    let shapes = def.shapes_at(p);
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 42 + i as u64))
        .collect();

    let mut dprog = session.compile_on(&def.expr, &shapes, p)?;
    let drep = dprog.run(&inputs)?;

    let mut bprog = session.compile_baseline_on(&def.expr, &shapes, p)?;
    let brep = bprog.run(&inputs)?;

    // Cross-check: two independent schedules must agree.
    debug_assert!(
        drep.output.rel_error(&brep.output) < 1e-3,
        "{}@P={p}: schedulers disagree ({})",
        def.name,
        drep.output.rel_error(&brep.output)
    );

    let point = BenchPoint {
        name: def.name.clone(),
        p,
        deinsum: drep.time,
        baseline: brep.time,
        deinsum_comm_bytes: drep.comm.p2p_bytes + drep.comm.allreduce_bytes,
        baseline_comm_bytes: brep.comm.p2p_bytes + brep.comm.allreduce_bytes,
        speedup: brep.time.total() / drep.time.total().max(1e-12),
    };
    Ok((point, drep, brep))
}

/// Format a Fig. 5-style table header.
pub fn header() -> String {
    format!(
        "{:<14} {:>5} {:>12} {:>12} {:>12} {:>12} {:>9}",
        "benchmark", "P", "dein comp s", "dein comm s", "dein total", "ctf-like s", "speedup"
    )
}

/// Format one row.
pub fn row(pt: &BenchPoint) -> String {
    format!(
        "{:<14} {:>5} {:>12.5} {:>12.5} {:>12.5} {:>12.5} {:>8.2}x",
        pt.name,
        pt.p,
        pt.deinsum.compute,
        pt.deinsum.comm,
        pt.deinsum.total(),
        pt.baseline.total(),
        pt.speedup
    )
}

/// Geometric mean of speedups (the paper's closing 4.18× figure).
pub fn geomean(points: &[BenchPoint]) -> f64 {
    if points.is_empty() {
        return 1.0;
    }
    let s: f64 = points.iter().map(|p| p.speedup.max(1e-12).ln()).sum();
    (s / points.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_matches_table_iv() {
        let s = suite(1);
        assert_eq!(s.len(), 10);
        let names: Vec<&str> = s.iter().map(|b| b.name.as_str()).collect();
        assert!(names.contains(&"1MM"));
        assert!(names.contains(&"MTTKRP-05-M4"));
        assert!(names.contains(&"TTMc-05-M0"));
        // Table V base sizes at size_factor 1.
        assert_eq!(s[0].base[&'i'], 4096);
        assert_eq!(s[3].base[&'i'], 1024);
        assert_eq!(s[3].base[&'a'], 24);
        assert_eq!(s[9].base[&'i'], 60);
    }

    #[test]
    fn weak_scaling_follows_table_v() {
        let s = suite(1);
        // 1MM: ∛P — at P=8 extents double.
        let mm = &s[0];
        assert_eq!(mm.extents_at(8)[&'i'], 8192);
        // MTTKRP-03: ⁴√P — at P=16 extents double, rank stays 24.
        let m3 = &s[3];
        assert_eq!(m3.extents_at(16)[&'i'], 2048);
        assert_eq!(m3.extents_at(16)[&'a'], 24);
        // MTTKRP-05: ⁶√P — at P=64 extents double.
        let m5 = &s[6];
        assert_eq!(m5.extents_at(64)[&'j'], 2048);
    }

    #[test]
    fn shapes_match_expr() {
        let s = suite(8);
        for b in &s {
            let spec = b.spec_at(1).unwrap();
            assert_eq!(spec.inputs.len(), b.shapes_at(1).len(), "{}", b.name);
            for p in [1, 2, 4] {
                assert!(b.spec_at(p).is_ok(), "{} P={p}", b.name);
            }
        }
    }

    #[test]
    fn run_point_small() {
        let defs = suite(64);
        let m0 = defs.iter().find(|d| d.name == "MTTKRP-03-M0").unwrap();
        let session = Session::builder().build().unwrap();
        let (pt, drep, brep) = run_point(m0, 4, &session).unwrap();
        assert!(pt.speedup > 0.0);
        assert!(drep.output.rel_error(&brep.output) < 1e-3);
        // Both schedulers' plans landed in the session cache.
        assert_eq!(session.cache_stats().misses, 2);
    }

    #[test]
    fn deinsum_moves_fewer_bytes_at_scale() {
        // The §IV-E claim (fused MTTKRP communicates less than the
        // two-step KRP+GEMM) holds at meaningful problem sizes — at toy
        // extents both schedules fit everywhere and the comparison is
        // noise, so this check uses the 64-base suite at P=8.
        let defs = suite(16);
        let m0 = defs.iter().find(|d| d.name == "MTTKRP-03-M0").unwrap();
        let session = Session::builder().build().unwrap();
        let (pt, _, _) = run_point(m0, 8, &session).unwrap();
        // Communication volume is deterministic — the §IV-E claim.
        assert!(
            pt.deinsum_comm_bytes < pt.baseline_comm_bytes,
            "deinsum {} vs baseline {}",
            pt.deinsum_comm_bytes,
            pt.baseline_comm_bytes
        );
        // Wall-clock speedup is asserted loosely here (single cold run in
        // a test environment); the bench harness measures it properly.
        assert!(pt.speedup > 0.5, "speedup {}", pt.speedup);
    }

    #[test]
    fn geomean_sane() {
        let mk = |s: f64| BenchPoint {
            name: "x".into(),
            p: 1,
            deinsum: TimeBreakdown::default(),
            baseline: TimeBreakdown::default(),
            deinsum_comm_bytes: 0,
            baseline_comm_bytes: 0,
            speedup: s,
        };
        let g = geomean(&[mk(2.0), mk(8.0)]);
        assert!((g - 4.0).abs() < 1e-9);
    }
}
