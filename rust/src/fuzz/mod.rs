//! Deterministic differential einsum fuzzing (ROADMAP item 4).
//!
//! The invariant this module enforces end to end: **every generated
//! einsum either plans and runs bitwise-identical to a naive dense
//! oracle, or is rejected with a typed [`Error`](crate::Error) — never a
//! panic, at any rank count.**
//!
//! Four pieces, mirroring franken_numpy's oracle-capture + differential
//! report pipeline:
//!
//! - [`generate`]: a SplitMix64-seeded generator (same PRNG family as
//!   [`crate::fault::FaultPlan`]) producing random einsum chains — 2–5
//!   operands, random index sharing, permuted/reduced/empty outputs,
//!   degenerate extents (0 and 1), skinny/fat aspect ratios.  Inputs are
//!   **small integers** (±2), so every multiply-add chain is exact in
//!   `f32` regardless of summation order — "bitwise identical" is then a
//!   meaningful cross-implementation check, not a tolerance fudge.
//! - [`oracle`]: a naive dense evaluator — an independent odometer loop
//!   nest over the full iteration space with its own minimal expression
//!   reader, sharing **no** kernel or parser code with the compile path.
//! - [`classify`]: runs one case through [`Session::compile`] at several
//!   rank counts and through `run`/`run_into` with a dirty recycled
//!   destination, classifying the outcome as oracle-identical,
//!   typed-reject, or BUG (mismatch/panic — panics are caught via
//!   `catch_unwind` so one bad case doesn't end a campaign).
//! - [`shrink`]: a greedy minimizer (drop operands, drop indices, shrink
//!   extents) that reduces a failing case and reports the one-line repro
//!   `DEINSUM_FUZZ_SEED=<n> DEINSUM_FUZZ_CASE=<k>`.
//!
//! [`campaign`] drives N cases and returns a [`CampaignReport`]; the CLI
//! (`deinsum fuzz`) and CI run fixed-seed campaigns, and
//! `tests/fuzz.rs` pins a 64-case corpus plus rejection determinism.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::api::Session;
use crate::error::Error;
use crate::exec::ExecBackend;
use crate::tensor::{strides_of, Tensor};

/// SplitMix64 — the same avalanche mixer [`crate::fault::FaultPlan`] and
/// [`Tensor::random`] seed from, kept local so the fuzzer's stream is
/// fixed forever (a kernel-side PRNG change must not re-roll the corpus).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the stream.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform draw in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// True with probability `num / den`.
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.next_u64() % den < num
    }
}

/// One generated fuzz case: an einsum expression, its operand shapes,
/// and the `(seed, case)` pair that regenerates it bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FuzzCase {
    /// Campaign seed (`DEINSUM_FUZZ_SEED`).
    pub seed: u64,
    /// Case index within the campaign (`DEINSUM_FUZZ_CASE`).
    pub case: u64,
    /// Einsum expression, e.g. `ijk,ja->ika`.
    pub expr: String,
    /// Operand shapes bound to the expression.
    pub shapes: Vec<Vec<usize>>,
}

impl FuzzCase {
    /// One-line repro: re-running the campaign binary with these env
    /// vars regenerates and re-executes exactly this case.
    pub fn repro(&self) -> String {
        format!("DEINSUM_FUZZ_SEED={} DEINSUM_FUZZ_CASE={}", self.seed, self.case)
    }

    /// Deterministic integer-valued inputs (entries in `{-2..2}`): with
    /// the generator's iteration-space cap every partial sum stays well
    /// under 2^24, so all f32 arithmetic is exact and results are
    /// bitwise identical across any summation order.
    pub fn inputs(&self) -> Vec<Tensor> {
        self.shapes
            .iter()
            .enumerate()
            .map(|(op, shape)| {
                let mut rng = SplitMix64::new(
                    self.seed
                        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                        .wrapping_add(self.case)
                        .wrapping_add(op as u64).wrapping_mul(0x2545_f491_4f6c_dd1d),
                );
                let len: usize = shape.iter().product();
                let data: Vec<f32> =
                    (0..len).map(|_| (rng.range(0, 4) as i64 - 2) as f32).collect();
                Tensor::from_vec(shape, data).expect("len = product of dims")
            })
            .collect()
    }
}

/// Hard cap on the full iteration space of a generated case.  Together
/// with entries in `{-2..2}` (product magnitude ≤ 2^5 over ≤ 5 operands)
/// every accumulated value stays below `2^5 * 2^16 = 2^21 << 2^24`, so
/// f32 arithmetic on the whole case is exact.
const MAX_ITER_SPACE: usize = 1 << 16;

/// Generate case `case` of the campaign seeded by `seed`.  Pure function
/// of `(seed, case)` — the repro contract.
pub fn generate(seed: u64, case: u64) -> FuzzCase {
    let mut rng = SplitMix64::new(
        seed.wrapping_mul(0x6c62_272e_07bb_0142).wrapping_add(case.wrapping_mul(2) | 1),
    );
    let n_ops = rng.range(2, 5);
    let n_idx = rng.range(2, 6);
    // Distinct index letters, drawn from a shuffled window of a-z so
    // expressions don't all reuse the same prefix.
    let base = rng.range(0, 25 - (n_idx - 1));
    let pool: Vec<char> = (0..n_idx).map(|q| (b'a' + (base + q) as u8) as char).collect();

    // Extents: mostly small (1..=6), occasionally degenerate 0, with one
    // optional "fat" dim (skinny/fat aspect ratios) capped afterwards.
    let mut extents: BTreeMap<char, usize> = BTreeMap::new();
    for &c in &pool {
        let e = if rng.chance(1, 12) {
            0
        } else if rng.chance(1, 5) {
            1
        } else {
            rng.range(2, 6)
        };
        extents.insert(c, e);
    }
    if rng.chance(1, 3) {
        let fat = pool[rng.range(0, n_idx - 1)];
        extents.insert(fat, rng.range(7, 9));
    }
    // Cap the iteration space so integer arithmetic stays exact.
    loop {
        let space: usize = extents.values().map(|&e| e.max(1)).product();
        if space <= MAX_ITER_SPACE {
            break;
        }
        let (&c, _) = extents.iter().max_by_key(|(_, &e)| e).expect("non-empty pool");
        let e = extents[&c];
        extents.insert(c, e / 2);
    }

    // Operands: each a random-order subset of the pool (no repeats — the
    // compile path rejects traces; index sharing emerges from overlap).
    let mut inputs: Vec<Vec<char>> = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let rank = rng.range(1, n_idx.min(4));
        let mut avail = pool.clone();
        let mut idx = Vec::with_capacity(rank);
        for _ in 0..rank {
            idx.push(avail.swap_remove(rng.range(0, avail.len() - 1)));
        }
        inputs.push(idx);
    }
    // Output: random-order subset of the indices actually used (possibly
    // empty — a full contraction to a scalar), so permuted and reduced
    // outputs both appear.
    let mut used: Vec<char> = Vec::new();
    for op in &inputs {
        for &c in op {
            if !used.contains(&c) {
                used.push(c);
            }
        }
    }
    let out_rank = rng.range(0, used.len());
    let mut avail = used.clone();
    let mut output = Vec::with_capacity(out_rank);
    for _ in 0..out_rank {
        output.push(avail.swap_remove(rng.range(0, avail.len() - 1)));
    }

    let expr = render_expr(&inputs, &output);
    let shapes: Vec<Vec<usize>> =
        inputs.iter().map(|op| op.iter().map(|c| extents[c]).collect()).collect();
    FuzzCase { seed, case, expr, shapes }
}

fn render_expr(inputs: &[Vec<char>], output: &[char]) -> String {
    let lhs: Vec<String> = inputs.iter().map(|v| v.iter().collect()).collect();
    format!("{}->{}", lhs.join(","), output.iter().collect::<String>())
}

/// The naive dense oracle: evaluate `expr` over `inputs` with one
/// odometer loop nest across the **full** iteration space — one
/// multiply chain per point, accumulated into the output slot.  Shares
/// no code with the compile path (it re-reads the expression with its
/// own minimal splitter).  Returns `None` when the expression is not a
/// well-formed simple einsum (the compile path must then reject typed).
pub fn oracle(expr: &str, shapes: &[Vec<usize>], inputs: &[Tensor]) -> Option<Tensor> {
    let expr: String = expr.chars().filter(|c| !c.is_whitespace()).collect();
    let (lhs, rhs) = expr.split_once("->")?;
    let ops: Vec<Vec<char>> = lhs.split(',').map(|s| s.chars().collect()).collect();
    let out: Vec<char> = rhs.chars().collect();
    if ops.len() != shapes.len() || ops.len() != inputs.len() {
        return None;
    }
    // Bind extents; reject malformed structure the way the einsum
    // semantics do (empty operands, traces, non-letters, conflicts).
    let mut ext: BTreeMap<char, usize> = BTreeMap::new();
    for (op, shape) in ops.iter().zip(shapes) {
        if op.is_empty() || op.len() != shape.len() {
            return None;
        }
        for (q, (&c, &e)) in op.iter().zip(shape).enumerate() {
            if !c.is_ascii_alphabetic() || op[..q].contains(&c) {
                return None;
            }
            match ext.insert(c, e) {
                Some(prev) if prev != e => return None,
                _ => {}
            }
        }
    }
    for (q, &c) in out.iter().enumerate() {
        if !ext.contains_key(&c) || out[..q].contains(&c) {
            return None;
        }
    }
    for (t, shape) in inputs.iter().zip(shapes) {
        if t.dims() != &shape[..] {
            return None;
        }
    }

    let all: Vec<char> = ext.keys().copied().collect();
    let dims: Vec<usize> = all.iter().map(|c| ext[c]).collect();
    let out_dims: Vec<usize> = out.iter().map(|c| ext[c]).collect();
    let mut result = Tensor::zeros(&out_dims);
    let total: usize = dims.iter().product();
    if total == 0 {
        return Some(result); // an extent-0 index empties every sum
    }
    let out_strides = strides_of(&out_dims);
    // Position of each loop index in the output (usize::MAX = reduced)
    // and per-operand strides keyed by loop index.
    let out_pos: Vec<usize> = all
        .iter()
        .map(|c| out.iter().position(|o| o == c).unwrap_or(usize::MAX))
        .collect();
    let op_strides: Vec<Vec<usize>> = ops
        .iter()
        .zip(shapes)
        .map(|(op, shape)| {
            let s = strides_of(shape);
            all.iter()
                .map(|c| op.iter().position(|o| o == c).map(|q| s[q]).unwrap_or(0))
                .collect()
        })
        .collect();

    let n = all.len();
    let mut idx = vec![0usize; n];
    let mut offs = vec![0usize; inputs.len()];
    let mut out_off = 0usize;
    for _ in 0..total {
        let mut v = 1.0f32;
        for (t, &o) in inputs.iter().zip(&offs) {
            v *= t.data()[o];
        }
        result.data_mut()[out_off] += v;
        // Odometer carry, updating every offset incrementally.
        for d in (0..n).rev() {
            idx[d] += 1;
            if idx[d] < dims[d] {
                for (q, so) in op_strides.iter().enumerate() {
                    offs[q] += so[d];
                }
                if out_pos[d] != usize::MAX {
                    out_off += out_strides[out_pos[d]];
                }
                break;
            }
            idx[d] = 0;
            for (q, so) in op_strides.iter().enumerate() {
                offs[q] -= so[d] * (dims[d] - 1);
            }
            if out_pos[d] != usize::MAX {
                out_off -= out_strides[out_pos[d]] * (dims[d] - 1);
            }
        }
    }
    Some(result)
}

/// One typed rejection observed at a specific rank count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rejection {
    /// Rank count the rejection occurred at.
    pub ranks: usize,
    /// `Display` rendering of the typed error.
    pub message: String,
    /// [`Error::is_retryable`] of the rejection (must be `false`: a
    /// deterministically-rejected expression must never burn serve
    /// retry budget).
    pub retryable: bool,
}

/// Classification of one case across every probed rank count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Outcome {
    /// Every rank count either matched the oracle bitwise (at least one
    /// did) or rejected typed; rejections ride along for determinism
    /// checks.
    Match(Vec<Rejection>),
    /// Every rank count rejected with a typed error.
    Reject(Vec<Rejection>),
    /// A panic, an oracle mismatch, or an accepted-but-invalid
    /// expression.  The campaign fails on any of these.
    Bug(String),
}

impl Outcome {
    /// Stable one-line signature for cross-run determinism assertions.
    pub fn signature(&self) -> String {
        match self {
            Outcome::Match(rejects) => {
                let r: Vec<String> =
                    rejects.iter().map(|r| format!("p{}:{}", r.ranks, r.message)).collect();
                format!("match[{}]", r.join("|"))
            }
            Outcome::Reject(rejects) => {
                let r: Vec<String> =
                    rejects.iter().map(|r| format!("p{}:{}", r.ranks, r.message)).collect();
                format!("reject[{}]", r.join("|"))
            }
            Outcome::Bug(m) => format!("bug[{m}]"),
        }
    }

    /// True for [`Outcome::Bug`].
    pub fn is_bug(&self) -> bool {
        matches!(self, Outcome::Bug(_))
    }

    /// The typed rejections this outcome carries (empty for bugs).
    pub fn rejections(&self) -> &[Rejection] {
        match self {
            Outcome::Match(r) | Outcome::Reject(r) => r,
            Outcome::Bug(_) => &[],
        }
    }
}

/// The default rank counts a campaign probes.
pub const DEFAULT_RANKS: &[usize] = &[1, 4, 8];

/// Run one case through compile + `run` + `run_into` (dirty recycled
/// destination) at every rank count in `ranks` and compare against the
/// dense oracle.  Panics anywhere in the pipeline are caught and
/// classified as [`Outcome::Bug`].  The execution backend comes from
/// `DEINSUM_BACKEND` ([`ExecBackend::from_env`]); pin one explicitly
/// with [`classify_on`].
pub fn classify(case: &FuzzCase, ranks: &[usize]) -> Outcome {
    classify_on(case, ranks, ExecBackend::from_env())
}

/// [`classify`] pinned to an explicit execution backend — the CI matrix
/// fuzzes the message-passing backend with the same corpus this way,
/// and the oracle comparison doubles as a cross-backend identity check.
pub fn classify_on(case: &FuzzCase, ranks: &[usize], backend: ExecBackend) -> Outcome {
    let inputs = case.inputs();
    let want = oracle(&case.expr, &case.shapes, &inputs);
    let mut rejections: Vec<Rejection> = Vec::new();
    let mut matched = false;
    for &p in ranks {
        let expr = case.expr.clone();
        let shapes = case.shapes.clone();
        let ins = inputs.clone();
        let ran = catch_unwind(AssertUnwindSafe(move || -> crate::Result<(Tensor, Tensor)> {
            let session = Session::builder().ranks(p).backend(backend).build()?;
            let mut program = session.compile(&expr, &shapes)?;
            let report = program.run(&ins)?;
            // Dirty recycled destination: run_into must fully overwrite.
            let mut dest = Tensor::random(&program.output_dims(), 0x0D15_EA5E);
            program.run_into(&ins, &mut dest)?;
            Ok((report.output, dest))
        }));
        match ran {
            Err(payload) => {
                return Outcome::Bug(format!(
                    "panic at P={p}: {} [{}]",
                    panic_message(&payload),
                    case.repro()
                ));
            }
            Ok(Err(e)) => {
                let typed = matches!(
                    e,
                    Error::Parse(_) | Error::Shape(_) | Error::Plan(_)
                );
                if !typed {
                    return Outcome::Bug(format!(
                        "non-compile-class error at P={p}: {e} [{}]",
                        case.repro()
                    ));
                }
                rejections.push(Rejection {
                    ranks: p,
                    message: e.to_string(),
                    retryable: e.is_retryable(),
                });
            }
            Ok(Ok((out, out_into))) => {
                let Some(want) = want.as_ref() else {
                    return Outcome::Bug(format!(
                        "accepted an expression the oracle rejects at P={p} [{}]",
                        case.repro()
                    ));
                };
                if let Some(diff) = bitwise_diff(want, &out) {
                    return Outcome::Bug(format!(
                        "run mismatch vs oracle at P={p}: {diff} [{}]",
                        case.repro()
                    ));
                }
                if let Some(diff) = bitwise_diff(want, &out_into) {
                    return Outcome::Bug(format!(
                        "run_into (dirty dest) mismatch vs oracle at P={p}: {diff} [{}]",
                        case.repro()
                    ));
                }
                matched = true;
            }
        }
    }
    if matched {
        Outcome::Match(rejections)
    } else {
        Outcome::Reject(rejections)
    }
}

/// First bitwise difference between two tensors (`None` = identical).
/// Inputs are small integers, so plain `f32` equality *is* bitwise
/// equality here (no NaNs; ±0 cannot survive an additive accumulation).
fn bitwise_diff(want: &Tensor, got: &Tensor) -> Option<String> {
    if want.dims() != got.dims() {
        return Some(format!("dims {:?} != oracle {:?}", got.dims(), want.dims()));
    }
    for (i, (w, g)) in want.data().iter().zip(got.data()).enumerate() {
        if w != g {
            return Some(format!("elem {i}: {g} != oracle {w}"));
        }
    }
    None
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Greedily minimize a failing case: repeatedly try dropping an operand,
/// dropping one index occurrence, or shrinking one extent, keeping any
/// candidate for which `is_bug` still holds, until no step shrinks it
/// further.  The returned case keeps the original `(seed, case)` pair so
/// [`FuzzCase::repro`] still regenerates the *unshrunk* ancestor.
pub fn shrink(case: &FuzzCase, is_bug: &mut dyn FnMut(&FuzzCase) -> bool) -> FuzzCase {
    let mut cur = case.clone();
    loop {
        let mut improved = false;
        for cand in shrink_candidates(&cur) {
            if is_bug(&cand) {
                cur = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            return cur;
        }
    }
}

/// Parse the expression back into (inputs, output) index strings; cases
/// whose expression is too hostile to split structurally can't shrink.
fn split_expr(expr: &str) -> Option<(Vec<Vec<char>>, Vec<char>)> {
    let (lhs, rhs) = expr.split_once("->")?;
    Some((lhs.split(',').map(|s| s.chars().collect()).collect(), rhs.chars().collect()))
}

fn shrink_candidates(case: &FuzzCase) -> Vec<FuzzCase> {
    let Some((inputs, output)) = split_expr(&case.expr) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    // 1. Drop a whole operand (output indices it alone supplied go too).
    if inputs.len() > 1 {
        for q in 0..inputs.len() {
            let mut ins = inputs.clone();
            let mut shapes = case.shapes.clone();
            ins.remove(q);
            shapes.remove(q);
            let still: Vec<char> = output
                .iter()
                .copied()
                .filter(|c| ins.iter().any(|op| op.contains(c)))
                .collect();
            out.push(FuzzCase { expr: render_expr(&ins, &still), shapes, ..case.clone() });
        }
    }
    // 2. Drop one index occurrence from one operand.
    for q in 0..inputs.len() {
        if inputs[q].len() <= 1 {
            continue; // keep operands non-empty (parse would reject)
        }
        for d in 0..inputs[q].len() {
            let mut ins = inputs.clone();
            let mut shapes = case.shapes.clone();
            let c = ins[q].remove(d);
            shapes[q].remove(d);
            let still: Vec<char> = output
                .iter()
                .copied()
                .filter(|o| *o != c || ins.iter().any(|op| op.contains(o)))
                .collect();
            out.push(FuzzCase { expr: render_expr(&ins, &still), shapes, ..case.clone() });
        }
    }
    // 3. Shrink one index's extent everywhere it appears (halve).
    let mut seen: Vec<char> = Vec::new();
    for op in &inputs {
        for &c in op {
            if !seen.contains(&c) {
                seen.push(c);
            }
        }
    }
    for &c in &seen {
        let cur_ext = inputs
            .iter()
            .zip(&case.shapes)
            .find_map(|(op, sh)| op.iter().position(|&o| o == c).map(|q| sh[q]));
        let Some(e) = cur_ext else { continue };
        if e <= 1 {
            continue;
        }
        let mut shapes = case.shapes.clone();
        for (op, sh) in inputs.iter().zip(shapes.iter_mut()) {
            for (q, &o) in op.iter().enumerate() {
                if o == c {
                    sh[q] = e / 2;
                }
            }
        }
        out.push(FuzzCase { shapes, ..case.clone() });
    }
    out
}

/// A confirmed BUG: the triggering case, its greedy minimization, and
/// the classification detail.
#[derive(Debug, Clone)]
pub struct BugReport {
    /// The generated case that failed.
    pub case: FuzzCase,
    /// Its shrunk minimization (same classification failure).
    pub shrunk: FuzzCase,
    /// What went wrong (panic message / first mismatching element).
    pub detail: String,
}

/// Aggregate result of a fuzzing campaign.
#[derive(Debug, Clone, Default)]
pub struct CampaignReport {
    /// Cases run.
    pub cases: u64,
    /// Cases bitwise-identical to the oracle on ≥ 1 rank count.
    pub matches: u64,
    /// Cases rejected typed on every rank count.
    pub rejects: u64,
    /// BUG classifications (empty = the invariant held).
    pub bugs: Vec<BugReport>,
}

impl CampaignReport {
    /// Render the shrunk repro corpus (one block per bug) — the artifact
    /// CI uploads on failure.
    pub fn corpus(&self) -> String {
        if self.bugs.is_empty() {
            return format!(
                "# fuzz campaign clean: {} cases, {} oracle-identical, {} typed-reject\n",
                self.cases, self.matches, self.rejects
            );
        }
        let mut s = String::new();
        for b in &self.bugs {
            s.push_str(&format!(
                "# {}\n# original: {} shapes {:?}\n# shrunk:   {} shapes {:?}\n{}\n",
                b.detail, b.case.expr, b.case.shapes, b.shrunk.expr, b.shrunk.shapes,
                b.case.repro()
            ));
        }
        s
    }
}

/// Run a fixed-seed campaign of `cases` generated cases at the given
/// rank counts.  Failing cases are shrunk and reported; the campaign
/// always runs to completion (panics are contained per case).  Backend
/// from `DEINSUM_BACKEND`; pin one with [`campaign_on`].
pub fn campaign(seed: u64, cases: u64, ranks: &[usize]) -> CampaignReport {
    campaign_on(seed, cases, ranks, ExecBackend::from_env())
}

/// [`campaign`] pinned to an explicit execution backend.  Shrinking
/// re-classifies on the same backend, so a backend-specific bug shrinks
/// against the backend that exhibits it.
pub fn campaign_on(
    seed: u64,
    cases: u64,
    ranks: &[usize],
    backend: ExecBackend,
) -> CampaignReport {
    let mut report = CampaignReport { cases, ..Default::default() };
    for k in 0..cases {
        let case = generate(seed, k);
        match classify_on(&case, ranks, backend) {
            Outcome::Match(_) => report.matches += 1,
            Outcome::Reject(_) => report.rejects += 1,
            Outcome::Bug(detail) => {
                let shrunk = shrink(&case, &mut |c: &FuzzCase| {
                    classify_on(c, ranks, backend).is_bug()
                });
                report.bugs.push(BugReport { case, shrunk, detail });
            }
        }
    }
    report
}

/// The case pinned by `DEINSUM_FUZZ_SEED` / `DEINSUM_FUZZ_CASE` (the
/// repro line a shrunk corpus prints), if both are set and parse.
pub fn env_case() -> Option<FuzzCase> {
    let seed: u64 = std::env::var("DEINSUM_FUZZ_SEED").ok()?.parse().ok()?;
    let case: u64 = std::env::var("DEINSUM_FUZZ_CASE").ok()?.parse().ok()?;
    Some(generate(seed, case))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        for k in 0..32 {
            let a = generate(7, k);
            let b = generate(7, k);
            assert_eq!(a, b, "case {k} must be a pure function of (seed, case)");
            assert_eq!(a.inputs(), b.inputs());
        }
        assert_ne!(generate(7, 0), generate(8, 0));
        assert_ne!(generate(7, 0), generate(7, 1));
    }

    #[test]
    fn classify_on_mp_agrees_with_sim_signature() {
        // A small generated slice of the corpus classified on both
        // backends: no bugs on either, and identical signatures —
        // accept/reject decisions and rejection messages must not
        // depend on the execution backend.
        for k in 0..8 {
            let case = generate(20260808, k);
            let sim = classify_on(&case, &[1, 4], ExecBackend::Sim);
            let mp = classify_on(&case, &[1, 4], ExecBackend::Mp);
            assert!(!sim.is_bug(), "sim bug on case {k}: {}", sim.signature());
            assert!(!mp.is_bug(), "mp bug on case {k}: {}", mp.signature());
            assert_eq!(sim.signature(), mp.signature(), "case {k}");
        }
    }

    #[test]
    fn classify_on_proc_agrees_with_sim_signature() {
        // Same leg across the process boundary: every case drives real
        // `deinsum rank-worker` children over the wire format (`cargo
        // test` builds the bin target, and the worker-binary discovery
        // finds it next to the test executable).  Fewer cases than the
        // mp leg — each classification spawns a fleet per rank count —
        // but the contract is identical: zero bugs, and signatures that
        // do not depend on the backend.
        for k in 0..5 {
            let case = generate(20260808, k);
            let sim = classify_on(&case, &[1, 4], ExecBackend::Sim);
            let proc_ = classify_on(&case, &[1, 4], ExecBackend::Proc);
            assert!(!sim.is_bug(), "sim bug on case {k}: {}", sim.signature());
            assert!(!proc_.is_bug(), "proc bug on case {k}: {}", proc_.signature());
            assert_eq!(sim.signature(), proc_.signature(), "case {k}");
        }
    }

    #[test]
    fn generated_cases_cover_the_advertised_space() {
        let (mut zero_ext, mut one_ext, mut empty_out, mut permuted) = (0, 0, 0, 0);
        for k in 0..200 {
            let c = generate(11, k);
            let (inputs, output) = split_expr(&c.expr).unwrap();
            assert!((2..=5).contains(&inputs.len()), "{}", c.expr);
            if c.shapes.iter().flatten().any(|&e| e == 0) {
                zero_ext += 1;
            }
            if c.shapes.iter().flatten().any(|&e| e == 1) {
                one_ext += 1;
            }
            if output.is_empty() {
                empty_out += 1;
            }
            if output.len() >= 2 {
                permuted += 1;
            }
            // Exactness cap: the full iteration space stays small.
            let mut ext: BTreeMap<char, usize> = BTreeMap::new();
            for (op, sh) in inputs.iter().zip(&c.shapes) {
                for (&i, &e) in op.iter().zip(sh) {
                    ext.insert(i, e);
                }
            }
            let space: usize = ext.values().map(|&e| e.max(1)).product();
            assert!(space <= MAX_ITER_SPACE, "{}: space {space}", c.expr);
        }
        assert!(zero_ext > 5, "extent-0 cases: {zero_ext}");
        assert!(one_ext > 20, "extent-1 cases: {one_ext}");
        assert!(empty_out > 5, "scalar-output cases: {empty_out}");
        assert!(permuted > 40, "multi-index outputs: {permuted}");
    }

    #[test]
    fn oracle_matches_hand_computed_matmul() {
        // ij,jk->ki with tiny known integers.
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let got = oracle(
            "ij,jk->ki",
            &[vec![2, 2], vec![2, 2]],
            &[a.clone(), b.clone()],
        )
        .unwrap();
        // C = A*B = [[19,22],[43,50]]; ki transposes it.
        assert_eq!(got.dims(), &[2, 2]);
        assert_eq!(got.data(), &[19.0, 43.0, 22.0, 50.0]);
        // Scalar output: full contraction.
        let s = oracle("ij,ij->", &[vec![2, 2], vec![2, 2]], &[a.clone(), a]).unwrap();
        assert_eq!(s.dims(), &[] as &[usize]);
        assert_eq!(s.data(), &[1.0 + 4.0 + 9.0 + 16.0]);
        // Extent-0 index: empty sums everywhere.
        let x = Tensor::zeros(&[0, 3]);
        let y = Tensor::zeros(&[3, 2]);
        let z = oracle("ij,jk->ik", &[vec![0, 3], vec![3, 2]], &[x, y]).unwrap();
        assert_eq!(z.dims(), &[0, 2]);
        // Malformed structure is None, not a panic.
        let bad = oracle(",j->j", &[vec![], vec![3]], &[Tensor::zeros(&[]), Tensor::zeros(&[3])]);
        assert!(bad.is_none());
        assert!(oracle("ii->i", &[vec![2, 2]], &[Tensor::zeros(&[2, 2])]).is_none());
    }

    #[test]
    fn shrinker_reaches_a_minimal_case() {
        // Plant a synthetic "bug": any case with a contracted index of
        // extent >= 2 (mimicking an accumulation defect).  The minimizer
        // must reduce a multi-operand case to <= 2 operands with
        // single-digit extents while preserving the predicate.
        let mut is_bug = |c: &FuzzCase| {
            let Some((inputs, output)) = split_expr(&c.expr) else { return false };
            inputs.iter().zip(&c.shapes).any(|(op, sh)| {
                op.iter().zip(sh).any(|(i, &e)| !output.contains(i) && e >= 2)
            })
        };
        let mut found = None;
        for k in 0..64 {
            let c = generate(0xF00D, k);
            let (inputs, _) = split_expr(&c.expr).unwrap();
            if inputs.len() >= 3 && is_bug(&c) {
                found = Some(c);
                break;
            }
        }
        let case = found.expect("corpus contains a 3+-operand contracted case");
        let shrunk = shrink(&case, &mut is_bug);
        assert!(is_bug(&shrunk), "shrinking must preserve the failure");
        let (inputs, _) = split_expr(&shrunk.expr).unwrap();
        assert!(inputs.len() <= 2, "minimal case has <= 2 operands: {}", shrunk.expr);
        assert!(
            shrunk.shapes.iter().flatten().all(|&e| e <= 9),
            "single-digit extents: {:?}",
            shrunk.shapes
        );
        // The printed repro pair regenerates the unshrunk ancestor.
        assert_eq!(shrunk.repro(), case.repro());
        let repro = case.repro();
        let parts: Vec<u64> = repro
            .split_whitespace()
            .map(|kv| kv.split_once('=').unwrap().1.parse().unwrap())
            .collect();
        assert_eq!(generate(parts[0], parts[1]), case);
    }
}
