//! Multi-tenant serving layer over [`Session`]: a fixed worker pool
//! executing compiled [`Program`]s concurrently against one shared
//! engine, with first-class fault tolerance.
//!
//! The paper's compile-once/run-many shape (§II) is exactly what a
//! serving workload wants: a distributed schedule is compiled into a
//! cacheable [`crate::planner::Plan`], and the marginal cost of a query
//! is one warm `run_into` — zero planning, zero tensor allocations.  DISTAL and
//! EinDecomp make the same observation from the scheduling side: once
//! the schedule is a *value*, the win is running many of them
//! concurrently against shared local-compute machinery.  This module is
//! that layer:
//!
//! - a [`Server`] owns an `Arc<Session>` and a fixed pool of worker
//!   threads (one queue each, created at [`ServerBuilder::build`] and
//!   joined on drop);
//! - requests are **routed by program key** — the `(expr, shapes)` pair
//!   — so every request for one compiled program lands on the same
//!   worker and reuses that worker's warm [`Program`] (persistent
//!   machine, recycled buffers: steady-state requests perform zero
//!   tensor allocations, counter-asserted in `tests/serving.rs`);
//! - queued requests with the *same* key are **coalesced**: the worker
//!   pops the head of its queue plus every same-key request behind it
//!   (up to [`COALESCE_MAX`]);
//! - a coalesced batch of two or more is then **fused into one batched
//!   execution** through [`Program::run_batch_into`]: the whole batch
//!   pays one per-term engine configuration (and one staging pass for
//!   operands the members share — closed-loop clients submitting one
//!   `Arc`'d input set stage it once for the entire batch) instead of
//!   once per request.  Results are bitwise identical to serving the
//!   batch back-to-back with `run_into`, on every backend and at every
//!   thread count, because each member drives exactly the serial path's
//!   kernel sequence against its own recycled buffer set.  Per-ticket
//!   replies are still fulfilled individually: a member that fails
//!   admission (e.g. a shape-invalid destination) gets its own typed
//!   error while its batch-mates complete normally.  Every member of a
//!   fused batch is counted in [`ServeStats::batched`]; ordering within
//!   the batch is submission order, and the latency cost of riding in a
//!   batch is bounded by [`COALESCE_MAX`];
//! - each worker's queue is **bounded** ([`ServerBuilder::queue_capacity`]):
//!   a full queue blocks [`Server::submit`] until the worker drains —
//!   natural backpressure instead of unbounded memory growth;
//! - per-tenant [`ServeStats`] track queue depth, p50/p99 latency,
//!   throughput, and the warm-program cache hit rate.
//!
//! # Fault tolerance
//!
//! Production serving treats failure as traffic, not as an exception.
//! Every layer of this module has a typed, non-blocking answer to
//! something going wrong:
//!
//! **Admission.**  [`Server::submit`] blocks on backpressure;
//! [`Server::try_submit`] returns [`Error::QueueFull`] immediately
//! instead (counted as `shed` in [`ServeStats`]), and
//! [`Server::submit_with_deadline`] bounds both the backpressure wait
//! *and* the request's queue residency — a request whose deadline
//! expires before a worker picks it up is failed with
//! [`Error::DeadlineExceeded`] (counted as `timeouts`) rather than run
//! late.  A shut-down server fails all three with
//! [`Error::ServerShutdown`].  On the wait side,
//! [`Ticket::wait_timeout`] returns [`Error::DeadlineExceeded`] after a
//! bound instead of blocking forever; the worker still fulfills the
//! abandoned slot, so no state leaks.
//!
//! **Containment and retry.**  Both the compile path and the run path
//! execute under per-request panic containment: a panicking planner or
//! kernel costs that request a typed error (and drops the possibly
//! inconsistent program — the plan stays cached), never the worker.
//! Failures caused by *where* a request ran — [`Error::Transient`] run
//! errors, contained run panics, a dying worker — are retried with a
//! small exponential backoff up to [`ServerBuilder::max_retries`]
//! (counted as `retries`); deterministic failures of the request itself
//! (parse/shape/plan/compile) are never retried, they would fail
//! identically every time.
//!
//! **Supervision.**  A panic *outside* per-request containment (the
//! fault injector's `serve.worker` site, or a real bug in the worker
//! loop) kills the worker's incarnation; the supervisor restarts it in
//! the same OS thread with a **fresh warm-program LRU** (counted as
//! `restarts`), and the requests it had in hand are re-examined: each is
//! requeued for the new incarnation while it has retry budget left, or
//! failed with a typed [`Error::WorkerLost`] once the budget is spent.
//! Either way **every accepted ticket resolves** — the fulfill-on-drop
//! guard backstops even paths the supervisor cannot see.
//!
//! **Injection.**  All of the above is rehearsed, not hoped for: the
//! engine-wide [`crate::fault::FaultPlan`] seam has three serving sites
//! (`serve.worker`, uncontained; `serve.run` and `serve.compile`,
//! contained), the server inherits the session's plan (or takes its own
//! via [`ServerBuilder::fault_plan`]), and `DEINSUM_FAULT_SEED` arms a
//! deterministic chaos schedule in CI.  `tests/faults.rs` drives every
//! recovery path against exact injected-fault counts.
//!
//! Clients submit a [`ServeRequest`] (inputs shared by `Arc`, output
//! destination moved in and returned through the [`Ticket`] — the
//! recycled-output `run_into` path end to end) and wait on the ticket:
//!
//! ```
//! use std::sync::Arc;
//! use deinsum::{ServeRequest, Server, Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let session = Session::builder().ranks(4).build()?;
//! let server = Server::builder(session).workers(2).build();
//! let shapes = vec![vec![8, 6], vec![6, 4]];
//! let ticket = server.submit(ServeRequest {
//!     tenant: "docs".into(),
//!     expr: "ij,jk->ik".into(),
//!     shapes: shapes.clone(),
//!     inputs: Arc::new(vec![Tensor::random(&[8, 6], 1), Tensor::random(&[6, 4], 2)]),
//!     dest: Tensor::zeros(&Server::output_dims("ij,jk->ik", &shapes)?),
//! })?;
//! let reply = ticket.wait()?;
//! assert_eq!(reply.output.dims(), &[8, 4]);
//! # Ok(())
//! # }
//! ```

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::api::{Program, Session};
use crate::coordinator::{BatchRun, RunMetrics};
use crate::einsum::EinsumSpec;
use crate::error::{Error, Result};
use crate::fault::{self, Faults};
use crate::sync;
use crate::tensor::Tensor;

/// Maximum requests a worker serves back-to-back from one queue pop
/// (the coalescing window).  Bounds the latency a late same-key arrival
/// can add to requests of *other* keys queued behind it.
pub const COALESCE_MAX: usize = 16;

/// Latency samples retained per tenant for the p50/p99 estimates (a
/// sliding window, so long-running servers report recent behavior).
const LATENCY_WINDOW: usize = 1024;

/// Pause before a crashed worker incarnation is restarted: long enough
/// to keep a hard crash loop from spinning a core, short enough to be
/// invisible at serving timescales.
const RESTART_BACKOFF: Duration = Duration::from_millis(2);

/// What identifies a compiled program for routing and coalescing: the
/// einsum expression and the operand shapes (rank count and planner
/// knobs are session-wide).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ProgramKey {
    expr: String,
    shapes: Vec<Vec<usize>>,
}

impl ProgramKey {
    /// Stable routing hash (`DefaultHasher::new` is keyed with fixed
    /// constants, so the key→worker map is deterministic).
    fn route(&self, workers: usize) -> usize {
        let mut h = DefaultHasher::new();
        self.hash(&mut h);
        (h.finish() % workers as u64) as usize
    }
}

/// One unit of traffic: which tenant is asking, what program to run
/// (expression + operand shapes, compiled on first use and cached), the
/// input tensors (shared — a closed-loop client reuses one `Arc` across
/// requests), and the output destination (moved in, filled by
/// `run_into`, returned through the ticket — the fully recycled path).
pub struct ServeRequest {
    /// Tenant name for per-tenant accounting ([`Server::tenant_stats`]).
    pub tenant: String,
    /// Einsum expression, e.g. `"ijk,ja,ka->ia"`.
    pub expr: String,
    /// Global operand shapes (one per einsum operand, in order).
    pub shapes: Vec<Vec<usize>>,
    /// Global input tensors matching `shapes`.
    pub inputs: Arc<Vec<Tensor>>,
    /// Output destination; dims must equal
    /// [`Server::output_dims`]`(expr, shapes)` (checked at submit).
    pub dest: Tensor,
}

/// A served request's result: the filled output destination (the same
/// buffer submitted as [`ServeRequest::dest`]), the run's
/// time/communication accounting, and the end-to-end latency.
#[derive(Debug)]
pub struct ServeReply {
    /// The output tensor (the request's recycled `dest`, now filled).
    pub output: Tensor,
    /// Simulated time + exact communication volumes of the run.
    pub metrics: RunMetrics,
    /// Submit-to-completion wall-clock seconds (queueing included).
    pub latency_s: f64,
}

/// Per-tenant (or server-wide) serving counters.  Latency percentiles
/// are computed over a sliding window of the most recent 1024
/// completions (`LATENCY_WINDOW`).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests accepted by [`Server::submit`].
    pub submitted: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that finished with an error (delivered via the ticket).
    pub errors: u64,
    /// Accepted but not yet finished (queued or executing).
    pub in_flight: u64,
    /// Requests currently sitting in worker queues (server-wide stats
    /// only; per-tenant stats report `in_flight` here).
    pub queue_depth: usize,
    /// Requests served as part of a same-key batch behind a leader
    /// (each coalesced batch of `n` counts `n - 1`).
    pub coalesced: u64,
    /// Requests executed through the fused batched path
    /// ([`Program::run_batch_into`]) — every member of a fused batch
    /// counts, the leader included, so a batch of `n` counts `n`.
    /// Requests served one-at-a-time (no same-key follower was queued)
    /// are not counted here even when they were marked `coalesced`.
    pub batched: u64,
    /// Requests that found their program warm on the owning worker.
    pub program_hits: u64,
    /// Requests that had to construct (compile or re-instantiate) a
    /// program first.
    pub program_misses: u64,
    /// Requests rejected by [`Server::try_submit`] on a full queue
    /// (never admitted — not part of `submitted`).
    pub shed: u64,
    /// Deadline expiries: [`Server::submit_with_deadline`] admissions
    /// that timed out, queued requests whose deadline passed before a
    /// worker reached them, and (server-wide only)
    /// [`Ticket::wait_timeout`] waits that gave up.
    pub timeouts: u64,
    /// Retry attempts scheduled for requests that failed transiently or
    /// were in a dying worker's hands (each retry counts once).
    pub retries: u64,
    /// Worker incarnations restarted by the supervisor after a panic
    /// outside per-request containment (server-wide only; always 0 in
    /// per-tenant stats — workers are not tenant-owned).
    pub restarts: u64,
    /// Median submit-to-completion latency, seconds.
    pub p50_latency_s: f64,
    /// 99th-percentile latency, seconds.
    pub p99_latency_s: f64,
    /// Completions per second between the first submit and the latest
    /// completion.
    pub throughput_rps: f64,
    /// Whole-tensor allocations performed serving these requests (store
    /// destinations + compute outputs + local scratch; engine packing
    /// scratch is session-wide and excluded).  Flat in steady state.
    pub tensor_allocs: u64,
    /// Whole-tensor recycles serving these requests.
    pub tensor_reuses: u64,
}

impl ServeStats {
    /// Warm-program cache hit rate in `[0, 1]` (1.0 when no requests).
    pub fn hit_rate(&self) -> f64 {
        let total = self.program_hits + self.program_misses;
        if total == 0 {
            return 1.0;
        }
        self.program_hits as f64 / total as f64
    }
}

/// Per-tenant accumulator behind the stats mutex.
#[derive(Default)]
struct Acc {
    submitted: u64,
    completed: u64,
    errors: u64,
    coalesced: u64,
    batched: u64,
    program_hits: u64,
    program_misses: u64,
    shed: u64,
    timeouts: u64,
    retries: u64,
    tensor_allocs: u64,
    tensor_reuses: u64,
    latencies: VecDeque<f64>,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

impl Acc {
    fn note_submit(&mut self, now: Instant) {
        self.submitted += 1;
        self.first_submit.get_or_insert(now);
    }

    fn note_done(&mut self, latency_s: f64, ok: bool, now: Instant) {
        if ok {
            self.completed += 1;
        } else {
            self.errors += 1;
        }
        if self.latencies.len() >= LATENCY_WINDOW {
            self.latencies.pop_front();
        }
        self.latencies.push_back(latency_s);
        self.last_done = Some(now);
    }

    /// Cheap copy taken *under* the stats lock; the percentile sort runs
    /// on the copy after release ([`Frozen::finish`]) so a monitoring
    /// poll never stalls the submit/complete path behind an O(n log n)
    /// sort.
    fn freeze(&self) -> Frozen {
        Frozen {
            submitted: self.submitted,
            completed: self.completed,
            errors: self.errors,
            coalesced: self.coalesced,
            batched: self.batched,
            program_hits: self.program_hits,
            program_misses: self.program_misses,
            shed: self.shed,
            timeouts: self.timeouts,
            retries: self.retries,
            tensor_allocs: self.tensor_allocs,
            tensor_reuses: self.tensor_reuses,
            latencies: self.latencies.iter().copied().collect(),
            first_submit: self.first_submit,
            last_done: self.last_done,
        }
    }
}

/// Lock-free continuation of [`Acc::freeze`].
struct Frozen {
    submitted: u64,
    completed: u64,
    errors: u64,
    coalesced: u64,
    batched: u64,
    program_hits: u64,
    program_misses: u64,
    shed: u64,
    timeouts: u64,
    retries: u64,
    tensor_allocs: u64,
    tensor_reuses: u64,
    latencies: Vec<f64>,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

impl Frozen {
    fn finish(mut self, queue_depth: usize) -> ServeStats {
        self.latencies.sort_by(f64::total_cmp);
        let pct = |q: f64| -> f64 {
            if self.latencies.is_empty() {
                return 0.0;
            }
            let idx = ((self.latencies.len() - 1) as f64 * q).round() as usize;
            self.latencies[idx.min(self.latencies.len() - 1)]
        };
        let throughput = match (self.first_submit, self.last_done) {
            (Some(t0), Some(t1)) if self.completed > 0 => {
                self.completed as f64 / t1.duration_since(t0).as_secs_f64().max(1e-9)
            }
            _ => 0.0,
        };
        ServeStats {
            submitted: self.submitted,
            completed: self.completed,
            errors: self.errors,
            in_flight: self.submitted.saturating_sub(self.completed + self.errors),
            queue_depth,
            coalesced: self.coalesced,
            batched: self.batched,
            program_hits: self.program_hits,
            program_misses: self.program_misses,
            shed: self.shed,
            timeouts: self.timeouts,
            retries: self.retries,
            restarts: 0, // filled in by Server::stats (supervisor-owned)
            p50_latency_s: pct(0.50),
            p99_latency_s: pct(0.99),
            throughput_rps: throughput,
            tensor_allocs: self.tensor_allocs,
            tensor_reuses: self.tensor_reuses,
        }
    }
}

#[derive(Default)]
struct StatsInner {
    totals: Acc,
    tenants: HashMap<String, Acc>,
}

/// One queued request (internal).  Admission state that fault handling
/// needs — the deadline, the retry budget spent, the coalesced flag —
/// lives here, NOT on the public [`ServeRequest`] (whose literal-struct
/// construction across tests/benches/examples must stay stable).
struct Request {
    key: ProgramKey,
    tenant: String,
    inputs: Arc<Vec<Tensor>>,
    dest: Tensor,
    reply: ReplyGuard,
    submitted: Instant,
    /// Fail with [`Error::DeadlineExceeded`] if still unserved past this.
    deadline: Option<Instant>,
    /// Retry attempts consumed so far (bounded by `Shared::max_retries`).
    attempts: u32,
    /// Served behind a same-key batch leader (set by `pop_batch`).
    coalesced: bool,
}

/// Completion slot a [`Ticket`] waits on.
struct ReplySlot {
    result: Mutex<Option<Result<ServeReply>>>,
    cv: Condvar,
}

impl ReplySlot {
    fn new() -> Arc<Self> {
        Arc::new(ReplySlot { result: Mutex::new(None), cv: Condvar::new() })
    }

    fn fulfill(&self, r: Result<ServeReply>) {
        let mut slot = sync::lock(&self.result);
        if slot.is_none() {
            *slot = Some(r);
            self.cv.notify_all();
        }
    }
}

/// The worker-side handle on a reply slot.  Dropping it *unfulfilled* —
/// a worker thread dying outside the per-request panic containment, or
/// queued requests being torn down — delivers an error instead of
/// leaving [`Ticket::wait`] blocked forever: every accepted ticket
/// resolves, one way or the other.
struct ReplyGuard {
    slot: Arc<ReplySlot>,
}

impl ReplyGuard {
    fn fulfill(&self, r: Result<ServeReply>) {
        self.slot.fulfill(r);
    }
}

impl Drop for ReplyGuard {
    fn drop(&mut self) {
        // Poison-tolerant and non-panicking: this can run while
        // unwinding from a panic elsewhere.
        let mut slot = sync::lock(&self.slot.result);
        if slot.is_none() {
            *slot = Some(Err(Error::worker_lost(
                "request dropped unserved (worker died or server torn down)",
            )));
            self.slot.cv.notify_all();
        }
    }
}

/// Handle to one in-flight request; [`Ticket::wait`] blocks until the
/// serving worker fulfills it (success or typed error), and
/// [`Ticket::wait_timeout`] bounds the wait.
pub struct Ticket {
    slot: Arc<ReplySlot>,
    /// Back-reference for the `timeouts` counter; `Weak` so an abandoned
    /// ticket never keeps a dropped server's state alive.
    shared: Weak<Shared>,
}

impl Ticket {
    /// Block until the request finishes and take its result.
    pub fn wait(self) -> Result<ServeReply> {
        let mut r = sync::lock(&self.slot.result);
        loop {
            if let Some(res) = r.take() {
                return res;
            }
            r = sync::wait(&self.slot.cv, r);
        }
    }

    /// [`wait`](Self::wait) bounded by `timeout`: returns
    /// [`Error::DeadlineExceeded`] (counted in the server-wide
    /// [`ServeStats::timeouts`]) if no result arrived in time.  The
    /// request itself is *not* cancelled — the worker still runs it and
    /// fulfills the slot (the fulfill-on-drop guard's invariant), the
    /// result is simply discarded when this consumed ticket drops.
    pub fn wait_timeout(self, timeout: Duration) -> Result<ServeReply> {
        let deadline = Instant::now() + timeout;
        let mut r = sync::lock(&self.slot.result);
        loop {
            if let Some(res) = r.take() {
                return res;
            }
            let now = Instant::now();
            if now >= deadline {
                drop(r);
                if let Some(shared) = self.shared.upgrade() {
                    sync::lock(&shared.stats).totals.timeouts += 1;
                }
                return Err(Error::DeadlineExceeded);
            }
            let (guard, _timed_out) =
                sync::wait_timeout(&self.slot.cv, r, deadline - now);
            r = guard;
        }
    }

    /// Non-blocking poll: `true` once the result is ready.
    pub fn is_ready(&self) -> bool {
        sync::lock(&self.slot.result).is_some()
    }
}

/// One worker's bounded queue.
struct WorkQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl WorkQueue {
    fn new() -> Self {
        WorkQueue {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Request>,
    closed: bool,
}

/// How `submit_inner` behaves at a full queue.
enum Admission {
    /// Block until space frees up ([`Server::submit`]).
    Block,
    /// Fail immediately with [`Error::QueueFull`] ([`Server::try_submit`]).
    Try,
    /// Block until space or the deadline, whichever first
    /// ([`Server::submit_with_deadline`]).
    Deadline(Instant),
}

/// A completion record for the stats accumulators.
struct DoneNote {
    latency_s: f64,
    ok: bool,
    /// `Some(hit)` when a program lookup served the request; `None` when
    /// it never reached a program (expired in queue, retry budget spent
    /// in a dying worker) so hit/miss accounting stays exact.
    lookup: Option<bool>,
    coalesced: bool,
    /// Executed through the fused batched path (`run_batch_into`).
    batched: bool,
    allocs: u64,
    reuses: u64,
    /// Also count a deadline expiry.
    timeout: bool,
}

/// Bound on the memoized output-dims table (distinct program keys seen
/// at submit); an overflow clears the table rather than growing without
/// limit under adversarial unique-key traffic.
const DIMS_CACHE_CAP: usize = 1024;

struct Shared {
    session: Arc<Session>,
    queues: Vec<WorkQueue>,
    capacity: usize,
    programs_per_worker: usize,
    /// Max retry attempts per request for retryable failures (transient
    /// run errors, contained run panics, dying workers).
    max_retries: u32,
    /// The fault-injection seam the workers check (inherited from the
    /// session's engine unless overridden on the builder).
    faults: Faults,
    /// Worker incarnations restarted by the supervisor.
    restarts: AtomicU64,
    stats: Mutex<StatsInner>,
    /// Memoized `output_dims` per program key: submit validates the
    /// destination without re-parsing the expression on every request.
    dims_cache: Mutex<HashMap<ProgramKey, Vec<usize>>>,
}

impl Shared {
    /// Pop the next batch for worker `w`: the queue head plus every
    /// same-key request behind it (up to [`COALESCE_MAX`]).  `None` on
    /// shutdown with an empty queue — workers drain before exiting, so
    /// every accepted ticket is fulfilled.
    fn pop_batch(&self, w: usize) -> Option<Vec<Request>> {
        let q = &self.queues[w];
        let mut st = sync::lock(&q.state);
        loop {
            if let Some(leader) = st.queue.pop_front() {
                let key = leader.key.clone();
                let mut batch = vec![leader];
                let mut i = 0;
                while i < st.queue.len() && batch.len() < COALESCE_MAX {
                    if st.queue[i].key == key {
                        let mut req = st.queue.remove(i).expect("index checked");
                        req.coalesced = true;
                        batch.push(req);
                    } else {
                        i += 1;
                    }
                }
                q.not_full.notify_all();
                return Some(batch);
            }
            if st.closed {
                return None;
            }
            st = sync::wait(&q.not_empty, st);
        }
    }

    /// Run `f` against both the totals and the tenant's accumulator
    /// (created on first contact) under one lock acquisition.
    fn with_tenant(&self, tenant: &str, f: impl Fn(&mut Acc)) {
        let mut stats = sync::lock(&self.stats);
        let inner = &mut *stats;
        // Allocate the owned tenant key only on first contact; the
        // steady-state path stays allocation-free.
        if !inner.tenants.contains_key(tenant) {
            inner.tenants.insert(tenant.to_string(), Acc::default());
        }
        f(&mut inner.totals);
        f(inner.tenants.get_mut(tenant).expect("inserted above"));
    }

    /// Record a completion under both the tenant and the totals.
    fn note_done(&self, tenant: &str, d: DoneNote) {
        let now = Instant::now();
        self.with_tenant(tenant, |acc| {
            acc.note_done(d.latency_s, d.ok, now);
            match d.lookup {
                Some(true) => acc.program_hits += 1,
                Some(false) => acc.program_misses += 1,
                None => {}
            }
            if d.coalesced {
                acc.coalesced += 1;
            }
            if d.batched {
                acc.batched += 1;
            }
            if d.timeout {
                acc.timeouts += 1;
            }
            acc.tensor_allocs += d.allocs;
            acc.tensor_reuses += d.reuses;
        });
    }

    fn note_shed(&self, tenant: &str) {
        self.with_tenant(tenant, |acc| acc.shed += 1);
    }

    fn note_admission_timeout(&self, tenant: &str) {
        self.with_tenant(tenant, |acc| acc.timeouts += 1);
    }

    fn note_retry(&self, tenant: &str) {
        self.with_tenant(tenant, |acc| acc.retries += 1);
    }

    fn queue_depth(&self) -> usize {
        self.queues.iter().map(|q| sync::lock(&q.state).queue.len()).sum()
    }

    /// [`Server::output_dims`] memoized by program key — steady-state
    /// submits skip the einsum re-parse entirely.
    fn output_dims_cached(&self, key: &ProgramKey) -> Result<Vec<usize>> {
        if let Some(dims) = sync::lock(&self.dims_cache).get(key) {
            return Ok(dims.clone());
        }
        let dims = Server::output_dims(&key.expr, &key.shapes)?;
        let mut cache = sync::lock(&self.dims_cache);
        if cache.len() >= DIMS_CACHE_CAP {
            cache.clear();
        }
        cache.insert(key.clone(), dims.clone());
        Ok(dims)
    }
}

/// A warm compiled program held by one worker, with the last-seen
/// [`crate::api::RunStats::tensor_allocs`] /
/// [`crate::api::RunStats::tensor_reuses`] counters so each request's
/// allocation delta can be attributed (engine packing scratch is
/// deliberately excluded there: that pool is shared session-wide, so
/// its high-water mark can move when *another* program first runs a
/// larger shape — per-request accounting would misattribute it).
struct WarmProgram {
    program: Program,
    allocs_seen: u64,
    reuses_seen: u64,
}

/// Configures and builds a [`Server`].
pub struct ServerBuilder {
    session: Arc<Session>,
    workers: usize,
    queue_capacity: usize,
    programs_per_worker: usize,
    max_retries: u32,
    fault_plan: Option<fault::FaultPlan>,
}

impl ServerBuilder {
    /// Number of worker threads (default 4, minimum 1).  Requests are
    /// routed to workers by program key, so distinct programs execute
    /// concurrently while same-program traffic stays on one warm state.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Bound of each worker's submission queue (default 64, minimum 1);
    /// a full queue blocks `submit` until the worker drains.
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    /// Warm programs kept per worker before the least recently used is
    /// dropped (default 32, minimum 1).  Evicting a program frees its
    /// persistent machine and scratch; its *plan* stays in the session
    /// cache, so re-instantiating is cheap.
    pub fn programs_per_worker(mut self, n: usize) -> Self {
        self.programs_per_worker = n.max(1);
        self
    }

    /// Maximum retry attempts per request for **retryable** failures —
    /// [`Error::is_retryable`] run errors, contained run panics, and
    /// requests caught in a dying worker (default 2).  Deterministic
    /// compile/validation errors are never retried regardless.  `0`
    /// disables retry entirely.
    pub fn max_retries(mut self, n: u32) -> Self {
        self.max_retries = n;
        self
    }

    /// Install an explicit fault-injection plan for the `serve.*` sites,
    /// overriding the default (the session engine's plan, which itself
    /// defaults to `DEINSUM_FAULT_SEED`).  See [`crate::fault`].
    pub fn fault_plan(mut self, plan: fault::FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Spawn the worker pool and start serving.
    pub fn build(self) -> Server {
        let workers = self.workers;
        let faults = match self.fault_plan {
            Some(plan) => Faults::plan(plan),
            None => self.session.engine().faults().clone(),
        };
        let shared = Arc::new(Shared {
            session: self.session,
            queues: (0..workers).map(|_| WorkQueue::new()).collect(),
            capacity: self.queue_capacity,
            programs_per_worker: self.programs_per_worker,
            max_retries: self.max_retries,
            faults,
            restarts: AtomicU64::new(0),
            stats: Mutex::new(StatsInner::default()),
            dims_cache: Mutex::new(HashMap::new()),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("deinsum-serve-{w}"))
                    .spawn(move || worker_thread(shared, w))
                    .expect("spawn serve worker")
            })
            .collect();
        Server { shared, handles }
    }
}

/// The multi-tenant serving front: a fixed worker pool over one shared
/// [`Session`].  See the [module docs](self).
///
/// Dropping the server closes every queue, drains outstanding requests
/// (all accepted tickets are fulfilled), and joins the workers.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start configuring a server over `session` (an owned [`Session`]
    /// or an existing `Arc<Session>` — the session stays usable for
    /// direct compiles alongside the server).
    pub fn builder(session: impl Into<Arc<Session>>) -> ServerBuilder {
        ServerBuilder {
            session: session.into(),
            workers: 4,
            queue_capacity: 64,
            programs_per_worker: 32,
            max_retries: 2,
            fault_plan: None,
        }
    }

    /// Global output dims of `expr` over `shapes` — what a
    /// [`ServeRequest::dest`] must be allocated as.
    pub fn output_dims(expr: &str, shapes: &[Vec<usize>]) -> Result<Vec<usize>> {
        Ok(EinsumSpec::parse(expr, shapes)?.output_shape())
    }

    /// Enqueue a request on the worker owning its `(expr, shapes)` key.
    /// Validates the expression and destination dims up front (typed
    /// error now rather than through the ticket), then blocks only while
    /// that worker's queue is at capacity.  Execution errors are
    /// delivered through the returned [`Ticket`].  A shut-down server
    /// returns [`Error::ServerShutdown`].
    pub fn submit(&self, req: ServeRequest) -> Result<Ticket> {
        self.submit_inner(req, Admission::Block)
    }

    /// Non-blocking [`submit`](Self::submit): a full target queue
    /// returns [`Error::QueueFull`] immediately (counted as
    /// [`ServeStats::shed`]) instead of waiting — the load-shedding
    /// admission path for latency-sensitive callers.
    pub fn try_submit(&self, req: ServeRequest) -> Result<Ticket> {
        self.submit_inner(req, Admission::Try)
    }

    /// [`submit`](Self::submit) with an end-to-end deadline of
    /// `Instant::now() + timeout`.  The deadline bounds the backpressure
    /// wait (an admission that cannot get queue space in time returns
    /// [`Error::DeadlineExceeded`]) *and* the queue residency: a request
    /// still unserved when its deadline passes is failed through the
    /// ticket with [`Error::DeadlineExceeded`] rather than run late.
    /// Both count as [`ServeStats::timeouts`].  Pair with
    /// [`Ticket::wait_timeout`] to bound the client's wait as well.
    pub fn submit_with_deadline(
        &self,
        req: ServeRequest,
        timeout: Duration,
    ) -> Result<Ticket> {
        self.submit_inner(req, Admission::Deadline(Instant::now() + timeout))
    }

    fn submit_inner(&self, req: ServeRequest, admission: Admission) -> Result<Ticket> {
        let key = ProgramKey { expr: req.expr, shapes: req.shapes };
        // Validation is memoized by key: the first submit of a key pays
        // one parse; steady-state submits only compare dims.
        let want = self.shared.output_dims_cached(&key)?;
        if req.dest.dims() != want {
            return Err(Error::shape(format!(
                "submit: dest dims {:?} != output dims {want:?} of {}",
                req.dest.dims(),
                key.expr
            )));
        }
        let w = key.route(self.shared.queues.len());
        let slot = ReplySlot::new();
        let deadline = match admission {
            Admission::Deadline(d) => Some(d),
            _ => None,
        };
        let request = Request {
            key,
            tenant: req.tenant,
            inputs: req.inputs,
            dest: req.dest,
            reply: ReplyGuard { slot: Arc::clone(&slot) },
            submitted: Instant::now(),
            deadline,
            attempts: 0,
            coalesced: false,
        };
        {
            let q = &self.shared.queues[w];
            let mut st = sync::lock(&q.state);
            loop {
                if st.closed {
                    return Err(Error::ServerShutdown);
                }
                if st.queue.len() < self.shared.capacity {
                    break;
                }
                match admission {
                    Admission::Block => st = sync::wait(&q.not_full, st),
                    Admission::Try => {
                        drop(st);
                        self.shared.note_shed(&request.tenant);
                        return Err(Error::QueueFull);
                    }
                    Admission::Deadline(d) => {
                        let now = Instant::now();
                        if now >= d {
                            drop(st);
                            self.shared.note_admission_timeout(&request.tenant);
                            return Err(Error::DeadlineExceeded);
                        }
                        let (guard, _timed_out) =
                            sync::wait_timeout(&q.not_full, st, d - now);
                        st = guard;
                    }
                }
            }
            {
                let now = Instant::now();
                let mut stats = sync::lock(&self.shared.stats);
                stats.totals.note_submit(now);
                // Clone the tenant name only for a first-ever submit.
                match stats.tenants.get_mut(&request.tenant) {
                    Some(acc) => acc.note_submit(now),
                    None => {
                        let mut acc = Acc::default();
                        acc.note_submit(now);
                        stats.tenants.insert(request.tenant.clone(), acc);
                    }
                }
            }
            st.queue.push_back(request);
            q.not_empty.notify_all();
        }
        Ok(Ticket { slot, shared: Arc::downgrade(&self.shared) })
    }

    /// Server-wide counters (latency window spans all tenants).
    pub fn stats(&self) -> ServeStats {
        let depth = self.shared.queue_depth();
        let frozen = sync::lock(&self.shared.stats).totals.freeze();
        let mut stats = frozen.finish(depth);
        stats.restarts = self.shared.restarts.load(Ordering::Relaxed);
        stats
    }

    /// One tenant's counters (`queue_depth` reports the tenant's
    /// in-flight count), or `None` if the tenant never submitted.
    pub fn tenant_stats(&self, tenant: &str) -> Option<ServeStats> {
        let frozen = sync::lock(&self.shared.stats).tenants.get(tenant).map(Acc::freeze)?;
        let in_flight = frozen.submitted.saturating_sub(frozen.completed + frozen.errors);
        Some(frozen.finish(in_flight as usize))
    }

    /// Tenants seen so far (sorted).
    pub fn tenants(&self) -> Vec<String> {
        let mut t: Vec<String> =
            sync::lock(&self.shared.stats).tenants.keys().cloned().collect();
        t.sort();
        t
    }

    /// The session every worker compiles through (shared plan cache).
    pub fn session(&self) -> &Session {
        &self.shared.session
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }

    /// Stop admitting work: every queue is closed, subsequent submits
    /// return [`Error::ServerShutdown`], and workers exit after draining
    /// what was already accepted (every outstanding ticket still
    /// resolves).  Idempotent; dropping the server shuts down too and
    /// additionally joins the worker threads.
    pub fn shutdown(&self) {
        for q in &self.shared.queues {
            sync::lock(&q.state).closed = true;
            q.not_empty.notify_all();
            q.not_full.notify_all();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The supervisor: runs worker incarnations in this OS thread until one
/// exits cleanly (queue closed and drained).  An incarnation that dies —
/// a panic outside per-request containment, e.g. the injector's
/// `serve.worker` site — is counted, its in-hand requests are triaged
/// (requeued while retry budget remains, failed with
/// [`Error::WorkerLost`] otherwise), and a fresh incarnation starts
/// after a short pause with an empty warm-program LRU (the session's
/// plan cache makes re-instantiation cheap).
fn worker_thread(shared: Arc<Shared>, w: usize) {
    // Requests popped from the queue but not yet resolved.  Owned OUT
    // here so they survive an incarnation's unwind and can be triaged.
    let mut pending: VecDeque<Request> = VecDeque::new();
    loop {
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            worker_serve(&shared, w, &mut pending)
        }));
        if run.is_ok() {
            return; // clean shutdown
        }
        shared.restarts.fetch_add(1, Ordering::Relaxed);
        triage_after_crash(&shared, w, &mut pending);
        std::thread::sleep(RESTART_BACKOFF);
    }
}

/// Decide the fate of every request a dead incarnation had in hand.
fn triage_after_crash(shared: &Shared, w: usize, pending: &mut VecDeque<Request>) {
    let mut keep: VecDeque<Request> = VecDeque::with_capacity(pending.len());
    while let Some(mut req) = pending.pop_front() {
        if req.attempts < shared.max_retries {
            req.attempts += 1;
            shared.note_retry(&req.tenant);
            keep.push_back(req);
        } else {
            let latency_s = req.submitted.elapsed().as_secs_f64();
            shared.note_done(
                &req.tenant,
                DoneNote {
                    latency_s,
                    ok: false,
                    lookup: None,
                    coalesced: req.coalesced,
                    batched: false,
                    allocs: 0,
                    reuses: 0,
                    timeout: false,
                },
            );
            req.reply.fulfill(Err(Error::worker_lost(format!(
                "worker {w} died serving {}; retry budget exhausted",
                req.key.expr
            ))));
        }
    }
    *pending = keep;
}

/// One worker incarnation: refill `pending` from the queue in coalesced
/// same-key batches and serve it front-to-back on warm programs from an
/// incarnation-local LRU.  Returns on clean shutdown; panics here (the
/// uncontained `serve.worker` site, or a real bug) are the supervisor's
/// problem.
fn worker_serve(shared: &Shared, w: usize, pending: &mut VecDeque<Request>) {
    // MRU at the back, like the session's plan cache.  Incarnation-local
    // by design: a crash may have left any program inconsistent, so the
    // replacement starts cold and re-instantiates from cached plans.
    let mut warm: Vec<(ProgramKey, WarmProgram)> = Vec::new();
    loop {
        if pending.is_empty() {
            match shared.pop_batch(w) {
                Some(batch) => pending.extend(batch),
                None => return,
            }
        }
        // Uncontained fault site: a panic or escalated fault here kills
        // this incarnation with requests in hand — exactly the scenario
        // supervision + triage exists for.
        shared.faults.check_abort(fault::site::SERVE_WORKER);
        // `pending` only ever holds one coalesced same-key batch (refills
        // happen strictly on empty), so two or more requests dispatch as
        // one fused batched execution.
        if pending.len() > 1 {
            serve_batch(shared, pending, &mut warm);
        } else {
            serve_front(shared, pending, &mut warm);
        }
    }
}

/// Serve (or retry, or expire) the front request of `pending`.  The
/// request leaves the deque only when its ticket has been fulfilled;
/// a retryable failure leaves it at the front with one more attempt
/// consumed, so a crash mid-serve is triaged with the right budget.
fn serve_front(
    shared: &Shared,
    pending: &mut VecDeque<Request>,
    warm: &mut Vec<(ProgramKey, WarmProgram)>,
) {
    // Deadline first: don't spend compile/run work on a request nobody
    // is waiting for anymore.
    let expired = {
        let req = pending.front().expect("serve_front needs a request");
        req.deadline.is_some_and(|d| Instant::now() >= d)
    };
    if expired {
        let req = pending.pop_front().expect("checked above");
        let latency_s = req.submitted.elapsed().as_secs_f64();
        shared.note_done(
            &req.tenant,
            DoneNote {
                latency_s,
                ok: false,
                lookup: None,
                coalesced: req.coalesced,
                batched: false,
                allocs: 0,
                reuses: 0,
                timeout: true,
            },
        );
        req.reply.fulfill(Err(Error::DeadlineExceeded));
        return;
    }

    let key = pending.front().expect("checked above").key.clone();
    let (mut prog, hit) = match acquire_program(shared, warm, &key) {
        Ok(p) => p,
        Err(e) => {
            let req = pending.pop_front().expect("checked above");
            let latency_s = req.submitted.elapsed().as_secs_f64();
            shared.note_done(
                &req.tenant,
                DoneNote {
                    latency_s,
                    ok: false,
                    lookup: Some(false),
                    coalesced: req.coalesced,
                    batched: false,
                    allocs: 0,
                    reuses: 0,
                    timeout: false,
                },
            );
            // Deliver the planner's error as-is: clients match on the
            // typed variant (Shape vs Plan vs Runtime) to tell bad
            // requests from server faults.
            req.reply.fulfill(Err(e));
            return;
        }
    };

    // Run under containment.  The request stays at the front (served
    // through `&mut`), so an uncontained crash elsewhere still finds it
    // in `pending` for triage.
    let req = pending.front_mut().expect("checked above");
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> Result<RunMetrics> {
            shared.faults.check(fault::site::SERVE_RUN)?;
            prog.program.run_into(&req.inputs, &mut req.dest)
        },
    ));
    let latency_s = req.submitted.elapsed().as_secs_f64();
    match run {
        Ok(run_result) => {
            // Typed result: the program's state is consistent either
            // way, so it goes back in the LRU.
            let st = prog.program.stats();
            let allocs = st.tensor_allocs() - prog.allocs_seen;
            let reuses = st.tensor_reuses() - prog.reuses_seen;
            prog.allocs_seen = st.tensor_allocs();
            prog.reuses_seen = st.tensor_reuses();
            match run_result {
                Err(e) if e.is_retryable() && req.attempts < shared.max_retries => {
                    req.attempts += 1;
                    let attempts = req.attempts;
                    shared.note_retry(&req.tenant);
                    reinsert_warm(shared, warm, key, prog);
                    retry_backoff(attempts);
                    return;
                }
                run_result => {
                    let req = pending.pop_front().expect("checked above");
                    let ok = run_result.is_ok();
                    shared.note_done(
                        &req.tenant,
                        DoneNote {
                            latency_s,
                            ok,
                            lookup: Some(hit),
                            coalesced: req.coalesced,
                            batched: false,
                            allocs,
                            reuses,
                            timeout: false,
                        },
                    );
                    match run_result {
                        Ok(metrics) => req.reply.fulfill(Ok(ServeReply {
                            output: req.dest,
                            metrics,
                            latency_s,
                        })),
                        Err(e) => req.reply.fulfill(Err(e)),
                    }
                    reinsert_warm(shared, warm, key, prog);
                }
            }
        }
        Err(_panic) => {
            // Contained run panic: the program may be inconsistent —
            // drop it (`prog` falls out of scope un-reinserted; the next
            // attempt re-instantiates from the cached plan).  The
            // failure is positional, so it gets retry budget.
            if req.attempts < shared.max_retries {
                req.attempts += 1;
                let attempts = req.attempts;
                shared.note_retry(&req.tenant);
                retry_backoff(attempts);
            } else {
                let req = pending.pop_front().expect("checked above");
                shared.note_done(
                    &req.tenant,
                    DoneNote {
                        latency_s,
                        ok: false,
                        lookup: Some(hit),
                        coalesced: req.coalesced,
                        batched: false,
                        allocs: 0,
                        reuses: 0,
                        timeout: false,
                    },
                );
                req.reply.fulfill(Err(Error::runtime(format!(
                    "serving {} panicked; program state dropped, retry budget exhausted",
                    key.expr
                ))));
            }
        }
    }
}

/// Take the warm program for `key` out of the LRU, or compile one under
/// containment: a planner panic (or the injector's `serve.compile` site)
/// must cost the requester a typed error, not the worker thread — and
/// compile failures are deterministic, so they are NEVER retried.
/// Returns the program plus whether it was a warm hit.
fn acquire_program(
    shared: &Shared,
    warm: &mut Vec<(ProgramKey, WarmProgram)>,
    key: &ProgramKey,
) -> Result<(WarmProgram, bool)> {
    if let Some(pos) = warm.iter().position(|(k, _)| k == key) {
        return Ok((warm.remove(pos).1, true));
    }
    let compiled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        shared.faults.check(fault::site::SERVE_COMPILE)?;
        shared.session.compile(&key.expr, &key.shapes)
    }))
    .unwrap_or_else(|_| Err(Error::runtime(format!("planning {} panicked", key.expr))));
    compiled.map(|program| {
        let st = program.stats();
        let wp = WarmProgram {
            program,
            allocs_seen: st.tensor_allocs(),
            reuses_seen: st.tensor_reuses(),
        };
        (wp, false)
    })
}

/// Serve a coalesced same-key batch of two or more requests through one
/// fused [`Program::run_batch_into`] execution.  Per-ticket semantics
/// are unchanged from [`serve_front`]: every member's reply is fulfilled
/// individually (its own [`RunMetrics`] on success, its own typed error
/// on a per-member admission failure), deadline-expired members are
/// failed before any work is spent, and a batch-level failure is retried
/// against each member's own budget or fanned out typed.  The batch's
/// whole-run allocation delta is attributed to its leader's
/// [`DoneNote`], so the steady-state `tensor_allocs`-flat invariant is
/// asserted across the batched path exactly as for serial serving.
fn serve_batch(
    shared: &Shared,
    pending: &mut VecDeque<Request>,
    warm: &mut Vec<(ProgramKey, WarmProgram)>,
) {
    // Deadline sweep first: don't stage operands for a request nobody is
    // waiting for anymore (an expired member anywhere in the batch).
    let now = Instant::now();
    let mut i = 0;
    while i < pending.len() {
        if pending[i].deadline.is_some_and(|d| now >= d) {
            let req = pending.remove(i).expect("index checked");
            let latency_s = req.submitted.elapsed().as_secs_f64();
            shared.note_done(
                &req.tenant,
                DoneNote {
                    latency_s,
                    ok: false,
                    lookup: None,
                    coalesced: req.coalesced,
                    batched: false,
                    allocs: 0,
                    reuses: 0,
                    timeout: true,
                },
            );
            req.reply.fulfill(Err(Error::DeadlineExceeded));
        } else {
            i += 1;
        }
    }
    if pending.len() <= 1 {
        if !pending.is_empty() {
            serve_front(shared, pending, warm);
        }
        return;
    }

    let key = pending.front().expect("length checked").key.clone();
    debug_assert!(
        pending.iter().all(|r| r.key == key),
        "a worker's pending set must be one coalesced same-key batch"
    );
    let (mut prog, hit) = match acquire_program(shared, warm, &key) {
        Ok(p) => p,
        Err(e) => {
            // A compile failure is deterministic for the whole same-key
            // batch: fail every member typed, never retry any of them.
            while let Some(req) = pending.pop_front() {
                let latency_s = req.submitted.elapsed().as_secs_f64();
                shared.note_done(
                    &req.tenant,
                    DoneNote {
                        latency_s,
                        ok: false,
                        lookup: Some(false),
                        coalesced: req.coalesced,
                        batched: false,
                        allocs: 0,
                        reuses: 0,
                        timeout: false,
                    },
                );
                req.reply.fulfill(Err(e.duplicate()));
            }
            return;
        }
    };

    // Run the fused batch under containment.  The requests stay in
    // `pending` (served through disjoint `&mut` borrows), so an
    // uncontained crash mid-batch still finds all of them for triage.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> Result<Vec<Result<RunMetrics>>> {
            shared.faults.check(fault::site::SERVE_RUN)?;
            let mut members: Vec<BatchRun<'_>> = pending
                .iter_mut()
                .map(|r| BatchRun::new(&r.inputs, &mut r.dest))
                .collect();
            prog.program.run_batch_into(&mut members)
        },
    ));
    match run {
        Ok(run_result) => {
            // Typed result either way: the program's state is consistent,
            // it goes back in the LRU.  The whole batch's alloc delta is
            // attributed to the leader (member buffers are per-member,
            // but staging dedup makes the split member-dependent —
            // aggregate accounting is the honest number).
            let st = prog.program.stats();
            let allocs = st.tensor_allocs() - prog.allocs_seen;
            let reuses = st.tensor_reuses() - prog.reuses_seen;
            prog.allocs_seen = st.tensor_allocs();
            prog.reuses_seen = st.tensor_reuses();
            match run_result {
                Ok(results) => {
                    debug_assert_eq!(results.len(), pending.len());
                    let mut first = true;
                    for result in results {
                        let Some(req) = pending.pop_front() else { break };
                        let latency_s = req.submitted.elapsed().as_secs_f64();
                        shared.note_done(
                            &req.tenant,
                            DoneNote {
                                latency_s,
                                ok: result.is_ok(),
                                // Followers always find the program in
                                // hand — their lookup is a hit.
                                lookup: Some(if first { hit } else { true }),
                                coalesced: req.coalesced,
                                batched: true,
                                allocs: if first { allocs } else { 0 },
                                reuses: if first { reuses } else { 0 },
                                timeout: false,
                            },
                        );
                        first = false;
                        match result {
                            Ok(metrics) => req.reply.fulfill(Ok(ServeReply {
                                output: req.dest,
                                metrics,
                                latency_s,
                            })),
                            // Per-member admission failure (e.g. a
                            // shape-invalid dest): deterministic, typed,
                            // batch-mates unaffected.
                            Err(e) => req.reply.fulfill(Err(e)),
                        }
                    }
                    reinsert_warm(shared, warm, key, prog);
                }
                Err(e) if e.is_retryable() => {
                    // Batch-level positional failure: no member completed.
                    // Members with retry budget stay queued; the rest fail
                    // with a copy of the batch error.
                    reinsert_warm(shared, warm, key, prog);
                    let max_attempts =
                        retry_or_fail_batch(shared, pending, hit, |_| e.duplicate());
                    if max_attempts > 0 {
                        retry_backoff(max_attempts);
                    }
                }
                Err(e) => {
                    // Deterministic batch-level failure: fan out typed.
                    reinsert_warm(shared, warm, key, prog);
                    let mut first = true;
                    while let Some(req) = pending.pop_front() {
                        let latency_s = req.submitted.elapsed().as_secs_f64();
                        shared.note_done(
                            &req.tenant,
                            DoneNote {
                                latency_s,
                                ok: false,
                                lookup: Some(if first { hit } else { true }),
                                coalesced: req.coalesced,
                                batched: true,
                                allocs: if first { allocs } else { 0 },
                                reuses: if first { reuses } else { 0 },
                                timeout: false,
                            },
                        );
                        first = false;
                        req.reply.fulfill(Err(e.duplicate()));
                    }
                }
            }
        }
        Err(_panic) => {
            // Contained run panic mid-batch: the program may be
            // inconsistent — drop it (the next attempt re-instantiates
            // from the cached plan).  Positional failure: per-member
            // retry budget, like serve_front.
            let max_attempts = retry_or_fail_batch(shared, pending, hit, |_| {
                Error::runtime(format!(
                    "serving {} panicked; program state dropped, retry budget exhausted",
                    key.expr
                ))
            });
            if max_attempts > 0 {
                retry_backoff(max_attempts);
            }
        }
    }
}

/// Batch-level failure triage: every member with retry budget left stays
/// queued with one more attempt consumed (and `retries` counted); the
/// rest are failed with `err_for`'s typed error.  Returns the largest
/// attempt count bumped (`0` when every member was failed) so the caller
/// can back off before the re-attempt.
fn retry_or_fail_batch(
    shared: &Shared,
    pending: &mut VecDeque<Request>,
    hit: bool,
    mut err_for: impl FnMut(&Request) -> Error,
) -> u32 {
    let mut max_attempts = 0;
    let mut i = 0;
    while i < pending.len() {
        if pending[i].attempts < shared.max_retries {
            pending[i].attempts += 1;
            max_attempts = max_attempts.max(pending[i].attempts);
            shared.note_retry(&pending[i].tenant);
            i += 1;
        } else {
            let req = pending.remove(i).expect("index checked");
            let latency_s = req.submitted.elapsed().as_secs_f64();
            shared.note_done(
                &req.tenant,
                DoneNote {
                    latency_s,
                    ok: false,
                    lookup: Some(hit),
                    coalesced: req.coalesced,
                    batched: true,
                    allocs: 0,
                    reuses: 0,
                    timeout: false,
                },
            );
            let e = err_for(&req);
            req.reply.fulfill(Err(e));
        }
    }
    max_attempts
}

/// Return a program to the warm LRU as MRU, evicting the LRU entry at
/// capacity.
fn reinsert_warm(
    shared: &Shared,
    warm: &mut Vec<(ProgramKey, WarmProgram)>,
    key: ProgramKey,
    prog: WarmProgram,
) {
    if warm.len() >= shared.programs_per_worker {
        warm.remove(0);
    }
    warm.push((key, prog));
}

/// Small exponential backoff between retry attempts (100µs, 200µs,
/// 400µs, ... capped at ~25ms): long enough for a transient condition to
/// clear, short enough to stay invisible in p99 at test scales.
fn retry_backoff(attempts: u32) {
    let micros = 100u64 << attempts.min(8) as u64;
    std::thread::sleep(Duration::from_micros(micros));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm_request(tenant: &str, n: usize, seed: u64) -> ServeRequest {
        let shapes = vec![vec![n, 6], vec![6, 4]];
        ServeRequest {
            tenant: tenant.into(),
            expr: "ij,jk->ik".into(),
            shapes: shapes.clone(),
            inputs: Arc::new(vec![
                Tensor::random(&shapes[0], seed),
                Tensor::random(&shapes[1], seed + 1),
            ]),
            dest: Tensor::zeros(&[n, 4]),
        }
    }

    fn test_shared(queues: usize) -> Arc<Shared> {
        Arc::new(Shared {
            session: Arc::new(Session::builder().ranks(2).build().unwrap()),
            queues: (0..queues).map(|_| WorkQueue::new()).collect(),
            capacity: 64,
            programs_per_worker: 4,
            max_retries: 2,
            faults: Faults::none(),
            restarts: AtomicU64::new(0),
            stats: Mutex::new(StatsInner::default()),
            dims_cache: Mutex::new(HashMap::new()),
        })
    }

    fn raw_request(expr: &str, slot: Arc<ReplySlot>) -> Request {
        Request {
            key: ProgramKey { expr: expr.into(), shapes: vec![vec![4, 4], vec![4, 4]] },
            tenant: "t".into(),
            inputs: Arc::new(vec![]),
            dest: Tensor::zeros(&[4, 4]),
            reply: ReplyGuard { slot },
            submitted: Instant::now(),
            deadline: None,
            attempts: 0,
            coalesced: false,
        }
    }

    #[test]
    fn output_dims_matches_spec() {
        let dims =
            Server::output_dims("ijk,ja,ka->ai", &[vec![8, 6, 4], vec![6, 3], vec![4, 3]])
                .unwrap();
        assert_eq!(dims, vec![3, 8]);
        assert!(Server::output_dims("ij,jk->ik", &[vec![2, 2]]).is_err());
    }

    #[test]
    fn single_request_roundtrip_matches_direct_run() {
        let session = Session::builder().ranks(4).build().unwrap();
        let req = gemm_request("t0", 8, 10);
        let inputs = Arc::clone(&req.inputs);
        // Direct reference through a second program of the same session
        // shape (fresh session: identical config → bitwise-equal).
        let reference = {
            let s = Session::builder().ranks(4).build().unwrap();
            let mut p = s.compile("ij,jk->ik", &req.shapes).unwrap();
            p.run(&inputs).unwrap().output
        };
        let server = Server::builder(session).workers(2).build();
        let reply = server.submit(req).unwrap().wait().unwrap();
        assert!(reply.output.allclose(&reference, 0.0, 0.0));
        assert!(reply.latency_s >= 0.0);
        assert_eq!(reply.metrics.per_term.len(), 1);
        let st = server.stats();
        assert_eq!((st.submitted, st.completed, st.errors), (1, 1, 0));
        assert_eq!(st.program_misses, 1, "first request instantiates the program");
        assert_eq!((st.shed, st.timeouts, st.retries, st.restarts), (0, 0, 0, 0));
        let ts = server.tenant_stats("t0").unwrap();
        assert_eq!(ts.completed, 1);
        assert!(server.tenant_stats("nobody").is_none());
    }

    #[test]
    fn submit_rejects_bad_destination_and_bad_expr() {
        let server =
            Server::builder(Session::builder().ranks(2).build().unwrap()).workers(1).build();
        let mut req = gemm_request("t", 8, 3);
        req.dest = Tensor::zeros(&[3, 3]);
        assert!(matches!(server.submit(req), Err(Error::Shape(_))));
        let mut bad = gemm_request("t", 8, 4);
        bad.expr = "ij,jk-".into();
        assert!(server.submit(bad).is_err());
        // Nothing was accepted.
        assert_eq!(server.stats().submitted, 0);
    }

    #[test]
    fn same_key_requests_route_to_one_worker_and_coalesce_when_queued() {
        // Coalescing is deterministic at the queue level: pop_batch takes
        // the head plus every same-key request behind it, marking the
        // followers coalesced.
        let shared = test_shared(1);
        {
            let mut st = sync::lock(&shared.queues[0].state);
            for expr in ["ij,jk->ik", "ij,jk->ki", "ij,jk->ik", "ij,jk->ik"] {
                st.queue.push_back(raw_request(expr, ReplySlot::new()));
            }
        }
        let batch = shared.pop_batch(0).expect("head batch");
        assert_eq!(batch.len(), 3, "leader + two same-key followers");
        assert!(batch.iter().all(|r| r.key.expr == "ij,jk->ik"));
        assert!(!batch[0].coalesced, "the leader is not coalesced");
        assert!(batch[1..].iter().all(|r| r.coalesced), "followers are marked");
        let batch = shared.pop_batch(0).expect("remaining key");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].key.expr, "ij,jk->ki");
        // Routing is stable: the same key always picks the same worker.
        let k = ProgramKey { expr: "ijk,ja,ka->ia".into(), shapes: vec![vec![4, 4, 4]] };
        assert_eq!(k.route(8), k.route(8));
        assert!(k.route(8) < 8);
    }

    #[test]
    fn dropping_an_unserved_request_errors_the_ticket_instead_of_hanging() {
        // The no-hang guarantee: whatever kills a request between accept
        // and fulfill (worker death, teardown), the ticket resolves —
        // with the typed WorkerLost error since 0.7.0.
        let slot = ReplySlot::new();
        let ticket = Ticket { slot: Arc::clone(&slot), shared: Weak::new() };
        let req = raw_request("ij,jk->ik", slot);
        drop(req);
        let err = ticket.wait().expect_err("unserved request must deliver an error");
        assert!(matches!(err, Error::WorkerLost(_)), "{err}");
        assert!(err.is_retryable(), "a dropped-unserved request is safe to resubmit");
    }

    #[test]
    fn shutdown_drains_outstanding_tickets() {
        let server =
            Server::builder(Session::builder().ranks(2).build().unwrap()).workers(1).build();
        let tickets: Vec<Ticket> =
            (0..6).map(|i| server.submit(gemm_request("t", 8, 20 + i)).unwrap()).collect();
        drop(server);
        for t in tickets {
            assert!(t.wait().is_ok(), "accepted requests must be served before shutdown");
        }
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let server =
            Server::builder(Session::builder().ranks(2).build().unwrap()).workers(2).build();
        server.shutdown();
        for submit in [Server::submit, Server::try_submit] {
            match submit(&server, gemm_request("t", 8, 40)) {
                Err(Error::ServerShutdown) => {}
                other => panic!("expected ServerShutdown, got {:?}", other.err()),
            }
        }
        match server.submit_with_deadline(gemm_request("t", 8, 41), Duration::from_secs(1))
        {
            Err(Error::ServerShutdown) => {}
            other => panic!("expected ServerShutdown, got {:?}", other.err()),
        }
        assert_eq!(server.stats().submitted, 0);
        // Idempotent: shutting down again (and via Drop) is fine.
        server.shutdown();
    }

    #[test]
    fn try_submit_sheds_on_a_full_queue() {
        // Stuff the (single) worker's queue beyond capacity by hand so
        // the shed path is deterministic, then verify try_submit fails
        // typed and counted while blocking submit still works later.
        let session = Session::builder().ranks(2).build().unwrap();
        let server = Server::builder(session).workers(1).queue_capacity(1).build();
        // Occupy the worker and fill the queue: first request executes,
        // the second sits in the one queue slot.  A tiny sleep-free way
        // to make this deterministic: pause the worker by filling with
        // requests; capacity 1 means one queued request is "full".
        let t1 = server.submit(gemm_request("t", 32, 50)).unwrap();
        let t2 = server.submit(gemm_request("t", 32, 52)).unwrap();
        // Now hammer try_submit until one submission observes the full
        // queue (the worker may drain at any time; shed>=1 once we see
        // QueueFull).
        let mut saw_shed = false;
        let mut accepted: Vec<Ticket> = Vec::new();
        for i in 0..256 {
            match server.try_submit(gemm_request("t", 32, 60 + i)) {
                Err(Error::QueueFull) => {
                    saw_shed = true;
                    break;
                }
                Err(e) => panic!("unexpected admission error: {e}"),
                Ok(t) => accepted.push(t),
            }
        }
        let accepted_count = accepted.len() as u64;
        t1.wait().unwrap();
        t2.wait().unwrap();
        for t in accepted {
            t.wait().unwrap();
        }
        let st = server.stats();
        if saw_shed {
            assert!(st.shed >= 1, "QueueFull rejections must be counted: {st:?}");
        }
        assert_eq!(
            st.submitted, 2 + accepted_count,
            "shed requests are not admitted (not part of `submitted`)"
        );
        assert_eq!(st.errors, 0);
        assert_eq!(st.in_flight, 0);
    }

    #[test]
    fn wait_timeout_returns_typed_error_and_the_slot_still_resolves() {
        // A ticket abandoned at its wait deadline must not hang, and the
        // worker must still fulfill the slot afterwards.
        let slot = ReplySlot::new();
        let ticket = Ticket { slot: Arc::clone(&slot), shared: Weak::new() };
        let t0 = Instant::now();
        let err = ticket
            .wait_timeout(Duration::from_millis(20))
            .expect_err("nothing fulfills the slot in time");
        assert!(matches!(err, Error::DeadlineExceeded), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // The guard's fulfill-on-drop still resolves the abandoned slot.
        slot.fulfill(Err(Error::runtime("late result")));
        assert!(sync::lock(&slot.result).is_some());
    }

    #[test]
    fn wait_timeout_returns_early_when_fulfilled() {
        let session = Session::builder().ranks(2).build().unwrap();
        let server = Server::builder(session).workers(1).build();
        let ticket = server.submit(gemm_request("t", 8, 70)).unwrap();
        let reply = ticket
            .wait_timeout(Duration::from_secs(30))
            .expect("a served request resolves well before the bound");
        assert_eq!(reply.output.dims(), &[8, 4]);
        assert_eq!(server.stats().timeouts, 0);
    }

    #[test]
    fn queued_deadline_expiry_fails_typed_through_the_ticket() {
        // An already-expired deadline: admission succeeds (queue has
        // space) but the worker expires the request instead of running
        // it.
        let session = Session::builder().ranks(2).build().unwrap();
        let server = Server::builder(session).workers(1).build();
        let ticket = server
            .submit_with_deadline(gemm_request("t", 8, 80), Duration::from_nanos(1))
            .unwrap();
        match ticket.wait() {
            Err(Error::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {:?}", other.err()),
        }
        let st = server.stats();
        assert_eq!(st.timeouts, 1, "queue expiry must be counted: {st:?}");
        assert_eq!(st.errors, 1, "expiry resolves the request as an error");
        assert_eq!(st.in_flight, 0);
    }

    #[test]
    fn stats_percentiles_are_ordered() {
        let mut acc = Acc::default();
        let t0 = Instant::now();
        for _ in 0..100 {
            acc.note_submit(t0);
        }
        for i in 0..100 {
            acc.note_done(i as f64 / 100.0, true, Instant::now());
        }
        let s = acc.freeze().finish(0);
        assert!(s.p50_latency_s <= s.p99_latency_s);
        assert_eq!(s.completed, 100);
        assert_eq!(s.in_flight, 0);
        assert!(s.throughput_rps > 0.0);
        assert_eq!(s.hit_rate(), 1.0, "no program lookups recorded yet");
    }
}
