//! Multi-tenant serving layer over [`Session`]: a fixed worker pool
//! executing compiled [`Program`]s concurrently against one shared
//! engine.
//!
//! The paper's compile-once/run-many shape (§II) is exactly what a
//! serving workload wants: a distributed schedule is compiled into a
//! cacheable [`crate::planner::Plan`], and the marginal cost of a query
//! is one warm `run_into` — zero planning, zero tensor allocations.  DISTAL and
//! EinDecomp make the same observation from the scheduling side: once
//! the schedule is a *value*, the win is running many of them
//! concurrently against shared local-compute machinery.  This module is
//! that layer:
//!
//! - a [`Server`] owns an `Arc<Session>` and a fixed pool of worker
//!   threads (one queue each, created at [`ServerBuilder::build`] and
//!   joined on drop);
//! - requests are **routed by program key** — the `(expr, shapes)` pair
//!   — so every request for one compiled program lands on the same
//!   worker and reuses that worker's warm [`Program`] (persistent
//!   machine, recycled buffers: steady-state requests perform zero
//!   tensor allocations, counter-asserted in `tests/serving.rs`);
//! - queued requests with the *same* key are **coalesced**: the worker
//!   pops the head of its queue plus every same-key request behind it
//!   (up to [`COALESCE_MAX`]) and serves them back-to-back on the warm
//!   program, amortizing per-program staging and term configuration;
//! - each worker's queue is **bounded** ([`ServerBuilder::queue_capacity`]):
//!   a full queue blocks [`Server::submit`] until the worker drains —
//!   natural backpressure instead of unbounded memory growth;
//! - per-tenant [`ServeStats`] track queue depth, p50/p99 latency,
//!   throughput, and the warm-program cache hit rate.
//!
//! Clients submit a [`ServeRequest`] (inputs shared by `Arc`, output
//! destination moved in and returned through the [`Ticket`] — the
//! recycled-output `run_into` path end to end) and wait on the ticket:
//!
//! ```
//! use std::sync::Arc;
//! use deinsum::{ServeRequest, Server, Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let session = Session::builder().ranks(4).build()?;
//! let server = Server::builder(session).workers(2).build();
//! let shapes = vec![vec![8, 6], vec![6, 4]];
//! let ticket = server.submit(ServeRequest {
//!     tenant: "docs".into(),
//!     expr: "ij,jk->ik".into(),
//!     shapes: shapes.clone(),
//!     inputs: Arc::new(vec![Tensor::random(&[8, 6], 1), Tensor::random(&[6, 4], 2)]),
//!     dest: Tensor::zeros(&Server::output_dims("ij,jk->ik", &shapes)?),
//! })?;
//! let reply = ticket.wait()?;
//! assert_eq!(reply.output.dims(), &[8, 4]);
//! # Ok(())
//! # }
//! ```

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::api::{Program, Session};
use crate::coordinator::RunMetrics;
use crate::einsum::EinsumSpec;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Maximum requests a worker serves back-to-back from one queue pop
/// (the coalescing window).  Bounds the latency a late same-key arrival
/// can add to requests of *other* keys queued behind it.
pub const COALESCE_MAX: usize = 16;

/// Latency samples retained per tenant for the p50/p99 estimates (a
/// sliding window, so long-running servers report recent behavior).
const LATENCY_WINDOW: usize = 1024;

/// What identifies a compiled program for routing and coalescing: the
/// einsum expression and the operand shapes (rank count and planner
/// knobs are session-wide).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ProgramKey {
    expr: String,
    shapes: Vec<Vec<usize>>,
}

impl ProgramKey {
    /// Stable routing hash (`DefaultHasher::new` is keyed with fixed
    /// constants, so the key→worker map is deterministic).
    fn route(&self, workers: usize) -> usize {
        let mut h = DefaultHasher::new();
        self.hash(&mut h);
        (h.finish() % workers as u64) as usize
    }
}

/// One unit of traffic: which tenant is asking, what program to run
/// (expression + operand shapes, compiled on first use and cached), the
/// input tensors (shared — a closed-loop client reuses one `Arc` across
/// requests), and the output destination (moved in, filled by
/// `run_into`, returned through the ticket — the fully recycled path).
pub struct ServeRequest {
    /// Tenant name for per-tenant accounting ([`Server::tenant_stats`]).
    pub tenant: String,
    /// Einsum expression, e.g. `"ijk,ja,ka->ia"`.
    pub expr: String,
    /// Global operand shapes (one per einsum operand, in order).
    pub shapes: Vec<Vec<usize>>,
    /// Global input tensors matching `shapes`.
    pub inputs: Arc<Vec<Tensor>>,
    /// Output destination; dims must equal
    /// [`Server::output_dims`]`(expr, shapes)` (checked at submit).
    pub dest: Tensor,
}

/// A served request's result: the filled output destination (the same
/// buffer submitted as [`ServeRequest::dest`]), the run's
/// time/communication accounting, and the end-to-end latency.
#[derive(Debug)]
pub struct ServeReply {
    /// The output tensor (the request's recycled `dest`, now filled).
    pub output: Tensor,
    /// Simulated time + exact communication volumes of the run.
    pub metrics: RunMetrics,
    /// Submit-to-completion wall-clock seconds (queueing included).
    pub latency_s: f64,
}

/// Per-tenant (or server-wide) serving counters.  Latency percentiles
/// are computed over a sliding window of the most recent 1024
/// completions (`LATENCY_WINDOW`).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests accepted by [`Server::submit`].
    pub submitted: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that finished with an error (delivered via the ticket).
    pub errors: u64,
    /// Accepted but not yet finished (queued or executing).
    pub in_flight: u64,
    /// Requests currently sitting in worker queues (server-wide stats
    /// only; per-tenant stats report `in_flight` here).
    pub queue_depth: usize,
    /// Requests served as part of a same-key batch behind a leader
    /// (each coalesced batch of `n` counts `n - 1`).
    pub coalesced: u64,
    /// Requests that found their program warm on the owning worker.
    pub program_hits: u64,
    /// Requests that had to construct (compile or re-instantiate) a
    /// program first.
    pub program_misses: u64,
    /// Median submit-to-completion latency, seconds.
    pub p50_latency_s: f64,
    /// 99th-percentile latency, seconds.
    pub p99_latency_s: f64,
    /// Completions per second between the first submit and the latest
    /// completion.
    pub throughput_rps: f64,
    /// Whole-tensor allocations performed serving these requests (store
    /// destinations + compute outputs + local scratch; engine packing
    /// scratch is session-wide and excluded).  Flat in steady state.
    pub tensor_allocs: u64,
    /// Whole-tensor recycles serving these requests.
    pub tensor_reuses: u64,
}

impl ServeStats {
    /// Warm-program cache hit rate in `[0, 1]` (1.0 when no requests).
    pub fn hit_rate(&self) -> f64 {
        let total = self.program_hits + self.program_misses;
        if total == 0 {
            return 1.0;
        }
        self.program_hits as f64 / total as f64
    }
}

/// Per-tenant accumulator behind the stats mutex.
#[derive(Default)]
struct Acc {
    submitted: u64,
    completed: u64,
    errors: u64,
    coalesced: u64,
    program_hits: u64,
    program_misses: u64,
    tensor_allocs: u64,
    tensor_reuses: u64,
    latencies: VecDeque<f64>,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

impl Acc {
    fn note_submit(&mut self, now: Instant) {
        self.submitted += 1;
        self.first_submit.get_or_insert(now);
    }

    fn note_done(&mut self, latency_s: f64, ok: bool, now: Instant) {
        if ok {
            self.completed += 1;
        } else {
            self.errors += 1;
        }
        if self.latencies.len() >= LATENCY_WINDOW {
            self.latencies.pop_front();
        }
        self.latencies.push_back(latency_s);
        self.last_done = Some(now);
    }

    /// Cheap copy taken *under* the stats lock; the percentile sort runs
    /// on the copy after release ([`Frozen::finish`]) so a monitoring
    /// poll never stalls the submit/complete path behind an O(n log n)
    /// sort.
    fn freeze(&self) -> Frozen {
        Frozen {
            submitted: self.submitted,
            completed: self.completed,
            errors: self.errors,
            coalesced: self.coalesced,
            program_hits: self.program_hits,
            program_misses: self.program_misses,
            tensor_allocs: self.tensor_allocs,
            tensor_reuses: self.tensor_reuses,
            latencies: self.latencies.iter().copied().collect(),
            first_submit: self.first_submit,
            last_done: self.last_done,
        }
    }
}

/// Lock-free continuation of [`Acc::freeze`].
struct Frozen {
    submitted: u64,
    completed: u64,
    errors: u64,
    coalesced: u64,
    program_hits: u64,
    program_misses: u64,
    tensor_allocs: u64,
    tensor_reuses: u64,
    latencies: Vec<f64>,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

impl Frozen {
    fn finish(mut self, queue_depth: usize) -> ServeStats {
        self.latencies.sort_by(f64::total_cmp);
        let pct = |q: f64| -> f64 {
            if self.latencies.is_empty() {
                return 0.0;
            }
            let idx = ((self.latencies.len() - 1) as f64 * q).round() as usize;
            self.latencies[idx.min(self.latencies.len() - 1)]
        };
        let throughput = match (self.first_submit, self.last_done) {
            (Some(t0), Some(t1)) if self.completed > 0 => {
                self.completed as f64 / t1.duration_since(t0).as_secs_f64().max(1e-9)
            }
            _ => 0.0,
        };
        ServeStats {
            submitted: self.submitted,
            completed: self.completed,
            errors: self.errors,
            in_flight: self.submitted.saturating_sub(self.completed + self.errors),
            queue_depth,
            coalesced: self.coalesced,
            program_hits: self.program_hits,
            program_misses: self.program_misses,
            p50_latency_s: pct(0.50),
            p99_latency_s: pct(0.99),
            throughput_rps: throughput,
            tensor_allocs: self.tensor_allocs,
            tensor_reuses: self.tensor_reuses,
        }
    }
}

#[derive(Default)]
struct StatsInner {
    totals: Acc,
    tenants: HashMap<String, Acc>,
}

/// One queued request (internal).
struct Request {
    key: ProgramKey,
    tenant: String,
    inputs: Arc<Vec<Tensor>>,
    dest: Tensor,
    reply: ReplyGuard,
    submitted: Instant,
}

/// Completion slot a [`Ticket`] waits on.
struct ReplySlot {
    result: Mutex<Option<Result<ServeReply>>>,
    cv: Condvar,
}

impl ReplySlot {
    fn new() -> Arc<Self> {
        Arc::new(ReplySlot { result: Mutex::new(None), cv: Condvar::new() })
    }

    fn fulfill(&self, r: Result<ServeReply>) {
        let mut slot = self.result.lock().unwrap();
        if slot.is_none() {
            *slot = Some(r);
            self.cv.notify_all();
        }
    }
}

/// The worker-side handle on a reply slot.  Dropping it *unfulfilled* —
/// a worker thread dying outside the per-request panic containment, or
/// queued requests being torn down — delivers an error instead of
/// leaving [`Ticket::wait`] blocked forever: every accepted ticket
/// resolves, one way or the other.
struct ReplyGuard {
    slot: Arc<ReplySlot>,
}

impl ReplyGuard {
    fn fulfill(&self, r: Result<ServeReply>) {
        self.slot.fulfill(r);
    }
}

impl Drop for ReplyGuard {
    fn drop(&mut self) {
        // Poison-tolerant: this can run while unwinding from a panic
        // elsewhere; never double-panic out of a destructor.
        if let Ok(mut slot) = self.slot.result.lock() {
            if slot.is_none() {
                *slot = Some(Err(Error::runtime(
                    "request dropped unserved (worker died or server torn down)",
                )));
                self.slot.cv.notify_all();
            }
        }
    }
}

/// Handle to one in-flight request; [`Ticket::wait`] blocks until the
/// serving worker fulfills it (success or typed error).
pub struct Ticket {
    slot: Arc<ReplySlot>,
}

impl Ticket {
    /// Block until the request finishes and take its result.
    pub fn wait(self) -> Result<ServeReply> {
        let mut r = self.slot.result.lock().unwrap();
        loop {
            if let Some(res) = r.take() {
                return res;
            }
            r = self.slot.cv.wait(r).unwrap();
        }
    }

    /// Non-blocking poll: `true` once the result is ready.
    pub fn is_ready(&self) -> bool {
        self.slot.result.lock().unwrap().is_some()
    }
}

/// One worker's bounded queue.
struct WorkQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Bound on the memoized output-dims table (distinct program keys seen
/// at submit); an overflow clears the table rather than growing without
/// limit under adversarial unique-key traffic.
const DIMS_CACHE_CAP: usize = 1024;

struct Shared {
    session: Arc<Session>,
    queues: Vec<WorkQueue>,
    capacity: usize,
    programs_per_worker: usize,
    stats: Mutex<StatsInner>,
    /// Memoized `output_dims` per program key: submit validates the
    /// destination without re-parsing the expression on every request.
    dims_cache: Mutex<HashMap<ProgramKey, Vec<usize>>>,
}

impl Shared {
    /// Pop the next batch for worker `w`: the queue head plus every
    /// same-key request behind it (up to [`COALESCE_MAX`]).  `None` on
    /// shutdown with an empty queue — workers drain before exiting, so
    /// every accepted ticket is fulfilled.
    fn pop_batch(&self, w: usize) -> Option<Vec<Request>> {
        let q = &self.queues[w];
        let mut st = q.state.lock().unwrap();
        loop {
            if let Some(leader) = st.queue.pop_front() {
                let key = leader.key.clone();
                let mut batch = vec![leader];
                let mut i = 0;
                while i < st.queue.len() && batch.len() < COALESCE_MAX {
                    if st.queue[i].key == key {
                        let req = st.queue.remove(i).expect("index checked");
                        batch.push(req);
                    } else {
                        i += 1;
                    }
                }
                q.not_full.notify_all();
                return Some(batch);
            }
            if st.closed {
                return None;
            }
            st = q.not_empty.wait(st).unwrap();
        }
    }

    /// Record a completion under both the tenant and the totals.
    fn note_done(
        &self,
        tenant: &str,
        latency_s: f64,
        ok: bool,
        hit: bool,
        coalesced: bool,
        allocs: u64,
        reuses: u64,
    ) {
        let now = Instant::now();
        let mut stats = self.stats.lock().unwrap();
        let inner = &mut *stats;
        // Allocate the owned tenant key only on first contact; the
        // steady-state completion path stays allocation-free.
        if !inner.tenants.contains_key(tenant) {
            inner.tenants.insert(tenant.to_string(), Acc::default());
        }
        let tenant_acc = inner.tenants.get_mut(tenant).expect("inserted above");
        for acc in [&mut inner.totals, tenant_acc] {
            acc.note_done(latency_s, ok, now);
            if hit {
                acc.program_hits += 1;
            } else {
                acc.program_misses += 1;
            }
            if coalesced {
                acc.coalesced += 1;
            }
            acc.tensor_allocs += allocs;
            acc.tensor_reuses += reuses;
        }
    }

    fn queue_depth(&self) -> usize {
        self.queues.iter().map(|q| q.state.lock().unwrap().queue.len()).sum()
    }

    /// [`Server::output_dims`] memoized by program key — steady-state
    /// submits skip the einsum re-parse entirely.
    fn output_dims_cached(&self, key: &ProgramKey) -> Result<Vec<usize>> {
        if let Some(dims) = self.dims_cache.lock().unwrap().get(key) {
            return Ok(dims.clone());
        }
        let dims = Server::output_dims(&key.expr, &key.shapes)?;
        let mut cache = self.dims_cache.lock().unwrap();
        if cache.len() >= DIMS_CACHE_CAP {
            cache.clear();
        }
        cache.insert(key.clone(), dims.clone());
        Ok(dims)
    }
}

/// A warm compiled program held by one worker, with the last-seen
/// [`crate::api::RunStats::tensor_allocs`] /
/// [`crate::api::RunStats::tensor_reuses`] counters so each request's
/// allocation delta can be attributed (engine packing scratch is
/// deliberately excluded there: that pool is shared session-wide, so
/// its high-water mark can move when *another* program first runs a
/// larger shape — per-request accounting would misattribute it).
struct WarmProgram {
    program: Program,
    allocs_seen: u64,
    reuses_seen: u64,
}

/// Configures and builds a [`Server`].
pub struct ServerBuilder {
    session: Arc<Session>,
    workers: usize,
    queue_capacity: usize,
    programs_per_worker: usize,
}

impl ServerBuilder {
    /// Number of worker threads (default 4, minimum 1).  Requests are
    /// routed to workers by program key, so distinct programs execute
    /// concurrently while same-program traffic stays on one warm state.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Bound of each worker's submission queue (default 64, minimum 1);
    /// a full queue blocks `submit` until the worker drains.
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    /// Warm programs kept per worker before the least recently used is
    /// dropped (default 32, minimum 1).  Evicting a program frees its
    /// persistent machine and scratch; its *plan* stays in the session
    /// cache, so re-instantiating is cheap.
    pub fn programs_per_worker(mut self, n: usize) -> Self {
        self.programs_per_worker = n.max(1);
        self
    }

    /// Spawn the worker pool and start serving.
    pub fn build(self) -> Server {
        let workers = self.workers;
        let shared = Arc::new(Shared {
            session: self.session,
            queues: (0..workers)
                .map(|_| WorkQueue {
                    state: Mutex::new(QueueState::default()),
                    not_empty: Condvar::new(),
                    not_full: Condvar::new(),
                })
                .collect(),
            capacity: self.queue_capacity,
            programs_per_worker: self.programs_per_worker,
            stats: Mutex::new(StatsInner::default()),
            dims_cache: Mutex::new(HashMap::new()),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("deinsum-serve-{w}"))
                    .spawn(move || worker_loop(shared, w))
                    .expect("spawn serve worker")
            })
            .collect();
        Server { shared, handles }
    }
}

/// The multi-tenant serving front: a fixed worker pool over one shared
/// [`Session`].  See the [module docs](self).
///
/// Dropping the server closes every queue, drains outstanding requests
/// (all accepted tickets are fulfilled), and joins the workers.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start configuring a server over `session` (an owned [`Session`]
    /// or an existing `Arc<Session>` — the session stays usable for
    /// direct compiles alongside the server).
    pub fn builder(session: impl Into<Arc<Session>>) -> ServerBuilder {
        ServerBuilder {
            session: session.into(),
            workers: 4,
            queue_capacity: 64,
            programs_per_worker: 32,
        }
    }

    /// Global output dims of `expr` over `shapes` — what a
    /// [`ServeRequest::dest`] must be allocated as.
    pub fn output_dims(expr: &str, shapes: &[Vec<usize>]) -> Result<Vec<usize>> {
        Ok(EinsumSpec::parse(expr, shapes)?.output_shape())
    }

    /// Enqueue a request on the worker owning its `(expr, shapes)` key.
    /// Validates the expression and destination dims up front (typed
    /// error now rather than through the ticket), then blocks only while
    /// that worker's queue is at capacity.  Execution errors are
    /// delivered through the returned [`Ticket`].
    pub fn submit(&self, req: ServeRequest) -> Result<Ticket> {
        let key = ProgramKey { expr: req.expr, shapes: req.shapes };
        // Validation is memoized by key: the first submit of a key pays
        // one parse; steady-state submits only compare dims.
        let want = self.shared.output_dims_cached(&key)?;
        if req.dest.dims() != want {
            return Err(Error::shape(format!(
                "submit: dest dims {:?} != output dims {want:?} of {}",
                req.dest.dims(),
                key.expr
            )));
        }
        let w = key.route(self.shared.queues.len());
        let slot = ReplySlot::new();
        let request = Request {
            key,
            tenant: req.tenant,
            inputs: req.inputs,
            dest: req.dest,
            reply: ReplyGuard { slot: Arc::clone(&slot) },
            submitted: Instant::now(),
        };
        {
            let q = &self.shared.queues[w];
            let mut st = q.state.lock().unwrap();
            while st.queue.len() >= self.shared.capacity && !st.closed {
                st = q.not_full.wait(st).unwrap();
            }
            if st.closed {
                return Err(Error::runtime("server is shut down"));
            }
            {
                let now = Instant::now();
                let mut stats = self.shared.stats.lock().unwrap();
                stats.totals.note_submit(now);
                // Clone the tenant name only for a first-ever submit.
                match stats.tenants.get_mut(&request.tenant) {
                    Some(acc) => acc.note_submit(now),
                    None => {
                        let mut acc = Acc::default();
                        acc.note_submit(now);
                        stats.tenants.insert(request.tenant.clone(), acc);
                    }
                }
            }
            st.queue.push_back(request);
            q.not_empty.notify_all();
        }
        Ok(Ticket { slot })
    }

    /// Server-wide counters (latency window spans all tenants).
    pub fn stats(&self) -> ServeStats {
        let depth = self.shared.queue_depth();
        let frozen = self.shared.stats.lock().unwrap().totals.freeze();
        frozen.finish(depth)
    }

    /// One tenant's counters (`queue_depth` reports the tenant's
    /// in-flight count), or `None` if the tenant never submitted.
    pub fn tenant_stats(&self, tenant: &str) -> Option<ServeStats> {
        let frozen =
            self.shared.stats.lock().unwrap().tenants.get(tenant).map(Acc::freeze)?;
        let in_flight = frozen.submitted.saturating_sub(frozen.completed + frozen.errors);
        Some(frozen.finish(in_flight as usize))
    }

    /// Tenants seen so far (sorted).
    pub fn tenants(&self) -> Vec<String> {
        let mut t: Vec<String> =
            self.shared.stats.lock().unwrap().tenants.keys().cloned().collect();
        t.sort();
        t
    }

    /// The session every worker compiles through (shared plan cache).
    pub fn session(&self) -> &Session {
        &self.shared.session
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for q in &self.shared.queues {
            q.state.lock().unwrap().closed = true;
            q.not_empty.notify_all();
            q.not_full.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One worker: drain the queue in coalesced same-key batches, serving
/// each batch on a warm program from the worker-local LRU.
fn worker_loop(shared: Arc<Shared>, w: usize) {
    // MRU at the back, like the session's plan cache.
    let mut warm: Vec<(ProgramKey, WarmProgram)> = Vec::new();
    while let Some(batch) = shared.pop_batch(w) {
        let key = batch[0].key.clone();
        // Take the program out of the LRU for the whole batch (it goes
        // back, as MRU, unless a task panic poisoned it).
        let mut entry: Option<WarmProgram> =
            warm.iter().position(|(k, _)| *k == key).map(|pos| warm.remove(pos).1);
        let mut was_warm = entry.is_some();
        for (i, req) in batch.into_iter().enumerate() {
            let first_of_batch = i == 0;
            // A request is a program-cache hit when the worker already
            // held the warm program (including coalesced followers riding
            // the leader's instantiation); a fresh construction — first
            // contact, or recovery after a panic — is a miss.
            // Compile is panic-contained like the run below: a planner
            // panic must cost one request an error, not the worker
            // thread (a dead worker would strand its whole queue).
            let compiled = match entry.take() {
                Some(p) => Ok(p),
                None => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    shared.session.compile(&key.expr, &key.shapes)
                }))
                .unwrap_or_else(|_| {
                    Err(Error::runtime(format!("planning {} panicked", key.expr)))
                })
                .map(|program| {
                    let st = program.stats();
                    WarmProgram {
                        program,
                        allocs_seen: st.tensor_allocs(),
                        reuses_seen: st.tensor_reuses(),
                    }
                }),
            };
            let (mut prog, hit) = match compiled {
                Ok(p) => (p, was_warm),
                Err(e) => {
                    let latency = req.submitted.elapsed().as_secs_f64();
                    shared.note_done(
                        &req.tenant,
                        latency,
                        false,
                        false,
                        !first_of_batch,
                        0,
                        0,
                    );
                    // Deliver the planner's error as-is: clients match on
                    // the typed variant (Shape vs Plan vs Runtime) to
                    // tell bad requests from server faults.
                    req.reply.fulfill(Err(e));
                    continue;
                }
            };
            let mut dest = req.dest;
            // Contain kernel panics to the request: the program is
            // dropped (its state may be inconsistent), the ticket gets a
            // typed error, and the worker lives on.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prog.program.run_into(&req.inputs, &mut dest)
            }));
            let latency = req.submitted.elapsed().as_secs_f64();
            match run {
                Ok(run_result) => {
                    let st = prog.program.stats();
                    let allocs = st.tensor_allocs() - prog.allocs_seen;
                    let reuses = st.tensor_reuses() - prog.reuses_seen;
                    prog.allocs_seen = st.tensor_allocs();
                    prog.reuses_seen = st.tensor_reuses();
                    let ok = run_result.is_ok();
                    shared.note_done(
                        &req.tenant,
                        latency,
                        ok,
                        hit,
                        !first_of_batch,
                        allocs,
                        reuses,
                    );
                    match run_result {
                        Ok(metrics) => req.reply.fulfill(Ok(ServeReply {
                            output: dest,
                            metrics,
                            latency_s: latency,
                        })),
                        Err(e) => req.reply.fulfill(Err(e)),
                    }
                    was_warm = true;
                    entry = Some(prog);
                }
                Err(_panic) => {
                    shared.note_done(&req.tenant, latency, false, hit, !first_of_batch, 0, 0);
                    req.reply.fulfill(Err(Error::runtime(format!(
                        "serving {} panicked; program state dropped",
                        key.expr
                    ))));
                    // `prog` is dropped here; the next request for this
                    // key re-instantiates from the cached plan.
                    was_warm = false;
                }
            }
        }
        if let Some(prog) = entry {
            if warm.len() >= shared.programs_per_worker {
                warm.remove(0);
            }
            warm.push((key, prog));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm_request(tenant: &str, n: usize, seed: u64) -> ServeRequest {
        let shapes = vec![vec![n, 6], vec![6, 4]];
        ServeRequest {
            tenant: tenant.into(),
            expr: "ij,jk->ik".into(),
            shapes: shapes.clone(),
            inputs: Arc::new(vec![
                Tensor::random(&shapes[0], seed),
                Tensor::random(&shapes[1], seed + 1),
            ]),
            dest: Tensor::zeros(&[n, 4]),
        }
    }

    #[test]
    fn output_dims_matches_spec() {
        let dims =
            Server::output_dims("ijk,ja,ka->ai", &[vec![8, 6, 4], vec![6, 3], vec![4, 3]])
                .unwrap();
        assert_eq!(dims, vec![3, 8]);
        assert!(Server::output_dims("ij,jk->ik", &[vec![2, 2]]).is_err());
    }

    #[test]
    fn single_request_roundtrip_matches_direct_run() {
        let session = Session::builder().ranks(4).build().unwrap();
        let req = gemm_request("t0", 8, 10);
        let inputs = Arc::clone(&req.inputs);
        // Direct reference through a second program of the same session
        // shape (fresh session: identical config → bitwise-equal).
        let reference = {
            let s = Session::builder().ranks(4).build().unwrap();
            let mut p = s.compile("ij,jk->ik", &req.shapes).unwrap();
            p.run(&inputs).unwrap().output
        };
        let server = Server::builder(session).workers(2).build();
        let reply = server.submit(req).unwrap().wait().unwrap();
        assert!(reply.output.allclose(&reference, 0.0, 0.0));
        assert!(reply.latency_s >= 0.0);
        assert_eq!(reply.metrics.per_term.len(), 1);
        let st = server.stats();
        assert_eq!((st.submitted, st.completed, st.errors), (1, 1, 0));
        assert_eq!(st.program_misses, 1, "first request instantiates the program");
        let ts = server.tenant_stats("t0").unwrap();
        assert_eq!(ts.completed, 1);
        assert!(server.tenant_stats("nobody").is_none());
    }

    #[test]
    fn submit_rejects_bad_destination_and_bad_expr() {
        let server =
            Server::builder(Session::builder().ranks(2).build().unwrap()).workers(1).build();
        let mut req = gemm_request("t", 8, 3);
        req.dest = Tensor::zeros(&[3, 3]);
        assert!(matches!(server.submit(req), Err(Error::Shape(_))));
        let mut bad = gemm_request("t", 8, 4);
        bad.expr = "ij,jk-".into();
        assert!(server.submit(bad).is_err());
        // Nothing was accepted.
        assert_eq!(server.stats().submitted, 0);
    }

    #[test]
    fn same_key_requests_route_to_one_worker_and_coalesce_when_queued() {
        // Coalescing is deterministic at the queue level: pop_batch takes
        // the head plus every same-key request behind it.
        let session = Arc::new(Session::builder().ranks(2).build().unwrap());
        let shared = Arc::new(Shared {
            session,
            queues: vec![WorkQueue {
                state: Mutex::new(QueueState::default()),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            }],
            capacity: 64,
            programs_per_worker: 4,
            stats: Mutex::new(StatsInner::default()),
            dims_cache: Mutex::new(HashMap::new()),
        });
        let mk = |expr: &str| Request {
            key: ProgramKey {
                expr: expr.into(),
                shapes: vec![vec![4, 4], vec![4, 4]],
            },
            tenant: "t".into(),
            inputs: Arc::new(vec![]),
            dest: Tensor::zeros(&[4, 4]),
            reply: ReplyGuard { slot: ReplySlot::new() },
            submitted: Instant::now(),
        };
        {
            let mut st = shared.queues[0].state.lock().unwrap();
            for expr in ["ij,jk->ik", "ij,jk->ki", "ij,jk->ik", "ij,jk->ik"] {
                st.queue.push_back(mk(expr));
            }
        }
        let batch = shared.pop_batch(0).expect("head batch");
        assert_eq!(batch.len(), 3, "leader + two same-key followers");
        assert!(batch.iter().all(|r| r.key.expr == "ij,jk->ik"));
        let batch = shared.pop_batch(0).expect("remaining key");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].key.expr, "ij,jk->ki");
        // Routing is stable: the same key always picks the same worker.
        let k = ProgramKey { expr: "ijk,ja,ka->ia".into(), shapes: vec![vec![4, 4, 4]] };
        assert_eq!(k.route(8), k.route(8));
        assert!(k.route(8) < 8);
    }

    #[test]
    fn dropping_an_unserved_request_errors_the_ticket_instead_of_hanging() {
        // The no-hang guarantee: whatever kills a request between accept
        // and fulfill (worker death, teardown), the ticket resolves.
        let slot = ReplySlot::new();
        let ticket = Ticket { slot: Arc::clone(&slot) };
        let req = Request {
            key: ProgramKey { expr: "ij,jk->ik".into(), shapes: vec![] },
            tenant: "t".into(),
            inputs: Arc::new(vec![]),
            dest: Tensor::zeros(&[1]),
            reply: ReplyGuard { slot },
            submitted: Instant::now(),
        };
        drop(req);
        let err = ticket.wait().expect_err("unserved request must deliver an error");
        assert!(err.to_string().contains("unserved"), "{err}");
    }

    #[test]
    fn shutdown_drains_outstanding_tickets() {
        let server =
            Server::builder(Session::builder().ranks(2).build().unwrap()).workers(1).build();
        let tickets: Vec<Ticket> =
            (0..6).map(|i| server.submit(gemm_request("t", 8, 20 + i)).unwrap()).collect();
        drop(server);
        for t in tickets {
            assert!(t.wait().is_ok(), "accepted requests must be served before shutdown");
        }
    }

    #[test]
    fn stats_percentiles_are_ordered() {
        let mut acc = Acc::default();
        let t0 = Instant::now();
        for _ in 0..100 {
            acc.note_submit(t0);
        }
        for i in 0..100 {
            acc.note_done(i as f64 / 100.0, true, Instant::now());
        }
        let s = acc.freeze().finish(0);
        assert!(s.p50_latency_s <= s.p99_latency_s);
        assert_eq!(s.completed, 100);
        assert_eq!(s.in_flight, 0);
        assert!(s.throughput_rps > 0.0);
        assert_eq!(s.hit_rate(), 1.0, "no program lookups recorded yet");
    }
}
