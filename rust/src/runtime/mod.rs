//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them
//! as local-tile kernels.
//!
//! `make artifacts` (build time, python) lowers every kernel variant to
//! HLO *text* — the interchange format that survives the jax>=0.5 /
//! xla_extension 0.5.1 proto-id mismatch (see /opt/xla-example/README.md)
//! — plus a `manifest.json` index.  This module:
//!
//! - loads the manifest ([`Manifest`]),
//! - lazily compiles variants on the PJRT CPU client with an executable
//!   cache ([`Engine`]),
//! - dispatches local ops, **bucketing** ragged tile shapes up to the
//!   nearest variant by zero-padding (exact for multiply-add
//!   contractions) and falling back to the native kernels in
//!   [`crate::tensor::contract`] when no bucket fits ([`KernelEngine`]).
//!
//! Python never runs here: the rust binary is self-contained once
//! `artifacts/` exists.

pub mod json;
pub mod pool;

/// The PJRT C-API surface this module compiles against.  In the offline
/// build it is a stub whose client constructor fails (native kernels then
/// serve every op); swap in the real `xla` crate to enable artifacts.
#[path = "xla_shim.rs"]
mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::fault::{self, Faults};
use crate::planner::TermPlan;
use crate::sync;
use crate::tensor::kernel::{KernelConfig, ScratchPool, ScratchStats};
use crate::tensor::{contract, Tensor};

/// One AOT-lowered kernel variant (an entry of `manifest.json`).
#[derive(Debug, Clone)]
pub struct Variant {
    /// Unique variant name (dispatch key).
    pub name: String,
    /// Kernel family (`"einsum2"`, `"mttkrp"`, ...).
    pub op: String,
    /// Element dtype the artifact was lowered for (`"f32"`).
    pub dtype: String,
    /// Artifact file name relative to the artifacts directory.
    pub file: String,
    /// Content hash used to verify the artifact on load.
    pub sha256: String,
    /// Exact input shapes the artifact was specialized to.
    pub inputs: Vec<Vec<usize>>,
    /// Exact output shape.
    pub output: Vec<usize>,
    // op-specific metadata
    /// Tensor extents (MTTKRP-family variants).
    pub dims: Option<Vec<usize>>,
    /// Factor rank R (MTTKRP-family variants).
    pub r: Option<usize>,
    /// GEMM rows M.
    pub m: Option<usize>,
    /// GEMM shared dimension K.
    pub k: Option<usize>,
    /// GEMM columns N.
    pub n: Option<usize>,
    /// First free-index extent (einsum2 variants).
    pub i0: Option<usize>,
    /// Second free-index extent (einsum2 variants).
    pub i1: Option<usize>,
    /// Reduced-index extents (einsum2 variants).
    pub rs: Option<Vec<usize>>,
    /// Contracted mode (MTTKRP-family variants).
    pub mode: Option<usize>,
}

impl Variant {
    fn from_json(v: &json::Value) -> Result<Self> {
        let req_str = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .ok_or_else(|| Error::runtime(format!("variant missing '{k}'")))
        };
        let inputs = v
            .get("inputs")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::runtime("variant missing 'inputs'"))?
            .iter()
            .map(|s| s.as_usize_vec().ok_or_else(|| Error::runtime("bad input shape")))
            .collect::<Result<Vec<_>>>()?;
        let output = v
            .get("output")
            .and_then(|x| x.as_usize_vec())
            .ok_or_else(|| Error::runtime("variant missing 'output'"))?;
        Ok(Variant {
            name: req_str("name")?,
            op: req_str("op")?,
            dtype: req_str("dtype")?,
            file: req_str("file")?,
            sha256: v.get("sha256").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            inputs,
            output,
            dims: v.get("dims").and_then(|x| x.as_usize_vec()),
            r: v.get("r").and_then(|x| x.as_usize()),
            m: v.get("m").and_then(|x| x.as_usize()),
            k: v.get("k").and_then(|x| x.as_usize()),
            n: v.get("n").and_then(|x| x.as_usize()),
            i0: v.get("i0").and_then(|x| x.as_usize()),
            i1: v.get("i1").and_then(|x| x.as_usize()),
            rs: v.get("rs").and_then(|x| x.as_usize_vec()),
            mode: v.get("mode").and_then(|x| x.as_usize()),
        })
    }
}

/// The artifact index written by `python -m compile.aot`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema tag (`"deinsum-aot-v1"`).
    pub format: String,
    /// Every lowered kernel variant in the artifacts directory.
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let doc = json::parse(&text)?;
        let format = doc
            .get("format")
            .and_then(|x| x.as_str())
            .ok_or_else(|| Error::runtime("manifest missing 'format'"))?
            .to_string();
        if format != "hlo-text-v1" {
            return Err(Error::runtime(format!("unknown manifest format {format}")));
        }
        let variants = doc
            .get("variants")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::runtime("manifest missing 'variants'"))?
            .iter()
            .map(Variant::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { format, variants })
    }
}

/// Execution counters (exposed for tests and EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Ops served by a PJRT executable with exactly-matching shapes.
    pub pjrt_exact: u64,
    /// Ops served by a PJRT executable after zero-pad bucketing.
    pub pjrt_padded: u64,
    /// Ops served by the native fallback kernels.
    pub native: u64,
    /// Lazy compilations performed.
    pub compiles: u64,
}

/// PJRT engine: CPU client + lazily-compiled executable cache.  The
/// cache and counters sit behind mutexes (`Sync`): every program of a
/// session — including the serving layer's concurrent workers — shares
/// one engine.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<EngineStats>,
}

impl Engine {
    /// Create an engine over an artifacts directory (compiles nothing yet).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    /// The loaded artifact index.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Dispatch counters (PJRT vs native fallback executions).
    pub fn stats(&self) -> EngineStats {
        sync::lock(&self.stats).clone()
    }

    fn bump(&self, f: impl FnOnce(&mut EngineStats)) {
        f(&mut sync::lock(&self.stats));
    }

    /// Find a variant by name.
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.manifest.variants.iter().find(|v| v.name == name)
    }

    fn executable(&self, v: &Variant) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = sync::lock(&self.cache).get(&v.name) {
            return Ok(e.clone());
        }
        // Compile outside the lock (it can be slow); a concurrent racer
        // compiling the same variant just wins the insert below.
        let path = self.dir.join(&v.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| Error::runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {}: {e}", v.name)))?;
        self.bump(|s| s.compiles += 1);
        let exe = Arc::new(exe);
        let exe = sync::lock(&self.cache).entry(v.name.clone()).or_insert(exe).clone();
        Ok(exe)
    }

    /// Execute a variant with exactly-matching input tensors.
    pub fn execute(&self, v: &Variant, inputs: &[&Tensor]) -> Result<Tensor> {
        if inputs.len() != v.inputs.len() {
            return Err(Error::runtime(format!(
                "{}: expected {} inputs, got {}",
                v.name,
                v.inputs.len(),
                inputs.len()
            )));
        }
        for (t, want) in inputs.iter().zip(&v.inputs) {
            if t.dims() != &want[..] {
                return Err(Error::runtime(format!(
                    "{}: input dims {:?} != variant {:?}",
                    v.name,
                    t.dims(),
                    want
                )));
            }
        }
        let exe = self.executable(v)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        t.data().as_ptr() as *const u8,
                        t.data().len() * std::mem::size_of::<f32>(),
                    )
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    t.dims(),
                    bytes,
                )
                .map_err(|e| Error::runtime(format!("literal: {e}")))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::runtime(format!("execute {}: {e}", v.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("to_literal: {e}")))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| Error::runtime(format!("tuple1: {e}")))?;
        let data =
            out.to_vec::<f32>().map_err(|e| Error::runtime(format!("to_vec: {e}")))?;
        Tensor::from_vec(&v.output, data)
    }
}

/// Backend selection for [`KernelEngine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native rust kernels only (no PJRT, no artifacts needed).
    Native,
    /// PJRT artifacts with bucketing; native fallback when no bucket fits.
    Pjrt,
}

std::thread_local! {
    /// The calling thread's per-term kernel-config overrides, keyed by
    /// the identity of the engine each was set through.  Storing the
    /// overrides in TLS (instead of a `Cell` on the engine) is what
    /// makes [`KernelEngine`] `Sync`: concurrent programs sharing one
    /// engine — the serving layer's worker pool — each retarget the
    /// blocking for *their* current term without clobbering each other's
    /// dispatch.  Keying by engine id keeps multiple engines on ONE
    /// thread fully independent (a deinsum and a baseline session
    /// compared side by side): setting or resetting through engine A
    /// never changes what engine B dispatches with.  The map is a tiny
    /// linear-scan vec — a thread touches a handful of engines at most.
    /// The run loop sets an entry before each term and removes it
    /// through a drop guard after every run (even on error or a caught
    /// kernel panic), so overrides never leak across runs on a thread.
    static TERM_CONFIG: std::cell::RefCell<Vec<(u64, KernelConfig)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Process-unique engine identity for the TLS override tag.
fn next_engine_id() -> u64 {
    static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

/// The local-kernel dispatcher the coordinator calls on the hot path.
/// Carries the compute-engine handles the native kernels need: a
/// [`KernelConfig`] (cache blocks + thread count, possibly SOAP-derived)
/// and a [`ScratchPool`] reused across every step the engine serves, so
/// steady-state local compute performs zero packing/fold allocations.
///
/// The active config is split in two: a `base_config` (the engine's
/// installed blocks + thread count) and the config actually dispatched
/// with, which the coordinator retargets per term from that term's
/// SOAP-derived tile sizes ([`KernelEngine::configure_for_term`]) and
/// restores after the run ([`KernelEngine::reset_config`]).  The
/// override lives in thread-local state, so the engine is `Send + Sync`
/// and concurrently-running programs cannot cross-configure each other.
pub struct KernelEngine {
    engine: Option<Engine>,
    backend: Backend,
    /// Max padded/real volume ratio before bucketing is considered
    /// wasteful and the native kernel is used instead.
    max_pad_ratio: f64,
    /// Installed blocking/threading knobs (per-term derivation base).
    base_config: KernelConfig,
    /// Identity tag for this engine's thread-local overrides.
    engine_id: u64,
    /// Packing + fold scratch, reused across steps.
    scratch: ScratchPool,
    /// Deterministic fault-injection seam ([`crate::fault`]): dispatch
    /// methods check their `engine.*` sites against it.  Defaults to the
    /// environment plan (`DEINSUM_FAULT_SEED`), which arms no `engine.*`
    /// sites — production dispatch pays one `None` branch.
    faults: Faults,
}

impl Drop for KernelEngine {
    fn drop(&mut self) {
        // Purge this thread's TLS override entry so engine churn on a
        // long-lived thread (build session → configure → drop, repeated)
        // cannot grow the per-thread map without bound.  Entries left on
        // *other* threads are unreachable from here but inert forever —
        // ids are never reused — and threads that executed a program
        // already cleared theirs via run_plan's drop guard.  `try_with`:
        // never panic if the TLS is already torn down.
        let id = self.engine_id;
        let _ = TERM_CONFIG.try_with(|c| c.borrow_mut().retain(|(eid, _)| *eid != id));
    }
}

impl KernelEngine {
    /// Native-only engine (always available).
    pub fn native() -> Self {
        Self::native_with(KernelConfig::from_env())
    }

    /// Native-only engine with explicit kernel configuration.
    pub fn native_with(config: KernelConfig) -> Self {
        let config = config.normalized();
        KernelEngine {
            engine: None,
            backend: Backend::Native,
            max_pad_ratio: 1.0,
            base_config: config,
            engine_id: next_engine_id(),
            scratch: ScratchPool::new(),
            faults: Faults::from_env(),
        }
    }

    /// PJRT-backed engine over an artifacts dir; falls back to native per
    /// op when no variant fits.
    pub fn pjrt(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let config = KernelConfig::from_env();
        Ok(KernelEngine {
            engine: Some(Engine::new(artifacts_dir)?),
            backend: Backend::Pjrt,
            max_pad_ratio: 1.7,
            base_config: config,
            engine_id: next_engine_id(),
            scratch: ScratchPool::new(),
            faults: Faults::from_env(),
        })
    }

    /// Which local-kernel backend this engine dispatches to.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Install an explicit fault-injection plan (replaces the
    /// environment-seeded default).  See [`crate::fault`].
    pub fn set_faults(&mut self, faults: Faults) {
        self.faults = faults;
    }

    /// The installed fault seam (tests read fired counts off its plan).
    pub fn faults(&self) -> &Faults {
        &self.faults
    }

    /// The native-kernel configuration this engine currently dispatches
    /// with on the *calling thread* (the base config, or the thread's
    /// per-term override — if that override was set through *this*
    /// engine; another engine's override on the same thread is ignored).
    pub fn config(&self) -> KernelConfig {
        TERM_CONFIG
            .with(|c| {
                c.borrow().iter().find(|(id, _)| *id == self.engine_id).map(|(_, cfg)| *cfg)
            })
            .unwrap_or(self.base_config)
    }

    /// The installed base configuration per-term overrides derive from.
    pub fn base_config(&self) -> KernelConfig {
        self.base_config
    }

    /// Replace the base kernel configuration (e.g. with SOAP-derived
    /// tiles via [`KernelConfig::from_tiles`]); also resets any per-term
    /// override on this thread.
    pub fn set_config(&mut self, config: KernelConfig) {
        self.base_config = config.normalized();
        self.reset_config();
    }

    /// Retarget the native kernels to `term`'s SOAP-derived tile sizes
    /// ([`TermPlan::kernel_config`]).  The coordinator calls this before
    /// each term's local compute so every term runs with the cache
    /// blocking its I/O analysis assumed; benches use it to measure the
    /// same feed without reimplementing the derivation.  The override is
    /// thread-local: it only affects ops this thread dispatches, so
    /// concurrent programs on other threads keep their own blocking.
    pub fn configure_for_term(&self, term: &TermPlan) {
        self.configure_override(term.kernel_config(self.base_config));
    }

    /// Install `cfg` as this thread's per-term override for this engine.
    /// Backend rank threads use this to replay the coordinator's
    /// [`configure_for_term`](Self::configure_for_term) choice (carried
    /// in a [`crate::exec::ComputeStep`]) on their own thread-local
    /// config slot, so kernels dispatch with identical blocking on every
    /// backend.
    pub(crate) fn configure_override(&self, cfg: KernelConfig) {
        TERM_CONFIG.with(|c| {
            let mut map = c.borrow_mut();
            match map.iter_mut().find(|(id, _)| *id == self.engine_id) {
                Some(entry) => entry.1 = cfg,
                None => map.push((self.engine_id, cfg)),
            }
        });
    }

    /// Drop this thread's per-term override *for this engine* and
    /// dispatch with the base config (other engines' overrides on the
    /// thread are untouched).
    pub fn reset_config(&self) {
        TERM_CONFIG.with(|c| c.borrow_mut().retain(|(id, _)| *id != self.engine_id));
    }

    /// Scratch-pool counters (steady-state invariant: `allocs` flat).
    pub fn scratch_stats(&self) -> ScratchStats {
        self.scratch.stats()
    }

    /// Dispatch counters of the underlying PJRT engine (zeros when
    /// running purely native).
    pub fn stats(&self) -> EngineStats {
        self.engine.as_ref().map(|e| e.stats()).unwrap_or_default()
    }

    fn find_bucket<'a>(
        &'a self,
        op: &str,
        dims: &[usize],
        extra: impl Fn(&Variant) -> bool,
    ) -> Option<(&'a Engine, &'a Variant, bool)> {
        let engine = self.engine.as_ref()?;
        let real: usize = dims.iter().product();
        let mut best: Option<(&Variant, usize)> = None;
        for v in &engine.manifest.variants {
            if v.op != op || !extra(v) {
                continue;
            }
            let vd = match v.dims.as_ref() {
                Some(d) => d.clone(),
                None => v.inputs[0].clone(),
            };
            if vd.len() != dims.len() {
                continue;
            }
            if !vd.iter().zip(dims).all(|(b, d)| b >= d) {
                continue;
            }
            let vol: usize = vd.iter().product();
            if (vol as f64) > self.max_pad_ratio * (real as f64).max(1.0) {
                continue;
            }
            match best {
                Some((_, bv)) if bv <= vol => {}
                _ => best = Some((v, vol)),
            }
        }
        best.map(|(v, vol)| {
            let exact = vol == real && v.dims.as_ref().map(|d| d == dims).unwrap_or(false)
                || v.inputs[0] == dims;
            (engine, v, exact)
        })
    }

    /// `C[m,n] = A[m,k] @ B[k,n]`.
    pub fn gemm(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        self.faults.check(fault::site::ENGINE_GEMM)?;
        if self.backend == Backend::Pjrt {
            let (m, k) = (a.dims()[0], a.dims()[1]);
            let n = b.dims()[1];
            if let Some(engine) = self.engine.as_ref() {
                // exact match first
                let exact = engine.manifest.variants.iter().find(|v| {
                    v.op == "gemm"
                        && v.m == Some(m)
                        && v.k == Some(k)
                        && v.n == Some(n)
                });
                if let Some(v) = exact {
                    let out = engine.execute(v, &[a, b])?;
                    engine.bump(|s| s.pjrt_exact += 1);
                    return Ok(out);
                }
                // bucket: smallest variant covering (m, k, n)
                let mut best: Option<(&Variant, usize)> = None;
                for v in &engine.manifest.variants {
                    if v.op != "gemm" {
                        continue;
                    }
                    let (vm, vk, vn) = (v.m.unwrap(), v.k.unwrap(), v.n.unwrap());
                    if vm >= m && vk >= k && vn >= n {
                        let vol = vm * vk + vk * vn;
                        let real = m * k + k * n;
                        if (vol as f64) <= self.max_pad_ratio * real as f64 {
                            if best.map(|(_, bv)| vol < bv).unwrap_or(true) {
                                best = Some((v, vol));
                            }
                        }
                    }
                }
                if let Some((v, _)) = best {
                    let (vm, vk, vn) = (v.m.unwrap(), v.k.unwrap(), v.n.unwrap());
                    let ap = a.block(&[0, 0], &[vm, vk]);
                    let bp = b.block(&[0, 0], &[vk, vn]);
                    let out = engine.execute(v, &[&ap, &bp])?;
                    engine.bump(|s| s.pjrt_padded += 1);
                    return Ok(out.block(&[0, 0], &[m, n]));
                }
                engine.bump(|s| s.native += 1);
            }
        }
        contract::gemm_with(&self.config(), &self.scratch, a, b)
    }

    /// The PJRT dispatch attempt for a fused MTTKRP: `Some(result)` when
    /// a compiled variant (exact or bucketed) serves the op, `None` when
    /// the native engine should (also counts the native fallback).
    fn mttkrp_pjrt(
        &self,
        x: &Tensor,
        factors: &[&Tensor],
        mode: usize,
    ) -> Option<Result<Tensor>> {
        if self.backend != Backend::Pjrt {
            return None;
        }
        let order = x.order();
        let rest: Vec<usize> = (0..order).filter(|&m| m != mode).collect();
        let r = factors[rest[0]].dims()[1];
        // Artifacts are mode-0: permute X so `mode` leads (HPTT's role).
        let xp = if mode == 0 {
            x.clone()
        } else {
            let mut perm = vec![mode];
            perm.extend(rest.iter().copied());
            x.permute(&perm)
        };
        let want: Vec<usize> = xp.dims().to_vec();
        if let Some((engine, v, exact)) = self.find_bucket("mttkrp", &want, |v| {
            v.r == Some(r)
        }) {
            let run = || -> Result<Tensor> {
                let vdims = v.dims.clone().unwrap();
                let xpad =
                    if exact { xp.clone() } else { xp.block(&vec![0; want.len()], &vdims) };
                let mut ins: Vec<Tensor> = vec![xpad];
                for (q, &m) in rest.iter().enumerate() {
                    let f = factors[m];
                    if exact {
                        ins.push(f.clone());
                    } else {
                        ins.push(f.block(&[0, 0], &[vdims[q + 1], r]));
                    }
                }
                let refs: Vec<&Tensor> = ins.iter().collect();
                let out = engine.execute(v, &refs)?;
                engine.bump(|s| if exact { s.pjrt_exact += 1 } else { s.pjrt_padded += 1 });
                Ok(if exact { out } else { out.block(&[0, 0], &[x.dims()[mode], r]) })
            };
            return Some(run());
        }
        if let Some(engine) = self.engine.as_ref() {
            engine.bump(|s| s.native += 1);
        }
        None
    }

    /// Fused mode-`mode` MTTKRP. `factors` lists all `order` factor slots;
    /// the `mode` slot is ignored.
    pub fn mttkrp(&self, x: &Tensor, factors: &[&Tensor], mode: usize) -> Result<Tensor> {
        self.faults.check(fault::site::ENGINE_MTTKRP)?;
        if let Some(res) = self.mttkrp_pjrt(x, factors, mode) {
            return res;
        }
        contract::mttkrp_with(&self.config(), &self.scratch, x, factors, mode)
    }

    /// [`mttkrp`](Self::mttkrp) writing through a caller-provided
    /// `(I_mode, R)` destination — the coordinator's recycled-output hot
    /// path.  The native engine writes in place with zero allocations
    /// ([`contract::mttkrp_with_into`]); a PJRT-served op still
    /// materializes the executable's result and copies it in (device
    /// buffers are not recyclable host tensors).
    pub fn mttkrp_into(
        &self,
        x: &Tensor,
        factors: &[&Tensor],
        mode: usize,
        dest: &mut Tensor,
    ) -> Result<()> {
        self.faults.check(fault::site::ENGINE_MTTKRP)?;
        if let Some(res) = self.mttkrp_pjrt(x, factors, mode) {
            return dest.copy_from(&res?);
        }
        contract::mttkrp_with_into(&self.config(), &self.scratch, x, factors, mode, dest)
    }

    /// General binary einsum on the local tiles (the `Seq` kernel's
    /// workhorse), folding through this engine's scratch pool.  The AOT
    /// artifact set has no generic-einsum variants (only gemm / mttkrp /
    /// krp / ttmc are lowered), so this always runs on the native packed
    /// engine; on a PJRT backend the dispatch is still counted in
    /// [`EngineStats::native`] so telemetry reflects every op served.
    pub fn einsum2(
        &self,
        x: &Tensor,
        x_idx: &[char],
        y: &Tensor,
        y_idx: &[char],
        out_idx: &[char],
    ) -> Result<Tensor> {
        self.faults.check(fault::site::ENGINE_EINSUM2)?;
        if let Some(engine) = self.engine.as_ref() {
            engine.bump(|s| s.native += 1);
        }
        contract::einsum2_with(&self.config(), &self.scratch, x, x_idx, y, y_idx, out_idx)
    }

    /// [`einsum2`](Self::einsum2) writing through a caller-provided
    /// destination (shape-checked; contents overwritten) — always served
    /// by the native packed engine, with zero allocations once the
    /// scratch pool is warm.
    #[allow(clippy::too_many_arguments)]
    pub fn einsum2_into(
        &self,
        x: &Tensor,
        x_idx: &[char],
        y: &Tensor,
        y_idx: &[char],
        out_idx: &[char],
        dest: &mut Tensor,
    ) -> Result<()> {
        self.faults.check(fault::site::ENGINE_EINSUM2)?;
        if let Some(engine) = self.engine.as_ref() {
            engine.bump(|s| s.native += 1);
        }
        contract::einsum2_into_with(
            &self.config(),
            &self.scratch,
            x,
            x_idx,
            y,
            y_idx,
            out_idx,
            dest,
        )
    }

    /// Materialized flat KRP (baseline two-step path): `(I0*I1, R)`.
    pub fn krp_flat(&self, u0: &Tensor, u1: &Tensor) -> Result<Tensor> {
        if self.backend == Backend::Pjrt {
            let (i0, r) = (u0.dims()[0], u0.dims()[1]);
            let i1 = u1.dims()[0];
            if let Some(engine) = self.engine.as_ref() {
                let exact = engine.manifest.variants.iter().find(|v| {
                    v.op == "krp" && v.i0 == Some(i0) && v.i1 == Some(i1) && v.r == Some(r)
                });
                if let Some(v) = exact {
                    let out = engine.execute(v, &[u0, u1])?;
                    engine.bump(|s| s.pjrt_exact += 1);
                    return Ok(out);
                }
                engine.bump(|s| s.native += 1);
            }
        }
        let k = contract::krp_chain(&[u0, u1])?;
        let r = k.dims()[2];
        k.reshape(&[u0.dims()[0] * u1.dims()[0], r])
    }

    /// Mode-`mode` TTM chain. `factors[mode]` ignored.
    pub fn ttmc(&self, x: &Tensor, factors: &[&Tensor], mode: usize) -> Result<Tensor> {
        if self.backend == Backend::Pjrt {
            let rs: Vec<usize> = (0..x.order())
                .map(|m| if m == mode { 0 } else { factors[m].dims()[1] })
                .collect();
            if let Some(engine) = self.engine.as_ref() {
                let exact = engine.manifest.variants.iter().find(|v| {
                    v.op == "ttmc"
                        && v.mode == Some(mode)
                        && v.dims.as_deref() == Some(x.dims())
                        && v.rs
                            .as_ref()
                            .map(|vrs| {
                                vrs.iter()
                                    .enumerate()
                                    .all(|(m, &vr)| m == mode || vr == rs[m])
                            })
                            .unwrap_or(false)
                });
                if let Some(v) = exact {
                    let ins: Vec<&Tensor> =
                        (0..x.order()).filter(|&m| m != mode).map(|m| factors[m]).collect();
                    let mut all: Vec<&Tensor> = vec![x];
                    all.extend(ins);
                    let out = engine.execute(v, &all)?;
                    engine.bump(|s| s.pjrt_exact += 1);
                    return Ok(out);
                }
                engine.bump(|s| s.native += 1);
            }
        }
        contract::ttmc(x, factors, mode)
    }

    /// Tensor dot over paired axes (always native: arbitrary-rank folds).
    pub fn tdot(
        &self,
        x: &Tensor,
        y: &Tensor,
        axes_x: &[usize],
        axes_y: &[usize],
    ) -> Result<Tensor> {
        contract::tdot(x, y, axes_x, axes_y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_gemm() {
        let e = KernelEngine::native();
        let a = Tensor::random(&[8, 8], 1);
        let b = Tensor::random(&[8, 8], 2);
        let got = e.gemm(&a, &b).unwrap();
        let want = contract::gemm(&a, &b).unwrap();
        assert!(got.allclose(&want, 1e-6, 1e-6));
    }

    #[test]
    fn native_engine_einsum2_and_scratch_reuse() {
        let e = KernelEngine::native();
        let x = Tensor::random(&[12, 10, 8], 5);
        let y = Tensor::random(&[10, 8, 4], 6);
        // Warm the pool, then steady state must stop allocating.
        for _ in 0..2 {
            let _ = e.einsum2(&x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], &['a', 'i']).unwrap();
        }
        let warm = e.scratch_stats();
        let got = e.einsum2(&x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], &['a', 'i']).unwrap();
        let want =
            contract::einsum2(&x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], &['a', 'i']).unwrap();
        assert!(got.allclose(&want, 1e-5, 1e-5));
        let after = e.scratch_stats();
        assert_eq!(after.allocs, warm.allocs, "engine scratch must be reused");
        assert!(after.takes > warm.takes, "engine must route through the pool");
    }

    #[test]
    fn native_engine_with_explicit_config() {
        use crate::tensor::kernel::KernelConfig;
        let cfg = KernelConfig::from_tiles(64.0, 64.0, 24.0).with_threads(2);
        let e = KernelEngine::native_with(cfg);
        assert_eq!(e.config(), cfg.normalized());
        let a = Tensor::random(&[33, 17], 7);
        let b = Tensor::random(&[17, 21], 8);
        let got = e.gemm(&a, &b).unwrap();
        let want = contract::gemm(&a, &b).unwrap();
        assert!(got.allclose(&want, 1e-5, 1e-5));
    }

    #[test]
    fn per_term_config_feed_and_reset() {
        use crate::einsum::EinsumSpec;
        use crate::planner::{plan, PlannerConfig};
        let spec =
            EinsumSpec::parse("ij,jk->ik", &[vec![4096, 4096], vec![4096, 4096]]).unwrap();
        let p = plan(&spec, 8, &PlannerConfig::default()).unwrap();
        let e = KernelEngine::native_with(KernelConfig::default().with_threads(3));
        let base = e.base_config();
        e.configure_for_term(&p.terms[0]);
        assert_eq!(e.config(), p.terms[0].kernel_config(base));
        assert_eq!(e.config().threads, 3, "thread count comes from the base config");
        e.reset_config();
        assert_eq!(e.config(), base);
    }

    #[test]
    fn per_term_override_is_private_to_one_engine() {
        use crate::einsum::EinsumSpec;
        use crate::planner::{plan, PlannerConfig};
        // Two engines on one thread (deinsum vs baseline comparisons do
        // exactly this): an override set through A must not change what
        // B dispatches with.
        let spec =
            EinsumSpec::parse("ij,jk->ik", &[vec![4096, 4096], vec![4096, 4096]]).unwrap();
        let p = plan(&spec, 8, &PlannerConfig::default()).unwrap();
        let a = KernelEngine::native_with(KernelConfig::default().with_threads(2));
        let b = KernelEngine::native_with(
            KernelConfig { mc: 64, kc: 64, nc: 64, threads: 1 }.normalized(),
        );
        a.configure_for_term(&p.terms[0]);
        assert_eq!(a.config(), p.terms[0].kernel_config(a.base_config()));
        assert_eq!(b.config(), b.base_config(), "B must ignore A's override");
        // B setting and resetting (what run_plan's drop guard does) must
        // not wipe A's pending override.
        b.configure_for_term(&p.terms[0]);
        b.reset_config();
        assert_eq!(b.config(), b.base_config());
        assert_eq!(
            a.config(),
            p.terms[0].kernel_config(a.base_config()),
            "A's override must survive B's set/reset cycle"
        );
        a.reset_config();
        assert_eq!(a.config(), a.base_config());
    }

    #[test]
    fn native_engine_mttkrp_modes() {
        let e = KernelEngine::native();
        let x = Tensor::random(&[6, 5, 4], 3);
        let fs: Vec<Tensor> = (0..3).map(|m| Tensor::random(&[x.dims()[m], 3], 4 + m as u64)).collect();
        let refs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..3 {
            let got = e.mttkrp(&x, &refs, mode).unwrap();
            let want = contract::mttkrp(&x, &refs, mode).unwrap();
            assert!(got.allclose(&want, 1e-6, 1e-6));
        }
    }
}
