//! Build-time stand-in for the `xla` crate's PJRT surface.
//!
//! The offline build environment has no XLA/PJRT native library, so this
//! shim provides the exact API shape [`super`] compiles against and fails
//! at *client construction* time: [`PjRtClient::cpu`] returns an error,
//! `KernelEngine::pjrt` surfaces it, and every caller falls back to the
//! native kernels (the engine's designed degradation path —
//! `api::SessionBuilder::build_or_native`).  Swapping this module for
//! the real `xla` crate re-enables artifact execution without touching
//! `runtime/mod.rs`.
#![allow(dead_code)]

use std::fmt;
use std::path::Path;

/// Error type mirroring the real binding's displayable errors.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "XLA/PJRT backend not linked into this build (offline environment); \
         native kernels serve all local ops"
            .to_string(),
    ))
}

/// Element dtypes the runtime dispatches (f32 only; see DESIGN.md).
pub enum ElementType {
    F32,
}

/// PJRT client handle (construction always fails in the shim).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device-resident buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A host-side literal (typed, shaped value).
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
