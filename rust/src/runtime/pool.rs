//! Persistent work-stealing worker pool: the process-wide execution
//! substrate under every parallel macro loop in the compute engine.
//!
//! PR 1 parallelized the packed GEMM, fused MTTKRP and HPTT-lite
//! transpose with `std::thread::scope`, which respawns OS threads on
//! *every* macro step — on a multi-term coordinator run that is hundreds
//! of spawn/join cycles per plan execution.  This module replaces that
//! with a crate-wide pool created lazily on first use and kept for the
//! process lifetime (the DISTAL observation: a *persistent* mapping of
//! computation onto machine resources is what sustains peak local
//! throughput):
//!
//! - **Per-job slot deques.**  A parallel region ([`WorkerPool::run`])
//!   becomes a [`Job`]: the task index space is split into one
//!   contiguous run per participant, each guarded by an atomic claim
//!   cursor.  A participant drains its own run front-to-back (cache
//!   locality), then **steals** from the other runs by bumping their
//!   cursors — ragged task costs rebalance without any task queue
//!   allocation: the "deque" is `(cursor, end)`.
//! - **Park/unpark idling.**  Idle workers park on a condition variable
//!   and are unparked when a job is published; there is no spinning
//!   between jobs, so an idle pool costs nothing.
//! - **Caller participation.**  The submitting thread is always
//!   participant 0 and can finish the whole job alone by stealing, so
//!   nested `run` calls from inside a worker can never deadlock.
//! - **Multiple submitters.**  Any number of threads can submit jobs
//!   concurrently (the serving layer's workers all dispatch through this
//!   one pool): jobs coexist in the published list, every submitter
//!   drives its own job to completion, and idle workers join the job
//!   with the *fewest* participants so concurrent regions share the
//!   worker set instead of queueing behind the oldest job.
//! - **Panic containment.**  A panicking task is caught, counted
//!   finished, and re-raised from the submitter after the job drains
//!   (the `thread::scope` semantics kernels had before); workers
//!   survive to serve the next job.
//! - **Zero steady-state allocation on the data path.**  Tasks carry no
//!   boxed closures: a job holds one lifetime-erased `&dyn Fn(usize)`
//!   (the caller blocks until completion, so the borrow is live for
//!   every access) and fixed-size cursor arrays.  The only per-region
//!   heap traffic is one `Arc<Job>` control block.
//!
//! Publish/consume across phases is by the job completion protocol: task
//! effects (e.g. a cooperatively packed B panel) are released by each
//! worker's `AcqRel` decrement of the outstanding-task counter and
//! acquired by the submitter before `run` returns, so a subsequent job
//! reads them safely.
//!
//! The pool grows on demand up to [`MAX_WORKERS`] − 1 threads: a request
//! for `t` participants ensures `t − 1` workers exist, so explicit
//! `KernelConfig::with_threads(8)` runs get real parallelism even when
//! `available_parallelism` under-reports.  [`run_scoped`] retains the
//! PR 1 spawn-per-region dispatch as a measurable baseline
//! (`spawn_dispatch` in `BENCH_hotpath.json`), selectable process-wide
//! with [`set_spawn_baseline`] so benches can reconstruct the old
//! behavior end-to-end.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::sync;

/// Maximum participants in one parallel region (caller + workers); also
/// bounds the pool's worker-thread count.
pub const MAX_WORKERS: usize = 64;

/// One parallel region submitted to the pool.
///
/// Safety contract: the `&'static` on `work` is a lie told by
/// [`WorkerPool::run`], which blocks until `unfinished` reaches zero —
/// no worker touches `work` after `run` returns, so the erased borrow
/// is live for every access.
struct Job {
    work: &'static (dyn Fn(usize) + Sync),
    /// Participant slots this job admits (min(threads, tasks)).
    n_slots: usize,
    /// Slots handed out so far; slot 0 is reserved for the submitter.
    joiners: AtomicUsize,
    /// Per-slot claim cursor: the slot's private deque is
    /// `cursors[s]..ends[s]`; stealing is a `fetch_add` on a foreign
    /// cursor.
    cursors: [AtomicUsize; MAX_WORKERS],
    ends: [usize; MAX_WORKERS],
    /// Tasks claimed but whose effects are not yet published.
    unfinished: AtomicUsize,
    /// First panic payload from any task; the submitter resumes the
    /// unwind after waiting (matching `thread::scope` panic
    /// propagation) and pool workers survive to serve the next job.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Completion latch for the submitter.
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl Job {
    fn new(work: &'static (dyn Fn(usize) + Sync), n_tasks: usize, n_slots: usize) -> Job {
        // The const is only an array-repeat initializer (each element is
        // a fresh atomic, not a shared one).
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicUsize = AtomicUsize::new(0);
        let cursors = [ZERO; MAX_WORKERS];
        let mut ends = [0usize; MAX_WORKERS];
        let chunk = n_tasks.div_ceil(n_slots);
        for s in 0..n_slots {
            cursors[s].store((s * chunk).min(n_tasks), Ordering::Relaxed);
            ends[s] = ((s + 1) * chunk).min(n_tasks);
        }
        Job {
            work,
            n_slots,
            joiners: AtomicUsize::new(1), // slot 0 = submitter
            cursors,
            ends,
            unfinished: AtomicUsize::new(n_tasks),
            panic_payload: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    /// Any unclaimed task left in any slot's run?
    fn has_claimable(&self) -> bool {
        (0..self.n_slots).any(|s| self.cursors[s].load(Ordering::Relaxed) < self.ends[s])
    }

    /// Drain tasks as participant `slot`: own run first, then steal the
    /// other runs.  Publishes completion when the last task finishes.
    fn work_as(&self, slot: usize, counters: &PoolCounters) {
        for off in 0..self.n_slots {
            let victim = (slot + off) % self.n_slots;
            loop {
                let t = self.cursors[victim].fetch_add(1, Ordering::Relaxed);
                if t >= self.ends[victim] {
                    break;
                }
                if off != 0 {
                    counters.steals.fetch_add(1, Ordering::Relaxed);
                }
                counters.tasks.fetch_add(1, Ordering::Relaxed);
                // The guard counts the task finished even if `work`
                // unwinds, so waiters never hang on a panicked task; the
                // catch keeps pool workers alive across task panics and
                // defers the panic to the submitter.  AssertUnwindSafe:
                // a panicked region leaves its output half-written
                // exactly as the old scoped-spawn dispatch did, and the
                // re-raise below makes that state unobservable-by-
                // accident.
                let guard = FinishGuard { job: self };
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (self.work)(t)
                }));
                // Record the payload BEFORE the guard publishes
                // completion: if this was the job's last task, the
                // submitter must observe it when it wakes.
                if let Err(payload) = result {
                    let mut slot = sync::lock(&self.panic_payload);
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                drop(guard);
            }
        }
    }

    /// Mark one claimed task finished; the last one publishes completion.
    /// `AcqRel` chains every participant's task effects into the final
    /// decrement, which the submitter acquires through `done`'s mutex.
    fn finish_one(&self) {
        if self.unfinished.fetch_sub(1, Ordering::AcqRel) == 1 {
            *sync::lock(&self.done) = true;
            self.done_cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut d = sync::lock(&self.done);
        while !*d {
            d = sync::wait(&self.done_cv, d);
        }
    }
}

/// Completion accounting that survives unwinding out of a task.
struct FinishGuard<'a> {
    job: &'a Job,
}

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        self.job.finish_one();
    }
}

/// Blocks on job completion even when the submitter's own task panics:
/// `run` must never unwind past the lifetime-erased closure while other
/// workers can still touch it.
struct WaitGuard<'a> {
    job: &'a Job,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.job.wait();
    }
}

#[derive(Default)]
struct PoolCounters {
    jobs: AtomicU64,
    tasks: AtomicU64,
    steals: AtomicU64,
}

struct State {
    /// Jobs with (potentially) unclaimed tasks, submission order.
    jobs: Vec<Arc<Job>>,
    /// Worker threads spawned so far (pool lifetime).
    workers: usize,
    /// Set by `WorkerPool::drop`: idle workers exit instead of parking,
    /// so non-global pools don't leak threads.
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    counters: PoolCounters,
}

/// Pool telemetry (cumulative since process start).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Parallel regions dispatched through the pool.
    pub jobs: u64,
    /// Tasks executed (by workers and submitters).
    pub tasks: u64,
    /// Tasks claimed from a foreign slot's run.
    pub steals: u64,
    /// Worker threads currently alive.
    pub workers: usize,
}

/// The persistent worker pool.  Use the process-wide [`global`] handle;
/// separate instances exist only for isolation in unit tests.
pub struct WorkerPool {
    shared: Arc<Shared>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // No `run` can be in flight (it borrows &self), so workers are
        // idle or finishing their last tasks; tell them to exit instead
        // of parking again.  The global pool is never dropped.
        sync::lock(&self.shared.state).shutdown = true;
        self.shared.work_cv.notify_all();
    }
}

impl WorkerPool {
    /// An empty pool; workers are spawned lazily by [`run`](Self::run).
    pub fn new() -> Self {
        WorkerPool {
            shared: Arc::new(Shared {
                state: Mutex::new(State { jobs: Vec::new(), workers: 0, shutdown: false }),
                work_cv: Condvar::new(),
                counters: PoolCounters::default(),
            }),
        }
    }

    /// Lifetime counters (jobs, tasks, steals, workers) for the pool.
    pub fn stats(&self) -> PoolStats {
        let workers = sync::lock(&self.shared.state).workers;
        PoolStats {
            jobs: self.shared.counters.jobs.load(Ordering::Relaxed),
            tasks: self.shared.counters.tasks.load(Ordering::Relaxed),
            steals: self.shared.counters.steals.load(Ordering::Relaxed),
            workers,
        }
    }

    /// Execute `work(t)` for every `t in 0..n_tasks` on up to `threads`
    /// participants (the calling thread plus pool workers) and return
    /// when all tasks have finished.  Tasks must be independent; tasks
    /// that write shared output must write disjoint regions.
    ///
    /// `threads <= 1` (or a single task) runs inline with no
    /// synchronization at all, preserving the engine's serial paths.
    pub fn run<F>(&self, threads: usize, n_tasks: usize, work: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        let threads = threads.max(1).min(n_tasks).min(MAX_WORKERS);
        if threads <= 1 {
            for t in 0..n_tasks {
                work(t);
            }
            return;
        }
        if spawn_baseline() {
            run_scoped(threads, n_tasks, work);
            return;
        }
        self.shared.counters.jobs.fetch_add(1, Ordering::Relaxed);
        let erased: &(dyn Fn(usize) + Sync) = work;
        // SAFETY: `run` blocks on `job.wait()` below until every task
        // has finished, so the erased borrow outlives all accesses.
        let work_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(erased) };
        let job = Arc::new(Job::new(work_static, n_tasks, threads));
        {
            let mut st = sync::lock(&self.shared.state);
            // Grow the worker set on demand (never shrinks: persistence
            // is the point).
            let want = (threads - 1).min(MAX_WORKERS - 1);
            while st.workers < want {
                let shared = self.shared.clone();
                std::thread::Builder::new()
                    .name(format!("deinsum-pool-{}", st.workers))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker");
                st.workers += 1;
            }
            st.jobs.push(job.clone());
        }
        self.shared.work_cv.notify_all();
        {
            let _wait = WaitGuard { job: &job };
            job.work_as(0, &self.shared.counters);
            // _wait blocks here until every task is done.
        }
        {
            let mut st = sync::lock(&self.shared.state);
            st.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        }
        // Re-raise a task panic with its original payload, like the
        // scoped-spawn dispatch did.
        if let Some(payload) = sync::lock(&job.panic_payload).take() {
            std::panic::resume_unwind(payload);
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job: Arc<Job> = {
            let mut st = sync::lock(&shared.state);
            loop {
                st.jobs.retain(|j| j.has_claimable());
                // Fairness across concurrent submitters: join the job
                // with the fewest participants so far, not the oldest
                // one — with several serving threads submitting regions
                // at once, first-come ordering would pile every worker
                // onto one submitter's job while the others run alone.
                if let Some(j) = st
                    .jobs
                    .iter()
                    .filter(|j| j.joiners.load(Ordering::Relaxed) < j.n_slots)
                    .min_by_key(|j| j.joiners.load(Ordering::Relaxed))
                {
                    break j.clone();
                }
                if st.shutdown {
                    return;
                }
                // Park until a new job is published.
                st = sync::wait(&shared.work_cv, st);
            }
        };
        let slot = job.joiners.fetch_add(1, Ordering::Relaxed);
        if slot < job.n_slots {
            job.work_as(slot, &shared.counters);
        }
        // Raced past the slot cap: loop and look for other work.
    }
}

/// The process-wide pool behind every kernel macro loop.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(WorkerPool::new)
}

static SPAWN_BASELINE: AtomicBool = AtomicBool::new(false);

/// Route every subsequent [`WorkerPool::run`] through the retained
/// spawn-per-region dispatch ([`run_scoped`]).  Bench-only knob for
/// measuring the pool against the PR 1 baseline; not for production use.
pub fn set_spawn_baseline(on: bool) {
    SPAWN_BASELINE.store(on, Ordering::Relaxed);
}

fn spawn_baseline() -> bool {
    SPAWN_BASELINE.load(Ordering::Relaxed)
}

/// The PR 1 dispatch, retained as a perf baseline: spawn scoped threads
/// for this region only, static task partition, no stealing.
pub fn run_scoped<F>(threads: usize, n_tasks: usize, work: &F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n_tasks);
    if threads <= 1 {
        for t in 0..n_tasks {
            work(t);
        }
        return;
    }
    let chunk = n_tasks.div_ceil(threads);
    std::thread::scope(|s| {
        let mut t0 = 0usize;
        while t0 < n_tasks {
            let t1 = (t0 + chunk).min(n_tasks);
            s.spawn(move || {
                for t in t0..t1 {
                    work(t);
                }
            });
            t0 = t1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new();
        for n_tasks in [1usize, 2, 7, 64, 257] {
            let hits: Vec<AtomicU64> = (0..n_tasks).map(|_| AtomicU64::new(0)).collect();
            pool.run(4, n_tasks, &|t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} of {n_tasks}");
            }
        }
    }

    #[test]
    fn serial_path_runs_inline() {
        let pool = WorkerPool::new();
        let sum = AtomicU64::new(0);
        pool.run(1, 100, &|t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
        assert_eq!(pool.stats().jobs, 0, "threads=1 must not dispatch a job");
        assert_eq!(pool.stats().workers, 0);
    }

    #[test]
    fn workers_persist_across_jobs() {
        let pool = WorkerPool::new();
        let sink = AtomicU64::new(0);
        for _ in 0..10 {
            pool.run(3, 32, &|t| {
                sink.fetch_add(t as u64 + 1, Ordering::Relaxed);
            });
        }
        let s = pool.stats();
        assert_eq!(s.jobs, 10);
        assert_eq!(s.tasks, 320);
        assert!(s.workers <= 2, "grew {} workers for 3 participants", s.workers);
        assert_eq!(sink.load(Ordering::Relaxed), 10 * (32 * 33 / 2));
    }

    #[test]
    fn ragged_tasks_rebalance_by_stealing() {
        // One slot gets all the slow tasks; total still completes and
        // the claim accounting stays exact.
        let pool = WorkerPool::new();
        let done = AtomicU64::new(0);
        pool.run(4, 64, &|t| {
            if t < 16 {
                // slot 0's run is slow
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn nested_runs_do_not_deadlock() {
        let pool = global();
        let total = AtomicU64::new(0);
        pool.run(4, 8, &|_outer| {
            // Nested region executed from inside a task: the submitter
            // can always finish it alone by stealing.
            let inner = AtomicU64::new(0);
            pool.run(4, 8, &|t| {
                inner.fetch_add(t as u64 + 1, Ordering::Relaxed);
            });
            total.fetch_add(inner.load(Ordering::Relaxed), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 36);
    }

    #[test]
    fn concurrent_submitters_share_the_pool_without_serializing() {
        // The serving layer's dispatch shape: several OS threads submit
        // parallel regions to one pool concurrently.  Every region must
        // complete with exact task accounting — a submitter can always
        // finish its own job alone, so this cannot deadlock even when
        // the workers are all busy elsewhere.
        let pool = WorkerPool::new();
        let totals: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        std::thread::scope(|s| {
            for (sub, total) in totals.iter().enumerate() {
                let pool = &pool;
                s.spawn(move || {
                    for _ in 0..8 {
                        pool.run(3, 40, &|t| {
                            total.fetch_add(t as u64 + sub as u64, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        let tasks_per_region: u64 = (0..40).sum();
        for (sub, total) in totals.iter().enumerate() {
            assert_eq!(
                total.load(Ordering::Relaxed),
                8 * (tasks_per_region + 40 * sub as u64),
                "submitter {sub} lost tasks"
            );
        }
        let s = pool.stats();
        assert_eq!(s.tasks, 6 * 8 * 40, "every task ran exactly once");
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, 16, &|t| {
                if t == 7 {
                    panic!("boom");
                }
            });
        }));
        let err = res.expect_err("submitter must observe the task panic");
        assert_eq!(
            err.downcast_ref::<&str>(),
            Some(&"boom"),
            "original panic payload must be preserved"
        );
        // All workers survive; the pool stays fully functional.
        let sum = AtomicU64::new(0);
        pool.run(4, 32, &|t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 496);
    }

    #[test]
    fn scoped_baseline_matches_pool() {
        let a = AtomicU64::new(0);
        run_scoped(4, 100, &|t| {
            a.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(a.load(Ordering::Relaxed), 4950);
    }
}
