//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The offline vendored registry has no serde, so we parse the (small,
//! machine-generated) manifest with a ~150-line recursive-descent parser.
//! Supports the full JSON grammar except exotic number formats; good far
//! beyond what `aot.py` emits.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON `true`/`false`.
    Bool(bool),
    /// JSON number (always carried as `f64`).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (key-sorted for deterministic traversal).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// The string payload, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The number as a `usize`, if this is a non-negative integral
    /// [`Value::Num`].
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }
    /// The elements, if this is a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
    /// The key-value map, if this is a [`Value::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj[key]` convenience.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|m| m.get(key))
    }
    /// Array of usize convenience (shape lists).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(Error::runtime(format!("trailing JSON at byte {}", p.i)));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::runtime(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::runtime(format!(
                "unexpected JSON byte {:?} at {}",
                other.map(|x| x as char),
                self.i
            ))),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::runtime(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(Error::runtime(format!("bad object at byte {}", self.i))),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(Error::runtime(format!("bad array at byte {}", self.i))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| Error::runtime("eof in escape".to_string()))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::runtime("bad \\u".to_string()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::runtime("bad \\u".to_string()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::runtime("bad escape".to_string())),
                    }
                }
                _ => {
                    // collect UTF-8 bytes verbatim
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| Error::runtime("bad utf8".to_string()))?,
                    );
                    self.i = end;
                }
            }
        }
        Err(Error::runtime("unterminated string".to_string()))
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| Error::runtime(format!("bad number '{s}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"format": "hlo-text-v1", "variants": [
            {"op": "gemm", "m": 64, "k": 64, "n": 64,
             "name": "gemm_64", "file": "g.hlo.txt",
             "inputs": [[64, 64], [64, 64]], "output": [64, 64]}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text-v1"));
        let vars = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(vars.len(), 1);
        assert_eq!(vars[0].get("m").unwrap().as_usize(), Some(64));
        assert_eq!(
            vars[0].get("inputs").unwrap().as_arr().unwrap()[0].as_usize_vec(),
            Some(vec![64, 64])
        );
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
    }

    #[test]
    fn nested_and_empty() {
        let v = parse(r#"{"a": [], "b": {}, "c": [1, [2, 3]]}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(v.get("b").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }
}
