//! Backend-agnostic description of one term's local compute.
//!
//! [`ComputeStep`] is everything a rank needs to run a term's local
//! kernel: validated names, shapes, the per-term [`KernelConfig`], and
//! the op sequence — no borrows into the plan, no closures.  It is
//! `Send + Clone`, so the in-process [`SimExecutor`] runs it directly
//! while the message-passing backend ships it to rank threads; both
//! call the same [`execute_rank`] interpreter, which is what makes the
//! backends bitwise identical.
//!
//! All structural plan validation (slot ranges, index membership,
//! factor counts) happens once in [`ComputeStep::build`] on the
//! coordinator, with the same typed errors and precedence the run loop
//! always had; ranks only surface data-dependent kernel errors.
//!
//! [`SimExecutor`]: super::sim::SimExecutor

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::error::{Error, Result};
use crate::planner::{LocalKernel, TermPlan};
use crate::runtime::KernelEngine;
use crate::tensor::{contract, KernelConfig, Tensor};

use super::LocalScratchStats;

/// Scratch key of a term's MTTKRP permute buffer (never a real op id).
pub(crate) const PERMUTE_SLOT: usize = usize::MAX;

/// Base of the scratch-key slot range holding pre-reduction buffers
/// (`slot = REDUCE_BASE + 2·op + operand`); far above any real op count
/// and below [`PERMUTE_SLOT`].
pub(crate) const REDUCE_BASE: usize = usize::MAX / 2;

/// Read-only view of one rank's tensor store, as the interpreter sees
/// it.  The sim backend adapts the shared [`crate::sim::Machine`] store;
/// the mp backend adapts a rank thread's private `HashMap`.
pub(crate) trait RankStore {
    /// Borrow the rank-local buffer for `name`.
    fn tensor(&self, name: &str) -> Result<&Tensor>;
}

/// Per-rank recycled scratch (Seq intermediates, pre-reduction buffers,
/// MTTKRP permute staging), keyed by `(term, slot)`.  The per-rank half
/// of the old coordinator-global scratch table: each rank now owns its
/// own buffers (a hard requirement for thread-isolated sites), and the
/// counters sum to the same totals.
#[derive(Debug, Default)]
pub(crate) struct RankScratch {
    bufs: HashMap<(usize, usize), Tensor>,
    /// Keys the current run touched (pruned against at `end_run`).
    touched: BTreeSet<(usize, usize)>,
    stats: LocalScratchStats,
}

impl RankScratch {
    /// Take the buffer for `key` (recycled when the shape matches,
    /// freshly allocated otherwise) and mark the key live for this run.
    pub(crate) fn take(&mut self, key: (usize, usize), dims: &[usize]) -> Tensor {
        self.touched.insert(key);
        match self.bufs.remove(&key) {
            Some(t) if t.dims() == dims => {
                self.stats.reuses += 1;
                t
            }
            _ => {
                self.stats.allocs += 1;
                Tensor::zeros(dims)
            }
        }
    }

    /// Return a buffer for recycling by the next run.
    pub(crate) fn put(&mut self, key: (usize, usize), buf: Tensor) {
        self.bufs.insert(key, buf);
    }

    /// Start a run: reset the touched-key set.
    pub(crate) fn begin_run(&mut self) {
        self.touched.clear();
    }

    /// End a run: prune buffers under keys this run never touched.
    pub(crate) fn end_run(&mut self) {
        let touched = &self.touched;
        self.bufs.retain(|k, _| touched.contains(k));
    }

    /// Allocation counters (cumulative across runs).
    pub(crate) fn stats(&self) -> LocalScratchStats {
        self.stats
    }
}

/// Where a Seq operand lives at execution time.
#[derive(Debug, Clone)]
pub(crate) enum OperandSrc {
    /// Borrowed from the rank store under this name (a staged term
    /// input).
    Store(String),
    /// Output of earlier op `index` of the same term (tensor id `id`,
    /// kept for error messages).
    Op { index: usize, id: usize },
}

/// One operand's pre-reduction spec: indices private to the operand and
/// absent from the op output are summed away into a recycled scratch
/// buffer before the engine runs.
#[derive(Debug, Clone)]
pub(crate) struct RedSpec {
    /// Scratch slot (`REDUCE_BASE + 2·op + operand`).
    pub(crate) slot: usize,
    /// Surviving index string after the reduction.
    pub(crate) idx: Vec<char>,
    /// Dropped mode positions in the operand's original index string.
    pub(crate) drop: Vec<usize>,
    /// Local shape of the reduced operand.
    pub(crate) dims: Vec<usize>,
}

/// One resolved Seq operand.
#[derive(Debug, Clone)]
pub(crate) struct StepOperand {
    pub(crate) src: OperandSrc,
    pub(crate) idx: Vec<char>,
    pub(crate) red: Option<RedSpec>,
}

/// One resolved Seq op (unary or binary).
#[derive(Debug, Clone)]
pub(crate) struct StepOp {
    pub(crate) a: StepOperand,
    pub(crate) b: Option<StepOperand>,
    pub(crate) output_idx: Vec<char>,
}

/// The local kernel of a [`ComputeStep`].
#[derive(Debug, Clone)]
pub(crate) enum StepKind {
    /// Fused MTTKRP (natural or permuted output order).
    Mttkrp {
        x_name: String,
        f_names: Vec<String>,
        order: usize,
        mode: usize,
        natural_dims: Vec<usize>,
        perm: Option<Vec<usize>>,
    },
    /// Folded binary-op sequence.
    Seq { ops: Vec<StepOp>, op_dims: Vec<Vec<usize>>, n_ops: usize },
}

/// One term's local compute, fully resolved against the plan: what
/// every rank executes (via [`execute_rank`]) between staging and the
/// reduction.  Built once per term per run by the coordinator; cheap to
/// clone (names and index strings only).
#[derive(Debug, Clone)]
pub struct ComputeStep {
    pub(crate) term_index: usize,
    pub(crate) term_name: String,
    pub(crate) out_name: String,
    pub(crate) out_dims: Vec<usize>,
    pub(crate) kernel_cfg: KernelConfig,
    pub(crate) kind: StepKind,
}

impl ComputeStep {
    /// Resolve `term` (index `ti`, staged under `in_names`) into an
    /// executable step, with the run loop's historical validation order
    /// and error messages.  `base_cfg` seeds the per-term kernel config.
    pub(crate) fn build(
        term: &TermPlan,
        ti: usize,
        in_names: &[String],
        out_name: String,
        base_cfg: KernelConfig,
    ) -> Result<ComputeStep> {
        let kernel_cfg = term.kernel_config(base_cfg);
        match &term.kernel {
            LocalKernel::Mttkrp { x_input, mode, factor_inputs } => {
                if factor_inputs.is_empty() {
                    return Err(Error::malformed_plan(&term.name, "mttkrp with no factors"));
                }
                // Every slot index comes from the plan: range-check them
                // all so a corrupted plan is an Err, never a panic
                // (in_names is index-aligned with term.inputs).
                let x_in = term.inputs.get(*x_input).ok_or_else(|| {
                    Error::malformed_plan(
                        &term.name,
                        format!("mttkrp x slot {x_input} out of range"),
                    )
                })?;
                let x_name = in_names[*x_input].clone();
                let f_names: Vec<String> = factor_inputs
                    .iter()
                    .map(|&s| {
                        in_names.get(s).cloned().ok_or_else(|| {
                            Error::malformed_plan(
                                &term.name,
                                format!("mttkrp factor slot {s} out of range"),
                            )
                        })
                    })
                    .collect::<Result<_>>()?;
                let order = x_in.indices.len();
                let mode = *mode;
                // Local kernel output shape: (local mode extent, local R).
                let x_ldims = x_in.dist.local_dims();
                let mode_extent = x_ldims.get(mode).copied().ok_or_else(|| {
                    Error::malformed_plan(
                        &term.name,
                        format!("mttkrp mode {mode} out of range for order {order}"),
                    )
                })?;
                let r_local = term.inputs[factor_inputs[0]]
                    .dist
                    .local_dims()
                    .get(1)
                    .copied()
                    .ok_or_else(|| {
                        Error::malformed_plan(&term.name, "mttkrp factor is not a matrix")
                    })?;
                let natural_dims = vec![mode_extent, r_local];
                // Kernel output order is (mode_idx, r); a differing
                // term output order takes the recycled permute path.
                let x_idx = &x_in.indices;
                let r_char = term
                    .output_indices
                    .iter()
                    .copied()
                    .find(|c| !x_idx.contains(c))
                    .ok_or_else(|| {
                        Error::malformed_plan(&term.name, "mttkrp: no rank index")
                    })?;
                let natural = vec![x_idx[mode], r_char];
                let (perm, out_dims) = if term.output_indices == natural {
                    (None, natural_dims.clone())
                } else {
                    let perm: Vec<usize> = term
                        .output_indices
                        .iter()
                        .map(|c| {
                            natural.iter().position(|d| d == c).ok_or_else(|| {
                                Error::malformed_plan(
                                    &term.name,
                                    format!(
                                        "mttkrp output index '{c}' not in natural \
                                         layout {natural:?}"
                                    ),
                                )
                            })
                        })
                        .collect::<Result<_>>()?;
                    let permuted: Vec<usize> =
                        perm.iter().map(|&p| natural_dims[p]).collect();
                    (Some(perm), permuted)
                };
                Ok(ComputeStep {
                    term_index: ti,
                    term_name: term.name.clone(),
                    out_name,
                    out_dims,
                    kernel_cfg,
                    kind: StepKind::Mttkrp {
                        x_name,
                        f_names,
                        order,
                        mode,
                        natural_dims,
                        perm,
                    },
                })
            }
            LocalKernel::Seq => {
                // Local output extents per index char: inputs are staged
                // at their distribution's padded local dims, so every
                // op's local output shape is fixed by the chars it keeps
                // — known before any kernel runs, which is what lets the
                // destinations be recycled.
                let mut local_ext: BTreeMap<char, usize> = BTreeMap::new();
                for tin in &term.inputs {
                    for (c, e) in tin.indices.iter().zip(tin.dist.local_dims()) {
                        local_ext.insert(*c, e);
                    }
                }
                let op_dims: Vec<Vec<usize>> = term
                    .ops
                    .iter()
                    .map(|op| {
                        let d: Vec<usize> = op
                            .output
                            .iter()
                            .map(|c| {
                                local_ext.get(c).copied().ok_or_else(|| {
                                    Error::malformed_plan(
                                        &term.name,
                                        format!("seq: unknown index '{c}'"),
                                    )
                                })
                            })
                            .collect::<Result<_>>()?;
                        Ok(if d.is_empty() { vec![1] } else { d })
                    })
                    .collect::<Result<_>>()?;
                let n_ops = term.ops.len();
                if n_ops == 0 {
                    return Err(Error::malformed_plan(&term.name, "empty term"));
                }
                if term.ops[n_ops - 1].output_id != term.output_id {
                    return Err(Error::malformed_plan(
                        &term.name,
                        "last op does not produce the term output",
                    ));
                }
                // Tensor-id table: term inputs are *borrowed* from the
                // rank store (never deep-copied); intermediates live in
                // recycled per-rank scratch.  The final op writes the
                // store-recycled destination.
                #[derive(Clone, Copy)]
                enum SeqSrc {
                    Input(usize),
                    Op(usize),
                }
                let mut src_of: BTreeMap<usize, SeqSrc> = BTreeMap::new();
                for (slot, tin) in term.inputs.iter().enumerate() {
                    src_of.insert(tin.id, SeqSrc::Input(slot));
                }
                for (j, op) in term.ops.iter().enumerate() {
                    src_of.insert(op.output_id, SeqSrc::Op(j));
                }
                let idx_of = |id: usize| -> Result<&[char]> {
                    match src_of.get(&id) {
                        Some(SeqSrc::Input(slot)) => {
                            Ok(term.inputs[*slot].indices.as_slice())
                        }
                        Some(SeqSrc::Op(i)) => Ok(term.ops[*i].output.as_slice()),
                        None => Err(Error::malformed_plan(
                            &term.name,
                            format!("seq: operand t{id} never produced"),
                        )),
                    }
                };
                // Pre-reduction table: operands carrying indices private
                // to themselves and absent from the op output are summed
                // away *before* the engine sees them, through recycled
                // scratch buffers ([`contract::reduce_modes_into`]) — so
                // `einsum2`'s internal pre-reduction (which allocates)
                // stays off the hot path.
                let mut red_specs: Vec<Option<RedSpec>> =
                    Vec::with_capacity(term.ops.len() * 2);
                for (j, op) in term.ops.iter().enumerate() {
                    for q in 0..2 {
                        if q >= op.input_ids.len() {
                            red_specs.push(None);
                            continue;
                        }
                        let idx = idx_of(op.input_ids[q])?;
                        let other: Option<&[char]> = if op.input_ids.len() == 2 {
                            Some(idx_of(op.input_ids[1 - q])?)
                        } else {
                            None
                        };
                        let drop: Vec<usize> = idx
                            .iter()
                            .enumerate()
                            .filter(|&(_, c)| {
                                if op.output.contains(c) {
                                    return false;
                                }
                                match other {
                                    Some(o) => !o.contains(c),
                                    None => true,
                                }
                            })
                            .map(|(d, _)| d)
                            .collect();
                        if drop.is_empty() {
                            red_specs.push(None);
                            continue;
                        }
                        let mut kept: Vec<char> = idx
                            .iter()
                            .enumerate()
                            .filter(|(d, _)| !drop.contains(d))
                            .map(|(_, &c)| c)
                            .collect();
                        let dims: Vec<usize> = if kept.is_empty() {
                            if op.input_ids.len() == 2 {
                                // Fully-summed binary operand: hand
                                // einsum2 the synthetic already-reduced
                                // singleton it would have built itself
                                // (unary ops take the empty-index copy
                                // path instead).
                                kept.push('\u{1}');
                            }
                            vec![1]
                        } else {
                            kept.iter()
                                .map(|c| {
                                    local_ext.get(c).copied().ok_or_else(|| {
                                        Error::malformed_plan(
                                            &term.name,
                                            format!("seq: unknown index '{c}'"),
                                        )
                                    })
                                })
                                .collect::<Result<_>>()?
                        };
                        red_specs.push(Some(RedSpec {
                            slot: REDUCE_BASE + 2 * j + q,
                            idx: kept,
                            drop,
                            dims,
                        }));
                    }
                }
                let mut red_specs = red_specs.into_iter();
                let mut ops: Vec<StepOp> = Vec::with_capacity(n_ops);
                for op in term.ops.iter() {
                    let red_a = red_specs.next().flatten();
                    let red_b = red_specs.next().flatten();
                    if op.input_ids.is_empty() {
                        return Err(Error::malformed_plan(
                            &term.name,
                            "0-ary local op unsupported",
                        ));
                    }
                    if op.input_ids.len() > 2 {
                        return Err(Error::malformed_plan(
                            &term.name,
                            format!("{}-ary local op unsupported", op.input_ids.len()),
                        ));
                    }
                    let operand = |id: usize, red: Option<RedSpec>| -> Result<StepOperand> {
                        let (src, idx) = match src_of.get(&id) {
                            Some(SeqSrc::Input(slot)) => (
                                OperandSrc::Store(in_names[*slot].clone()),
                                term.inputs[*slot].indices.clone(),
                            ),
                            Some(SeqSrc::Op(i)) => (
                                OperandSrc::Op { index: *i, id },
                                term.ops[*i].output.clone(),
                            ),
                            None => {
                                return Err(Error::malformed_plan(
                                    &term.name,
                                    format!("seq: operand t{id} never produced"),
                                ))
                            }
                        };
                        Ok(StepOperand { src, idx, red })
                    };
                    let a = operand(op.input_ids[0], red_a)?;
                    let b = match op.input_ids.len() {
                        2 => Some(operand(op.input_ids[1], red_b)?),
                        _ => None,
                    };
                    ops.push(StepOp { a, b, output_idx: op.output.clone() });
                }
                let out_dims = op_dims[n_ops - 1].clone();
                Ok(ComputeStep {
                    term_index: ti,
                    term_name: term.name.clone(),
                    out_name,
                    out_dims,
                    kernel_cfg,
                    kind: StepKind::Seq { ops, op_dims, n_ops },
                })
            }
        }
    }
}

/// Execute `step` for one rank: read inputs from `store`, route
/// intermediates through the rank's recycled `scratch`, write the
/// result through `dest` (shape [`ComputeStep::out_dims`], contents
/// unspecified on entry).  Shared by every backend — this function *is*
/// the cross-backend bitwise-identity guarantee.
pub(crate) fn execute_rank(
    engine: &KernelEngine,
    store: &dyn RankStore,
    scratch: &mut RankScratch,
    step: &ComputeStep,
    dest: &mut Tensor,
) -> Result<()> {
    match &step.kind {
        StepKind::Mttkrp { x_name, f_names, order, mode, natural_dims, perm } => {
            match perm {
                None => mttkrp_rank(
                    engine, store, &step.term_name, x_name, f_names, *order, *mode, dest,
                ),
                Some(p) => {
                    // Natural-layout kernel output lands in a recycled
                    // scratch buffer, then permutes into the recycled
                    // destination (no allocation on either side).  The
                    // scratch goes back before error propagation so a
                    // recovered run stays allocation-free.
                    let key = (step.term_index, PERMUTE_SLOT);
                    let mut nat = scratch.take(key, natural_dims);
                    let res = mttkrp_rank(
                        engine, store, &step.term_name, x_name, f_names, *order, *mode,
                        &mut nat,
                    )
                    .and_then(|()| nat.permute_into(p, dest));
                    scratch.put(key, nat);
                    res
                }
            }
        }
        StepKind::Seq { ops, op_dims, n_ops } => {
            let ti = step.term_index;
            let mut opbufs: Vec<Tensor> =
                (0..n_ops - 1).map(|j| scratch.take((ti, j), &op_dims[j])).collect();
            let mut reds: Vec<Option<Tensor>> = Vec::with_capacity(2 * ops.len());
            for op in ops.iter() {
                reds.push(
                    op.a.red.as_ref().map(|s| scratch.take((ti, s.slot), &s.dims)),
                );
                reds.push(
                    op.b
                        .as_ref()
                        .and_then(|b| b.red.as_ref())
                        .map(|s| scratch.take((ti, s.slot), &s.dims)),
                );
            }
            // Bound (not `?`d) so the recycled buffers return to the
            // scratch table even when a kernel errors mid-step.
            let res = run_seq(engine, store, ops, *n_ops, &mut opbufs, &mut reds, dest);
            for (j, t) in opbufs.into_iter().enumerate() {
                scratch.put((ti, j), t);
            }
            for (q, t) in reds.into_iter().enumerate() {
                if let Some(t) = t {
                    scratch.put((ti, REDUCE_BASE + q), t);
                }
            }
            res
        }
    }
}

/// The Seq-kernel op loop for one rank (split out of [`execute_rank`]
/// so the scratch put-backs wrap it unconditionally).
fn run_seq(
    engine: &KernelEngine,
    store: &dyn RankStore,
    ops: &[StepOp],
    n_ops: usize,
    opbufs: &mut [Tensor],
    reds: &mut [Option<Tensor>],
    dest: &mut Tensor,
) -> Result<()> {
    for (j, op) in ops.iter().enumerate() {
        // Ops run in order: everything before `j` is readable, `j`'s
        // buffer (or the final destination) is writable.
        let (done, rest) = opbufs.split_at_mut(j.min(n_ops - 1));
        let dst: &mut Tensor = if j == n_ops - 1 { &mut *dest } else { &mut rest[0] };
        let (ra, rai) = resolve_operand(&op.a, store, done, j)?;
        if let Some(spec) = &op.a.red {
            let buf = reds[2 * j].as_mut().ok_or_else(|| {
                Error::plan(format!("seq: missing pre-reduction buffer at op {j}"))
            })?;
            contract::reduce_modes_into(ra, &spec.drop, buf)?;
        }
        match &op.b {
            Some(bop) => {
                let (rb, rbi) = resolve_operand(bop, store, done, j)?;
                if let Some(spec) = &bop.red {
                    let buf = reds[2 * j + 1].as_mut().ok_or_else(|| {
                        Error::plan(format!("seq: missing pre-reduction buffer at op {j}"))
                    })?;
                    contract::reduce_modes_into(rb, &spec.drop, buf)?;
                }
                let (a, ai) = reduced_view(&op.a, ra, rai, &reds[2 * j]);
                let (b, bi) = reduced_view(bop, rb, rbi, &reds[2 * j + 1]);
                engine.einsum2_into(a, ai, b, bi, &op.output_idx, dst)?;
            }
            None => {
                let (a, ai) = reduced_view(&op.a, ra, rai, &reds[2 * j]);
                unary_local_into(a, ai, &op.output_idx, dst)?;
            }
        }
    }
    Ok(())
}

/// Resolve a Seq operand to a borrowed tensor + index string.
fn resolve_operand<'a>(
    opnd: &'a StepOperand,
    store: &'a dyn RankStore,
    done: &'a [Tensor],
    j: usize,
) -> Result<(&'a Tensor, &'a [char])> {
    match &opnd.src {
        OperandSrc::Store(name) => Ok((store.tensor(name)?, opnd.idx.as_slice())),
        OperandSrc::Op { index, id } => match done.get(*index) {
            Some(t) => Ok((t, opnd.idx.as_slice())),
            None => Err(Error::plan(format!(
                "seq: operand t{id} not available at op {j}"
            ))),
        },
    }
}

/// The operand the engine actually sees: the pre-reduced scratch buffer
/// when a reduction spec fired, the raw operand otherwise.
fn reduced_view<'a>(
    opnd: &'a StepOperand,
    raw: &'a Tensor,
    raw_idx: &'a [char],
    red: &'a Option<Tensor>,
) -> (&'a Tensor, &'a [char]) {
    match (&opnd.red, red) {
        (Some(spec), Some(buf)) => (buf, spec.idx.as_slice()),
        _ => (raw, raw_idx),
    }
}

/// One rank's fused-MTTKRP local kernel through the recycled-output
/// engine path (`slots` layout: `order` entries, the `mode` slot is a
/// placeholder the kernel ignores).
#[allow(clippy::too_many_arguments)]
fn mttkrp_rank(
    engine: &KernelEngine,
    store: &dyn RankStore,
    term_name: &str,
    x_name: &str,
    f_names: &[String],
    order: usize,
    mode: usize,
    dest: &mut Tensor,
) -> Result<()> {
    let x = store.tensor(x_name)?;
    let fs: Vec<&Tensor> =
        f_names.iter().map(|n| store.tensor(n)).collect::<Result<_>>()?;
    let mut slots: Vec<&Tensor> = Vec::with_capacity(order);
    let mut fi = fs.iter();
    for mm in 0..order {
        if mm == mode {
            slots.push(x); // placeholder, ignored
        } else {
            slots.push(fi.next().ok_or_else(|| {
                Error::malformed_plan(
                    term_name,
                    format!(
                        "mttkrp factor count mismatch: {} factors for order {order}",
                        f_names.len()
                    ),
                )
            })?);
        }
    }
    engine.mttkrp_into(x, &slots, mode, dest)
}

/// Unary local op writing through a recycled destination: the final
/// permutation (the common case — pure mode reorder) lands directly in
/// `dest` with zero allocations.  Summed-away indices are normally gone
/// by the time this runs (the Seq loop pre-reduces them through
/// recycled scratch); the allocating [`contract::reduce_mode`] fallback
/// remains for direct callers.
pub(crate) fn unary_local_into(
    a: &Tensor,
    a_idx: &[char],
    out_idx: &[char],
    dest: &mut Tensor,
) -> Result<()> {
    let mut owned: Option<Tensor> = None;
    let mut idx = a_idx.to_vec();
    // reduce dropped indices
    while let Some(d) = idx.iter().position(|c| !out_idx.contains(c)) {
        let cur = owned.as_ref().unwrap_or(a);
        owned = Some(contract::reduce_mode(cur, d));
        idx.remove(d);
    }
    let t = owned.as_ref().unwrap_or(a);
    if idx == out_idx || idx.is_empty() {
        return dest.copy_from(t);
    }
    let perm: Vec<usize> = out_idx
        .iter()
        .map(|c| {
            idx.iter()
                .position(|d| d == c)
                .ok_or_else(|| Error::shape(format!("unary: index '{c}' missing")))
        })
        .collect::<Result<_>>()?;
    t.permute_into(&perm, dest)
}
