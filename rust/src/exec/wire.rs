//! The proc backend's wire format: versioned, length-prefixed frames
//! carrying the broadcast/ack instruction protocol across a process
//! boundary.
//!
//! Layout rules, chosen for a dependency-free hand-rolled codec:
//!
//! - Every frame is `u32` little-endian length + payload, capped at
//!   [`MAX_FRAME`] so a corrupt length prefix is rejected before any
//!   allocation.
//! - The first frame each way is a **handshake**: magic [`MAGIC`] +
//!   protocol version [`VERSION`] + rank identity.  Mismatches are
//!   typed [`Error::Protocol`] values with expected-vs-got detail —
//!   a coordinator never drives a worker speaking another version.
//! - Scalars are little-endian; `usize` travels as `u64`; index
//!   characters as `u32` code points; tensor payloads as raw `f32`
//!   little-endian bytes (bitwise exact, NaN payloads included).
//! - Enums are `u8`-tagged.  Unknown tags are protocol errors, never
//!   panics.
//!
//! Everything the mp backend moves over channels has a wire encoding
//! here: instructions ([`WireInstr`], including the redistribution
//! box payloads and allreduce partials of the star-topology
//! collectives), acknowledgements ([`WireAck`] with the cumulative
//! recycling counters), and typed [`Error`]s so a worker-side failure
//! reconstructs **display-identically** on the coordinator — which is
//! what keeps rejection signatures equal across backends.

use std::io::{self, Read, Write};

use crate::error::{Error, Result};
use crate::redist::Message;
use crate::sim::StoreStats;
use crate::tensor::{KernelConfig, Tensor};

use super::step::{
    ComputeStep, OperandSrc, RedSpec, StepKind, StepOp, StepOperand,
};
use super::LocalScratchStats;

/// Wire magic: the first bytes of every handshake frame.
pub(crate) const MAGIC: [u8; 4] = *b"DEWF";

/// Protocol version.  Bump on any layout change: a coordinator refuses
/// to drive a worker speaking a different version.
pub(crate) const VERSION: u16 = 1;

/// Upper bound on a frame payload (1 GiB): a corrupt or hostile length
/// prefix fails typed instead of attempting the allocation.
pub(crate) const MAX_FRAME: usize = 1 << 30;

/// Decode-side protocol error (no rank context at the codec layer; the
/// transport wraps it with the failing site).
fn bad(detail: impl Into<String>) -> Error {
    Error::protocol_at(None, "decode", detail)
}

// ---------------------------------------------------------------- frames

/// Write one length-prefixed frame and flush.
pub(crate) fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidInput, "frame exceeds u32 length")
    })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame (bounded by [`MAX_FRAME`]).
pub(crate) fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {n} exceeds the {MAX_FRAME} cap"),
        ));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

// ------------------------------------------------------------- handshake

/// Coordinator→worker hello: magic, version, the worker's rank, and the
/// machine size.
pub(crate) fn hello(rank: usize, ranks: usize) -> Vec<u8> {
    let mut e = Enc::default();
    e.buf.extend_from_slice(&MAGIC);
    e.put_u16(VERSION);
    e.put_u8(0); // kind: hello
    e.put_usize(rank);
    e.put_usize(ranks);
    e.buf
}

/// Worker→coordinator hello acknowledgement, echoing the rank.
pub(crate) fn hello_ack(rank: usize) -> Vec<u8> {
    let mut e = Enc::default();
    e.buf.extend_from_slice(&MAGIC);
    e.put_u16(VERSION);
    e.put_u8(1); // kind: hello-ack
    e.put_usize(rank);
    e.buf
}

fn check_preamble(d: &mut Dec<'_>, want_kind: u8) -> Result<()> {
    let mut magic = [0u8; 4];
    for b in magic.iter_mut() {
        *b = d.u8()?;
    }
    if magic != MAGIC {
        return Err(Error::protocol_at(
            None,
            "handshake",
            format!("wire magic mismatch: expected {MAGIC:?}, got {magic:?}"),
        ));
    }
    let version = d.u16()?;
    if version != VERSION {
        return Err(Error::protocol_at(
            None,
            "handshake",
            format!("protocol version mismatch: expected {VERSION}, got {version}"),
        ));
    }
    let kind = d.u8()?;
    if kind != want_kind {
        return Err(Error::protocol_at(
            None,
            "handshake",
            format!("handshake kind mismatch: expected {want_kind}, got {kind}"),
        ));
    }
    Ok(())
}

/// Validate a hello frame; returns `(rank, ranks)`.
pub(crate) fn check_hello(frame: &[u8]) -> Result<(usize, usize)> {
    let mut d = Dec::new(frame);
    check_preamble(&mut d, 0)?;
    let rank = d.usize()?;
    let ranks = d.usize()?;
    if rank >= ranks {
        return Err(Error::protocol_at(
            None,
            "handshake",
            format!("hello rank {rank} out of range for {ranks} ranks"),
        ));
    }
    Ok((rank, ranks))
}

/// Validate a hello-ack frame against the rank the coordinator assigned.
pub(crate) fn check_hello_ack(frame: &[u8], expect_rank: usize) -> Result<()> {
    let mut d = Dec::new(frame);
    check_preamble(&mut d, 1)?;
    let rank = d.usize()?;
    if rank != expect_rank {
        return Err(Error::protocol_at(
            None,
            "handshake",
            format!("hello-ack rank mismatch: expected {expect_rank}, got {rank}"),
        ));
    }
    Ok(())
}

// ----------------------------------------------------------- primitives

/// Append-only encoder over a byte buffer.
#[derive(Default)]
pub(crate) struct Enc {
    pub(crate) buf: Vec<u8>,
}

impl Enc {
    fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }
    fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }
    fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn put_usizes(&mut self, v: &[usize]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_usize(x);
        }
    }
    fn put_chars(&mut self, v: &[char]) {
        self.put_usize(v.len());
        for &c in v {
            self.put_u32(c as u32);
        }
    }
    fn put_opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(x) => {
                self.put_bool(true);
                self.put_usize(x);
            }
            None => self.put_bool(false),
        }
    }
    fn put_tensor(&mut self, t: &Tensor) {
        self.put_usizes(t.dims());
        let data = t.data();
        self.put_usize(data.len());
        for &x in data {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn put_opt_tensor(&mut self, t: &Option<Tensor>) {
        match t {
            Some(t) => {
                self.put_bool(true);
                self.put_tensor(t);
            }
            None => self.put_bool(false),
        }
    }
    fn put_message(&mut self, m: &Message) {
        self.put_usize(m.src);
        self.put_usize(m.dst);
        self.put_usizes(&m.src_off);
        self.put_usizes(&m.dst_off);
        self.put_usizes(&m.size);
    }
}

/// Cursor decoder; every read is bounds-checked and returns a typed
/// protocol error on truncation.
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(e) => {
                let s = &self.buf[self.pos..e];
                self.pos = e;
                Ok(s)
            }
            None => Err(bad(format!(
                "truncated frame: wanted {n} bytes at offset {}, frame is {}",
                self.pos,
                self.buf.len()
            ))),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| bad(format!("u64 {v} exceeds usize")))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(bad(format!("bool tag {v}: expected 0 or 1"))),
        }
    }
    fn str(&mut self) -> Result<String> {
        let n = self.usize()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| bad(format!("invalid utf-8 string: {e}")))
    }
    fn len(&mut self, what: &str) -> Result<usize> {
        let n = self.usize()?;
        // A length can never promise more elements than bytes remain;
        // rejecting here bounds every `Vec::with_capacity` below.
        if n > self.buf.len().saturating_sub(self.pos) {
            return Err(bad(format!(
                "{what} length {n} exceeds remaining frame ({})",
                self.buf.len() - self.pos
            )));
        }
        Ok(n)
    }
    fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.len("usize list")?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.usize()?);
        }
        Ok(v)
    }
    fn chars(&mut self) -> Result<Vec<char>> {
        let n = self.len("char list")?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let cp = self.u32()?;
            v.push(
                char::from_u32(cp)
                    .ok_or_else(|| bad(format!("invalid char code point {cp}")))?,
            );
        }
        Ok(v)
    }
    fn opt_usize(&mut self) -> Result<Option<usize>> {
        Ok(if self.bool()? { Some(self.usize()?) } else { None })
    }
    fn tensor(&mut self) -> Result<Tensor> {
        let dims = self.usizes()?;
        let n = self.usize()?;
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| bad("tensor length overflow"))?)?;
        let mut data = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Tensor::from_vec(&dims, data)
            .map_err(|e| bad(format!("tensor dims/data mismatch: {e}")))
    }
    fn opt_tensor(&mut self) -> Result<Option<Tensor>> {
        Ok(if self.bool()? { Some(self.tensor()?) } else { None })
    }
    fn message(&mut self) -> Result<Message> {
        Ok(Message {
            src: self.usize()?,
            dst: self.usize()?,
            src_off: self.usizes()?,
            dst_off: self.usizes()?,
            size: self.usizes()?,
        })
    }

    /// All bytes must be consumed: trailing garbage is a framing bug.
    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(bad(format!(
                "{} trailing bytes after decode",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ----------------------------------------------------------- error codec

fn put_error(e: &mut Enc, err: &Error) {
    match err {
        Error::Parse(m) => {
            e.put_u8(0);
            e.put_str(m);
        }
        Error::Shape(m) => {
            e.put_u8(1);
            e.put_str(m);
        }
        Error::Plan(m) => {
            e.put_u8(2);
            e.put_str(m);
        }
        Error::MalformedPlan { term, detail } => {
            e.put_u8(3);
            e.put_str(term);
            e.put_str(detail);
        }
        Error::Runtime(m) => {
            e.put_u8(4);
            e.put_str(m);
        }
        Error::Io(io_err) => {
            e.put_u8(5);
            e.put_str(&io_err.to_string());
        }
        Error::Transient(m) => {
            e.put_u8(6);
            e.put_str(m);
        }
        Error::WorkerLost(m) => {
            e.put_u8(7);
            e.put_str(m);
        }
        Error::QueueFull => e.put_u8(8),
        Error::DeadlineExceeded => e.put_u8(9),
        Error::ServerShutdown => e.put_u8(10),
        Error::Protocol { rank, instr, detail } => {
            e.put_u8(11);
            e.put_opt_usize(*rank);
            e.put_str(instr);
            e.put_str(detail);
        }
    }
}

fn get_error(d: &mut Dec<'_>) -> Result<Error> {
    Ok(match d.u8()? {
        0 => Error::Parse(d.str()?),
        1 => Error::Shape(d.str()?),
        2 => Error::Plan(d.str()?),
        3 => Error::MalformedPlan { term: d.str()?, detail: d.str()? },
        4 => Error::Runtime(d.str()?),
        // io::Error is not cloneable/serializable; the message survives
        // the wire and Displays identically.
        5 => Error::Io(io::Error::other(d.str()?)),
        6 => Error::Transient(d.str()?),
        7 => Error::WorkerLost(d.str()?),
        8 => Error::QueueFull,
        9 => Error::DeadlineExceeded,
        10 => Error::ServerShutdown,
        11 => Error::Protocol { rank: d.opt_usize()?, instr: d.str()?, detail: d.str()? },
        t => return Err(bad(format!("unknown error tag {t}"))),
    })
}

// ----------------------------------------------------- compute-step codec

fn put_kernel_config(e: &mut Enc, c: KernelConfig) {
    e.put_usize(c.mc);
    e.put_usize(c.kc);
    e.put_usize(c.nc);
    e.put_usize(c.threads);
}

fn get_kernel_config(d: &mut Dec<'_>) -> Result<KernelConfig> {
    Ok(KernelConfig {
        mc: d.usize()?,
        kc: d.usize()?,
        nc: d.usize()?,
        threads: d.usize()?,
    })
}

fn put_operand(e: &mut Enc, o: &StepOperand) {
    match &o.src {
        OperandSrc::Store(name) => {
            e.put_u8(0);
            e.put_str(name);
        }
        OperandSrc::Op { index, id } => {
            e.put_u8(1);
            e.put_usize(*index);
            e.put_usize(*id);
        }
    }
    e.put_chars(&o.idx);
    match &o.red {
        Some(r) => {
            e.put_bool(true);
            e.put_usize(r.slot);
            e.put_chars(&r.idx);
            e.put_usizes(&r.drop);
            e.put_usizes(&r.dims);
        }
        None => e.put_bool(false),
    }
}

fn get_operand(d: &mut Dec<'_>) -> Result<StepOperand> {
    let src = match d.u8()? {
        0 => OperandSrc::Store(d.str()?),
        1 => OperandSrc::Op { index: d.usize()?, id: d.usize()? },
        t => return Err(bad(format!("unknown operand source tag {t}"))),
    };
    let idx = d.chars()?;
    let red = if d.bool()? {
        Some(RedSpec {
            slot: d.usize()?,
            idx: d.chars()?,
            drop: d.usizes()?,
            dims: d.usizes()?,
        })
    } else {
        None
    };
    Ok(StepOperand { src, idx, red })
}

pub(crate) fn put_step(e: &mut Enc, s: &ComputeStep) {
    e.put_usize(s.term_index);
    e.put_str(&s.term_name);
    e.put_str(&s.out_name);
    e.put_usizes(&s.out_dims);
    put_kernel_config(e, s.kernel_cfg);
    match &s.kind {
        StepKind::Mttkrp { x_name, f_names, order, mode, natural_dims, perm } => {
            e.put_u8(0);
            e.put_str(x_name);
            e.put_usize(f_names.len());
            for f in f_names {
                e.put_str(f);
            }
            e.put_usize(*order);
            e.put_usize(*mode);
            e.put_usizes(natural_dims);
            match perm {
                Some(p) => {
                    e.put_bool(true);
                    e.put_usizes(p);
                }
                None => e.put_bool(false),
            }
        }
        StepKind::Seq { ops, op_dims, n_ops } => {
            e.put_u8(1);
            e.put_usize(ops.len());
            for op in ops {
                put_operand(e, &op.a);
                match &op.b {
                    Some(b) => {
                        e.put_bool(true);
                        put_operand(e, b);
                    }
                    None => e.put_bool(false),
                }
                e.put_chars(&op.output_idx);
            }
            e.put_usize(op_dims.len());
            for d in op_dims {
                e.put_usizes(d);
            }
            e.put_usize(*n_ops);
        }
    }
}

pub(crate) fn get_step(d: &mut Dec<'_>) -> Result<ComputeStep> {
    let term_index = d.usize()?;
    let term_name = d.str()?;
    let out_name = d.str()?;
    let out_dims = d.usizes()?;
    let kernel_cfg = get_kernel_config(d)?;
    let kind = match d.u8()? {
        0 => {
            let x_name = d.str()?;
            let nf = d.len("mttkrp factors")?;
            let mut f_names = Vec::with_capacity(nf);
            for _ in 0..nf {
                f_names.push(d.str()?);
            }
            StepKind::Mttkrp {
                x_name,
                f_names,
                order: d.usize()?,
                mode: d.usize()?,
                natural_dims: d.usizes()?,
                perm: if d.bool()? { Some(d.usizes()?) } else { None },
            }
        }
        1 => {
            let no = d.len("seq ops")?;
            let mut ops = Vec::with_capacity(no);
            for _ in 0..no {
                let a = get_operand(d)?;
                let b = if d.bool()? { Some(get_operand(d)?) } else { None };
                let output_idx = d.chars()?;
                ops.push(StepOp { a, b, output_idx });
            }
            let nd = d.len("seq op dims")?;
            let mut op_dims = Vec::with_capacity(nd);
            for _ in 0..nd {
                op_dims.push(d.usizes()?);
            }
            StepKind::Seq { ops, op_dims, n_ops: d.usize()? }
        }
        t => return Err(bad(format!("unknown step kind tag {t}"))),
    };
    Ok(ComputeStep { term_index, term_name, out_name, out_dims, kernel_cfg, kind })
}

// ------------------------------------------------------ instruction codec

/// One redistribution box riding the star topology: where it lands in
/// the receiver's destination buffer, plus the payload.
#[derive(Debug, Clone)]
pub(crate) struct WireBox {
    pub(crate) dst_off: Vec<usize>,
    pub(crate) size: Vec<usize>,
    pub(crate) data: Tensor,
}

/// One coordinator→worker instruction.  The mp backend's rank-to-rank
/// collectives become star-topology rounds here (the coordinator relays
/// the payloads), which keeps every round at exactly `p` instructions
/// and `p` acknowledgements — the same lockstep barrier discipline.
pub(crate) enum WireInstr {
    /// This rank sits a round out (keeps the barrier balanced).
    Nop,
    BeginRun,
    Stage { name: String, block: Tensor },
    Put { name: String, tensor: Tensor },
    Fetch { name: String },
    /// First redistribution round: extract and return the outgoing
    /// boxes of `sends` from `src` (every rank checks `src` presence,
    /// matching the mp backend's typed-error semantics).
    RedistExtract { src: String, sends: Vec<Message> },
    /// Second redistribution round: fill the recycled destination from
    /// the rank-local `locals` plus the relayed `incoming` boxes.
    RedistApply {
        src: String,
        dst: String,
        ldims: Vec<usize>,
        locals: Vec<Message>,
        incoming: Vec<WireBox>,
    },
    Compute { step: ComputeStep },
    /// First allreduce round: return this member's local block.
    ReduceExtract { name: String },
    /// Second allreduce round (group root only): accumulate `contribs`
    /// (ordered `g[1..]`) onto the local block and return the sum.
    ReduceAccum { name: String, root: usize, contribs: Vec<(usize, Tensor)> },
    /// Third allreduce round: overwrite the local block with the root's
    /// reduced `result`.
    ReduceStore { name: String, result: Tensor },
    EndRun { live: Vec<String> },
    Stop,
}

pub(crate) fn encode_instr(i: &WireInstr) -> Vec<u8> {
    let mut e = Enc::default();
    match i {
        WireInstr::Nop => e.put_u8(0),
        WireInstr::BeginRun => e.put_u8(1),
        WireInstr::Stage { name, block } => {
            e.put_u8(2);
            e.put_str(name);
            e.put_tensor(block);
        }
        WireInstr::Put { name, tensor } => {
            e.put_u8(3);
            e.put_str(name);
            e.put_tensor(tensor);
        }
        WireInstr::Fetch { name } => {
            e.put_u8(4);
            e.put_str(name);
        }
        WireInstr::RedistExtract { src, sends } => {
            e.put_u8(5);
            e.put_str(src);
            e.put_usize(sends.len());
            for m in sends {
                e.put_message(m);
            }
        }
        WireInstr::RedistApply { src, dst, ldims, locals, incoming } => {
            e.put_u8(6);
            e.put_str(src);
            e.put_str(dst);
            e.put_usizes(ldims);
            e.put_usize(locals.len());
            for m in locals {
                e.put_message(m);
            }
            e.put_usize(incoming.len());
            for b in incoming {
                e.put_usizes(&b.dst_off);
                e.put_usizes(&b.size);
                e.put_tensor(&b.data);
            }
        }
        WireInstr::Compute { step } => {
            e.put_u8(7);
            put_step(&mut e, step);
        }
        WireInstr::ReduceExtract { name } => {
            e.put_u8(8);
            e.put_str(name);
        }
        WireInstr::ReduceAccum { name, root, contribs } => {
            e.put_u8(9);
            e.put_str(name);
            e.put_usize(*root);
            e.put_usize(contribs.len());
            for (r, t) in contribs {
                e.put_usize(*r);
                e.put_tensor(t);
            }
        }
        WireInstr::ReduceStore { name, result } => {
            e.put_u8(10);
            e.put_str(name);
            e.put_tensor(result);
        }
        WireInstr::EndRun { live } => {
            e.put_u8(11);
            e.put_usize(live.len());
            for n in live {
                e.put_str(n);
            }
        }
        WireInstr::Stop => e.put_u8(12),
    }
    e.buf
}

pub(crate) fn decode_instr(frame: &[u8]) -> Result<WireInstr> {
    let mut d = Dec::new(frame);
    let instr = match d.u8()? {
        0 => WireInstr::Nop,
        1 => WireInstr::BeginRun,
        2 => WireInstr::Stage { name: d.str()?, block: d.tensor()? },
        3 => WireInstr::Put { name: d.str()?, tensor: d.tensor()? },
        4 => WireInstr::Fetch { name: d.str()? },
        5 => {
            let src = d.str()?;
            let n = d.len("redist sends")?;
            let mut sends = Vec::with_capacity(n);
            for _ in 0..n {
                sends.push(d.message()?);
            }
            WireInstr::RedistExtract { src, sends }
        }
        6 => {
            let src = d.str()?;
            let dst = d.str()?;
            let ldims = d.usizes()?;
            let nl = d.len("redist locals")?;
            let mut locals = Vec::with_capacity(nl);
            for _ in 0..nl {
                locals.push(d.message()?);
            }
            let nb = d.len("redist boxes")?;
            let mut incoming = Vec::with_capacity(nb);
            for _ in 0..nb {
                incoming.push(WireBox {
                    dst_off: d.usizes()?,
                    size: d.usizes()?,
                    data: d.tensor()?,
                });
            }
            WireInstr::RedistApply { src, dst, ldims, locals, incoming }
        }
        7 => WireInstr::Compute { step: get_step(&mut d)? },
        8 => WireInstr::ReduceExtract { name: d.str()? },
        9 => {
            let name = d.str()?;
            let root = d.usize()?;
            let n = d.len("reduce contribs")?;
            let mut contribs = Vec::with_capacity(n);
            for _ in 0..n {
                contribs.push((d.usize()?, d.tensor()?));
            }
            WireInstr::ReduceAccum { name, root, contribs }
        }
        10 => WireInstr::ReduceStore { name: d.str()?, result: d.tensor()? },
        11 => {
            let n = d.len("live names")?;
            let mut live = Vec::with_capacity(n);
            for _ in 0..n {
                live.push(d.str()?);
            }
            WireInstr::EndRun { live }
        }
        12 => WireInstr::Stop,
        t => return Err(bad(format!("unknown instruction tag {t}"))),
    };
    d.finish()?;
    Ok(instr)
}

// -------------------------------------------------------------- ack codec

/// Per-instruction acknowledgement payload: cumulative counters plus
/// whatever the instruction produced (the wire twin of the mp backend's
/// `AckData`, extended with the extracted redistribution boxes the star
/// topology relays).
#[derive(Default)]
pub(crate) struct WireAckData {
    pub(crate) compute_s: f64,
    /// Fetched block, extracted allreduce contribution, or reduced
    /// result — whichever the instruction asked for.
    pub(crate) tensor: Option<Tensor>,
    /// Allreduce payload length reported by a group root.
    pub(crate) payload_len: Option<usize>,
    /// Extracted redistribution boxes, each tagged with its
    /// destination rank.
    pub(crate) boxes: Vec<(usize, WireBox)>,
    pub(crate) store: StoreStats,
    pub(crate) scratch: LocalScratchStats,
}

/// One worker→coordinator acknowledgement.
pub(crate) enum WireAck {
    Ok(WireAckData),
    /// Typed data-dependent failure; the site is still consistent.
    Err { err: Error, data: WireAckData },
    /// The site is broken; the executor must be poisoned.
    Fatal { err: Error },
}

fn put_store_stats(e: &mut Enc, s: StoreStats) {
    e.put_u64(s.dest_allocs);
    e.put_u64(s.dest_reuses);
    e.put_u64(s.out_allocs);
    e.put_u64(s.out_reuses);
}

fn get_store_stats(d: &mut Dec<'_>) -> Result<StoreStats> {
    Ok(StoreStats {
        dest_allocs: d.u64()?,
        dest_reuses: d.u64()?,
        out_allocs: d.u64()?,
        out_reuses: d.u64()?,
    })
}

fn put_ack_data(e: &mut Enc, a: &WireAckData) {
    e.put_f64(a.compute_s);
    e.put_opt_tensor(&a.tensor);
    e.put_opt_usize(a.payload_len);
    e.put_usize(a.boxes.len());
    for (dst, b) in &a.boxes {
        e.put_usize(*dst);
        e.put_usizes(&b.dst_off);
        e.put_usizes(&b.size);
        e.put_tensor(&b.data);
    }
    put_store_stats(e, a.store);
    e.put_u64(a.scratch.allocs);
    e.put_u64(a.scratch.reuses);
}

fn get_ack_data(d: &mut Dec<'_>) -> Result<WireAckData> {
    let compute_s = d.f64()?;
    let tensor = d.opt_tensor()?;
    let payload_len = d.opt_usize()?;
    let nb = d.len("ack boxes")?;
    let mut boxes = Vec::with_capacity(nb);
    for _ in 0..nb {
        let dst = d.usize()?;
        boxes.push((
            dst,
            WireBox { dst_off: d.usizes()?, size: d.usizes()?, data: d.tensor()? },
        ));
    }
    let store = get_store_stats(d)?;
    let scratch = LocalScratchStats { allocs: d.u64()?, reuses: d.u64()? };
    Ok(WireAckData { compute_s, tensor, payload_len, boxes, store, scratch })
}

pub(crate) fn encode_ack(a: &WireAck) -> Vec<u8> {
    let mut e = Enc::default();
    match a {
        WireAck::Ok(data) => {
            e.put_u8(0);
            put_ack_data(&mut e, data);
        }
        WireAck::Err { err, data } => {
            e.put_u8(1);
            put_error(&mut e, err);
            put_ack_data(&mut e, data);
        }
        WireAck::Fatal { err } => {
            e.put_u8(2);
            put_error(&mut e, err);
        }
    }
    e.buf
}

pub(crate) fn decode_ack(frame: &[u8]) -> Result<WireAck> {
    let mut d = Dec::new(frame);
    let ack = match d.u8()? {
        0 => WireAck::Ok(get_ack_data(&mut d)?),
        1 => WireAck::Err { err: get_error(&mut d)?, data: get_ack_data(&mut d)? },
        2 => WireAck::Fatal { err: get_error(&mut d)? },
        t => return Err(bad(format!("unknown ack tag {t}"))),
    };
    d.finish()?;
    Ok(ack)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ELEM_BYTES;

    fn t(dims: &[usize], data: &[f32]) -> Tensor {
        Tensor::from_vec(dims, data.to_vec()).unwrap()
    }

    #[test]
    fn frame_roundtrip_and_cap() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        // A hostile length prefix fails before allocating.
        let bogus = (u32::MAX).to_le_bytes();
        assert!(read_frame(&mut &bogus[..]).is_err());
        // Truncated payload is an io error, not a hang or panic.
        let mut short = Vec::new();
        write_frame(&mut short, b"abcdef").unwrap();
        short.truncate(short.len() - 2);
        assert!(read_frame(&mut &short[..]).is_err());
    }

    #[test]
    fn handshake_roundtrip_and_mismatches_are_typed() {
        let h = hello(3, 8);
        assert_eq!(check_hello(&h).unwrap(), (3, 8));
        let a = hello_ack(3);
        check_hello_ack(&a, 3).unwrap();
        // Wrong echoed rank.
        let err = check_hello_ack(&a, 4).unwrap_err();
        assert!(matches!(err, Error::Protocol { .. }), "got {err}");
        assert!(err.to_string().contains("expected 4, got 3"), "got {err}");
        // Version skew: expected-vs-got in the message.
        let mut skew = hello(0, 1);
        skew[4] = VERSION as u8 + 1;
        let err = check_hello(&skew).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "got {err}");
        // Foreign magic.
        let mut foreign = hello(0, 1);
        foreign[0] = b'X';
        assert!(check_hello(&foreign).is_err());
        // A hello is not a hello-ack.
        assert!(check_hello_ack(&h, 3).is_err());
    }

    #[test]
    fn tensor_payloads_are_bitwise_exact() {
        // NaN payloads, signed zeros, denormals: the codec must move
        // bits, not values.
        let vals = [f32::NAN, -0.0, f32::MIN_POSITIVE / 2.0, 1.5e-42, f32::INFINITY];
        let src = t(&[5], &vals);
        let mut e = Enc::default();
        e.put_tensor(&src);
        let mut d = Dec::new(&e.buf);
        let back = d.tensor().unwrap();
        d.finish().unwrap();
        assert_eq!(back.dims(), src.dims());
        for (a, b) in src.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ELEM_BYTES, 4, "wire tensor encoding assumes f32 elements");
    }

    #[test]
    fn instr_roundtrip_covers_every_variant() {
        let msg = Message {
            src: 0,
            dst: 1,
            src_off: vec![0, 2],
            dst_off: vec![1, 0],
            size: vec![2, 2],
        };
        let instrs = vec![
            WireInstr::Nop,
            WireInstr::BeginRun,
            WireInstr::Stage { name: "x".into(), block: t(&[2], &[1.0, 2.0]) },
            WireInstr::Put { name: "y".into(), tensor: t(&[1], &[3.0]) },
            WireInstr::Fetch { name: "z".into() },
            WireInstr::RedistExtract { src: "s".into(), sends: vec![msg.clone()] },
            WireInstr::RedistApply {
                src: "s".into(),
                dst: "d".into(),
                ldims: vec![4, 4],
                locals: vec![msg],
                incoming: vec![WireBox {
                    dst_off: vec![0, 0],
                    size: vec![1, 2],
                    data: t(&[1, 2], &[5.0, 6.0]),
                }],
            },
            WireInstr::ReduceExtract { name: "r".into() },
            WireInstr::ReduceAccum {
                name: "r".into(),
                root: 0,
                contribs: vec![(1, t(&[2], &[1.0, 1.0])), (2, t(&[2], &[2.0, 2.0]))],
            },
            WireInstr::ReduceStore { name: "r".into(), result: t(&[2], &[9.0, 9.0]) },
            WireInstr::EndRun { live: vec!["a".into(), "b".into()] },
            WireInstr::Stop,
        ];
        for i in &instrs {
            let frame = encode_instr(i);
            let back = decode_instr(&frame).unwrap();
            // Structural equality via re-encoding (the types carry
            // tensors, so no derived PartialEq).
            assert_eq!(encode_instr(&back), frame);
        }
    }

    #[test]
    fn compute_step_roundtrips_both_kinds() {
        use crate::exec::step::{PERMUTE_SLOT, REDUCE_BASE};
        let cfg = KernelConfig { mc: 96, kc: 256, nc: 2048, threads: 3 };
        let mttkrp = ComputeStep {
            term_index: 2,
            term_name: "T2".into(),
            out_name: "out@T2".into(),
            out_dims: vec![4, 6],
            kernel_cfg: cfg,
            kind: StepKind::Mttkrp {
                x_name: "x@T2".into(),
                f_names: vec!["f1".into(), "f2".into()],
                order: 3,
                mode: 1,
                natural_dims: vec![6, 4],
                perm: Some(vec![1, 0]),
            },
        };
        let seq = ComputeStep {
            term_index: 0,
            term_name: "T0".into(),
            out_name: "o".into(),
            out_dims: vec![3],
            kernel_cfg: cfg,
            kind: StepKind::Seq {
                ops: vec![StepOp {
                    a: StepOperand {
                        src: OperandSrc::Store("a".into()),
                        idx: vec!['i', 'j'],
                        red: Some(RedSpec {
                            slot: REDUCE_BASE + 1,
                            idx: vec!['i'],
                            drop: vec![1],
                            dims: vec![3],
                        }),
                    },
                    b: Some(StepOperand {
                        src: OperandSrc::Op { index: 0, id: 7 },
                        idx: vec!['i'],
                        red: None,
                    }),
                    output_idx: vec!['i'],
                }],
                op_dims: vec![vec![3]],
                n_ops: 1,
            },
        };
        for step in [&mttkrp, &seq] {
            let mut e = Enc::default();
            put_step(&mut e, step);
            let mut d = Dec::new(&e.buf);
            let back = get_step(&mut d).unwrap();
            d.finish().unwrap();
            let mut e2 = Enc::default();
            put_step(&mut e2, &back);
            assert_eq!(e.buf, e2.buf);
        }
        // The sentinel scratch slots survive the u64 trip.
        let mut e = Enc::default();
        e.put_usize(PERMUTE_SLOT);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.usize().unwrap(), PERMUTE_SLOT);
    }

    #[test]
    fn ack_and_error_roundtrip_display_identical() {
        let errs = vec![
            Error::parse("bad expr"),
            Error::shape("dims"),
            Error::plan("redistribute: s missing"),
            Error::malformed_plan("T1", "empty term"),
            Error::runtime("kernel"),
            Error::Io(io::Error::other("pipe broke")),
            Error::transient("flaky"),
            Error::worker_lost("gone"),
            Error::QueueFull,
            Error::DeadlineExceeded,
            Error::ServerShutdown,
            Error::protocol_at(3, "allreduce", "expected contribution, got Nop"),
            Error::protocol("generic"),
        ];
        for err in errs {
            let want = err.to_string();
            let data = WireAckData {
                compute_s: 0.5,
                tensor: Some(t(&[1], &[2.0])),
                payload_len: Some(7),
                boxes: vec![(
                    2,
                    WireBox { dst_off: vec![1], size: vec![1], data: t(&[1], &[4.0]) },
                )],
                store: StoreStats {
                    dest_allocs: 1,
                    dest_reuses: 2,
                    out_allocs: 3,
                    out_reuses: 4,
                },
                scratch: LocalScratchStats { allocs: 5, reuses: 6 },
            };
            let frame = encode_ack(&WireAck::Err { err, data });
            match decode_ack(&frame).unwrap() {
                WireAck::Err { err, data } => {
                    assert_eq!(err.to_string(), want, "error must Display identically");
                    assert_eq!(data.compute_s, 0.5);
                    assert_eq!(data.payload_len, Some(7));
                    assert_eq!(data.store.out_reuses, 4);
                    assert_eq!(data.scratch.reuses, 6);
                    assert_eq!(data.boxes.len(), 1);
                }
                _ => panic!("wrong ack variant"),
            }
        }
        // Truncated and trailing-garbage frames are typed errors.
        let frame = encode_ack(&WireAck::Ok(WireAckData::default()));
        assert!(decode_ack(&frame[..frame.len() - 1]).is_err());
        let mut longer = frame.clone();
        longer.push(0);
        assert!(decode_ack(&longer).is_err());
        assert!(decode_ack(&[99]).is_err());
    }
}
