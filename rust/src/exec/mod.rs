//! Pluggable plan-execution backends.
//!
//! The coordinator's run loop used to be welded to [`crate::sim::Machine`];
//! this module dissolves that dependency into the [`Executor`] trait —
//! the full plan-execution surface (staging, redistribution, local
//! compute, allreduce, gather, plus the recycling counters) — so the
//! simulator becomes one backend among several:
//!
//! - [`ExecBackend::Sim`] ([`sim::SimExecutor`]): the in-process
//!   simulated machine.  Fast, deterministic, allocation-free in steady
//!   state (counter-asserted), with α–β-modeled communication time.
//! - [`ExecBackend::Mp`] ([`mp::MpExecutor`]): a message-passing
//!   backend.  Each rank is a real thread-isolated site owning only its
//!   local store slice, executing instructions from its own channel and
//!   exchanging redistribution/allreduce payloads rank-to-rank over
//!   channels — the in-process rehearsal of a multi-node MPI run.
//!   Protocol violations surface as typed [`Error::Protocol`] values,
//!   never panics.
//! - [`ExecBackend::Proc`] ([`proc::ProcExecutor`]): out-of-process
//!   rank sites.  Every rank is a `deinsum rank-worker` child process
//!   (or a remote TCP peer via `DEINSUM_RANK_ADDR`) speaking the
//!   versioned, length-prefixed wire format of [`wire`]; instruction
//!   streams and block payloads cross a genuine process boundary with
//!   read/write deadlines layered on the same ack/abort discipline.
//!
//! All backends execute the identical per-rank interpreter
//! ([`ComputeStep`] + `execute_rank`) over identically-cut blocks, so
//! their outputs are **bitwise identical** — pinned as a tier-1 test at
//! P ∈ {1, 4, 8}.  Select a backend per session with
//! [`crate::api::SessionBuilder::backend`] or process-wide with the
//! `DEINSUM_BACKEND` environment variable (`sim` | `mp` | `proc`).
//!
//! [`Error::Protocol`]: crate::error::Error::Protocol

pub(crate) mod mp;
pub(crate) mod proc;
pub(crate) mod sim;
pub(crate) mod site;
pub(crate) mod step;
pub(crate) mod wire;

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use crate::dist::TensorDist;
use crate::error::Result;
use crate::redist::RedistPlan;
use crate::runtime::KernelEngine;
use crate::sim::{CommStats, NetworkModel, StoreStats, TimeBreakdown};
use crate::tensor::Tensor;

pub use proc::rank_worker;
pub use step::ComputeStep;

/// Allocation counters for a backend's local scratch (Seq
/// intermediates, pre-reduction buffers, MTTKRP permute buffers, the
/// gather's permute staging).  Steady-state invariant: `allocs` stops
/// growing after the first run of a plan while `reuses` keeps counting.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LocalScratchStats {
    /// Whole local tensors heap-allocated (first run, or shape change).
    pub allocs: u64,
    /// Whole local tensors recycled across runs.
    pub reuses: u64,
}

impl LocalScratchStats {
    /// Counter-wise sum (per-rank stats roll up into one figure).
    pub(crate) fn add(&mut self, other: LocalScratchStats) {
        self.allocs += other.allocs;
        self.reuses += other.reuses;
    }
}

/// Which execution backend a session drives plans through.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub enum ExecBackend {
    /// In-process simulated machine (`sim::Machine`): sequential ranks,
    /// shared store, modeled communication time.  The default.
    #[default]
    Sim,
    /// Message-passing thread sites: one OS thread per rank, private
    /// stores, real channel traffic for every redistribution and
    /// reduction.
    Mp,
    /// Out-of-process rank sites: one `deinsum rank-worker` child
    /// process per rank (or a remote TCP peer per `DEINSUM_RANK_ADDR`),
    /// driven over the versioned wire format of [`wire`].
    Proc,
}

impl ExecBackend {
    /// Resolve the process-wide default from `DEINSUM_BACKEND`
    /// (case-insensitive `"mp"` selects [`ExecBackend::Mp`], `"proc"`
    /// selects [`ExecBackend::Proc`]; anything else — including unset —
    /// selects [`ExecBackend::Sim`]).
    pub fn from_env() -> ExecBackend {
        match std::env::var("DEINSUM_BACKEND") {
            Ok(v) if v.eq_ignore_ascii_case("mp") => ExecBackend::Mp,
            Ok(v) if v.eq_ignore_ascii_case("proc") => ExecBackend::Proc,
            _ => ExecBackend::Sim,
        }
    }

    /// Stable lowercase name (CLI flag values, bench labels).
    pub fn name(&self) -> &'static str {
        match self {
            ExecBackend::Sim => "sim",
            ExecBackend::Mp => "mp",
            ExecBackend::Proc => "proc",
        }
    }
}

/// Transport tuning shared by the distributed backends, resolved once
/// per session ([`crate::api::SessionBuilder`] overrides beat the
/// environment).
#[derive(Debug, Clone)]
pub(crate) struct ExecTuning {
    /// Bound on every coordinator↔rank and rank↔rank wait inside the
    /// mp and proc backends (`DEINSUM_PEER_TIMEOUT_MS`; default 60 s).
    /// A blown deadline is a fatal protocol error: the executor is
    /// poisoned and rebuilt on the next run.
    pub(crate) peer_timeout: Duration,
    /// Pre-existing rank listeners for the proc backend
    /// (`DEINSUM_RANK_ADDR`, comma-separated `host:port`).  `None`:
    /// spawn `deinsum rank-worker` child processes over pipes.
    pub(crate) rank_addrs: Option<Vec<String>>,
}

impl Default for ExecTuning {
    fn default() -> Self {
        ExecTuning { peer_timeout: env_peer_timeout(), rank_addrs: env_rank_addrs() }
    }
}

/// `DEINSUM_PEER_TIMEOUT_MS` (integer milliseconds), defaulting to the
/// historical 60 s on unset or unparsable values.
pub(crate) fn env_peer_timeout() -> Duration {
    std::env::var("DEINSUM_PEER_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_secs(60))
}

/// `DEINSUM_RANK_ADDR`: comma-separated `host:port` listeners, one per
/// rank in rank order.  Empty or unset means "spawn child processes".
pub(crate) fn env_rank_addrs() -> Option<Vec<String>> {
    let v = std::env::var("DEINSUM_RANK_ADDR").ok()?;
    let addrs: Vec<String> =
        v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    if addrs.is_empty() {
        None
    } else {
        Some(addrs)
    }
}

/// The plan-execution surface the coordinator drives: everything that
/// used to be a direct `sim::Machine` call.  One executor instance is
/// owned by one [`crate::api::Program`] and persists across runs — its
/// stores and scratch recycle buffers run-to-run, which is where the
/// zero-allocation steady state lives.
///
/// Determinism contract: for a fixed plan and inputs, `gather_into`
/// must produce bitwise-identical bytes on every backend (block cuts,
/// accumulation order, and kernel configuration are all fixed by the
/// plan, never by the backend).
pub trait Executor: Send {
    /// Which backend this executor implements.
    fn backend(&self) -> ExecBackend;

    /// Number of ranks.
    fn ranks(&self) -> usize;

    /// Whether the executor can run another plan.  A message-passing
    /// executor that observed a protocol violation (dead rank, timed
    /// out collective) reports `false` and is rebuilt by the run loop.
    fn healthy(&self) -> bool {
        true
    }

    /// Start a run: reset per-run time/volume accounting, keep stores.
    fn begin_run(&mut self) -> Result<()>;

    /// Scatter `global` into per-rank blocks under `name` per `dist`
    /// (recycled destination buffers; uncharged staging).
    fn stage_blocks(&mut self, name: &str, global: &Tensor, dist: &TensorDist)
        -> Result<()>;

    /// Install an explicit per-rank tensor set under `name`.
    fn put(&mut self, name: &str, per_rank: Vec<Tensor>) -> Result<()>;

    /// Fetch rank `rank`'s buffer for `name` (owned: the mp backend
    /// moves a copy across the channel).
    fn get(&mut self, name: &str, rank: usize) -> Result<Tensor>;

    /// Execute a redistribution plan from `src_name` into `dst_name`,
    /// charging the α–β model on the exact per-rank volumes.
    fn redistribute(
        &mut self,
        src_name: &str,
        dst_name: &str,
        rp: &RedistPlan,
        src: &TensorDist,
        dst: &TensorDist,
    ) -> Result<()>;

    /// Run `step` on every rank (measured per-rank wall clock; outputs
    /// recycled under [`ComputeStep`]'s output name).
    fn compute_step_into(&mut self, step: &ComputeStep) -> Result<()>;

    /// Close the step: parallel compute time = max over ranks.
    fn end_step(&mut self);

    /// Allreduce-sum `name` over each rank group (paper §II-D).
    fn allreduce_sum(&mut self, name: &str, groups: &[Vec<usize>]) -> Result<()>;

    /// Assemble `name`'s distributed blocks into `dest` (global layout
    /// per `dist`, optionally permuted into spec order by `perm`).
    fn gather_into(
        &mut self,
        name: &str,
        dist: &TensorDist,
        perm: Option<&[usize]>,
        dest: &mut Tensor,
    ) -> Result<()>;

    /// End a run: prune stores/scratch down to the names this run
    /// touched (persistent buffers stay bounded across plan switches).
    fn end_run(&mut self, live: &BTreeSet<String>) -> Result<()>;

    /// Store-buffer recycling counters (cumulative across runs).
    fn store_stats(&self) -> StoreStats;

    /// Local-scratch recycling counters (cumulative across runs).
    fn scratch_stats(&self) -> LocalScratchStats;

    /// Simulated/modeled time of the current (or last) run.
    fn time(&self) -> TimeBreakdown;

    /// Exact communication volumes of the current (or last) run.
    fn comm(&self) -> CommStats;
}

/// Build an executor for `backend` over `ranks` ranks.  The engine
/// reference is how rank sites dispatch local kernels (and replay the
/// coordinator's per-term kernel config on their own threads).
pub(crate) fn make(
    backend: ExecBackend,
    ranks: usize,
    net: NetworkModel,
    engine: Arc<KernelEngine>,
    tuning: &ExecTuning,
) -> Box<dyn Executor> {
    match backend {
        ExecBackend::Sim => Box::new(sim::SimExecutor::new(ranks, net, engine)),
        ExecBackend::Mp => {
            Box::new(mp::MpExecutor::new(ranks, net, engine, tuning.peer_timeout))
        }
        ExecBackend::Proc => Box::new(proc::ProcExecutor::new(ranks, net, engine, tuning)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_from_env_name_roundtrip() {
        assert_eq!(ExecBackend::Sim.name(), "sim");
        assert_eq!(ExecBackend::Mp.name(), "mp");
        assert_eq!(ExecBackend::Proc.name(), "proc");
        assert_eq!(ExecBackend::default(), ExecBackend::Sim);
    }

    #[test]
    fn default_tuning_peer_timeout_is_60s_when_env_unset() {
        // Tests never mutate process-global env (parallel test threads
        // share it); this pins the default only when the variable is
        // absent from the environment the suite runs under.
        if std::env::var("DEINSUM_PEER_TIMEOUT_MS").is_err() {
            assert_eq!(ExecTuning::default().peer_timeout, Duration::from_secs(60));
        }
    }
}
