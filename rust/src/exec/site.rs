//! The per-rank site state shared by every distributed backend.
//!
//! A rank site — whether it lives on a thread ([`super::mp`]) or in a
//! child process ([`super::proc`]) — owns exactly the same local world:
//! a private store slice, recycled scratch, and the store-recycling
//! counters.  [`SiteState`] is that world, with the recycling policies
//! (stage-in-place, zeroed redistribution destinations, compute-output
//! recycling) implemented **once**, so the counters the coordinator
//! caches line up bitwise across backends and the typed error messages
//! the fuzzer compares are identical by construction.

use std::any::Any;
use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::KernelEngine;
use crate::sim::StoreStats;
use crate::tensor::Tensor;

use super::step::{self, ComputeStep, RankScratch, RankStore};
use super::LocalScratchStats;

/// The interpreter's read-only view of a rank site's store.
pub(crate) struct LocalStore<'a> {
    pub(crate) store: &'a HashMap<String, Tensor>,
    pub(crate) rank: usize,
}

impl RankStore for LocalStore<'_> {
    fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.store.get(name).ok_or_else(|| {
            Error::plan(format!("tensor {name} rank {} missing", self.rank))
        })
    }
}

/// One rank's private world: local store slice, recycled scratch, and
/// cumulative recycling counters.  Transport-agnostic — the mp backend
/// wraps it in a thread, the proc backend in a worker process.
pub(crate) struct SiteState {
    pub(crate) rank: usize,
    pub(crate) engine: Arc<KernelEngine>,
    pub(crate) store: HashMap<String, Tensor>,
    pub(crate) scratch: RankScratch,
    pub(crate) stats: StoreStats,
}

impl SiteState {
    pub(crate) fn new(rank: usize, engine: Arc<KernelEngine>) -> Self {
        SiteState {
            rank,
            engine,
            store: HashMap::new(),
            scratch: RankScratch::default(),
            stats: StoreStats::default(),
        }
    }

    /// Cumulative local-scratch counters.
    pub(crate) fn scratch_stats(&self) -> LocalScratchStats {
        self.scratch.stats()
    }

    pub(crate) fn begin_run(&mut self) {
        self.scratch.begin_run();
    }

    /// Prune the store/scratch down to the names this run touched.
    pub(crate) fn end_run(&mut self, live: &BTreeSet<String>) {
        self.store.retain(|k, _| live.contains(k));
        self.scratch.end_run();
    }

    /// Install a staged input block, recycling the resident buffer in
    /// place when the shape matches (the per-rank half of the
    /// simulator's `dest_allocs`/`dest_reuses` accounting — the totals
    /// line up because staging shapes are uniform across ranks).
    pub(crate) fn stage(&mut self, name: String, block: Tensor) {
        match self.store.remove(&name) {
            Some(mut t) if t.dims() == block.dims() => {
                self.stats.dest_reuses += 1;
                t.data_mut().copy_from_slice(block.data());
                self.store.insert(name, t);
            }
            _ => {
                self.stats.dest_allocs += 1;
                self.store.insert(name, block);
            }
        }
    }

    /// Take a zeroed destination buffer for a redistribution (recycled
    /// when the resident shape matches, cleared so edge padding outside
    /// the incoming boxes stays exact).
    pub(crate) fn take_dest(&mut self, dst: &str, ldims: &[usize]) -> Tensor {
        match self.store.remove(dst) {
            Some(mut t) if t.dims() == ldims => {
                self.stats.dest_reuses += 1;
                t.data_mut().fill(0.0);
                t
            }
            _ => {
                self.stats.dest_allocs += 1;
                Tensor::zeros(ldims)
            }
        }
    }

    /// Run the term's local kernel through the shared interpreter,
    /// recycling the output buffer under the step's output name.
    /// Returns the measured kernel seconds; errors are typed and
    /// data-dependent (the site stays consistent — the buffer goes back
    /// even on error, so a recovered run still recycles it).
    pub(crate) fn compute(&mut self, step: &ComputeStep) -> Result<f64> {
        // Replay the coordinator's per-term kernel config on this
        // thread/process (thread-local overrides don't cross site
        // boundaries).
        self.engine.configure_override(step.kernel_cfg);
        let mut dest = match self.store.remove(&step.out_name) {
            Some(t) if t.dims() == step.out_dims.as_slice() => {
                self.stats.out_reuses += 1;
                t
            }
            _ => {
                self.stats.out_allocs += 1;
                Tensor::zeros(&step.out_dims)
            }
        };
        let t0 = Instant::now();
        let res = {
            let view = LocalStore { store: &self.store, rank: self.rank };
            step::execute_rank(&self.engine, &view, &mut self.scratch, step, &mut dest)
        };
        let dt = t0.elapsed().as_secs_f64();
        self.store.insert(step.out_name.clone(), dest);
        res.map(|()| dt)
    }
}

/// The group root's allreduce accumulation: shape pre-check over the
/// whole group before any accumulation (so a mismatch is a clean typed
/// error with nothing half-summed), then accumulate in group order —
/// the simulator's order, which is what keeps the backends bitwise
/// identical.  `contribs` must already be ordered `g[1..]`.  Returns
/// the payload length for the coordinator's cost model.
pub(crate) fn accumulate_group(
    name: &str,
    root: usize,
    buf: &mut Tensor,
    contribs: &[(usize, &Tensor)],
) -> Result<usize> {
    for (r, c) in contribs {
        if c.dims() != buf.dims() {
            return Err(Error::shape(format!(
                "allreduce {name}: rank {r} block {:?} != rank {root} block {:?}",
                c.dims(),
                buf.dims()
            )));
        }
    }
    for (_, c) in contribs {
        buf.add_assign(c)?;
    }
    Ok(buf.len())
}

/// Best-effort extraction of a panic payload's message.
pub(crate) fn panic_msg(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}
