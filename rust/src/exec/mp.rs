//! The message-passing backend: one OS thread per rank, private stores,
//! real channel traffic for every data movement.
//!
//! Each rank is a [`RankSite`] owning only its local store slice.  The
//! coordinator side ([`MpExecutor`]) drives the sites with a strict
//! instruction protocol: every instruction is broadcast to **all** `p`
//! ranks, then all `p` acknowledgements are collected in rank order
//! before the next instruction goes out.  That per-instruction barrier
//! — together with balanced send/receive counts inside every collective
//! — guarantees the rank-to-rank data channels are empty at each
//! barrier, so data from different instructions can never interleave.
//!
//! Collectives:
//!
//! - **Redistribute**: the coordinator splits the redistribution plan's
//!   message list per rank; each site ships its outgoing boxes
//!   ([`DataTag::Redist`]), applies its rank-local boxes, then drains
//!   exactly its expected receive count.  Boxes are disjoint, so
//!   arrival order cannot affect the bytes.
//! - **Allreduce**: pairwise exchange through the group root — members
//!   send contributions ([`DataTag::ReduceContrib`]), the root
//!   accumulates them in group order (the same order the simulator
//!   uses, which keeps the backends bitwise identical) and broadcasts
//!   the result ([`DataTag::ReduceResult`]).
//!
//! Failure taxonomy: data-dependent failures (missing tensor, shape
//! mismatch) travel as typed errors — the site stays consistent and the
//! executor stays [`healthy`](super::Executor::healthy).  Protocol
//! violations (unexpected tag, dead peer, timed-out collective, rank
//! panic) are *fatal*: the executor is poisoned and the run loop
//! rebuilds it before the next run.  Nothing in this module panics
//! across the rank boundary — rank panics are caught and surfaced as
//! [`Error::Runtime`].
//!
//! [`Error::Runtime`]: crate::error::Error::Runtime

use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::dist::TensorDist;
use crate::error::{Error, Result};
use crate::redist::{Message, RedistPlan};
use crate::runtime::KernelEngine;
use crate::sim::{CommStats, NetworkModel, StoreStats, TimeBreakdown};
use crate::tensor::{Tensor, ELEM_BYTES};

use super::site::{accumulate_group, panic_msg, SiteState};
use super::step::ComputeStep;
use super::{ExecBackend, Executor, LocalScratchStats};

/// One coordinator→rank instruction.  Every instruction goes to every
/// rank and is acknowledged before the next one is sent.
enum Instr {
    BeginRun,
    /// Install (or overwrite in place) this rank's staged input block.
    Stage { name: String, block: Tensor },
    /// Install this rank's buffer verbatim (no recycling counters).
    Put { name: String, tensor: Tensor },
    /// Return a copy of this rank's buffer in the ack (absent → `None`).
    Fetch { name: String },
    /// Run this rank's half of a redistribution: ship `sends`, apply
    /// `locals`, drain exactly `recv_count` incoming boxes.
    Redistribute {
        src: String,
        dst: String,
        ldims: Vec<usize>,
        sends: Vec<Message>,
        locals: Vec<Message>,
        recv_count: usize,
    },
    /// Execute the term's local kernel into the recycled output buffer.
    Compute { step: Arc<ComputeStep> },
    /// Allreduce-sum `name` over `group` (`None`: this rank reduces with
    /// nobody this round and acks immediately).
    Allreduce { name: String, group: Option<Arc<Vec<usize>>> },
    /// Prune the store/scratch down to the names this run touched.
    EndRun { live: Arc<BTreeSet<String>> },
    /// Shut the rank thread down.
    Stop,
}

/// Per-instruction acknowledgement payload: cumulative counters plus
/// whatever the instruction produced.
#[derive(Default)]
struct AckData {
    /// Measured kernel seconds for a `Compute` instruction.
    compute_s: f64,
    /// The fetched tensor for a `Fetch` instruction.
    tensor: Option<Tensor>,
    /// Allreduce payload length reported by a group root (drives the
    /// coordinator's α–β cost model).
    payload_len: Option<usize>,
    /// Cumulative store recycling counters for this rank.
    store: StoreStats,
    /// Cumulative local-scratch counters for this rank.
    scratch: LocalScratchStats,
}

/// One rank→coordinator acknowledgement.
enum AckMsg {
    Ok(AckData),
    /// The instruction failed with a typed (data-dependent) error; the
    /// site is still consistent.  Counters ride along so the
    /// coordinator's caches never lag.
    Err(Error, AckData),
    /// The site is broken (protocol violation or panic); the executor
    /// must be poisoned.
    Fatal(Error),
}

/// Coarse error class carried inside an abort notice, so the receiving
/// rank can reconstruct the same typed variant the originator saw.
#[derive(Debug, Clone, Copy)]
enum AbortClass {
    Plan,
    Shape,
    Protocol,
}

impl AbortClass {
    fn into_error(self, msg: String) -> Error {
        match self {
            AbortClass::Plan => Error::Plan(msg),
            AbortClass::Shape => Error::Shape(msg),
            // Rank/instruction context does not survive an abort notice;
            // the generic constructor keeps the detail intact.
            AbortClass::Protocol => Error::protocol(msg),
        }
    }
}

/// Split an error into an abort class plus its *inner* message (so the
/// reconstructed error Displays identically — no double prefix).
fn abort_of(e: &Error) -> (AbortClass, String) {
    match e {
        Error::Shape(m) => (AbortClass::Shape, m.clone()),
        Error::Plan(m) => (AbortClass::Plan, m.clone()),
        Error::Protocol { detail, .. } => (AbortClass::Protocol, detail.clone()),
        other => (AbortClass::Protocol, other.to_string()),
    }
}

/// One rank-to-rank payload.
struct DataMsg {
    src: usize,
    tag: DataTag,
    data: Tensor,
}

/// What a [`DataMsg`] means.  Abort tags keep the receive counts
/// balanced when the sender hits a typed error mid-collective.
#[derive(Debug)]
enum DataTag {
    /// A redistribution box landing at `dst_off`/`size` in the
    /// receiver's destination buffer.
    Redist { dst_off: Vec<usize>, size: Vec<usize> },
    /// The sender could not produce its redistribution boxes.
    RedistAbort(String),
    /// A member's allreduce contribution (full local block).
    ReduceContrib,
    /// The root's reduced block, broadcast back to a member.
    ReduceResult,
    /// The sender's half of the allreduce failed.
    ReduceAbort { class: AbortClass, msg: String },
}

/// How a rank-side handler failed.
enum Fail {
    /// Data-dependent error: the site is still consistent, the run
    /// continues to the next instruction.
    Typed(Error),
    /// Protocol violation: the site (or a peer) is broken.
    Fatal(Error),
}

impl From<Error> for Fail {
    fn from(e: Error) -> Self {
        Fail::Typed(e)
    }
}

type RankResult<T> = std::result::Result<T, Fail>;

/// One rank's thread-hosted site: the shared [`SiteState`] plus the
/// channel endpoints this transport uses (its data inbox and senders to
/// every peer's inbox).
struct RankSite {
    site: SiteState,
    /// How long to wait on peer data inside a collective before
    /// declaring the collective dead (fatal; poisons the executor).
    timeout: Duration,
    data_rx: Receiver<DataMsg>,
    data_tx: Vec<Sender<DataMsg>>,
}

impl RankSite {
    fn rank(&self) -> usize {
        self.site.rank
    }

    /// Baseline ack: cumulative counters, no payload.
    fn ok(&self) -> AckData {
        AckData {
            store: self.site.stats,
            scratch: self.site.scratch_stats(),
            ..AckData::default()
        }
    }

    fn recv_data(&self, instr: &str, what: &str) -> RankResult<DataMsg> {
        self.data_rx.recv_timeout(self.timeout).map_err(|_| {
            Fail::Fatal(Error::protocol_at(
                self.rank(),
                instr,
                format!("timed out waiting for {what} after {:?}", self.timeout),
            ))
        })
    }

    fn handle(&mut self, instr: Instr) -> RankResult<AckData> {
        match instr {
            Instr::BeginRun => {
                self.site.begin_run();
                Ok(self.ok())
            }
            Instr::Stage { name, block } => {
                self.site.stage(name, block);
                Ok(self.ok())
            }
            Instr::Put { name, tensor } => {
                self.site.store.insert(name, tensor);
                Ok(self.ok())
            }
            Instr::Fetch { name } => {
                let mut ack = self.ok();
                ack.tensor = self.site.store.get(&name).cloned();
                Ok(ack)
            }
            Instr::Redistribute { src, dst, ldims, sends, locals, recv_count } => {
                self.redistribute(src, dst, ldims, sends, locals, recv_count)
            }
            Instr::Compute { step } => match self.site.compute(&step) {
                Ok(dt) => {
                    let mut ack = self.ok();
                    ack.compute_s = dt;
                    Ok(ack)
                }
                Err(e) => Err(Fail::Typed(e)),
            },
            Instr::Allreduce { name, group } => self.allreduce(name, group),
            Instr::EndRun { live } => {
                self.site.end_run(&live);
                Ok(self.ok())
            }
            // Stop is intercepted by `rank_main` before dispatch.
            Instr::Stop => Ok(self.ok()),
        }
    }

    /// One rank's half of a redistribution round.
    fn redistribute(
        &mut self,
        src: String,
        dst: String,
        ldims: Vec<usize>,
        sends: Vec<Message>,
        locals: Vec<Message>,
        recv_count: usize,
    ) -> RankResult<AckData> {
        let zero = vec![0usize; ldims.len()];
        if !self.site.store.contains_key(&src) {
            // Every box this rank owed becomes an abort notice, so the
            // receivers' expected counts stay balanced; then drain our
            // own inbox before surfacing the typed error.
            for m in &sends {
                let _ = self.data_tx[m.dst].send(DataMsg {
                    src: self.rank(),
                    tag: DataTag::RedistAbort(format!("redistribute: {src} missing")),
                    data: Tensor::zeros(&[1]),
                });
            }
            for _ in 0..recv_count {
                let msg = self.recv_data("redistribute", "redistribution data")?;
                match msg.tag {
                    DataTag::Redist { .. } | DataTag::RedistAbort(_) => {}
                    tag => {
                        return Err(Fail::Fatal(Error::protocol_at(
                            self.rank(),
                            "redistribute",
                            format!("expected box or abort, got {tag:?}"),
                        )))
                    }
                }
            }
            return Err(Fail::Typed(Error::plan(format!(
                "redistribute: {src} missing"
            ))));
        }
        // Ship every outgoing box first so no peer stalls on our local
        // work.
        {
            let src_buf = self.site.store.get(&src).ok_or_else(|| {
                Fail::Fatal(Error::protocol_at(
                    self.rank(),
                    "redistribute",
                    format!("{src} vanished mid-redistribute"),
                ))
            })?;
            for m in &sends {
                let mut payload = Tensor::zeros(&m.size);
                payload.copy_box_from(src_buf, &m.src_off, &zero, &m.size);
                if self.data_tx[m.dst]
                    .send(DataMsg {
                        src: self.rank(),
                        tag: DataTag::Redist { dst_off: m.dst_off.clone(), size: m.size.clone() },
                        data: payload,
                    })
                    .is_err()
                {
                    return Err(Fail::Fatal(Error::protocol_at(
                        self.rank(),
                        "redistribute",
                        format!("peer {} is gone", m.dst),
                    )));
                }
            }
        }
        // Destination buffer: recycled when the shape matches, cleared
        // so edge padding outside the incoming boxes stays exact.
        let mut dstbuf = self.site.take_dest(&dst, &ldims);
        {
            let src_buf = self.site.store.get(&src).ok_or_else(|| {
                Fail::Fatal(Error::protocol_at(
                    self.rank(),
                    "redistribute",
                    format!("{src} vanished mid-redistribute"),
                ))
            })?;
            for m in &locals {
                dstbuf.copy_box_from(src_buf, &m.src_off, &m.dst_off, &m.size);
            }
        }
        let mut typed: Option<Error> = None;
        for _ in 0..recv_count {
            let msg = self.recv_data("redistribute", "redistribution data")?;
            match msg.tag {
                DataTag::Redist { dst_off, size } => {
                    let zo = vec![0usize; size.len()];
                    dstbuf.copy_box_from(&msg.data, &zo, &dst_off, &size);
                }
                DataTag::RedistAbort(m) => {
                    if typed.is_none() {
                        typed = Some(Error::plan(m));
                    }
                }
                tag => {
                    return Err(Fail::Fatal(Error::protocol_at(
                        self.rank(),
                        "redistribute",
                        format!("expected box or abort, got {tag:?}"),
                    )))
                }
            }
        }
        self.site.store.insert(dst, dstbuf);
        match typed {
            Some(e) => Err(Fail::Typed(e)),
            None => Ok(self.ok()),
        }
    }

    /// One rank's half of an allreduce round: members send their block
    /// to the group root, the root accumulates in group order and
    /// broadcasts the sum back.
    fn allreduce(
        &mut self,
        name: String,
        group: Option<Arc<Vec<usize>>>,
    ) -> RankResult<AckData> {
        let Some(g) = group else {
            return Ok(self.ok());
        };
        let root = g[0];
        if self.rank() != root {
            return self.allreduce_member(&name, root);
        }
        let others = &g[1..];
        let mut member_err: Option<Error> = None;
        let mut contribs: BTreeMap<usize, Tensor> = BTreeMap::new();
        for _ in 0..others.len() {
            let msg = self.recv_data("allreduce", "allreduce contributions")?;
            match msg.tag {
                DataTag::ReduceContrib => {
                    if contribs.insert(msg.src, msg.data).is_some() && member_err.is_none() {
                        member_err = Some(Error::protocol_at(
                            root,
                            "allreduce",
                            format!(
                                "duplicate contribution from rank {} for {name}",
                                msg.src
                            ),
                        ));
                    }
                }
                DataTag::ReduceAbort { class, msg: m } => {
                    if member_err.is_none() {
                        member_err = Some(class.into_error(m));
                    }
                }
                tag => {
                    return Err(Fail::Fatal(Error::protocol_at(
                        self.rank(),
                        "allreduce",
                        format!("expected contribution or abort, got {tag:?}"),
                    )))
                }
            }
        }
        let mut root_buf = self.site.store.remove(&name);
        let verdict = root_verdict(&name, root, others, member_err, &contribs, &mut root_buf);
        match (verdict, root_buf) {
            (Ok(len), Some(buf)) => {
                for &r in others {
                    if self.data_tx[r]
                        .send(DataMsg {
                            src: self.rank(),
                            tag: DataTag::ReduceResult,
                            data: buf.clone(),
                        })
                        .is_err()
                    {
                        self.site.store.insert(name, buf);
                        return Err(Fail::Fatal(Error::protocol_at(
                            self.rank(),
                            "allreduce",
                            format!("peer {r} is gone"),
                        )));
                    }
                }
                self.site.store.insert(name, buf);
                let mut ack = self.ok();
                ack.payload_len = Some(len);
                Ok(ack)
            }
            (Ok(_), None) => Err(Fail::Fatal(Error::protocol_at(
                self.rank(),
                "allreduce",
                format!("{name}: verdict without a root buffer"),
            ))),
            (Err(e), maybe) => {
                if let Some(buf) = maybe {
                    self.site.store.insert(name, buf);
                }
                // Members are blocked on a response; abort them all so
                // the round stays balanced, then surface the typed error.
                let (class, msg) = abort_of(&e);
                for &r in others {
                    let _ = self.data_tx[r].send(DataMsg {
                        src: self.rank(),
                        tag: DataTag::ReduceAbort { class, msg: msg.clone() },
                        data: Tensor::zeros(&[1]),
                    });
                }
                Err(Fail::Typed(e))
            }
        }
    }

    fn allreduce_member(&mut self, name: &str, root: usize) -> RankResult<AckData> {
        match self.site.store.get(name) {
            Some(t) => {
                let contrib = t.clone();
                if self.data_tx[root]
                    .send(DataMsg {
                        src: self.rank(),
                        tag: DataTag::ReduceContrib,
                        data: contrib,
                    })
                    .is_err()
                {
                    return Err(Fail::Fatal(Error::protocol_at(
                        self.rank(),
                        "allreduce",
                        format!("root {root} is gone"),
                    )));
                }
            }
            None => {
                let _ = self.data_tx[root].send(DataMsg {
                    src: self.rank(),
                    tag: DataTag::ReduceAbort {
                        class: AbortClass::Plan,
                        msg: format!("allreduce: {name} missing"),
                    },
                    data: Tensor::zeros(&[1]),
                });
            }
        }
        let msg = self.recv_data("allreduce", "allreduce result")?;
        match msg.tag {
            DataTag::ReduceResult => match self.site.store.get_mut(name) {
                Some(buf) if buf.dims() == msg.data.dims() => {
                    buf.data_mut().copy_from_slice(msg.data.data());
                    Ok(self.ok())
                }
                _ => Err(Fail::Fatal(Error::protocol_at(
                    self.rank(),
                    "allreduce",
                    format!("result shape mismatch for {name}"),
                ))),
            },
            DataTag::ReduceAbort { class, msg: m } => Err(Fail::Typed(class.into_error(m))),
            tag => Err(Fail::Fatal(Error::protocol_at(
                self.rank(),
                "allreduce",
                format!("expected result or abort, got {tag:?}"),
            ))),
        }
    }
}

/// The root's allreduce decision, computed against the buffer *in
/// place* (`root_buf` is reinserted by the caller whatever happens, so
/// a typed error never loses the buffer).  Returns the payload length
/// for the coordinator's cost model.
fn root_verdict(
    name: &str,
    root: usize,
    others: &[usize],
    member_err: Option<Error>,
    contribs: &BTreeMap<usize, Tensor>,
    root_buf: &mut Option<Tensor>,
) -> Result<usize> {
    if let Some(e) = member_err {
        return Err(e);
    }
    let buf = root_buf
        .as_mut()
        .ok_or_else(|| Error::plan(format!("allreduce: {name} missing")))?;
    // Resolve the contributions in group order, then run the shared
    // shape pre-check + accumulation (the simulator's order, which is
    // what keeps the backends bitwise identical).
    let mut ordered: Vec<(usize, &Tensor)> = Vec::with_capacity(others.len());
    for &r in others {
        let c = contribs.get(&r).ok_or_else(|| {
            Error::protocol_at(
                root,
                "allreduce",
                format!("missing contribution from rank {r} for {name}"),
            )
        })?;
        ordered.push((r, c));
    }
    accumulate_group(name, root, buf, &ordered)
}

/// A rank thread's main loop: receive, execute (panic-contained), ack.
fn rank_main(
    rank: usize,
    engine: Arc<KernelEngine>,
    timeout: Duration,
    instr_rx: Receiver<Instr>,
    ack_tx: Sender<AckMsg>,
    data_rx: Receiver<DataMsg>,
    data_tx: Vec<Sender<DataMsg>>,
) {
    let mut site = RankSite {
        site: SiteState::new(rank, engine),
        timeout,
        data_rx,
        data_tx,
    };
    loop {
        let instr = match instr_rx.recv() {
            Ok(i) => i,
            Err(_) => break, // coordinator gone: shut down
        };
        if matches!(instr, Instr::Stop) {
            site.site.engine.reset_config();
            break;
        }
        let ack = match catch_unwind(AssertUnwindSafe(|| site.handle(instr))) {
            Ok(Ok(d)) => AckMsg::Ok(d),
            Ok(Err(Fail::Typed(e))) => AckMsg::Err(e, site.ok()),
            Ok(Err(Fail::Fatal(e))) => AckMsg::Fatal(e),
            Err(p) => AckMsg::Fatal(Error::runtime(format!(
                "mp rank {rank} panicked: {}",
                panic_msg(p.as_ref())
            ))),
        };
        if ack_tx.send(ack).is_err() {
            break;
        }
    }
}

/// Coordinator side of the message-passing backend.
pub(crate) struct MpExecutor {
    p: usize,
    net: NetworkModel,
    /// Bound on every coordinator↔rank and rank↔rank wait
    /// ([`crate::api::SessionBuilder::peer_timeout`] /
    /// `DEINSUM_PEER_TIMEOUT_MS`; default 60 s).
    peer_timeout: Duration,
    instr_tx: Vec<Sender<Instr>>,
    ack_rx: Vec<Receiver<AckMsg>>,
    threads: Vec<JoinHandle<()>>,
    step_compute: Vec<f64>,
    time: TimeBreakdown,
    comm: CommStats,
    /// Last-seen cumulative counters per rank (refreshed on every ack).
    rank_store: Vec<StoreStats>,
    rank_scratch: Vec<LocalScratchStats>,
    /// Recycled permuted-gather staging (global extents).
    gather_stage: Option<Tensor>,
    gather_stats: LocalScratchStats,
    gather_live: bool,
    /// Set on any fatal ack/dead channel; `healthy()` turns false and
    /// the run loop rebuilds the executor.
    poisoned: bool,
}

impl MpExecutor {
    pub(crate) fn new(
        ranks: usize,
        net: NetworkModel,
        engine: Arc<KernelEngine>,
        peer_timeout: Duration,
    ) -> Self {
        let p = ranks.max(1);
        // Full p×p data mesh: one inbox per rank, every rank holds a
        // sender to every inbox.
        let mut data_tx_master: Vec<Sender<DataMsg>> = Vec::with_capacity(p);
        let mut data_rx_all: Vec<Receiver<DataMsg>> = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            data_tx_master.push(tx);
            data_rx_all.push(rx);
        }
        let mut instr_tx = Vec::with_capacity(p);
        let mut ack_rx = Vec::with_capacity(p);
        let mut threads = Vec::with_capacity(p);
        for (r, drx) in data_rx_all.into_iter().enumerate() {
            let (itx, irx) = channel();
            let (atx, arx) = channel();
            instr_tx.push(itx);
            ack_rx.push(arx);
            let dtx = data_tx_master.clone();
            let eng = Arc::clone(&engine);
            threads.push(
                thread::Builder::new()
                    .name(format!("deinsum-mp-{r}"))
                    .spawn(move || rank_main(r, eng, peer_timeout, irx, atx, drx, dtx))
                    .expect("spawn mp rank thread"),
            );
        }
        MpExecutor {
            p,
            net,
            peer_timeout,
            instr_tx,
            ack_rx,
            threads,
            step_compute: vec![0.0; p],
            time: TimeBreakdown::default(),
            comm: CommStats::default(),
            rank_store: vec![StoreStats::default(); p],
            rank_scratch: vec![LocalScratchStats::default(); p],
            gather_stage: None,
            gather_stats: LocalScratchStats::default(),
            gather_live: false,
            poisoned: false,
        }
    }

    fn send_instr(&mut self, r: usize, i: Instr) -> Result<()> {
        if self.poisoned {
            return Err(Error::protocol_at(
                None,
                "send",
                "executor is poisoned (a rank site failed fatally)",
            ));
        }
        match self.instr_tx[r].send(i) {
            Ok(()) => Ok(()),
            Err(_) => {
                self.poisoned = true;
                Err(Error::protocol_at(None, "send", format!("rank {r} is gone")))
            }
        }
    }

    /// Collect all `p` acks in rank order.  Counter caches are updated
    /// from every non-fatal ack; the first error (typed before later
    /// typed, fatal poisons) is returned only after the full barrier,
    /// so the channels are provably drained.
    fn collect_acks(&mut self) -> Result<Vec<AckData>> {
        let mut first_err: Option<Error> = None;
        let mut acks = Vec::with_capacity(self.p);
        for r in 0..self.p {
            match self.ack_rx[r].recv_timeout(self.peer_timeout) {
                Ok(AckMsg::Ok(d)) => {
                    self.rank_store[r] = d.store;
                    self.rank_scratch[r] = d.scratch;
                    acks.push(d);
                }
                Ok(AckMsg::Err(e, d)) => {
                    self.rank_store[r] = d.store;
                    self.rank_scratch[r] = d.scratch;
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    acks.push(d);
                }
                Ok(AckMsg::Fatal(e)) => {
                    self.poisoned = true;
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    acks.push(AckData::default());
                }
                Err(_) => {
                    self.poisoned = true;
                    if first_err.is_none() {
                        first_err = Some(Error::protocol_at(
                            None,
                            "ack",
                            format!(
                                "no ack from rank {r} within {:?} (dead or stalled)",
                                self.peer_timeout
                            ),
                        ));
                    }
                    acks.push(AckData::default());
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(acks),
        }
    }

    /// Broadcast one instruction (built per rank) and collect the acks.
    fn round(&mut self, mk: impl Fn(usize) -> Instr) -> Result<Vec<AckData>> {
        for r in 0..self.p {
            self.send_instr(r, mk(r))?;
        }
        self.collect_acks()
    }
}

impl Executor for MpExecutor {
    fn backend(&self) -> ExecBackend {
        ExecBackend::Mp
    }

    fn ranks(&self) -> usize {
        self.p
    }

    fn healthy(&self) -> bool {
        !self.poisoned
    }

    fn begin_run(&mut self) -> Result<()> {
        self.time = TimeBreakdown::default();
        self.comm = CommStats::default();
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
        self.gather_live = false;
        self.round(|_| Instr::BeginRun).map(|_| ())
    }

    fn stage_blocks(&mut self, name: &str, global: &Tensor, dist: &TensorDist) -> Result<()> {
        // Cut the blocks with the simulator's exact semantics (zeroed
        // buffer + clipped box copy ≡ zero padding at global edges), so
        // the staged bytes are identical across backends.
        let ldims = dist.local_dims();
        let zero_off = vec![0usize; ldims.len()];
        for r in 0..self.p {
            let (off, _size) = dist.block_for_rank(r);
            let mut block = Tensor::zeros(&ldims);
            block.copy_box_from(global, &off, &zero_off, &ldims);
            self.send_instr(r, Instr::Stage { name: name.to_string(), block })?;
        }
        self.collect_acks().map(|_| ())
    }

    fn put(&mut self, name: &str, per_rank: Vec<Tensor>) -> Result<()> {
        if per_rank.len() != self.p {
            return Err(Error::plan(format!(
                "put {name}: {} tensors for {} ranks",
                per_rank.len(),
                self.p
            )));
        }
        for (r, tensor) in per_rank.into_iter().enumerate() {
            self.send_instr(r, Instr::Put { name: name.to_string(), tensor })?;
        }
        self.collect_acks().map(|_| ())
    }

    fn get(&mut self, name: &str, rank: usize) -> Result<Tensor> {
        if rank >= self.p {
            return Err(Error::plan(format!("tensor {name} rank {rank} missing")));
        }
        let acks = self.round(|_| Instr::Fetch { name: name.to_string() })?;
        acks.into_iter()
            .nth(rank)
            .and_then(|d| d.tensor)
            .ok_or_else(|| Error::plan(format!("tensor {name} rank {rank} missing")))
    }

    fn redistribute(
        &mut self,
        src_name: &str,
        dst_name: &str,
        rp: &RedistPlan,
        src: &TensorDist,
        dst: &TensorDist,
    ) -> Result<()> {
        debug_assert_eq!(src.extents, dst.extents);
        if src_name == dst_name {
            return Err(Error::plan(format!(
                "redistribute: in-place aliasing ({src_name}) unsupported"
            )));
        }
        if src.grid.size() > self.p || dst.grid.size() > self.p {
            return Err(Error::plan(format!(
                "redistribute: distribution grid ({} -> {} ranks) exceeds machine ({})",
                src.grid.size(),
                dst.grid.size(),
                self.p
            )));
        }
        // Split the plan's message list per rank: what each site sends,
        // applies locally, and must receive.
        let mut per_rank: Vec<(Vec<Message>, Vec<Message>, usize)> =
            (0..self.p).map(|_| (Vec::new(), Vec::new(), 0)).collect();
        for m in &rp.messages {
            if m.src >= self.p || m.dst >= self.p {
                return Err(Error::plan(format!(
                    "redistribute: message rank {}->{} exceeds machine ({})",
                    m.src, m.dst, self.p
                )));
            }
            if m.src == m.dst {
                per_rank[m.src].1.push(m.clone());
            } else {
                per_rank[m.src].0.push(m.clone());
                per_rank[m.dst].2 += 1;
            }
        }
        let ldims = dst.local_dims();
        for (r, (sends, locals, recv_count)) in per_rank.into_iter().enumerate() {
            self.send_instr(
                r,
                Instr::Redistribute {
                    src: src_name.to_string(),
                    dst: dst_name.to_string(),
                    ldims: ldims.clone(),
                    sends,
                    locals,
                    recv_count,
                },
            )?;
        }
        self.collect_acks()?;
        // Charge the simulator's α–β model on the identical message set
        // (max per-rank volume; links are parallel across rank pairs).
        let mut sent = vec![0u128; self.p];
        let mut recv = vec![0u128; self.p];
        let mut msgs = vec![0u64; self.p];
        for m in &rp.messages {
            if m.src == m.dst {
                continue;
            }
            let b = m.bytes() as u128;
            sent[m.src] += b;
            recv[m.dst] += b;
            msgs[m.src] += 1;
            self.comm.p2p_bytes += b;
            self.comm.p2p_msgs += 1;
        }
        let max_bytes = sent.iter().zip(&recv).map(|(s, r)| s + r).max().unwrap_or(0) as f64;
        let max_msgs = msgs.iter().max().copied().unwrap_or(0) as f64;
        self.time.comm += self.net.p2p_time(max_msgs, max_bytes);
        Ok(())
    }

    fn compute_step_into(&mut self, step: &ComputeStep) -> Result<()> {
        let shared = Arc::new(step.clone());
        for r in 0..self.p {
            self.send_instr(r, Instr::Compute { step: Arc::clone(&shared) })?;
        }
        let acks = self.collect_acks()?;
        for (r, d) in acks.iter().enumerate() {
            self.step_compute[r] += d.compute_s;
        }
        Ok(())
    }

    fn end_step(&mut self) {
        let max = self.step_compute.iter().cloned().fold(0.0, f64::max);
        self.time.compute += max;
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
    }

    fn allreduce_sum(&mut self, name: &str, groups: &[Vec<usize>]) -> Result<()> {
        for g in groups {
            for &r in g {
                if r >= self.p {
                    return Err(Error::plan(format!(
                        "allreduce {name}: rank {r} exceeds machine ({})",
                        self.p
                    )));
                }
            }
        }
        let mut per_rank: Vec<Option<Arc<Vec<usize>>>> = vec![None; self.p];
        for g in groups {
            if g.len() <= 1 {
                continue;
            }
            let shared = Arc::new(g.clone());
            for &r in g {
                per_rank[r] = Some(Arc::clone(&shared));
            }
        }
        for (r, group) in per_rank.into_iter().enumerate() {
            self.send_instr(r, Instr::Allreduce { name: name.to_string(), group })?;
        }
        let acks = self.collect_acks()?;
        // Charge the simulator's tree-allreduce model per group from the
        // payload length each group root measured.
        let mut max_t = 0.0f64;
        for g in groups {
            if g.len() <= 1 {
                continue;
            }
            let len = acks[g[0]].payload_len.ok_or_else(|| {
                Error::protocol_at(
                    None,
                    "allreduce",
                    format!("missing payload length from root rank {} for {name}", g[0]),
                )
            })?;
            let bytes = (len * ELEM_BYTES) as f64;
            let t = self.net.allreduce_time(g.len(), bytes);
            self.comm.allreduce_bytes += (len * ELEM_BYTES) as u128 * (g.len() as u128);
            self.comm.allreduces += 1;
            max_t = max_t.max(t);
        }
        self.time.comm += max_t;
        Ok(())
    }

    fn gather_into(
        &mut self,
        name: &str,
        dist: &TensorDist,
        perm: Option<&[usize]>,
        dest: &mut Tensor,
    ) -> Result<()> {
        // One Fetch round pulls every rank's block across the channels;
        // assembly then uses the same owner/box math as the simulator.
        let acks = self.round(|_| Instr::Fetch { name: name.to_string() })?;
        let tensors: Vec<Option<Tensor>> = acks.into_iter().map(|d| d.tensor).collect();
        let assemble = |target: &mut Tensor| -> Result<()> {
            let zero_off = vec![0usize; dist.extents.len()];
            for bc in dist.block_coords() {
                let owner = dist.owner_of_block(&bc);
                let (off, size) = dist.block_for_rank(owner);
                let t = tensors
                    .get(owner)
                    .and_then(|o| o.as_ref())
                    .ok_or_else(|| Error::plan(format!("tensor {name} rank {owner} missing")))?;
                target.copy_box_from(t, &zero_off, &off, &size);
            }
            Ok(())
        };
        match perm {
            None => assemble(dest),
            Some(p) => {
                self.gather_live = true;
                let mut g = match self.gather_stage.take() {
                    Some(t) if t.dims() == &dist.extents[..] => {
                        self.gather_stats.reuses += 1;
                        t
                    }
                    _ => {
                        self.gather_stats.allocs += 1;
                        Tensor::zeros(&dist.extents)
                    }
                };
                let res = assemble(&mut g).and_then(|()| g.permute_into(p, dest));
                self.gather_stage = Some(g);
                res
            }
        }
    }

    fn end_run(&mut self, live: &BTreeSet<String>) -> Result<()> {
        let shared = Arc::new(live.clone());
        for r in 0..self.p {
            self.send_instr(r, Instr::EndRun { live: Arc::clone(&shared) })?;
        }
        self.collect_acks()?;
        if !self.gather_live {
            self.gather_stage = None;
        }
        Ok(())
    }

    fn store_stats(&self) -> StoreStats {
        let mut s = StoreStats::default();
        for r in &self.rank_store {
            s.dest_allocs += r.dest_allocs;
            s.dest_reuses += r.dest_reuses;
            s.out_allocs += r.out_allocs;
            s.out_reuses += r.out_reuses;
        }
        s
    }

    fn scratch_stats(&self) -> LocalScratchStats {
        let mut s = self.gather_stats;
        for r in &self.rank_scratch {
            s.add(*r);
        }
        s
    }

    fn time(&self) -> TimeBreakdown {
        self.time
    }

    fn comm(&self) -> CommStats {
        self.comm.clone()
    }
}

impl Drop for MpExecutor {
    fn drop(&mut self) {
        for tx in &self.instr_tx {
            let _ = tx.send(Instr::Stop);
        }
        self.instr_tx.clear();
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec(p: usize) -> MpExecutor {
        MpExecutor::new(
            p,
            NetworkModel::aries(),
            Arc::new(KernelEngine::native()),
            Duration::from_secs(60),
        )
    }

    fn t(dims: &[usize], data: &[f32]) -> Tensor {
        Tensor::from_vec(dims, data.to_vec()).unwrap()
    }

    #[test]
    fn put_fetch_roundtrip_and_missing_is_typed() {
        let mut e = exec(2);
        e.begin_run().unwrap();
        e.put("a", vec![t(&[2], &[1.0, 2.0]), t(&[2], &[3.0, 4.0])]).unwrap();
        assert_eq!(e.get("a", 1).unwrap().data(), &[3.0, 4.0]);
        assert!(matches!(e.get("missing", 0), Err(Error::Plan(_))));
        assert!(matches!(e.get("a", 9), Err(Error::Plan(_))));
        assert!(e.healthy(), "typed errors must not poison the executor");
    }

    #[test]
    fn put_wrong_rank_count_is_typed_before_any_send() {
        let mut e = exec(2);
        e.begin_run().unwrap();
        assert!(matches!(e.put("z", vec![Tensor::zeros(&[1])]), Err(Error::Plan(_))));
        assert!(e.healthy());
        // The protocol is still in lockstep afterwards.
        e.put("z", vec![t(&[1], &[7.0]), t(&[1], &[8.0])]).unwrap();
        assert_eq!(e.get("z", 0).unwrap().data(), &[7.0]);
    }

    #[test]
    fn allreduce_sums_groups_over_channels() {
        let mut e = exec(4);
        e.begin_run().unwrap();
        e.put(
            "x",
            vec![
                t(&[2], &[1.0, 2.0]),
                t(&[2], &[3.0, 4.0]),
                t(&[2], &[10.0, 20.0]),
                t(&[2], &[30.0, 40.0]),
            ],
        )
        .unwrap();
        e.allreduce_sum("x", &[vec![0, 1], vec![2, 3]]).unwrap();
        assert_eq!(e.get("x", 0).unwrap().data(), &[4.0, 6.0]);
        assert_eq!(e.get("x", 1).unwrap().data(), &[4.0, 6.0]);
        assert_eq!(e.get("x", 2).unwrap().data(), &[40.0, 60.0]);
        assert_eq!(e.get("x", 3).unwrap().data(), &[40.0, 60.0]);
        let c = e.comm();
        assert_eq!(c.allreduces, 2);
        assert_eq!(c.allreduce_bytes, (2 * ELEM_BYTES) as u128 * 4);
    }

    #[test]
    fn allreduce_equal_len_different_dims_is_typed_shape_error() {
        let mut e = exec(2);
        e.begin_run().unwrap();
        // Equal element counts, different shapes: must be a typed shape
        // error (never a panic, never a hang), and must not poison.
        e.put("y", vec![t(&[2, 3], &[1.0; 6]), t(&[3, 2], &[1.0; 6])]).unwrap();
        let err = e.allreduce_sum("y", &[vec![0, 1]]).unwrap_err();
        assert!(matches!(err, Error::Shape(_)), "got: {err}");
        assert!(e.healthy(), "shape mismatch is data-dependent, not fatal");
        // Buffers survive untouched (the pre-check runs before any
        // accumulation) and the protocol stays usable.
        assert_eq!(e.get("y", 0).unwrap().dims(), &[2, 3]);
        assert_eq!(e.get("y", 1).unwrap().dims(), &[3, 2]);
    }

    #[test]
    fn allreduce_missing_tensor_is_typed_plan_error() {
        let mut e = exec(2);
        e.begin_run().unwrap();
        let err = e.allreduce_sum("nope", &[vec![0, 1]]).unwrap_err();
        assert!(matches!(err, Error::Plan(_)), "got: {err}");
        assert!(e.healthy());
    }

    #[test]
    fn short_peer_timeout_poisons_instead_of_hanging() {
        // A deliberately inconsistent instruction stream: rank 0 is told
        // to expect one incoming redistribution box that no rank will
        // ever send.  Under a short peer timeout the rank must give up,
        // report a fatal timeout (a typed Protocol error at the
        // coordinator), and poison the executor — never hang.
        let mut e = MpExecutor::new(
            2,
            NetworkModel::aries(),
            Arc::new(KernelEngine::native()),
            Duration::from_millis(100),
        );
        e.begin_run().unwrap();
        e.put("s", vec![t(&[1], &[1.0]), t(&[1], &[2.0])]).unwrap();
        e.send_instr(
            0,
            Instr::Redistribute {
                src: "s".to_string(),
                dst: "d".to_string(),
                ldims: vec![1],
                sends: vec![],
                locals: vec![],
                recv_count: 1,
            },
        )
        .unwrap();
        e.send_instr(1, Instr::BeginRun).unwrap();
        let err = e.collect_acks().unwrap_err();
        assert!(
            matches!(err, Error::Protocol { rank: Some(0), .. }),
            "want a rank-0 protocol timeout, got: {err}"
        );
        assert!(
            err.to_string().contains("timed out"),
            "timeout context missing from: {err}"
        );
        assert!(!e.healthy(), "a timed-out collective must poison the executor");
    }

    #[test]
    fn end_run_prunes_dead_names() {
        let mut e = exec(2);
        e.begin_run().unwrap();
        e.put("keep", vec![t(&[1], &[1.0]), t(&[1], &[2.0])]).unwrap();
        e.put("drop", vec![t(&[1], &[3.0]), t(&[1], &[4.0])]).unwrap();
        let mut live = BTreeSet::new();
        live.insert("keep".to_string());
        e.end_run(&live).unwrap();
        assert!(e.get("keep", 0).is_ok());
        assert!(matches!(e.get("drop", 0), Err(Error::Plan(_))));
    }
}
