//! The out-of-process backend: one rank site per child process (or
//! remote TCP peer), driven over the versioned wire format of
//! [`super::wire`].
//!
//! The instruction protocol is the mp backend's, serialized: every
//! round sends exactly `p` instructions and collects exactly `p`
//! acknowledgements in rank order, so the transport is provably
//! drained at every barrier.  Rank-to-rank data movement becomes a
//! **star topology** — the coordinator relays redistribution boxes and
//! allreduce partials — which keeps workers free of peer connections
//! while preserving the exact per-rank interpreter, recycling
//! counters, accumulation order, and typed error messages of the
//! other backends (bitwise-pinned in `tests/backends.rs`).
//!
//! Transports:
//!
//! - **Pipes** (default): each rank is a spawned `deinsum rank-worker`
//!   child, instructions on its stdin, acks on its stdout, stderr
//!   passed through.  Spawn failures are retried a few times; a spawn
//!   that still fails poisons the executor, and the run loop's rebuild
//!   retries the spawn on the next run.
//! - **TCP** (`DEINSUM_RANK_ADDR` or
//!   [`crate::api::SessionBuilder::rank_addrs`]): each rank is a
//!   pre-existing `deinsum rank-worker --listen host:port` process;
//!   the coordinator dials it with a bounded retry window.
//!
//! Deadlines: every ack/handshake wait is bounded by the session's
//! peer timeout (a dedicated reader thread per peer feeds a channel,
//! so pipes get real timeouts too); TCP writes carry a write timeout.
//! A blown deadline, dead peer, or wire violation surfaces as a typed
//! [`Error::Protocol`] and poisons the executor — never a hang, never
//! a panic across the process boundary.
//!
//! [`Error::Protocol`]: crate::error::Error::Protocol

use std::collections::BTreeSet;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::dist::TensorDist;
use crate::error::{Error, Result};
use crate::redist::{Message, RedistPlan};
use crate::runtime::KernelEngine;
use crate::sim::{CommStats, NetworkModel, StoreStats, TimeBreakdown};
use crate::tensor::{Tensor, ELEM_BYTES};

use super::site::{accumulate_group, panic_msg, SiteState};
use super::step::ComputeStep;
use super::wire::{self, WireAck, WireAckData, WireBox, WireInstr};
use super::{ExecBackend, ExecTuning, Executor, LocalScratchStats};

// ---------------------------------------------------------- worker side

/// How a worker-side handler failed (the proc twin of the mp backend's
/// failure split).
enum WFail {
    /// Data-dependent: the site is consistent, the run continues.
    Typed(Error),
    /// The site is broken; the coordinator must poison the executor.
    Fatal(Error),
}

/// Baseline ack: cumulative counters, no payload.
fn ok_data(site: &SiteState) -> WireAckData {
    WireAckData {
        store: site.stats,
        scratch: site.scratch_stats(),
        ..WireAckData::default()
    }
}

/// Execute one instruction against the rank site.  Every typed error
/// message here matches the mp/sim backends byte-for-byte — that is
/// what keeps fuzz rejection signatures equal across backends.
fn handle(site: &mut SiteState, instr: WireInstr) -> std::result::Result<WireAckData, WFail> {
    match instr {
        WireInstr::Nop | WireInstr::Stop => Ok(ok_data(site)),
        WireInstr::BeginRun => {
            site.begin_run();
            Ok(ok_data(site))
        }
        WireInstr::Stage { name, block } => {
            site.stage(name, block);
            Ok(ok_data(site))
        }
        WireInstr::Put { name, tensor } => {
            site.store.insert(name, tensor);
            Ok(ok_data(site))
        }
        WireInstr::Fetch { name } => {
            let tensor = site.store.get(&name).cloned();
            let mut ack = ok_data(site);
            ack.tensor = tensor;
            Ok(ack)
        }
        WireInstr::RedistExtract { src, sends } => {
            let Some(src_buf) = site.store.get(&src) else {
                return Err(WFail::Typed(Error::plan(format!(
                    "redistribute: {src} missing"
                ))));
            };
            let mut boxes = Vec::with_capacity(sends.len());
            for m in &sends {
                let zero = vec![0usize; m.size.len()];
                let mut payload = Tensor::zeros(&m.size);
                payload.copy_box_from(src_buf, &m.src_off, &zero, &m.size);
                boxes.push((
                    m.dst,
                    WireBox { dst_off: m.dst_off.clone(), size: m.size.clone(), data: payload },
                ));
            }
            let mut ack = ok_data(site);
            ack.boxes = boxes;
            Ok(ack)
        }
        WireInstr::RedistApply { src, dst, ldims, locals, incoming } => {
            let mut dstbuf = site.take_dest(&dst, &ldims);
            {
                let src_buf = site.store.get(&src).ok_or_else(|| {
                    WFail::Fatal(Error::protocol_at(
                        site.rank,
                        "redistribute",
                        format!("{src} vanished mid-redistribute"),
                    ))
                })?;
                for m in &locals {
                    dstbuf.copy_box_from(src_buf, &m.src_off, &m.dst_off, &m.size);
                }
            }
            for b in &incoming {
                let zo = vec![0usize; b.size.len()];
                dstbuf.copy_box_from(&b.data, &zo, &b.dst_off, &b.size);
            }
            site.store.insert(dst, dstbuf);
            Ok(ok_data(site))
        }
        WireInstr::Compute { step } => match site.compute(&step) {
            Ok(dt) => {
                let mut ack = ok_data(site);
                ack.compute_s = dt;
                Ok(ack)
            }
            Err(e) => Err(WFail::Typed(e)),
        },
        WireInstr::ReduceExtract { name } => match site.store.get(&name) {
            Some(t) => {
                let contrib = t.clone();
                let mut ack = ok_data(site);
                ack.tensor = Some(contrib);
                Ok(ack)
            }
            None => Err(WFail::Typed(Error::plan(format!("allreduce: {name} missing")))),
        },
        WireInstr::ReduceAccum { name, root, contribs } => {
            let Some(mut buf) = site.store.remove(&name) else {
                return Err(WFail::Typed(Error::plan(format!(
                    "allreduce: {name} missing"
                ))));
            };
            let refs: Vec<(usize, &Tensor)> =
                contribs.iter().map(|(r, t)| (*r, t)).collect();
            match accumulate_group(&name, root, &mut buf, &refs) {
                Ok(len) => {
                    let result = buf.clone();
                    site.store.insert(name, buf);
                    let mut ack = ok_data(site);
                    ack.payload_len = Some(len);
                    ack.tensor = Some(result);
                    Ok(ack)
                }
                Err(e) => {
                    // The buffer goes back untouched (the shape
                    // pre-check runs before any accumulation).
                    site.store.insert(name, buf);
                    Err(WFail::Typed(e))
                }
            }
        }
        WireInstr::ReduceStore { name, result } => {
            match site.store.get_mut(&name) {
                Some(buf) if buf.dims() == result.dims() => {
                    buf.data_mut().copy_from_slice(result.data());
                }
                _ => {
                    return Err(WFail::Fatal(Error::protocol_at(
                        site.rank,
                        "allreduce",
                        format!("result shape mismatch for {name}"),
                    )))
                }
            }
            Ok(ok_data(site))
        }
        WireInstr::EndRun { live } => {
            let live: BTreeSet<String> = live.into_iter().collect();
            site.end_run(&live);
            Ok(ok_data(site))
        }
    }
}

/// Serve one coordinator connection: handshake, then the
/// receive/execute/ack loop (panic-contained) until `Stop` or EOF.
fn serve_stream<R: Read, W: Write>(
    engine: Arc<KernelEngine>,
    mut r: R,
    mut w: W,
) -> io::Result<()> {
    let hello = wire::read_frame(&mut r)?;
    let (rank, _ranks) = wire::check_hello(&hello)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    wire::write_frame(&mut w, &wire::hello_ack(rank))?;
    let mut site = SiteState::new(rank, engine);
    loop {
        let frame = match wire::read_frame(&mut r) {
            Ok(f) => f,
            // Coordinator gone (pipe closed / connection dropped): a
            // clean shutdown, not an error.
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e),
        };
        let instr = match wire::decode_instr(&frame) {
            Ok(i) => i,
            Err(e) => {
                // Corrupt stream: report fatally with this rank's
                // identity attached, then stop serving it.
                let err = match e {
                    Error::Protocol { instr, detail, .. } => {
                        Error::Protocol { rank: Some(rank), instr, detail }
                    }
                    other => other,
                };
                let _ = wire::write_frame(&mut w, &wire::encode_ack(&WireAck::Fatal { err }));
                return Ok(());
            }
        };
        if matches!(instr, WireInstr::Stop) {
            site.engine.reset_config();
            return Ok(());
        }
        let ack = match catch_unwind(AssertUnwindSafe(|| handle(&mut site, instr))) {
            Ok(Ok(d)) => WireAck::Ok(d),
            Ok(Err(WFail::Typed(e))) => WireAck::Err { err: e, data: ok_data(&site) },
            Ok(Err(WFail::Fatal(e))) => WireAck::Fatal { err: e },
            Err(p) => WireAck::Fatal {
                err: Error::runtime(format!(
                    "proc rank {rank} panicked: {}",
                    panic_msg(p.as_ref())
                )),
            },
        };
        wire::write_frame(&mut w, &wire::encode_ack(&ack))?;
    }
}

/// Run the per-rank serve loop of the proc backend in this process
/// (the `deinsum rank-worker` CLI entry).
///
/// - `listen: None`: serve one coordinator over stdin/stdout (the
///   spawned-subprocess transport).  Returns when the coordinator
///   sends `Stop` or closes the pipe.
/// - `listen: Some(addr)`: bind a TCP listener, print
///   `listening <addr>` on stdout (so `--listen 127.0.0.1:0` callers
///   can discover the ephemeral port), and serve coordinators one
///   connection at a time — each connection gets a fresh rank site, so
///   a rebuilt executor can reconnect after a failure.  Runs until the
///   process is killed.
pub fn rank_worker(listen: Option<&str>) -> Result<()> {
    // Workers always dispatch native kernels: the engine lives on this
    // side of the process boundary.
    let engine = Arc::new(KernelEngine::native());
    match listen {
        None => {
            let stdin = io::stdin();
            let stdout = io::stdout();
            let r = stdin.lock();
            let w = BufWriter::new(stdout.lock());
            serve_stream(engine, r, w).map_err(Error::Io)
        }
        Some(addr) => {
            let listener = TcpListener::bind(addr)?;
            let local = listener.local_addr()?;
            println!("listening {local}");
            io::stdout().flush()?;
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let _ = stream.set_nodelay(true);
                let Ok(rd) = stream.try_clone() else { continue };
                // A wire/transport failure kills this connection only;
                // the listener survives for the next coordinator.
                let _ = serve_stream(
                    Arc::clone(&engine),
                    BufReader::new(rd),
                    BufWriter::new(stream),
                );
            }
            Ok(())
        }
    }
}

// ----------------------------------------------------- coordinator side

/// Locate the `deinsum` binary to spawn as a rank worker.
///
/// Resolution order: `DEINSUM_WORKER_BIN`, the current executable if it
/// *is* the CLI (exact file stem `deinsum` — a `deinsum-<hash>` test
/// binary would re-run the test harness), then a sibling search from
/// the current executable's directory upward (test binaries live in
/// `target/<profile>/deps`, the CLI one directory up).
fn worker_binary() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("DEINSUM_WORKER_BIN") {
        if !p.trim().is_empty() {
            return Ok(PathBuf::from(p));
        }
    }
    let is_cli = |p: &Path| p.file_stem().map(|s| s == "deinsum").unwrap_or(false);
    let exe = std::env::current_exe().map_err(|e| {
        Error::protocol_at(None, "spawn", format!("cannot resolve current executable: {e}"))
    })?;
    if is_cli(&exe) {
        return Ok(exe);
    }
    let name = format!("deinsum{}", std::env::consts::EXE_SUFFIX);
    let mut dir = exe.parent();
    for _ in 0..2 {
        if let Some(d) = dir {
            let cand = d.join(&name);
            if cand.is_file() {
                return Ok(cand);
            }
            dir = d.parent();
        }
    }
    Err(Error::protocol_at(
        None,
        "spawn",
        "cannot locate the deinsum worker binary; set DEINSUM_WORKER_BIN",
    ))
}

/// One connected rank peer: a framed writer, a reader thread feeding a
/// channel (which is what gives pipes a real receive deadline), and
/// the child process handle when this peer was spawned.
struct Peer {
    writer: Box<dyn Write + Send>,
    frames: Receiver<io::Result<Vec<u8>>>,
    child: Option<Child>,
}

impl Peer {
    fn send(&mut self, frame: &[u8]) -> io::Result<()> {
        wire::write_frame(&mut self.writer, frame)
    }
}

impl Drop for Peer {
    fn drop(&mut self) {
        // Best-effort Stop, then a bounded wait: never hang the
        // coordinator on a wedged or dead worker.
        let _ = wire::write_frame(&mut self.writer, &wire::encode_instr(&WireInstr::Stop));
        if let Some(child) = self.child.as_mut() {
            let deadline = Instant::now() + Duration::from_secs(2);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        thread::sleep(Duration::from_millis(20));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        // The reader thread exits on its own at EOF (child dead /
        // connection closed); it is deliberately not joined, so a
        // wedged remote peer can never hang a drop.
    }
}

/// Spawn a detached reader thread pushing frames into a channel; the
/// coordinator then waits with `recv_timeout` (pipes have no native
/// read deadline).
fn spawn_reader(mut r: Box<dyn Read + Send>) -> Receiver<io::Result<Vec<u8>>> {
    let (tx, rx) = channel();
    thread::Builder::new()
        .name("deinsum-proc-reader".to_string())
        .spawn(move || loop {
            match wire::read_frame(&mut r) {
                Ok(f) => {
                    if tx.send(Ok(f)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(Err(e));
                    break;
                }
            }
        })
        .expect("spawn proc reader thread");
    rx
}

/// Coordinator side of the handshake: send hello, await the echoed
/// hello-ack under the peer deadline.
fn handshake(peer: &mut Peer, rank: usize, timeout: Duration, ranks: usize) -> Result<()> {
    peer.send(&wire::hello(rank, ranks)).map_err(|e| {
        Error::protocol_at(None, "handshake", format!("rank {rank}: {e}"))
    })?;
    match peer.frames.recv_timeout(timeout) {
        Ok(Ok(frame)) => wire::check_hello_ack(&frame, rank),
        Ok(Err(e)) => Err(Error::protocol_at(
            None,
            "handshake",
            format!("rank {rank} connection failed: {e}"),
        )),
        Err(_) => Err(Error::protocol_at(
            None,
            "handshake",
            format!("no hello-ack from rank {rank} within {timeout:?}"),
        )),
    }
}

/// Spawn one `deinsum rank-worker` child and handshake it.
fn connect_child(bin: &Path, rank: usize, ranks: usize, timeout: Duration) -> Result<Peer> {
    let mut child = Command::new(bin)
        .arg("rank-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| {
            Error::protocol_at(None, "spawn", format!("rank {rank}: cannot spawn {bin:?}: {e}"))
        })?;
    let stdin = child.stdin.take().ok_or_else(|| {
        Error::protocol_at(None, "spawn", format!("rank {rank}: no stdin pipe"))
    })?;
    let stdout = child.stdout.take().ok_or_else(|| {
        Error::protocol_at(None, "spawn", format!("rank {rank}: no stdout pipe"))
    })?;
    let mut peer = Peer {
        writer: Box::new(BufWriter::new(stdin)),
        frames: spawn_reader(Box::new(stdout)),
        child: Some(child),
    };
    handshake(&mut peer, rank, timeout, ranks)?;
    Ok(peer)
}

/// Spawn with retry: a transient spawn/handshake failure (fork
/// pressure, slow child start) gets a few fresh attempts before the
/// executor is poisoned — and the poisoned executor is rebuilt by the
/// run loop, which retries the spawn again on the next run.
fn connect_child_retry(
    bin: &Path,
    rank: usize,
    ranks: usize,
    timeout: Duration,
) -> Result<Peer> {
    let mut last: Option<Error> = None;
    for attempt in 0..3u32 {
        if attempt > 0 {
            thread::sleep(Duration::from_millis(50 << attempt));
        }
        match connect_child(bin, rank, ranks, timeout) {
            Ok(p) => return Ok(p),
            Err(e) => last = Some(e),
        }
    }
    Err(last.expect("at least one spawn attempt"))
}

/// Dial one pre-existing TCP rank listener (bounded retry window, then
/// handshake under the same deadline).
fn connect_tcp(addr: &str, rank: usize, ranks: usize, timeout: Duration) -> Result<Peer> {
    let deadline = Instant::now() + timeout;
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::protocol_at(
                        None,
                        "connect",
                        format!("rank {rank}: cannot reach {addr} within {timeout:?}: {e}"),
                    ));
                }
                thread::sleep(Duration::from_millis(50));
            }
        }
    };
    let _ = stream.set_nodelay(true);
    stream.set_write_timeout(Some(timeout)).map_err(|e| {
        Error::protocol_at(None, "connect", format!("rank {rank}: {e}"))
    })?;
    let rd = stream.try_clone().map_err(|e| {
        Error::protocol_at(None, "connect", format!("rank {rank}: {e}"))
    })?;
    let mut peer = Peer {
        writer: Box::new(BufWriter::new(stream)),
        frames: spawn_reader(Box::new(rd)),
        child: None,
    };
    handshake(&mut peer, rank, timeout, ranks)?;
    Ok(peer)
}

/// One rank's drained acknowledgement for a round.
struct AckOutcome {
    /// Typed or reconstructed error (fatal outcomes also land here so
    /// rank-order error selection sees them).
    err: Option<Error>,
    /// Whether the error was fatal (executor poisoned).
    fatal: bool,
    data: WireAckData,
}

/// Coordinator side of the out-of-process backend.
pub(crate) struct ProcExecutor {
    p: usize,
    net: NetworkModel,
    tuning: ExecTuning,
    /// Connected peers (empty until the first `begin_run`; connection
    /// is lazy so construction is infallible and spawn failures are
    /// typed errors the rebuild seam retries).
    peers: Vec<Peer>,
    step_compute: Vec<f64>,
    time: TimeBreakdown,
    comm: CommStats,
    rank_store: Vec<StoreStats>,
    rank_scratch: Vec<LocalScratchStats>,
    gather_stage: Option<Tensor>,
    gather_stats: LocalScratchStats,
    gather_live: bool,
    poisoned: bool,
}

impl ProcExecutor {
    pub(crate) fn new(
        ranks: usize,
        net: NetworkModel,
        _engine: Arc<KernelEngine>,
        tuning: &ExecTuning,
    ) -> Self {
        // The engine parameter is the factory's shared signature; rank
        // workers build their own native engines behind the process
        // boundary.
        let p = ranks.max(1);
        ProcExecutor {
            p,
            net,
            tuning: tuning.clone(),
            peers: Vec::new(),
            step_compute: vec![0.0; p],
            time: TimeBreakdown::default(),
            comm: CommStats::default(),
            rank_store: vec![StoreStats::default(); p],
            rank_scratch: vec![LocalScratchStats::default(); p],
            gather_stage: None,
            gather_stats: LocalScratchStats::default(),
            gather_live: false,
            poisoned: false,
        }
    }

    /// Connect every peer (spawn children or dial TCP listeners).  Any
    /// failure poisons the executor: the run loop rebuilds it, which is
    /// what retries the spawn/dial on the next run.
    fn ensure_peers(&mut self) -> Result<()> {
        if !self.peers.is_empty() {
            return Ok(());
        }
        if self.poisoned {
            return Err(Error::protocol_at(
                None,
                "connect",
                "executor is poisoned (a rank site failed fatally)",
            ));
        }
        let timeout = self.tuning.peer_timeout;
        let p = self.p;
        let connect = || -> Result<Vec<Peer>> {
            let mut peers = Vec::with_capacity(p);
            match &self.tuning.rank_addrs {
                Some(addrs) => {
                    if addrs.len() < p {
                        return Err(Error::protocol_at(
                            None,
                            "connect",
                            format!("{} rank addresses for {p} ranks", addrs.len()),
                        ));
                    }
                    for (r, addr) in addrs.iter().take(p).enumerate() {
                        peers.push(connect_tcp(addr, r, p, timeout)?);
                    }
                }
                None => {
                    let bin = worker_binary()?;
                    for r in 0..p {
                        peers.push(connect_child_retry(&bin, r, p, timeout)?);
                    }
                }
            }
            Ok(peers)
        };
        match connect() {
            Ok(peers) => {
                self.peers = peers;
                Ok(())
            }
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    fn send_frame(&mut self, r: usize, frame: &[u8]) -> Result<()> {
        if self.poisoned {
            return Err(Error::protocol_at(
                None,
                "send",
                "executor is poisoned (a rank site failed fatally)",
            ));
        }
        match self.peers[r].send(frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.poisoned = true;
                Err(Error::protocol_at(None, "send", format!("rank {r} is gone: {e}")))
            }
        }
    }

    fn send_instr(&mut self, r: usize, instr: &WireInstr) -> Result<()> {
        let frame = wire::encode_instr(instr);
        self.send_frame(r, &frame)
    }

    /// Receive and decode one ack from rank `r` under the peer deadline.
    fn recv_ack(&mut self, r: usize) -> Result<WireAck> {
        match self.peers[r].frames.recv_timeout(self.tuning.peer_timeout) {
            Ok(Ok(frame)) => wire::decode_ack(&frame)
                .map_err(|e| Error::protocol_at(None, "ack", format!("rank {r}: {e}"))),
            Ok(Err(e)) => Err(Error::protocol_at(
                None,
                "ack",
                format!("rank {r} connection failed: {e}"),
            )),
            Err(RecvTimeoutError::Timeout) => Err(Error::protocol_at(
                None,
                "ack",
                format!(
                    "no ack from rank {r} within {:?} (dead or stalled)",
                    self.tuning.peer_timeout
                ),
            )),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::protocol_at(None, "ack", format!("rank {r} is gone")))
            }
        }
    }

    /// Drain all `p` acks in rank order.  Counter caches are refreshed
    /// from every non-fatal ack; fatal outcomes (worker Fatal ack, dead
    /// peer, decode failure, timeout) poison the executor but the drain
    /// still completes, so the per-rank error/payload picture is whole.
    fn collect_acks_each(&mut self) -> Vec<AckOutcome> {
        let mut outs = Vec::with_capacity(self.p);
        for r in 0..self.p {
            let out = match self.recv_ack(r) {
                Ok(WireAck::Ok(d)) => {
                    self.rank_store[r] = d.store;
                    self.rank_scratch[r] = d.scratch;
                    AckOutcome { err: None, fatal: false, data: d }
                }
                Ok(WireAck::Err { err, data }) => {
                    self.rank_store[r] = data.store;
                    self.rank_scratch[r] = data.scratch;
                    AckOutcome { err: Some(err), fatal: false, data }
                }
                Ok(WireAck::Fatal { err }) => {
                    self.poisoned = true;
                    AckOutcome { err: Some(err), fatal: true, data: WireAckData::default() }
                }
                Err(e) => {
                    self.poisoned = true;
                    AckOutcome { err: Some(e), fatal: true, data: WireAckData::default() }
                }
            };
            outs.push(out);
        }
        outs
    }

    /// The mp backend's barrier semantics: the first error in rank
    /// order is surfaced only after all `p` acks drained.
    fn collect_acks(&mut self) -> Result<Vec<WireAckData>> {
        let mut outs = self.collect_acks_each();
        match outs.iter_mut().find_map(|o| o.err.take()) {
            Some(e) => Err(e),
            None => Ok(outs.into_iter().map(|o| o.data).collect()),
        }
    }

    /// Send the same instruction to every rank (encoded once) and
    /// collect the acks.
    fn broadcast(&mut self, instr: &WireInstr) -> Result<Vec<WireAckData>> {
        let frame = wire::encode_instr(instr);
        for r in 0..self.p {
            self.send_frame(r, &frame)?;
        }
        self.collect_acks()
    }
}

impl Executor for ProcExecutor {
    fn backend(&self) -> ExecBackend {
        ExecBackend::Proc
    }

    fn ranks(&self) -> usize {
        self.p
    }

    fn healthy(&self) -> bool {
        !self.poisoned
    }

    fn begin_run(&mut self) -> Result<()> {
        self.time = TimeBreakdown::default();
        self.comm = CommStats::default();
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
        self.gather_live = false;
        self.ensure_peers()?;
        self.broadcast(&WireInstr::BeginRun).map(|_| ())
    }

    fn stage_blocks(&mut self, name: &str, global: &Tensor, dist: &TensorDist) -> Result<()> {
        // Cut the blocks with the simulator's exact semantics (zeroed
        // buffer + clipped box copy ≡ zero padding at global edges), so
        // the staged bytes are identical across backends.
        let ldims = dist.local_dims();
        let zero_off = vec![0usize; ldims.len()];
        for r in 0..self.p {
            let (off, _size) = dist.block_for_rank(r);
            let mut block = Tensor::zeros(&ldims);
            block.copy_box_from(global, &off, &zero_off, &ldims);
            self.send_instr(r, &WireInstr::Stage { name: name.to_string(), block })?;
        }
        self.collect_acks().map(|_| ())
    }

    fn put(&mut self, name: &str, per_rank: Vec<Tensor>) -> Result<()> {
        if per_rank.len() != self.p {
            return Err(Error::plan(format!(
                "put {name}: {} tensors for {} ranks",
                per_rank.len(),
                self.p
            )));
        }
        for (r, tensor) in per_rank.into_iter().enumerate() {
            self.send_instr(r, &WireInstr::Put { name: name.to_string(), tensor })?;
        }
        self.collect_acks().map(|_| ())
    }

    fn get(&mut self, name: &str, rank: usize) -> Result<Tensor> {
        if rank >= self.p {
            return Err(Error::plan(format!("tensor {name} rank {rank} missing")));
        }
        let acks = self.broadcast(&WireInstr::Fetch { name: name.to_string() })?;
        acks.into_iter()
            .nth(rank)
            .and_then(|d| d.tensor)
            .ok_or_else(|| Error::plan(format!("tensor {name} rank {rank} missing")))
    }

    fn redistribute(
        &mut self,
        src_name: &str,
        dst_name: &str,
        rp: &RedistPlan,
        src: &TensorDist,
        dst: &TensorDist,
    ) -> Result<()> {
        debug_assert_eq!(src.extents, dst.extents);
        if src_name == dst_name {
            return Err(Error::plan(format!(
                "redistribute: in-place aliasing ({src_name}) unsupported"
            )));
        }
        if src.grid.size() > self.p || dst.grid.size() > self.p {
            return Err(Error::plan(format!(
                "redistribute: distribution grid ({} -> {} ranks) exceeds machine ({})",
                src.grid.size(),
                dst.grid.size(),
                self.p
            )));
        }
        // Split the plan's message list per rank: what each site
        // extracts for shipping and what it applies locally (the
        // coordinator relays the shipped boxes — star topology).
        let mut sends: Vec<Vec<Message>> = (0..self.p).map(|_| Vec::new()).collect();
        let mut locals: Vec<Vec<Message>> = (0..self.p).map(|_| Vec::new()).collect();
        for m in &rp.messages {
            if m.src >= self.p || m.dst >= self.p {
                return Err(Error::plan(format!(
                    "redistribute: message rank {}->{} exceeds machine ({})",
                    m.src, m.dst, self.p
                )));
            }
            if m.src == m.dst {
                locals[m.src].push(m.clone());
            } else {
                sends[m.src].push(m.clone());
            }
        }
        // Round one: every rank extracts its outgoing boxes (and checks
        // the source's presence — the typed `redistribute: .. missing`
        // error comes from the rank side, as in the mp backend).
        for (r, s) in sends.iter().enumerate() {
            self.send_instr(
                r,
                &WireInstr::RedistExtract { src: src_name.to_string(), sends: s.clone() },
            )?;
        }
        let mut outs = self.collect_acks_each();
        if outs.iter().any(|o| o.fatal) {
            return Err(outs
                .iter_mut()
                .find_map(|o| o.err.take())
                .expect("fatal outcome carries an error"));
        }
        let mut typed: Vec<Option<Error>> = Vec::with_capacity(self.p);
        let mut incoming: Vec<Vec<WireBox>> = (0..self.p).map(|_| Vec::new()).collect();
        for out in &mut outs {
            typed.push(out.err.take());
            for (dst_rank, b) in out.data.boxes.drain(..) {
                if dst_rank >= self.p {
                    self.poisoned = true;
                    return Err(Error::protocol_at(
                        None,
                        "redistribute",
                        format!("extracted box for rank {dst_rank} exceeds machine ({})", self.p),
                    ));
                }
                incoming[dst_rank].push(b);
            }
        }
        // Round two: ranks whose source was missing sit out (their
        // destination stays untouched, as in the mp backend); everyone
        // else fills the recycled destination from locals + relayed
        // boxes.  Disjoint boxes make application order irrelevant to
        // the bytes.
        let ldims = dst.local_dims();
        let nop = wire::encode_instr(&WireInstr::Nop);
        for r in 0..self.p {
            if typed[r].is_some() {
                self.send_frame(r, &nop)?;
            } else {
                self.send_instr(
                    r,
                    &WireInstr::RedistApply {
                        src: src_name.to_string(),
                        dst: dst_name.to_string(),
                        ldims: ldims.clone(),
                        locals: std::mem::take(&mut locals[r]),
                        incoming: std::mem::take(&mut incoming[r]),
                    },
                )?;
            }
        }
        let res = self.collect_acks();
        if let Some(e) = typed.into_iter().flatten().next() {
            return Err(e);
        }
        res?;
        // Charge the simulator's α–β model on the identical message set
        // (max per-rank volume; links are parallel across rank pairs).
        let mut sent = vec![0u128; self.p];
        let mut recv = vec![0u128; self.p];
        let mut msgs = vec![0u64; self.p];
        for m in &rp.messages {
            if m.src == m.dst {
                continue;
            }
            let b = m.bytes() as u128;
            sent[m.src] += b;
            recv[m.dst] += b;
            msgs[m.src] += 1;
            self.comm.p2p_bytes += b;
            self.comm.p2p_msgs += 1;
        }
        let max_bytes = sent.iter().zip(&recv).map(|(s, r)| s + r).max().unwrap_or(0) as f64;
        let max_msgs = msgs.iter().max().copied().unwrap_or(0) as f64;
        self.time.comm += self.net.p2p_time(max_msgs, max_bytes);
        Ok(())
    }

    fn compute_step_into(&mut self, step: &ComputeStep) -> Result<()> {
        let acks = self.broadcast(&WireInstr::Compute { step: step.clone() })?;
        for (r, d) in acks.iter().enumerate() {
            self.step_compute[r] += d.compute_s;
        }
        Ok(())
    }

    fn end_step(&mut self) {
        let max = self.step_compute.iter().cloned().fold(0.0, f64::max);
        self.time.compute += max;
        self.step_compute.iter_mut().for_each(|t| *t = 0.0);
    }

    fn allreduce_sum(&mut self, name: &str, groups: &[Vec<usize>]) -> Result<()> {
        for g in groups {
            for &r in g {
                if r >= self.p {
                    return Err(Error::plan(format!(
                        "allreduce {name}: rank {r} exceeds machine ({})",
                        self.p
                    )));
                }
            }
        }
        let eff: Vec<&Vec<usize>> = groups.iter().filter(|g| g.len() > 1).collect();
        if eff.is_empty() {
            // The mp backend still runs a (no-group) round; a Nop round
            // keeps the lockstep identical.
            self.broadcast(&WireInstr::Nop)?;
            return Ok(());
        }
        // Membership maps (later groups win, matching the mp backend's
        // per-rank slot assignment).
        let mut member_group: Vec<Option<usize>> = vec![None; self.p];
        let mut root_group: Vec<Option<usize>> = vec![None; self.p];
        for (gi, g) in eff.iter().enumerate() {
            root_group[g[0]] = Some(gi);
            member_group[g[0]] = None;
            for &r in &g[1..] {
                member_group[r] = Some(gi);
                root_group[r] = None;
            }
        }
        // Round one: members hand their local block to the coordinator.
        let extract = wire::encode_instr(&WireInstr::ReduceExtract { name: name.to_string() });
        let nop = wire::encode_instr(&WireInstr::Nop);
        for r in 0..self.p {
            let frame = if member_group[r].is_some() { &extract } else { &nop };
            self.send_frame(r, frame)?;
        }
        let mut outs = self.collect_acks_each();
        if outs.iter().any(|o| o.fatal) {
            return Err(outs
                .iter_mut()
                .find_map(|o| o.err.take())
                .expect("fatal outcome carries an error"));
        }
        let mut group_err: Vec<Option<Error>> = (0..eff.len()).map(|_| None).collect();
        let mut contrib: Vec<Option<Tensor>> = vec![None; self.p];
        for (r, out) in outs.iter_mut().enumerate() {
            let Some(gi) = member_group[r] else { continue };
            if let Some(e) = out.err.take() {
                if group_err[gi].is_none() {
                    group_err[gi] = Some(e);
                }
            } else {
                contrib[r] = out.data.tensor.take();
            }
        }
        // Round two: each healthy group's root accumulates the relayed
        // contributions in group order (the simulator's order — the
        // bitwise-identity anchor) and returns the sum.
        for r in 0..self.p {
            let instr = match root_group[r] {
                Some(gi) if group_err[gi].is_none() => {
                    let g = eff[gi];
                    let mut contribs = Vec::with_capacity(g.len() - 1);
                    for &m in &g[1..] {
                        let Some(t) = contrib[m].take() else {
                            self.poisoned = true;
                            return Err(Error::protocol_at(
                                None,
                                "allreduce",
                                format!("rank {m} acked extract without a payload for {name}"),
                            ));
                        };
                        contribs.push((m, t));
                    }
                    WireInstr::ReduceAccum { name: name.to_string(), root: r, contribs }
                }
                _ => WireInstr::Nop,
            };
            self.send_instr(r, &instr)?;
        }
        let mut outs = self.collect_acks_each();
        if outs.iter().any(|o| o.fatal) {
            return Err(outs
                .iter_mut()
                .find_map(|o| o.err.take())
                .expect("fatal outcome carries an error"));
        }
        let mut payload: Vec<Option<usize>> = vec![None; eff.len()];
        let mut result: Vec<Option<Tensor>> = (0..eff.len()).map(|_| None).collect();
        for (r, out) in outs.iter_mut().enumerate() {
            let Some(gi) = root_group[r] else { continue };
            if group_err[gi].is_some() {
                continue;
            }
            match out.err.take() {
                Some(e) => group_err[gi] = Some(e),
                None => {
                    payload[gi] = out.data.payload_len;
                    result[gi] = out.data.tensor.take();
                }
            }
        }
        // Round three: broadcast each healthy group's sum back to its
        // members (the root already holds it).  Failing groups sit the
        // round out — other groups still complete, as in the mp backend.
        let mut store_frames: Vec<Option<Vec<u8>>> = (0..eff.len()).map(|_| None).collect();
        for gi in 0..eff.len() {
            if group_err[gi].is_none() {
                let Some(res) = result[gi].take() else {
                    self.poisoned = true;
                    return Err(Error::protocol_at(
                        None,
                        "allreduce",
                        format!("root rank {} acked accumulate without a sum for {name}", eff[gi][0]),
                    ));
                };
                store_frames[gi] = Some(wire::encode_instr(&WireInstr::ReduceStore {
                    name: name.to_string(),
                    result: res,
                }));
            }
        }
        for r in 0..self.p {
            let frame = match member_group[r] {
                Some(gi) => store_frames[gi].clone().unwrap_or_else(|| nop.clone()),
                None => nop.clone(),
            };
            self.send_frame(r, &frame)?;
        }
        self.collect_acks()?;
        // Error selection matches the mp backend's first-in-rank-order
        // barrier: every rank of a failing group saw the same message
        // there, so the group with the smallest member rank wins.
        let mut best: Option<(usize, Error)> = None;
        for (gi, g) in eff.iter().enumerate() {
            if let Some(e) = group_err[gi].take() {
                let mr = g.iter().copied().min().unwrap_or(usize::MAX);
                if best.as_ref().map_or(true, |(m, _)| mr < *m) {
                    best = Some((mr, e));
                }
            }
        }
        if let Some((_, e)) = best {
            return Err(e);
        }
        // Charge the simulator's tree-allreduce model per group from
        // the payload length each group root measured.
        let mut max_t = 0.0f64;
        for (gi, g) in eff.iter().enumerate() {
            let len = payload[gi].ok_or_else(|| {
                Error::protocol_at(
                    None,
                    "allreduce",
                    format!("missing payload length from root rank {} for {name}", g[0]),
                )
            })?;
            let bytes = (len * ELEM_BYTES) as f64;
            let t = self.net.allreduce_time(g.len(), bytes);
            self.comm.allreduce_bytes += (len * ELEM_BYTES) as u128 * (g.len() as u128);
            self.comm.allreduces += 1;
            max_t = max_t.max(t);
        }
        self.time.comm += max_t;
        Ok(())
    }

    fn gather_into(
        &mut self,
        name: &str,
        dist: &TensorDist,
        perm: Option<&[usize]>,
        dest: &mut Tensor,
    ) -> Result<()> {
        // One Fetch round pulls every rank's block across the wire;
        // assembly then uses the same owner/box math as the simulator.
        let acks = self.broadcast(&WireInstr::Fetch { name: name.to_string() })?;
        let tensors: Vec<Option<Tensor>> = acks.into_iter().map(|d| d.tensor).collect();
        let assemble = |target: &mut Tensor| -> Result<()> {
            let zero_off = vec![0usize; dist.extents.len()];
            for bc in dist.block_coords() {
                let owner = dist.owner_of_block(&bc);
                let (off, size) = dist.block_for_rank(owner);
                let t = tensors
                    .get(owner)
                    .and_then(|o| o.as_ref())
                    .ok_or_else(|| Error::plan(format!("tensor {name} rank {owner} missing")))?;
                target.copy_box_from(t, &zero_off, &off, &size);
            }
            Ok(())
        };
        match perm {
            None => assemble(dest),
            Some(p) => {
                self.gather_live = true;
                let mut g = match self.gather_stage.take() {
                    Some(t) if t.dims() == &dist.extents[..] => {
                        self.gather_stats.reuses += 1;
                        t
                    }
                    _ => {
                        self.gather_stats.allocs += 1;
                        Tensor::zeros(&dist.extents)
                    }
                };
                let res = assemble(&mut g).and_then(|()| g.permute_into(p, dest));
                self.gather_stage = Some(g);
                res
            }
        }
    }

    fn end_run(&mut self, live: &BTreeSet<String>) -> Result<()> {
        self.broadcast(&WireInstr::EndRun { live: live.iter().cloned().collect() })?;
        if !self.gather_live {
            self.gather_stage = None;
        }
        Ok(())
    }

    fn store_stats(&self) -> StoreStats {
        let mut s = StoreStats::default();
        for r in &self.rank_store {
            s.dest_allocs += r.dest_allocs;
            s.dest_reuses += r.dest_reuses;
            s.out_allocs += r.out_allocs;
            s.out_reuses += r.out_reuses;
        }
        s
    }

    fn scratch_stats(&self) -> LocalScratchStats {
        let mut s = self.gather_stats;
        for r in &self.rank_scratch {
            s.add(*r);
        }
        s
    }

    fn time(&self) -> TimeBreakdown {
        self.time
    }

    fn comm(&self) -> CommStats {
        self.comm.clone()
    }
}

// Dropping the executor drops each Peer: best-effort Stop frame, a
// bounded child wait (then kill), detached readers exiting at EOF.

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::JoinHandle;

    fn t(dims: &[usize], data: &[f32]) -> Tensor {
        Tensor::from_vec(dims, data.to_vec()).unwrap()
    }

    /// In-process TCP workers: each serves exactly one connection with
    /// the real `serve_stream` loop (the full wire protocol without
    /// spawning child processes).
    fn spawn_tcp_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<()>>) {
        let mut addrs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            addrs.push(listener.local_addr().unwrap().to_string());
            handles.push(thread::spawn(move || {
                let engine = Arc::new(KernelEngine::native());
                if let Ok((stream, _)) = listener.accept() {
                    let _ = stream.set_nodelay(true);
                    let rd = stream.try_clone().unwrap();
                    let _ = serve_stream(engine, BufReader::new(rd), BufWriter::new(stream));
                }
            }));
        }
        (addrs, handles)
    }

    fn exec_tcp(addrs: Vec<String>, timeout_ms: u64) -> ProcExecutor {
        let p = addrs.len();
        let tuning = ExecTuning {
            peer_timeout: Duration::from_millis(timeout_ms),
            rank_addrs: Some(addrs),
        };
        ProcExecutor::new(p, NetworkModel::aries(), Arc::new(KernelEngine::native()), &tuning)
    }

    #[test]
    fn put_fetch_roundtrip_and_missing_is_typed() {
        let (addrs, handles) = spawn_tcp_workers(2);
        {
            let mut e = exec_tcp(addrs, 10_000);
            e.begin_run().unwrap();
            e.put("a", vec![t(&[2], &[1.0, 2.0]), t(&[2], &[3.0, 4.0])]).unwrap();
            assert_eq!(e.get("a", 1).unwrap().data(), &[3.0, 4.0]);
            assert!(matches!(e.get("missing", 0), Err(Error::Plan(_))));
            assert!(matches!(e.get("a", 9), Err(Error::Plan(_))));
            assert!(e.healthy(), "typed errors must not poison the executor");
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn allreduce_sums_groups_over_the_wire() {
        let (addrs, handles) = spawn_tcp_workers(4);
        {
            let mut e = exec_tcp(addrs, 10_000);
            e.begin_run().unwrap();
            e.put(
                "x",
                vec![
                    t(&[2], &[1.0, 2.0]),
                    t(&[2], &[3.0, 4.0]),
                    t(&[2], &[10.0, 20.0]),
                    t(&[2], &[30.0, 40.0]),
                ],
            )
            .unwrap();
            e.allreduce_sum("x", &[vec![0, 1], vec![2, 3]]).unwrap();
            assert_eq!(e.get("x", 0).unwrap().data(), &[4.0, 6.0]);
            assert_eq!(e.get("x", 1).unwrap().data(), &[4.0, 6.0]);
            assert_eq!(e.get("x", 2).unwrap().data(), &[40.0, 60.0]);
            assert_eq!(e.get("x", 3).unwrap().data(), &[40.0, 60.0]);
            let c = e.comm();
            assert_eq!(c.allreduces, 2);
            assert_eq!(c.allreduce_bytes, (2 * ELEM_BYTES) as u128 * 4);
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn allreduce_typed_errors_match_mp_and_do_not_poison() {
        let (addrs, handles) = spawn_tcp_workers(2);
        {
            let mut e = exec_tcp(addrs, 10_000);
            e.begin_run().unwrap();
            // Missing tensor: a typed plan error.
            let err = e.allreduce_sum("nope", &[vec![0, 1]]).unwrap_err();
            assert!(matches!(err, Error::Plan(_)), "got: {err}");
            assert_eq!(err.to_string(), "planning error: allreduce: nope missing");
            assert!(e.healthy());
            // Equal element counts, different shapes: a typed shape
            // error with the buffers untouched.
            e.put("y", vec![t(&[2, 3], &[1.0; 6]), t(&[3, 2], &[1.0; 6])]).unwrap();
            let err = e.allreduce_sum("y", &[vec![0, 1]]).unwrap_err();
            assert!(matches!(err, Error::Shape(_)), "got: {err}");
            assert!(e.healthy(), "shape mismatch is data-dependent, not fatal");
            assert_eq!(e.get("y", 0).unwrap().dims(), &[2, 3]);
            assert_eq!(e.get("y", 1).unwrap().dims(), &[3, 2]);
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn dead_peer_is_typed_and_poisons() {
        // A worker that handshakes and then dies: the next round must
        // surface a typed protocol error under the peer deadline and
        // poison the executor — never hang, never panic.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut rd = BufReader::new(stream.try_clone().unwrap());
            let mut wr = BufWriter::new(stream);
            let hello = wire::read_frame(&mut rd).unwrap();
            let (rank, _) = wire::check_hello(&hello).unwrap();
            wire::write_frame(&mut wr, &wire::hello_ack(rank)).unwrap();
            // ... and vanish before serving any instruction.
        });
        let mut e = exec_tcp(vec![addr], 1_000);
        let err = e.begin_run().unwrap_err();
        assert!(matches!(err, Error::Protocol { .. }), "got: {err}");
        assert!(!e.healthy(), "a dead peer must poison the executor");
        h.join().unwrap();
    }

    #[test]
    fn unreachable_listener_is_typed_and_poisons() {
        // Bind-then-drop guarantees nobody is listening on the port.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut e = exec_tcp(vec![addr], 200);
        let err = e.begin_run().unwrap_err();
        assert!(matches!(err, Error::Protocol { .. }), "got: {err}");
        assert!(err.to_string().contains("cannot reach"), "got: {err}");
        assert!(!e.healthy());
    }

    #[test]
    fn too_few_rank_addrs_is_typed() {
        let tuning = ExecTuning {
            peer_timeout: Duration::from_millis(200),
            rank_addrs: Some(vec!["127.0.0.1:1".to_string()]),
        };
        let mut e =
            ProcExecutor::new(2, NetworkModel::aries(), Arc::new(KernelEngine::native()), &tuning);
        let err = e.begin_run().unwrap_err();
        assert!(matches!(err, Error::Protocol { .. }), "got: {err}");
        assert!(err.to_string().contains("1 rank addresses for 2 ranks"), "got: {err}");
        assert!(!e.healthy());
    }
}
