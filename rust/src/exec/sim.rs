//! The simulated-machine backend: [`crate::sim::Machine`] behind the
//! [`Executor`] seam.
//!
//! Semantics are unchanged from the pre-trait run loop — shared
//! in-process store, sequential ranks, measured compute + α–β-modeled
//! communication, and the zero-allocation steady state (store and
//! scratch counters stay flat across reruns, counter-asserted in
//! tests).  Local kernels run through the same
//! [`execute_rank`](step::execute_rank) interpreter as the
//! message-passing backend.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::dist::TensorDist;
use crate::error::Result;
use crate::redist::RedistPlan;
use crate::runtime::KernelEngine;
use crate::sim::{CommStats, Machine, NetworkModel, StoreStats, TimeBreakdown};
use crate::tensor::Tensor;

use super::step::{self, ComputeStep, RankScratch, RankStore};
use super::{ExecBackend, Executor, LocalScratchStats};

/// In-process simulated backend (the default).
pub(crate) struct SimExecutor {
    engine: Arc<KernelEngine>,
    machine: Machine,
    /// Per-rank recycled compute scratch.
    scratch: Vec<RankScratch>,
    /// Recycled permuted-gather staging (global extents).
    gather_stage: Option<Tensor>,
    gather_stats: LocalScratchStats,
    /// Whether the current run's gather used the staging buffer (if
    /// not, `end_run` prunes it — a plan switch must not pin it).
    gather_live: bool,
}

impl SimExecutor {
    pub(crate) fn new(ranks: usize, net: NetworkModel, engine: Arc<KernelEngine>) -> Self {
        SimExecutor {
            engine,
            machine: Machine::new(ranks, net),
            scratch: (0..ranks).map(|_| RankScratch::default()).collect(),
            gather_stage: None,
            gather_stats: LocalScratchStats::default(),
            gather_live: false,
        }
    }
}

/// One rank's view of the shared machine store.
struct MachineRank<'m> {
    m: &'m Machine,
    rank: usize,
}

impl RankStore for MachineRank<'_> {
    fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.m.get(name, self.rank)
    }
}

/// Assemble `name`'s distributed blocks into `target` (term output
/// order) by direct strided copies out of the owners' local buffers —
/// no temporary block tensor per block.
fn assemble(m: &Machine, name: &str, dist: &TensorDist, target: &mut Tensor) -> Result<()> {
    let zero_off = vec![0usize; dist.extents.len()];
    for bc in dist.block_coords() {
        let owner = dist.owner_of_block(&bc);
        let (off, size) = dist.block_for_rank(owner);
        target.copy_box_from(m.get(name, owner)?, &zero_off, &off, &size);
    }
    Ok(())
}

impl Executor for SimExecutor {
    fn backend(&self) -> ExecBackend {
        ExecBackend::Sim
    }

    fn ranks(&self) -> usize {
        self.machine.ranks()
    }

    fn begin_run(&mut self) -> Result<()> {
        self.machine.begin_run();
        for s in &mut self.scratch {
            s.begin_run();
        }
        self.gather_live = false;
        Ok(())
    }

    fn stage_blocks(
        &mut self,
        name: &str,
        global: &Tensor,
        dist: &TensorDist,
    ) -> Result<()> {
        self.machine.stage_blocks(name, global, dist)
    }

    fn put(&mut self, name: &str, per_rank: Vec<Tensor>) -> Result<()> {
        self.machine.put(name, per_rank)
    }

    fn get(&mut self, name: &str, rank: usize) -> Result<Tensor> {
        self.machine.get(name, rank).cloned()
    }

    fn redistribute(
        &mut self,
        src_name: &str,
        dst_name: &str,
        rp: &RedistPlan,
        src: &TensorDist,
        dst: &TensorDist,
    ) -> Result<()> {
        self.machine.redistribute(src_name, dst_name, rp, src, dst)
    }

    fn compute_step_into(&mut self, step: &ComputeStep) -> Result<()> {
        // The coordinator installed the per-term kernel config on this
        // thread (sim ranks run on the caller's thread), so the closure
        // only needs the interpreter.
        let SimExecutor { engine, machine, scratch, .. } = self;
        machine.compute_step_into(&step.out_name, &step.out_dims, |r, m, dest| {
            let view = MachineRank { m, rank: r };
            step::execute_rank(engine, &view, &mut scratch[r], step, dest)
        })
    }

    fn end_step(&mut self) {
        self.machine.end_step();
    }

    fn allreduce_sum(&mut self, name: &str, groups: &[Vec<usize>]) -> Result<()> {
        self.machine.allreduce_sum(name, groups)
    }

    fn gather_into(
        &mut self,
        name: &str,
        dist: &TensorDist,
        perm: Option<&[usize]>,
        dest: &mut Tensor,
    ) -> Result<()> {
        match perm {
            None => assemble(&self.machine, name, dist, dest),
            Some(p) => {
                // Assemble into recycled staging, permute into the
                // caller's buffer: zero allocations in steady state.
                self.gather_live = true;
                let mut g = match self.gather_stage.take() {
                    Some(t) if t.dims() == &dist.extents[..] => {
                        self.gather_stats.reuses += 1;
                        t
                    }
                    _ => {
                        self.gather_stats.allocs += 1;
                        Tensor::zeros(&dist.extents)
                    }
                };
                let res = assemble(&self.machine, name, dist, &mut g)
                    .and_then(|()| g.permute_into(p, dest));
                self.gather_stage = Some(g);
                res
            }
        }
    }

    fn end_run(&mut self, live: &BTreeSet<String>) -> Result<()> {
        self.machine.retain_tensors(|n| live.contains(n));
        for s in &mut self.scratch {
            s.end_run();
        }
        if !self.gather_live {
            self.gather_stage = None;
        }
        Ok(())
    }

    fn store_stats(&self) -> StoreStats {
        self.machine.store_stats()
    }

    fn scratch_stats(&self) -> LocalScratchStats {
        let mut s = self.gather_stats;
        for r in &self.scratch {
            s.add(r.stats());
        }
        s
    }

    fn time(&self) -> TimeBreakdown {
        self.machine.time
    }

    fn comm(&self) -> CommStats {
        self.machine.comm.clone()
    }
}
