//! CTF-like comparator (paper §VI: the "state of the art" baseline).
//!
//! Models the behaviours the paper attributes to Cyclops/folding
//! libraries:
//!
//! - **no cross-statement fusion**: every binary contraction is its own
//!   distributed term — in particular MTTKRP runs as the two-step
//!   KRP-materialize + GEMM pipeline the paper proves communication-
//!   suboptimal (§IV-E);
//! - **extent-balanced grids** rather than SOAP-tile-proportioned ones
//!   (CTF picks grids from tensor shapes, not from a data-movement
//!   model);
//! - local work still uses the same fold-to-GEMM kernels, so the
//!   comparison isolates *schedule* quality, exactly like the paper's
//!   CTF runs linking the same BLAS/HPTT.

use crate::einsum::EinsumSpec;
use crate::error::Result;
use crate::planner::{plan, Plan, PlannerConfig};

/// Baseline planner configuration.
pub fn baseline_config(s_elements: f64) -> PlannerConfig {
    PlannerConfig { s_elements, fuse: false, soap_grids: false }
}

/// Plan `spec` with the CTF-like baseline scheduler.
pub fn plan_baseline(spec: &EinsumSpec, p: usize) -> Result<Plan> {
    plan(spec, p, &baseline_config(PlannerConfig::default().s_elements))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::LocalKernel;

    #[test]
    fn baseline_mttkrp_is_two_step() {
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![64, 64, 64], vec![64, 24], vec![64, 24]],
        )
        .unwrap();
        let p = plan_baseline(&spec, 8).unwrap();
        assert_eq!(p.terms.len(), 2, "KRP materialization + TDOT");
        // No fused MTTKRP kernel anywhere.
        assert!(p.terms.iter().all(|t| matches!(t.kernel, LocalKernel::Seq)));
        // The materialized KRP (jka) must flow through a redistribution.
        assert_eq!(p.moves.len(), 1);
        // The KRP term's output is the full jka tensor — the §IV-E
        // communication blow-up.
        let krp_term = &p.terms[0];
        let out_elems: usize = krp_term
            .output_dist
            .extents
            .iter()
            .product();
        assert_eq!(out_elems, 64 * 64 * 24);
    }

    #[test]
    fn baseline_q_bound_worse_than_deinsum() {
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![1 << 12, 1 << 12, 1 << 12], vec![1 << 12, 24], vec![1 << 12, 24]],
        )
        .unwrap();
        let deinsum = plan(&spec, 8, &PlannerConfig::default()).unwrap();
        let base = plan_baseline(&spec, 8).unwrap();
        assert!(
            base.total_q > deinsum.total_q,
            "baseline Q {} must exceed fused Q {}",
            base.total_q,
            deinsum.total_q
        );
    }
}
