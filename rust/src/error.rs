//! Library-wide error type.

use std::fmt;

/// Errors surfaced by the Deinsum library.
#[derive(Debug)]
pub enum Error {
    /// Malformed einsum string or inconsistent operand shapes.
    Parse(String),
    /// Shape/extent mismatch in a tensor operation.
    Shape(String),
    /// Planning failure (no valid grid, unsupported program, ...).
    Plan(String),
    /// A plan whose internal structure is inconsistent at *execution*
    /// time (an output index missing from the kernel's natural layout, a
    /// factor-count mismatch, an operand that is never produced).  The
    /// run loop surfaces these as typed errors instead of panicking
    /// mid-run, so a hand-edited or corrupted [`crate::planner::Plan`]
    /// fails cleanly.
    MalformedPlan {
        /// Name of the term being executed when the inconsistency was found.
        term: String,
        /// What was inconsistent.
        detail: String,
    },
    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// I/O failure loading artifacts.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "einsum parse error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Plan(m) => write!(f, "planning error: {m}"),
            Error::MalformedPlan { term, detail } => {
                write!(f, "malformed plan (term {term}): {detail}")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructors used across modules.
impl Error {
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
    pub fn plan(m: impl Into<String>) -> Self {
        Error::Plan(m.into())
    }
    pub fn malformed_plan(term: impl Into<String>, detail: impl Into<String>) -> Self {
        Error::MalformedPlan { term: term.into(), detail: detail.into() }
    }
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
}
