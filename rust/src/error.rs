//! Library-wide error type.

use std::fmt;

/// Errors surfaced by the Deinsum library.
#[derive(Debug)]
pub enum Error {
    /// Malformed einsum string or inconsistent operand shapes.
    Parse(String),
    /// Shape/extent mismatch in a tensor operation.
    Shape(String),
    /// Planning failure (no valid grid, unsupported program, ...).
    Plan(String),
    /// A plan whose internal structure is inconsistent at *execution*
    /// time (an output index missing from the kernel's natural layout, a
    /// factor-count mismatch, an operand that is never produced).  The
    /// run loop surfaces these as typed errors instead of panicking
    /// mid-run, so a hand-edited or corrupted [`crate::planner::Plan`]
    /// fails cleanly.
    MalformedPlan {
        /// Name of the term being executed when the inconsistency was found.
        term: String,
        /// What was inconsistent.
        detail: String,
    },
    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// I/O failure loading artifacts.
    Io(std::io::Error),
    /// A transient failure that is safe to retry (flaky execute, injected
    /// fault).  The serving layer retries these up to its budget;
    /// [`Error::is_retryable`] returns `true`.
    Transient(String),
    /// The request was accepted but its worker died (panic outside
    /// per-request containment) before it could be completed or requeued
    /// within the retry budget.  Retryable: resubmission lands on a fresh
    /// worker incarnation.
    WorkerLost(String),
    /// Non-blocking admission ([`crate::serve::Server::try_submit`])
    /// found the target queue full.  The caller sheds or retries later.
    QueueFull,
    /// A deadline attached to the request or its `wait` expired before a
    /// result was produced.
    DeadlineExceeded,
    /// The server has been shut down (or dropped); no new work is
    /// accepted.
    ServerShutdown,
    /// A distributed backend ([`crate::exec::ExecBackend::Mp`] or
    /// [`crate::exec::ExecBackend::Proc`]) observed a protocol violation
    /// between the coordinator and a rank site — an unexpected message
    /// tag, a dead peer, a timed-out collective, a wire-format mismatch.
    /// The executor is poisoned afterwards (the next run rebuilds it);
    /// the error is not retryable on the same executor.
    ///
    /// Carries the site context needed to diagnose a cross-process
    /// failure from the message alone: which rank observed it (`None`
    /// for the coordinator), which instruction/protocol stage was in
    /// flight, and an expected-vs-got detail.
    Protocol {
        /// Rank site that observed the violation (`None`: coordinator).
        rank: Option<usize>,
        /// Instruction kind or protocol stage in flight (`"handshake"`,
        /// `"redistribute"`, `"allreduce"`, `"ack"`, ...).
        instr: String,
        /// What was expected vs what was observed.
        detail: String,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "einsum parse error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Plan(m) => write!(f, "planning error: {m}"),
            Error::MalformedPlan { term, detail } => {
                write!(f, "malformed plan (term {term}): {detail}")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Transient(m) => write!(f, "transient error (retryable): {m}"),
            Error::WorkerLost(m) => write!(f, "worker lost: {m}"),
            Error::QueueFull => write!(f, "queue full: request shed (try again later)"),
            Error::DeadlineExceeded => write!(f, "deadline exceeded"),
            Error::ServerShutdown => write!(f, "server is shut down"),
            Error::Protocol { rank, instr, detail } => match rank {
                Some(r) => {
                    write!(f, "protocol error [rank {r}, {instr}]: {detail}")
                }
                None => write!(f, "protocol error [coordinator, {instr}]: {detail}"),
            },
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructors used across modules.
impl Error {
    /// An [`Error::Parse`]: the einsum expression is malformed.
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    /// An [`Error::Shape`]: operands or destinations disagree with
    /// the spec's dimensions.
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
    /// An [`Error::Plan`]: the planner cannot produce a schedule.
    pub fn plan(m: impl Into<String>) -> Self {
        Error::Plan(m.into())
    }
    /// An [`Error::MalformedPlan`]: an internally inconsistent plan,
    /// named by the offending term.
    pub fn malformed_plan(term: impl Into<String>, detail: impl Into<String>) -> Self {
        Error::MalformedPlan { term: term.into(), detail: detail.into() }
    }
    /// An [`Error::Runtime`]: execution failed deterministically.
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
    /// An [`Error::Transient`]: a retryable infrastructure failure.
    pub fn transient(m: impl Into<String>) -> Self {
        Error::Transient(m.into())
    }
    /// An [`Error::WorkerLost`]: a serving worker died with this
    /// request in flight (retryable).
    pub fn worker_lost(m: impl Into<String>) -> Self {
        Error::WorkerLost(m.into())
    }
    /// Coordinator-side protocol violation with no specific instruction
    /// context.  Prefer [`Error::protocol_at`] where the failing rank
    /// and instruction are known.
    pub fn protocol(m: impl Into<String>) -> Self {
        Error::Protocol { rank: None, instr: "exec".to_string(), detail: m.into() }
    }

    /// Protocol violation observed at a specific site: `rank` is the
    /// rank that observed it (`None` for the coordinator), `instr` the
    /// instruction kind or protocol stage in flight, `detail` the
    /// expected-vs-got description.
    pub fn protocol_at(
        rank: impl Into<Option<usize>>,
        instr: impl Into<String>,
        detail: impl Into<String>,
    ) -> Self {
        Error::Protocol { rank: rank.into(), instr: instr.into(), detail: detail.into() }
    }

    /// Duplicate an error so one batch-level failure can be fanned out
    /// to every member of a coalesced batch (the serving layer fulfills
    /// each ticket individually).  `Error` cannot be `Clone` because
    /// [`Error::Io`] wraps a `std::io::Error`; that variant is
    /// duplicated lossily (kind + message preserved, source chain
    /// dropped), every other variant copies exactly.
    pub(crate) fn duplicate(&self) -> Self {
        match self {
            Error::Parse(m) => Error::Parse(m.clone()),
            Error::Shape(m) => Error::Shape(m.clone()),
            Error::Plan(m) => Error::Plan(m.clone()),
            Error::MalformedPlan { term, detail } => {
                Error::MalformedPlan { term: term.clone(), detail: detail.clone() }
            }
            Error::Runtime(m) => Error::Runtime(m.clone()),
            Error::Io(e) => Error::Io(std::io::Error::new(e.kind(), e.to_string())),
            Error::Transient(m) => Error::Transient(m.clone()),
            Error::WorkerLost(m) => Error::WorkerLost(m.clone()),
            Error::QueueFull => Error::QueueFull,
            Error::DeadlineExceeded => Error::DeadlineExceeded,
            Error::ServerShutdown => Error::ServerShutdown,
            Error::Protocol { rank, instr, detail } => Error::Protocol {
                rank: *rank,
                instr: instr.clone(),
                detail: detail.clone(),
            },
        }
    }

    /// Whether resubmitting the same request can reasonably succeed.
    /// True only for failures caused by *where* the request ran
    /// ([`Error::Transient`], [`Error::WorkerLost`]) — never for
    /// deterministic failures of the request itself (parse, shape, plan,
    /// compile), which would fail identically on every retry.
    pub fn is_retryable(&self) -> bool {
        matches!(self, Error::Transient(_) | Error::WorkerLost(_))
    }
}
