//! End-to-end planning: einsum string → distributed schedule (paper Fig. 2).
//!
//! The pipeline (§II): decompose into FLOP-minimal binary ops
//! ([`crate::contraction`]), find the I/O-minimal kernel fusion
//! ([`crate::soap::sdg`]), then for each fused **term**:
//!
//! 1. derive the SOAP-optimal tile proportions and factorize `P` into a
//!    Cartesian grid matching them (§II-C);
//! 2. block-distribute every operand onto the term grid with replication
//!    over the unmapped dims (§II-D);
//! 3. mark the reduction sub-grids (partial-result Allreduce);
//! 4. infer redistribution plans for intermediates flowing between terms
//!    with different distributions (§V-C).
//!
//! The resulting [`Plan`] is the paper's "intermediate program" (§II-E):
//! [`Plan::render`] prints the same grid/sub-grid/compute/Allreduce/
//! Redistribute structure the paper's generated Python shows.

use std::collections::BTreeMap;

use crate::contraction::{optimize, Path};
use crate::dist::TensorDist;
use crate::einsum::{BinaryOp, EinsumSpec};
use crate::error::{Error, Result};
use crate::grid::{optimize_grid_dims, ProcessGrid};
use crate::redist::{self, RedistPlan};
use crate::soap::bound::Statement;
use crate::soap::sdg::{best_fusion, FusedGroup};
use crate::soap::{self, IoBound};
use crate::tensor::kernel::KernelConfig;

/// Planner knobs.
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Fast-memory size in elements for the SOAP analysis (per-process).
    pub s_elements: f64,
    /// Enable cross-statement fusion (§IV-C). The CTF-like baseline
    /// disables it.
    pub fuse: bool,
    /// Use SOAP tile proportions for grid shapes.  When false, grids are
    /// balanced by raw extents (the baseline's heuristic).
    pub soap_grids: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig { s_elements: (1u64 << 26) as f64, fuse: true, soap_grids: true }
    }
}

/// How a term's local tiles are computed on each rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LocalKernel {
    /// Fused MTTKRP: term input `x_input` is the big tensor, the rest are
    /// rank-R factors; `mode` is the kept mode of X.  Served by the L1
    /// Pallas artifact through the PJRT engine.
    Mttkrp { x_input: usize, mode: usize, factor_inputs: Vec<usize> },
    /// Generic: execute the term's constituent binary ops in order on the
    /// local tiles (each op via the folded-GEMM einsum2 path).
    Seq,
}

/// One input operand of a term.
#[derive(Debug, Clone)]
pub struct TermInput {
    /// Tensor-table id.
    pub id: usize,
    /// Index string.
    pub indices: Vec<char>,
    /// Distribution on the term grid.
    pub dist: TensorDist,
}

/// A fused group scheduled on its own Cartesian grid.
#[derive(Debug, Clone)]
pub struct TermPlan {
    /// Display name (`term0`, `term1`, ...).
    pub name: String,
    /// Term iteration indices (sorted) and extents.
    pub indices: Vec<char>,
    /// Extent of each iteration index, in `indices` order.
    pub extents: Vec<usize>,
    /// The Cartesian process grid over `indices`.
    pub grid: ProcessGrid,
    /// Per-index nominal block size `ceil(N_d / P_d)`.
    pub block: Vec<usize>,
    /// Term inputs with their distributions.
    pub inputs: Vec<TermInput>,
    /// Output tensor id, index string, distribution.
    pub output_id: usize,
    /// Output index letters, in storage order.
    pub output_indices: Vec<char>,
    /// Output block distribution on this term's grid.
    pub output_dist: TensorDist,
    /// Grid dims over contracted indices (P_d > 1 ⇒ Allreduce needed).
    pub reduced_grid_dims: Vec<usize>,
    /// Local kernel selection.
    pub kernel: LocalKernel,
    /// Constituent binary ops (for `Seq` execution and rendering).
    pub ops: Vec<BinaryOp>,
    /// The term's SOAP bound at the analysis S.
    pub bound: IoBound,
}

impl TermPlan {
    /// Grid dim handling iteration index `c`.
    pub fn grid_dim_of(&self, c: char) -> usize {
        self.indices.iter().position(|&i| i == c).expect("index in term")
    }

    /// Block size of index `c`.
    pub fn block_of(&self, c: char) -> usize {
        self.block[self.grid_dim_of(c)]
    }

    /// Derive a local-kernel configuration from this term's SOAP-optimal
    /// tile sizes (§IV), so the cache blocking of the packed engine
    /// follows the same proportions the I/O analysis assumed: `mc` from
    /// the leading output index tile, `nc` from the trailing one (the
    /// rank-like dimension in MTTKRP terms), `kc` from the tightest
    /// contracted-index tile.  Indices without a tile keep `base`'s
    /// blocks; the thread count is always `base`'s.
    ///
    /// The coordinator feeds this automatically into the engine before
    /// each term's local compute
    /// ([`crate::runtime::KernelEngine::configure_for_term`]); callers
    /// only need it directly for ad-hoc kernel experiments.
    pub fn kernel_config(&self, base: KernelConfig) -> KernelConfig {
        let tile = |c: char| self.bound.tiles.get(&c).copied();
        let tm = self.output_indices.first().copied().and_then(tile);
        let tn = self.output_indices.last().copied().and_then(tile);
        let tk = self
            .indices
            .iter()
            .filter(|c| !self.output_indices.contains(c))
            .filter_map(|&c| tile(c))
            .fold(f64::INFINITY, f64::min);
        KernelConfig::from_tiles(
            tm.unwrap_or(base.mc as f64),
            if tk.is_finite() { tk } else { base.kc as f64 },
            tn.unwrap_or(base.nc as f64),
        )
        .with_threads(base.threads)
    }
}

/// A redistribution edge between terms.
#[derive(Debug, Clone)]
pub struct Move {
    /// Tensor flowing between the terms.
    pub tensor_id: usize,
    /// Producing term index (in `Plan::terms`).
    pub from_term: usize,
    /// Consuming term index.
    pub to_term: usize,
    /// Input slot in the consuming term.
    pub to_slot: usize,
    /// Message-matched plan (§V-C).
    pub plan: RedistPlan,
    /// Distribution the tensor leaves the producing term with.
    pub src: TensorDist,
    /// Distribution the consuming term expects.
    pub dst: TensorDist,
}

/// A complete distributed schedule.
#[derive(Debug, Clone)]
pub struct Plan {
    /// The parsed, validated einsum specification.
    pub spec: EinsumSpec,
    /// FLOP-minimal binary decomposition driving the term order.
    pub path: Path,
    /// One scheduled term per fused group, in execution order.
    pub terms: Vec<TermPlan>,
    /// Inter-term redistributions, message-matched.
    pub moves: Vec<Move>,
    /// Rank count.
    pub p: usize,
    /// Total modeled I/O lower bound (the SOAP Q at the analysis S).
    pub total_q: f64,
}

/// Detect the fused-MTTKRP pattern in a group (one order-≥3 tensor, all
/// other inputs rank-R matrices sharing index `r`, output = (mode, r)).
fn detect_mttkrp(group: &FusedGroup) -> Option<LocalKernel> {
    if group.outputs.len() != 1 || group.inputs.len() < 3 {
        return None;
    }
    let out = &group.outputs[0].1;
    if out.len() != 2 {
        return None;
    }
    // X = unique input with order >= 3.
    let big: Vec<usize> = group
        .inputs
        .iter()
        .enumerate()
        .filter(|(_, (_, idx))| idx.len() >= 3)
        .map(|(slot, _)| slot)
        .collect();
    if big.len() != 1 {
        return None;
    }
    let x_slot = big[0];
    let x_idx = &group.inputs[x_slot].1;
    // All other inputs are matrices (m_c, r) with m_c ∈ X, sharing r.
    let mut r_char: Option<char> = None;
    let mut factor_slots = Vec::new();
    let mut covered: Vec<char> = Vec::new();
    for (slot, (_, idx)) in group.inputs.iter().enumerate() {
        if slot == x_slot {
            continue;
        }
        if idx.len() != 2 {
            return None;
        }
        let (a, b) = (idx[0], idx[1]);
        let (m, r) = if x_idx.contains(&a) && !x_idx.contains(&b) {
            (a, b)
        } else if x_idx.contains(&b) && !x_idx.contains(&a) {
            (b, a)
        } else {
            return None;
        };
        match r_char {
            None => r_char = Some(r),
            Some(rc) if rc == r => {}
            _ => return None,
        }
        covered.push(m);
        factor_slots.push(slot);
    }
    let r = r_char?;
    // Output must be (mode, r) with mode the one X index not covered.
    let mode_char = out.iter().copied().find(|&c| c != r)?;
    if !out.contains(&r) || !x_idx.contains(&mode_char) {
        return None;
    }
    // Every X index except mode must be covered by exactly one factor.
    let mut rest: Vec<char> =
        x_idx.iter().copied().filter(|&c| c != mode_char).collect();
    rest.sort_unstable();
    let mut cov = covered.clone();
    cov.sort_unstable();
    if rest != cov {
        return None;
    }
    let mode = x_idx.iter().position(|&c| c == mode_char)?;
    // Order factor slots by X's mode order (the engine's convention).
    let mut ordered = Vec::new();
    for &c in x_idx.iter() {
        if c == mode_char {
            continue;
        }
        let slot = group
            .inputs
            .iter()
            .enumerate()
            .position(|(s, (_, idx))| {
                s != x_slot && idx.contains(&c) && factor_slots.contains(&s)
            })?;
        ordered.push(slot);
    }
    Some(LocalKernel::Mttkrp { x_input: x_slot, mode, factor_inputs: ordered })
}

/// Plan a distributed schedule for `spec` on `p` ranks.
///
/// Degenerate programs are rejected up front, before any grid or SOAP
/// machinery sees them: a zero-extent index makes every block empty (no
/// distributed schedule exists), and a rank-0 output has no dimension to
/// lay a process grid over.  Both come back as typed errors naming the
/// offender — the fuzz harness ([`crate::fuzz`]) counts them as clean
/// rejections, never bugs.
pub fn plan(spec: &EinsumSpec, p: usize, cfg: &PlannerConfig) -> Result<Plan> {
    if let Some((&c, _)) = spec.extents.iter().find(|&(_, &n)| n == 0) {
        return Err(Error::shape(format!(
            "index '{c}' has extent 0: empty tensors cannot be scheduled"
        )));
    }
    if spec.output.is_empty() {
        return Err(Error::plan(
            "scalar (rank-0) output unsupported: keep at least one output index",
        ));
    }
    let path = optimize(spec)?;
    let fusion = if cfg.fuse {
        best_fusion(&path, spec, cfg.s_elements)?
    } else {
        // Baseline: one group per op (no cross-statement fusion).
        let mut groups = Vec::new();
        for q in 0..path.ops.len() {
            groups.push(single_group(&path, spec, q, cfg.s_elements)?);
        }
        crate::soap::Fusion {
            total_q: groups.iter().map(|g| g.bound.q).sum(),
            candidates: 1,
            groups,
        }
    };

    // Track where each tensor id lives: (term index, dist, index string).
    let mut locations: BTreeMap<usize, (usize, TensorDist, Vec<char>)> = BTreeMap::new();
    let mut terms: Vec<TermPlan> = Vec::new();
    let mut moves: Vec<Move> = Vec::new();

    for (ti, group) in fusion.groups.iter().enumerate() {
        if group.outputs.len() != 1 {
            return Err(Error::plan(format!(
                "term {ti}: {} outputs unsupported",
                group.outputs.len()
            )));
        }
        let indices: Vec<char> = group.indices.clone();
        let extents: Vec<usize> = indices
            .iter()
            .map(|c| {
                spec.extents.get(c).copied().ok_or_else(|| {
                    Error::plan(format!("term {ti}: index '{c}' has no extent"))
                })
            })
            .collect::<Result<_>>()?;

        // Grid shape: SOAP tile proportions (unclamped extents give clean
        // asymptotic ratios; see DESIGN.md) or raw-extent balance.
        let out_idx_chars = &group.outputs[0].1;
        // Weight_d = N_d / t_d: how many SOAP-optimal tiles span dim d.
        // Values < 1 mean the optimal tile already covers the extent —
        // prefer NOT splitting that dim (e.g. the rank dim R=24 whose
        // optimal tile is S^{2/3}/2, §IV-E / Table I's P_a = 1).
        let mut weights: Vec<f64> = if cfg.soap_grids {
            let unclamped = unclamped_bound(group, spec, cfg.s_elements)?;
            indices
                .iter()
                .zip(&extents)
                .map(|(c, &n)| {
                    n as f64 / unclamped.tiles.get(c).copied().unwrap_or(1.0).max(1.0)
                })
                .collect()
        } else {
            extents.iter().map(|&n| n as f64).collect()
        };
        // Tie-bias: among equal-weight dims prefer splitting *output*
        // indices — they never need an Allreduce (§II-D).
        if cfg.soap_grids {
            for (w, c) in weights.iter_mut().zip(&indices) {
                if out_idx_chars.contains(c) {
                    *w *= 1.2;
                }
            }
        }
        let gdims = optimize_grid_dims(p, &extents, &weights);
        let grid = ProcessGrid::new(&gdims)?;
        let block: Vec<usize> =
            extents.iter().zip(&gdims).map(|(&n, &g)| n.div_ceil(g)).collect();

        // Distributions.
        let mk_dist = |idx: &[char]| -> Result<TensorDist> {
            let ext: Vec<usize> = idx
                .iter()
                .map(|c| {
                    spec.extents.get(c).copied().ok_or_else(|| {
                        Error::plan(format!("term {ti}: index '{c}' has no extent"))
                    })
                })
                .collect::<Result<_>>()?;
            let gd: Vec<usize> = idx
                .iter()
                .map(|c| {
                    indices.iter().position(|i| i == c).ok_or_else(|| {
                        Error::plan(format!(
                            "term {ti}: operand index '{c}' not in term iteration space"
                        ))
                    })
                })
                .collect::<Result<_>>()?;
            TensorDist::new(&ext, &grid, &gd)
        };
        let mut term_inputs = Vec::new();
        for (slot, (id, idx)) in group.inputs.iter().enumerate() {
            let dist = mk_dist(idx)?;
            // Intermediates flowing in need a redistribution edge.
            if let Some((from_term, src, _)) = locations.get(id) {
                let rp = redist::plan(src, &dist)?;
                moves.push(Move {
                    tensor_id: *id,
                    from_term: *from_term,
                    to_term: ti,
                    to_slot: slot,
                    plan: rp,
                    src: src.clone(),
                    dst: dist.clone(),
                });
            }
            term_inputs.push(TermInput { id: *id, indices: idx.clone(), dist });
        }
        let (out_id, out_idx) = group.outputs[0].clone();
        let output_dist = mk_dist(&out_idx)?;
        locations.insert(out_id, (ti, output_dist.clone(), out_idx.clone()));

        let reduced_grid_dims: Vec<usize> = indices
            .iter()
            .enumerate()
            .filter(|&(d, c)| !out_idx.contains(c) && gdims[d] > 1)
            .map(|(d, _)| d)
            .collect();

        let kernel = detect_mttkrp(group).unwrap_or(LocalKernel::Seq);
        let ops: Vec<BinaryOp> =
            group.op_indices.iter().map(|&q| path.ops[q].clone()).collect();

        terms.push(TermPlan {
            name: format!("term{ti}"),
            indices,
            extents,
            grid,
            block,
            inputs: term_inputs,
            output_id: out_id,
            output_indices: out_idx,
            output_dist,
            reduced_grid_dims,
            kernel,
            ops,
            bound: group.bound.clone(),
        });
    }

    Ok(Plan { spec: spec.clone(), path, terms, moves, p, total_q: fusion.total_q })
}

/// Bound a single-op group (baseline helper: the op `q` of `path` as its
/// own unfused term).
fn single_group(
    path: &Path,
    spec: &EinsumSpec,
    q: usize,
    s: f64,
) -> Result<FusedGroup> {
    let sub = Path { ops: vec![path.ops[q].clone()], flops: 0, n_inputs: path.n_inputs };
    let groups = crate::soap::sdg::best_fusion(&sub, spec, s)?;
    let mut g = groups
        .groups
        .into_iter()
        .next()
        .ok_or_else(|| Error::plan(format!("op {q}: empty fusion for single-op term")))?;
    g.op_indices = vec![q]; // renumber into the original path
    Ok(g)
}

/// The group's SOAP bound with extents unclamped (for grid proportions).
fn unclamped_bound(group: &FusedGroup, spec: &EinsumSpec, s: f64) -> Result<IoBound> {
    let extents: BTreeMap<char, f64> =
        group.indices.iter().map(|&c| (c, 1e15)).collect();
    let accesses: Vec<soap::bound::AccessSet> = group
        .inputs
        .iter()
        .chain(group.outputs.iter())
        .map(|(id, idx)| soap::bound::AccessSet {
            name: format!("t{id}"),
            indices: idx.clone(),
        })
        .collect();
    let st = Statement::new(extents, accesses)?;
    let _ = spec;
    Ok(st.io_bound(s))
}

impl Plan {
    /// Render as the paper's §II-E intermediate program.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "# plan for P={} ranks, {} term(s), Q_lower={:.3e} elems\n",
            self.p,
            self.terms.len(),
            self.total_q
        ));
        for (ti, t) in self.terms.iter().enumerate() {
            let idx: String = t.indices.iter().collect();
            s.push_str(&format!(
                "grid{ti} = mpi.Cart_create(dims={:?})  # over ({idx})\n",
                t.grid.dims()
            ));
            for mv in self.moves.iter().filter(|m| m.to_term == ti) {
                s.push_str(&format!(
                    "t{} = deinsum.Redistribute(t{}, comm1=grid{}, comm2=grid{})  # {} msgs, {} elems remote\n",
                    mv.tensor_id,
                    mv.tensor_id,
                    mv.from_term,
                    ti,
                    mv.plan.messages.len(),
                    mv.plan.remote_volume
                ));
            }
            for op in &t.ops {
                s.push_str(&format!("# {}\n", op.einsum()));
            }
            let kern = match &t.kernel {
                LocalKernel::Mttkrp { mode, .. } => format!("fused MTTKRP (mode {mode})"),
                LocalKernel::Seq => "local binary-op sequence".to_string(),
            };
            let out_idx: String = t.output_indices.iter().collect();
            s.push_str(&format!(
                "t{} = {}  # -> {out_idx}, block {:?}\n",
                t.output_id, kern, t.block
            ));
            if !t.reduced_grid_dims.is_empty() {
                let remain: Vec<bool> =
                    (0..t.grid.ndim()).map(|d| t.reduced_grid_dims.contains(&d)).collect();
                s.push_str(&format!(
                    "mpi.Allreduce(t{}, comm=mpi.Cart_sub(grid{ti}, remain={:?}))\n",
                    t.output_id, remain
                ));
            }
        }
        s
    }

    /// Total remote redistribution volume (elements).
    pub fn redist_volume(&self) -> usize {
        self.moves.iter().map(|m| m.plan.remote_volume).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlannerConfig {
        PlannerConfig::default()
    }

    #[test]
    fn paper_worked_example_structure() {
        // §II: ijk,ja,ka,al->il on P=8, at paper-relevant extents (the
        // illustrative N=10 of Tables I/II fits entirely in fast memory,
        // where the model correctly fuses everything into one term; the
        // two-term [MTTKRP, MM] structure is the optimum at real sizes).
        let n = 1 << 12;
        let spec = EinsumSpec::parse(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        )
        .unwrap();
        let plan = plan(&spec, 8, &cfg()).unwrap();
        assert_eq!(plan.terms.len(), 2, "MTTKRP term + MM term");
        let t0 = &plan.terms[0];
        // Term 0: 4-dim grid over (a,i,j,k); the paper's (2,2,2,1) with
        // the rank dim unsplit.
        assert_eq!(t0.grid.size(), 8);
        let a_dim = t0.grid_dim_of('a');
        assert_eq!(t0.grid.dims()[a_dim], 1, "rank dim must not be split");
        assert!(matches!(t0.kernel, LocalKernel::Mttkrp { .. }));
        // Term 1: MM over (a,i,l).
        let t1 = &plan.terms[1];
        assert_eq!(t1.grid.size(), 8);
        assert_eq!(t1.ops.len(), 1);
        // There is exactly one redistribution: t1 (ia) between the terms.
        assert_eq!(plan.moves.len(), 1);
        assert_eq!(plan.moves[0].from_term, 0);
        assert_eq!(plan.moves[0].to_term, 1);
    }

    #[test]
    fn mttkrp_detection_order3() {
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![64, 64, 64], vec![64, 24], vec![64, 24]],
        )
        .unwrap();
        let p = plan(&spec, 4, &cfg()).unwrap();
        assert_eq!(p.terms.len(), 1);
        match &p.terms[0].kernel {
            LocalKernel::Mttkrp { x_input, mode, factor_inputs } => {
                assert_eq!(p.terms[0].inputs[*x_input].indices, vec!['i', 'j', 'k']);
                assert_eq!(*mode, 0);
                assert_eq!(factor_inputs.len(), 2);
            }
            k => panic!("expected MTTKRP kernel, got {k:?}"),
        }
    }

    #[test]
    fn mttkrp_mode1_detection() {
        let spec = EinsumSpec::parse(
            "ijk,ia,ka->ja",
            &[vec![64, 64, 64], vec![64, 24], vec![64, 24]],
        )
        .unwrap();
        let p = plan(&spec, 4, &cfg()).unwrap();
        match &p.terms[0].kernel {
            LocalKernel::Mttkrp { mode, .. } => assert_eq!(*mode, 1),
            k => panic!("expected MTTKRP, got {k:?}"),
        }
    }

    #[test]
    fn baseline_config_does_not_fuse() {
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![64, 64, 64], vec![64, 24], vec![64, 24]],
        )
        .unwrap();
        let base = PlannerConfig { fuse: false, soap_grids: false, ..cfg() };
        let p = plan(&spec, 4, &base).unwrap();
        assert_eq!(p.terms.len(), 2, "unfused: KRP term + TDOT term");
        assert!(matches!(p.terms[0].kernel, LocalKernel::Seq));
        // The KRP intermediate (jka) flows through a redistribution.
        assert_eq!(p.moves.len(), 1);
    }

    #[test]
    fn single_gemm_plan() {
        let spec =
            EinsumSpec::parse("ij,jk->ik", &[vec![256, 256], vec![256, 256]]).unwrap();
        let p = plan(&spec, 8, &cfg()).unwrap();
        assert_eq!(p.terms.len(), 1);
        assert!(p.moves.is_empty());
        assert_eq!(p.terms[0].grid.size(), 8);
    }

    #[test]
    fn reduction_dims_marked() {
        // GEMM on enough ranks that the contracted dim j gets split.
        let spec =
            EinsumSpec::parse("ij,jk->ik", &[vec![4096, 4096], vec![4096, 4096]]).unwrap();
        let p = plan(&spec, 8, &cfg()).unwrap();
        let t = &p.terms[0];
        let j_dim = t.grid_dim_of('j');
        if t.grid.dims()[j_dim] > 1 {
            assert!(t.reduced_grid_dims.contains(&j_dim));
        }
        // i and k are output dims: never in reduced set.
        assert!(!t.reduced_grid_dims.contains(&t.grid_dim_of('i')));
        assert!(!t.reduced_grid_dims.contains(&t.grid_dim_of('k')));
    }

    #[test]
    fn blocks_cover_extents() {
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![100, 90, 80], vec![90, 24], vec![80, 24]],
        )
        .unwrap();
        let p = plan(&spec, 6, &cfg()).unwrap();
        let t = &p.terms[0];
        for (d, (&b, &n)) in t.block.iter().zip(&t.extents).enumerate() {
            assert!(b * t.grid.dims()[d] >= n, "dim {d} under-covered");
        }
    }

    #[test]
    fn kernel_config_from_soap_tiles() {
        let spec =
            EinsumSpec::parse("ij,jk->ik", &[vec![4096, 4096], vec![4096, 4096]]).unwrap();
        let p = plan(&spec, 8, &cfg()).unwrap();
        let base = KernelConfig::default().with_threads(3);
        let kcfg = p.terms[0].kernel_config(base);
        assert_eq!(kcfg.threads, 3, "thread count comes from base");
        assert_eq!(kcfg.mc % 8, 0);
        assert_eq!(kcfg.nc % 8, 0);
        assert!(kcfg.kc >= 8);
        // GEMM tiles at S = 2^26 are ~sqrt(S/3) ≈ 4730, clamped to the
        // packing maxima — the config must stay in the engine's range.
        assert!(kcfg.mc <= 1024 && kcfg.kc <= 2048 && kcfg.nc <= 4096);
    }

    #[test]
    fn render_mentions_grids_and_terms() {
        let n = 1 << 12;
        let spec = EinsumSpec::parse(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        )
        .unwrap();
        let p = plan(&spec, 8, &cfg()).unwrap();
        let r = p.render();
        assert!(r.contains("Cart_create"));
        assert!(r.contains("Redistribute"));
        assert!(r.contains("fused MTTKRP"));
    }

    #[test]
    fn zero_extent_rejects_typed_naming_the_index() {
        let spec = EinsumSpec::parse("ij,jk->ik", &[vec![4, 0], vec![0, 3]]).unwrap();
        for p in [1, 4, 8] {
            match plan(&spec, p, &cfg()) {
                Err(Error::Shape(m)) => {
                    assert!(m.contains("'j'"), "P={p}: should name index j: {m}");
                    assert!(m.contains("extent 0"), "P={p}: {m}");
                }
                Err(e) => panic!("P={p}: want Shape error, got {e:?}"),
                Ok(_) => panic!("P={p}: zero-extent program must not plan"),
            }
        }
    }

    #[test]
    fn scalar_output_rejects_typed() {
        let spec = EinsumSpec::parse("ij,ij->", &[vec![3, 4], vec![3, 4]]).unwrap();
        match plan(&spec, 4, &cfg()) {
            Err(e @ Error::Plan(_)) => {
                assert!(e.to_string().contains("scalar"), "{e}");
                assert!(!e.is_retryable());
            }
            Err(e) => panic!("want Plan error, got {e:?}"),
            Ok(_) => panic!("rank-0 output must not plan"),
        }
    }

    #[test]
    fn p1_plans_are_trivial_grids() {
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![32, 32, 32], vec![32, 8], vec![32, 8]],
        )
        .unwrap();
        let p = plan(&spec, 1, &cfg()).unwrap();
        assert_eq!(p.terms[0].grid.size(), 1);
        assert!(p.terms[0].reduced_grid_dims.is_empty());
    }
}
