//! Symbolic Directed Graph (SDG) and kernel-fusion enumeration (§IV-C).
//!
//! Every vertex of the SDG is a tensor (input or intermediate); edges are
//! data dependencies induced by the contraction path.  Each partition of
//! the non-input vertices describes one candidate kernel fusion: the
//! vertices of a part are computed together as a single fused SOAP
//! statement whose access sets are the part's *external* tensors
//! (intermediates internal to the part never touch slow memory — this is
//! how the fused MTTKRP beats the two-step formulation by `S^{1/6}`).
//! The partition minimizing total `Q` is the program's I/O lower bound
//! and its grouping is the schedule the planner materializes.

use std::collections::{BTreeMap, BTreeSet};

use crate::contraction::Path;
use crate::einsum::EinsumSpec;
use crate::error::Result;
use crate::soap::bound::{AccessSet, IoBound, Statement};

/// One fused group of contraction-path ops.
#[derive(Debug, Clone)]
pub struct FusedGroup {
    /// Indices into `path.ops` fused into this statement (execution order).
    pub op_indices: Vec<usize>,
    /// External input tensors: (tensor id, index string).
    pub inputs: Vec<(usize, Vec<char>)>,
    /// Output tensors escaping the group: (tensor id, index string).
    pub outputs: Vec<(usize, Vec<char>)>,
    /// The fused statement's iteration indices.
    pub indices: Vec<char>,
    /// I/O bound of the fused statement at the analysis `S`.
    pub bound: IoBound,
}

impl FusedGroup {
    /// Render like the paper's term naming (e.g. `MTTKRP term`).
    pub fn render(&self) -> String {
        let ins: Vec<String> = self
            .inputs
            .iter()
            .map(|(_, idx)| idx.iter().collect::<String>())
            .collect();
        let outs: Vec<String> = self
            .outputs
            .iter()
            .map(|(_, idx)| idx.iter().collect::<String>())
            .collect();
        format!("{}->{}", ins.join(","), outs.join(","))
    }
}

/// The I/O-minimal fusion of a contraction path.
#[derive(Debug, Clone)]
pub struct Fusion {
    /// Fused groups in execution order (the plan's "terms", §II-B).
    pub groups: Vec<FusedGroup>,
    /// Total I/O lower bound (sum over groups).
    pub total_q: f64,
    /// Number of candidate partitions evaluated.
    pub candidates: usize,
}

/// Build the fused statement for a contiguous slice of ops and bound it.
fn group_statement(
    path: &Path,
    spec: &EinsumSpec,
    ops: &[usize],
    s: f64,
) -> Result<FusedGroup> {
    let produced: BTreeSet<usize> =
        ops.iter().map(|&q| path.ops[q].output_id).collect();
    // External inputs: consumed by the group, not produced inside it.
    let mut inputs: Vec<(usize, Vec<char>)> = Vec::new();
    for &q in ops {
        let op = &path.ops[q];
        for (slot, &id) in op.input_ids.iter().enumerate() {
            if !produced.contains(&id)
                && !inputs.iter().any(|(iid, _)| *iid == id)
            {
                inputs.push((id, op.inputs[slot].clone()));
            }
        }
    }
    // Outputs: produced inside, consumed outside (or the program result).
    let result_id = path.result_id();
    let mut outputs: Vec<(usize, Vec<char>)> = Vec::new();
    for &q in ops {
        let op = &path.ops[q];
        let id = op.output_id;
        let consumed_outside = path
            .ops
            .iter()
            .enumerate()
            .any(|(p, other)| !ops.contains(&p) && other.input_ids.contains(&id));
        if (consumed_outside || id == result_id)
            && !outputs.iter().any(|(oid, _)| *oid == id)
        {
            outputs.push((id, op.output.clone()));
        }
    }
    // Iteration indices: union over the grouped ops.
    let mut idx: BTreeSet<char> = BTreeSet::new();
    for &q in ops {
        idx.extend(path.ops[q].all_indices());
    }
    let extents: BTreeMap<char, f64> =
        idx.iter().map(|&c| (c, spec.extents[&c] as f64)).collect();
    let mut accesses: Vec<AccessSet> = Vec::new();
    for (id, ind) in inputs.iter().chain(outputs.iter()) {
        accesses.push(AccessSet { name: format!("t{id}"), indices: ind.clone() });
    }
    let st = Statement::new(extents, accesses)?;
    let bound = st.io_bound(s);
    Ok(FusedGroup {
        op_indices: ops.to_vec(),
        inputs,
        outputs,
        indices: idx.into_iter().collect(),
        bound,
    })
}

/// A fused group is *schedulable* only when its single-statement
/// evaluation does not asymptotically increase the arithmetic: the fused
/// iteration space (product of the union indices) must not exceed the
/// largest constituent op's volume by more than a constant slack.
/// (Fusing two unrelated contractions CAN lower the I/O bound at the
/// price of recomputing one operand per iteration of the other — a
/// FLOP blowup the paper's schedules never take.)
fn group_is_schedulable(path: &Path, spec: &EinsumSpec, ops: &[usize]) -> bool {
    let mut union: BTreeSet<char> = BTreeSet::new();
    let mut max_op_vol: f64 = 0.0;
    for &q in ops {
        let op = &path.ops[q];
        let vol: f64 =
            op.all_indices().iter().map(|c| spec.extents[c] as f64).product();
        max_op_vol = max_op_vol.max(vol);
        union.extend(op.all_indices());
    }
    let fused_vol: f64 = union.iter().map(|c| spec.extents[c] as f64).product();
    fused_vol <= 2.0 * max_op_vol
}

/// Enumerate contiguous partitions of the op sequence (2^{n-1} for n ops
/// — the SDG of a contraction path is a tree whose execution order makes
/// contiguous groupings the candidate fusions) and return the partition
/// with minimal total I/O among schedulable partitions (no recomputation
/// blowup).
pub fn best_fusion(path: &Path, spec: &EinsumSpec, s: f64) -> Result<Fusion> {
    let n = path.ops.len();
    if n == 0 {
        return Ok(Fusion { groups: vec![], total_q: 0.0, candidates: 0 });
    }
    let mut best: Option<(Vec<FusedGroup>, f64)> = None;
    let masks = 1usize << (n - 1);
    for cut_mask in 0..masks {
        let mut groups: Vec<Vec<usize>> = vec![vec![0]];
        for q in 1..n {
            if cut_mask & (1 << (q - 1)) != 0 {
                groups.push(vec![q]);
            } else {
                groups.last_mut().unwrap().push(q);
            }
        }
        if !groups.iter().all(|g| group_is_schedulable(path, spec, g)) {
            continue;
        }
        let mut fgs = Vec::with_capacity(groups.len());
        let mut total = 0.0;
        for g in &groups {
            let fg = group_statement(path, spec, g, s)?;
            total += fg.bound.q;
            fgs.push(fg);
        }
        if best.as_ref().map(|(_, bq)| total < *bq).unwrap_or(true) {
            best = Some((fgs, total));
        }
    }
    let (groups, total_q) =
        best.ok_or_else(|| crate::error::Error::plan("no schedulable fusion"))?;
    Ok(Fusion { groups, total_q, candidates: masks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::contraction::optimize;

    const S: f64 = 1e6;

    fn analyzed(expr: &str, shapes: &[Vec<usize>]) -> (Path, EinsumSpec, Fusion) {
        let spec = EinsumSpec::parse(expr, shapes).unwrap();
        let path = optimize(&spec).unwrap();
        let fusion = best_fusion(&path, &spec, S).unwrap();
        (path, spec, fusion)
    }

    #[test]
    fn mttkrp_fuses_krp_and_tdot() {
        // §II-B: the optimal schedule fuses KRP + TDOT into one MTTKRP term.
        let n = 1 << 14;
        let (_, _, fusion) = analyzed(
            "ijk,ja,ka->ia",
            &[vec![n, n, n], vec![n, 24], vec![n, 24]],
        );
        assert_eq!(fusion.groups.len(), 1, "expected single fused MTTKRP group");
        let g = &fusion.groups[0];
        assert_eq!(g.op_indices, vec![0, 1]);
        assert_eq!(g.inputs.len(), 3);
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(fusion.candidates, 2);
    }

    #[test]
    fn fused_mttkrp_beats_two_step() {
        // The S^{1/6} separation (§IV-E): fused Q strictly below unfused.
        let n = 1 << 14;
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![n, n, n], vec![n, 24], vec![n, 24]],
        )
        .unwrap();
        let path = optimize(&spec).unwrap();
        let fused = group_statement(&path, &spec, &[0, 1], S).unwrap();
        let krp = group_statement(&path, &spec, &[0], S).unwrap();
        let tdot = group_statement(&path, &spec, &[1], S).unwrap();
        assert!(
            fused.bound.q < krp.bound.q + tdot.bound.q,
            "fused {} !< two-step {}",
            fused.bound.q,
            krp.bound.q + tdot.bound.q
        );
    }

    #[test]
    fn worked_example_groups_into_mttkrp_and_mm() {
        // §II-B: ijk,ja,ka,al->il fuses into [MTTKRP term] + [MM term].
        let n = 1 << 12;
        let (_, _, fusion) = analyzed(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        );
        assert_eq!(fusion.groups.len(), 2, "{:?}", fusion.groups.iter().map(|g| g.render()).collect::<Vec<_>>());
        // First group: 3 inputs (X, A, B), output ia.
        assert_eq!(fusion.groups[0].inputs.len(), 3);
        let out0: String = fusion.groups[0].outputs[0].1.iter().collect();
        assert_eq!(out0, "ia");
        // Second group: the GEMM ia,al->il.
        assert_eq!(fusion.groups[1].inputs.len(), 2);
        let out1: String = fusion.groups[1].outputs[0].1.iter().collect();
        assert_eq!(out1, "il");
    }

    #[test]
    fn single_gemm_single_group() {
        let (_, _, fusion) =
            analyzed("ij,jk->ik", &[vec![4096, 4096], vec![4096, 4096]]);
        assert_eq!(fusion.groups.len(), 1);
        assert_eq!(fusion.candidates, 1);
    }

    #[test]
    fn mm_chain_not_fused() {
        // 2MM: fusing two GEMMs does not reduce I/O (no shared reuse to
        // exploit at this S) — expect two groups.
        let n = 4096;
        let (_, _, fusion) = analyzed(
            "ij,jk,kl->il",
            &[vec![n, n], vec![n, n], vec![n, n]],
        );
        assert_eq!(fusion.groups.len(), 2);
    }

    #[test]
    fn group_external_io_accounting() {
        // In a 2-group split of the worked example, t1 (ia) must appear as
        // the first group's output and the second group's input.
        let n = 1 << 12;
        let (path, spec, fusion) = analyzed(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        );
        assert_eq!(fusion.groups.len(), 2);
        let t1_id = fusion.groups[0].outputs[0].0;
        assert!(fusion.groups[1].inputs.iter().any(|(id, _)| *id == t1_id));
        assert_eq!(t1_id, path.ops[fusion.groups[0].op_indices[1]].output_id);
        let _ = spec;
    }

    #[test]
    fn total_q_is_sum_of_groups() {
        let n = 1 << 12;
        let (_, _, fusion) = analyzed(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        );
        let sum: f64 = fusion.groups.iter().map(|g| g.bound.q).sum();
        assert!((sum - fusion.total_q).abs() / sum < 1e-12);
    }
}
