//! SOAP: Simple Overlap Access Programs — automated I/O lower bounds
//! (paper §IV, after Kwasniewski et al. [27]).
//!
//! A multilinear statement is modeled by its iteration indices and the
//! *access sets* of every array it touches (inputs **and** output).  For a
//! computation set `Ψ` with `|Ψ| = X` elementary operations, the maximum
//! number of new values computable per loaded element — the computational
//! intensity `ρ` — is bounded by maximizing the tile volume subject to the
//! accessed elements fitting in `X`:
//!
//! ```text
//!   max  ∏_d t_d    s.t.   Σ_arrays ∏_{d ∈ access(array)} t_d  ≤  X,
//!                           1 ≤ t_d ≤ N_d
//! ```
//!
//! then minimizing `ρ(X) = V(X) / (X − S)` over `X > S` (the tightest
//! choice of `X` per Lemma 1).  The closed forms the paper derives fall
//! out of this machinery numerically:
//!
//! - GEMM: `ρ = √S / 2` at `X₀ = 3S`, square tiles `√(S/3)`  (§IV-A);
//! - fused MTTKRP: `ρ = S^{2/3} / 3` at `X₀ = 5S/2`, tiles
//!   `I = J = K = S^{1/3}`, `L = S^{2/3}/2`  (§IV-E) — the paper's
//!   headline bound, 3^{5/3} ≈ 6.24× tighter than Ballard et al. [20].
//!
//! [`sdg`] builds the Symbolic Directed Graph over a contraction path and
//! enumerates kernel fusions to find the I/O-minimal grouping (§IV-C).

pub mod bound;
pub mod sdg;

pub use bound::{IoBound, Statement};
pub use sdg::{best_fusion, Fusion, FusedGroup};

/// The paper's improvement factor of the fused-MTTKRP bound over the
/// previously best-known (Ballard et al.): `3^{5/3} ≈ 6.24`.
pub fn mttkrp_improvement_factor() -> f64 {
    3f64.powf(5.0 / 3.0)
}

/// Closed-form fused-MTTKRP computational intensity `ρ = S^{2/3}/3`
/// (§IV-E) — the regression anchor for the numeric machinery.
pub fn mttkrp_rho_closed_form(s: f64) -> f64 {
    s.powf(2.0 / 3.0) / 3.0
}

/// Closed-form GEMM computational intensity `ρ = √S/2` (§IV-A).
pub fn gemm_rho_closed_form(s: f64) -> f64 {
    s.sqrt() / 2.0
}

/// Closed-form fused-MTTKRP I/O lower bound
/// `Q ≥ 3 N₁N₂N₃N₄ / S^{2/3}` (§IV-E).
pub fn mttkrp_q_closed_form(n: &[f64], s: f64) -> f64 {
    3.0 * n.iter().product::<f64>() / s.powf(2.0 / 3.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvement_factor_value() {
        // §IV-E: 3^{5/3} ≈ 6.24
        assert!((mttkrp_improvement_factor() - 6.24).abs() < 0.02);
    }

    #[test]
    fn closed_forms_consistent() {
        let s = 1e6;
        let n = [1e4, 1e4, 1e4, 24.0];
        let v: f64 = n.iter().product();
        assert!(
            (mttkrp_q_closed_form(&n, s) - v / mttkrp_rho_closed_form(s)).abs()
                / mttkrp_q_closed_form(&n, s)
                < 1e-12
        );
    }
}
