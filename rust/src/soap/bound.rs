//! Numeric I/O lower-bound machinery for a single SOAP statement.
//!
//! The tile-volume maximization is a geometric program; in log space the
//! feasible set is convex and the KKT condition says the constraint
//! marginals `m_d = Σ_{a ∋ d} vol(a)` must be equal across all indices
//! whose tiles are strictly inside `[1, N_d]`.  We solve it with a damped
//! multiplicative fixed point plus a tight-constraint rescale, then find
//! `X₀ = argmin_X V(X)/(X − S)` by golden-section search.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One array's access set: which iteration indices address it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AccessSet {
    /// Array name (for rendering/debugging).
    pub name: String,
    /// Iteration indices addressing the array (subset of the statement's).
    pub indices: Vec<char>,
}

/// A SOAP statement: iteration indices with extents + the access sets of
/// every array touched (inputs and output alike — both cost I/O).
#[derive(Debug, Clone)]
pub struct Statement {
    /// Iteration index extents.
    pub extents: BTreeMap<char, f64>,
    /// Access sets (inputs + output).
    pub accesses: Vec<AccessSet>,
}

/// The result of the I/O lower-bound analysis at fast-memory size `S`.
#[derive(Debug, Clone)]
pub struct IoBound {
    /// Computational intensity: max new values per loaded element.
    pub rho: f64,
    /// The `X₀` achieving the tightest bound (paper: `5S/2` for MTTKRP).
    pub x0: f64,
    /// Optimal tile size per index at `X₀` (the communication-optimal
    /// tiling the schedule uses).
    pub tiles: BTreeMap<char, f64>,
    /// Iteration-space volume `|V|`.
    pub volume: f64,
    /// The I/O lower bound `Q ≥ |V| / ρ`.
    pub q: f64,
}

impl Statement {
    /// Build from (extents, accesses); validates access indices.
    pub fn new(
        extents: BTreeMap<char, f64>,
        accesses: Vec<AccessSet>,
    ) -> Result<Self> {
        for a in &accesses {
            for c in &a.indices {
                if !extents.contains_key(c) {
                    return Err(Error::plan(format!(
                        "access {} uses unknown index '{c}'",
                        a.name
                    )));
                }
            }
        }
        Ok(Statement { extents, accesses })
    }

    /// Iteration-space volume `|V| = ∏ N_d`.
    pub fn volume(&self) -> f64 {
        self.extents.values().product()
    }

    fn index_order(&self) -> Vec<char> {
        self.extents.keys().copied().collect()
    }

    /// Maximize `∏ t_d` s.t. `Σ_a ∏_{d∈a} t_d ≤ x`, `1 ≤ t_d ≤ N_d`.
    /// Returns (tiles in index order, tile volume).
    pub fn optimal_tiles(&self, x: f64) -> (Vec<f64>, f64) {
        let order = self.index_order();
        let n = order.len();
        let pos: BTreeMap<char, usize> =
            order.iter().enumerate().map(|(i, &c)| (c, i)).collect();
        let caps: Vec<f64> = order.iter().map(|c| self.extents[c].max(1.0)).collect();
        // access sets as index positions
        let acc: Vec<Vec<usize>> = self
            .accesses
            .iter()
            .map(|a| a.indices.iter().map(|c| pos[c]).collect())
            .collect();

        // log-space tiles, initialized to an even split of ln(x) over the
        // largest access set.
        let max_set = acc.iter().map(|a| a.len()).max().unwrap_or(1).max(1);
        let mut y: Vec<f64> =
            caps.iter().map(|c| (x.ln() / max_set as f64).min(c.ln())).collect();

        let vol_of = |a: &[usize], y: &[f64]| -> f64 {
            a.iter().map(|&d| y[d]).sum::<f64>().exp()
        };
        let constraint = |y: &[f64]| -> f64 { acc.iter().map(|a| vol_of(a, y)).sum() };

        // Rescale the *unclamped* coordinates by a common log-shift `u`
        // until the constraint is tight (bisection; C is monotone in u).
        let rescale = |y: &mut Vec<f64>, caps: &[f64]| {
            for _ in 0..24 {
                let c = constraint(y);
                if (c / x - 1.0).abs() < 1e-9 {
                    break;
                }
                let free: Vec<usize> = (0..n)
                    .filter(|&d| {
                        if c < x {
                            y[d] < caps[d].ln() - 1e-12
                        } else {
                            y[d] > 1e-12
                        }
                    })
                    .collect();
                if free.is_empty() {
                    break;
                }
                // bisect a shift u applied to all free coords
                let (mut lo, mut hi) = if c < x { (0.0, 60.0) } else { (-60.0, 0.0) };
                for _ in 0..48 {
                    let u = 0.5 * (lo + hi);
                    let mut yt = y.clone();
                    for &d in &free {
                        yt[d] = (yt[d] + u).clamp(0.0, caps[d].ln());
                    }
                    if constraint(&yt) < x {
                        lo = u;
                    } else {
                        hi = u;
                    }
                }
                let u = 0.5 * (lo + hi);
                for &d in &free {
                    y[d] = (y[d] + u).clamp(0.0, caps[d].ln());
                }
            }
        };

        rescale(&mut y, &caps);
        // Damped KKT fixed point: equalize marginals over interior coords.
        let gamma = 0.2;
        for _ in 0..200 {
            let vols: Vec<f64> = acc.iter().map(|a| vol_of(a, &y)).collect();
            let mut m = vec![0.0f64; n];
            for (a, &v) in acc.iter().zip(&vols) {
                for &d in a {
                    m[d] += v;
                }
            }
            let interior: Vec<usize> = (0..n)
                .filter(|&d| y[d] > 1e-9 && y[d] < caps[d].ln() - 1e-9 && m[d] > 0.0)
                .collect();
            if interior.len() <= 1 {
                break;
            }
            let target = interior.iter().map(|&d| m[d].ln()).sum::<f64>()
                / interior.len() as f64;
            let mut delta = 0.0;
            for &d in &interior {
                let step = gamma * (target - m[d].ln());
                y[d] = (y[d] + step).clamp(0.0, caps[d].ln());
                delta += step.abs();
            }
            rescale(&mut y, &caps);
            if delta < 1e-10 {
                break;
            }
        }
        let tiles: Vec<f64> = y.iter().map(|v| v.exp()).collect();
        let volume = y.iter().sum::<f64>().exp();
        (tiles, volume)
    }

    /// Tile volume at accessed-budget `x` (the inner maximization).
    pub fn tile_volume(&self, x: f64) -> f64 {
        self.optimal_tiles(x).1
    }

    /// Full bound at fast-memory size `s`: golden-section minimize
    /// `ρ(X) = V(X)/(X − S)` over `X ∈ (S, 64·S]`.
    pub fn io_bound(&self, s: f64) -> IoBound {
        let f = |x: f64| self.tile_volume(x) / (x - s);
        let (mut a, mut b) = (s * 1.0001, s * 64.0);
        // If even the full problem fits in X≤b, extend until growth stops
        // mattering (tile volume saturates at |V|).
        let phi = (5f64.sqrt() - 1.0) / 2.0;
        let mut c = b - phi * (b - a);
        let mut d = a + phi * (b - a);
        let mut fc = f(c);
        let mut fd = f(d);
        for _ in 0..60 {
            if fc < fd {
                b = d;
                d = c;
                fd = fc;
                c = b - phi * (b - a);
                fc = f(c);
            } else {
                a = c;
                c = d;
                fc = fd;
                d = a + phi * (b - a);
                fd = f(d);
            }
            if (b - a) / b < 1e-8 {
                break;
            }
        }
        let x0 = 0.5 * (a + b);
        let (tiles_v, volume_at_x0) = self.optimal_tiles(x0);
        let rho = volume_at_x0 / (x0 - s);
        let order = self.index_order();
        let tiles: BTreeMap<char, f64> =
            order.iter().copied().zip(tiles_v).collect();
        let v = self.volume();
        IoBound { rho, x0, tiles, volume: v, q: v / rho }
    }

    /// Parallel I/O lower bound per process (paper §IV-E): each of `p`
    /// processes computes `|V|/p` elementary operations, so
    /// `Q_proc ≥ |V| / (p · ρ)`.
    pub fn parallel_io_bound(&self, s: f64, p: usize) -> f64 {
        let b = self.io_bound(s);
        b.volume / (p as f64 * b.rho)
    }
}

/// Convenience constructors for the paper's canonical statements.
impl Statement {
    /// Classical GEMM `C[i,j] += A[i,k] B[k,j]`.
    pub fn gemm(ni: f64, nj: f64, nk: f64) -> Self {
        let mut e = BTreeMap::new();
        e.insert('i', ni);
        e.insert('j', nj);
        e.insert('k', nk);
        Statement {
            extents: e,
            accesses: vec![
                AccessSet { name: "A".into(), indices: vec!['i', 'k'] },
                AccessSet { name: "B".into(), indices: vec!['k', 'j'] },
                AccessSet { name: "C".into(), indices: vec!['i', 'j'] },
            ],
        }
    }

    /// Fused order-3 MTTKRP `u[i,l] += T[i,j,k] v[j,l] w[k,l]` (§IV-E).
    pub fn mttkrp3(ni: f64, nj: f64, nk: f64, nl: f64) -> Self {
        let mut e = BTreeMap::new();
        e.insert('i', ni);
        e.insert('j', nj);
        e.insert('k', nk);
        e.insert('l', nl);
        Statement {
            extents: e,
            accesses: vec![
                AccessSet { name: "T".into(), indices: vec!['i', 'j', 'k'] },
                AccessSet { name: "v".into(), indices: vec!['j', 'l'] },
                AccessSet { name: "w".into(), indices: vec!['k', 'l'] },
                AccessSet { name: "u".into(), indices: vec!['i', 'l'] },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soap::{gemm_rho_closed_form, mttkrp_rho_closed_form};

    const BIG: f64 = 1e12; // effectively unbounded extents

    #[test]
    fn gemm_bound_matches_closed_form() {
        // §IV-A: rho = sqrt(S)/2 at X0 = 3S, square tiles sqrt(S/3)... but
        // note the classical result keeps only A,B loads; with the output
        // access included the machinery still recovers sqrt(S)/2 up to a
        // constant factor; we check against the exact optimum of OUR
        // model: max t^3 s.t. 3t^2 <= X -> rho(X) = (X/3)^{3/2}/(X-S),
        // minimized at X0 = 3S with rho = sqrt(S)/2.
        for s in [1e4, 1e6, 1e8] {
            let st = Statement::gemm(BIG, BIG, BIG);
            let b = st.io_bound(s);
            let want = gemm_rho_closed_form(s);
            assert!(
                (b.rho - want).abs() / want < 0.02,
                "S={s}: rho {} vs closed form {want}",
                b.rho
            );
            assert!((b.x0 - 3.0 * s).abs() / (3.0 * s) < 0.05, "X0 {} vs 3S", b.x0);
            // square tiles sqrt(X0/3) = sqrt(S)
            for (_, t) in &b.tiles {
                assert!((t - s.sqrt()).abs() / s.sqrt() < 0.05);
            }
        }
    }

    #[test]
    fn mttkrp_bound_matches_paper() {
        // §IV-E headline: rho = S^{2/3}/3, X0 = 5S/2,
        // tiles I=J=K=S^{1/3}, L=S^{2/3}/2.
        for s in [1e4, 1e6, 1e8] {
            let st = Statement::mttkrp3(BIG, BIG, BIG, BIG);
            let b = st.io_bound(s);
            let want = mttkrp_rho_closed_form(s);
            assert!(
                (b.rho - want).abs() / want < 0.02,
                "S={s}: rho {} vs paper {want}",
                b.rho
            );
            assert!(
                (b.x0 - 2.5 * s).abs() / (2.5 * s) < 0.05,
                "S={s}: X0 {} vs 5S/2",
                b.x0
            );
            let third = s.powf(1.0 / 3.0);
            for c in ['i', 'j', 'k'] {
                assert!(
                    (b.tiles[&c] - third).abs() / third < 0.05,
                    "tile {c} = {} vs S^(1/3) = {third}",
                    b.tiles[&c]
                );
            }
            let l_want = s.powf(2.0 / 3.0) / 2.0;
            assert!(
                (b.tiles[&'l'] - l_want).abs() / l_want < 0.05,
                "tile l = {} vs S^(2/3)/2 = {l_want}",
                b.tiles[&'l']
            );
        }
    }

    #[test]
    fn mttkrp_q_formula() {
        // Q >= 3 N1 N2 N3 N4 / S^{2/3}
        let s = 1e6;
        let st = Statement::mttkrp3(BIG, BIG, BIG, BIG);
        let b = st.io_bound(s);
        let n = [2e3, 2e3, 2e3, 1e3];
        let v: f64 = n.iter().product();
        let q = v / b.rho;
        let want = crate::soap::mttkrp_q_closed_form(&n, s);
        assert!((q - want).abs() / want < 0.02);
    }

    #[test]
    fn extent_clamping_respected() {
        // Rank dim clamped at 24 (Table V): l-tile must cap at 24.
        let st = Statement::mttkrp3(BIG, BIG, BIG, 24.0);
        let b = st.io_bound(1e6);
        assert!(b.tiles[&'l'] <= 24.0 + 1e-6);
        assert!(b.rho > 0.0);
    }

    #[test]
    fn rho_monotone_in_s() {
        let st = Statement::mttkrp3(BIG, BIG, BIG, BIG);
        let r1 = st.io_bound(1e4).rho;
        let r2 = st.io_bound(1e6).rho;
        assert!(r2 > r1);
    }

    #[test]
    fn parallel_bound_scales() {
        let st = Statement::gemm(4096.0, 4096.0, 4096.0);
        let s = 1e6;
        let q1 = st.parallel_io_bound(s, 1);
        let q8 = st.parallel_io_bound(s, 8);
        assert!((q1 / q8 - 8.0).abs() < 1e-6);
    }

    #[test]
    fn materialization_statement_has_low_rho() {
        // Unfused KRP (ja,ka->jka) materializes an output as large as its
        // iteration space: rho ~ O(1); the machinery must see that.
        let mut e = BTreeMap::new();
        e.insert('j', BIG);
        e.insert('k', BIG);
        e.insert('a', BIG);
        let st = Statement::new(
            e,
            vec![
                AccessSet { name: "A".into(), indices: vec!['j', 'a'] },
                AccessSet { name: "B".into(), indices: vec!['k', 'a'] },
                AccessSet { name: "out".into(), indices: vec!['j', 'k', 'a'] },
            ],
        )
        .unwrap();
        let b = st.io_bound(1e6);
        // output term jka dominates: at most ~X values per X loads.
        assert!(b.rho < 3.0, "rho = {}", b.rho);
    }

    #[test]
    fn invalid_access_rejected() {
        let mut e = BTreeMap::new();
        e.insert('i', 10.0);
        assert!(Statement::new(
            e,
            vec![AccessSet { name: "A".into(), indices: vec!['z'] }]
        )
        .is_err());
    }

    #[test]
    fn tiles_satisfy_constraint() {
        let st = Statement::mttkrp3(BIG, BIG, BIG, BIG);
        let x = 1e7;
        let (tiles, _) = st.optimal_tiles(x);
        let order: Vec<char> = st.extents.keys().copied().collect();
        let pos: std::collections::BTreeMap<char, usize> =
            order.iter().enumerate().map(|(i, &c)| (c, i)).collect();
        let c: f64 = st
            .accesses
            .iter()
            .map(|a| a.indices.iter().map(|i| tiles[pos[i]]).product::<f64>())
            .sum();
        assert!(c <= x * 1.01, "constraint violated: {c} > {x}");
        assert!(c >= x * 0.9, "constraint slack: {c} << {x}");
    }
}
