//! The public front door: compile an einsum **once** into a [`Program`]
//! and run it many times.
//!
//! The paper's whole premise is that a multilinear expression is
//! *compiled once* into an I/O-optimal distributed schedule and then
//! executed repeatedly (CP-ALS sweeps, the Fig. 5/6 repeat runs).  The
//! handle API mirrors that shape:
//!
//! - a [`Session`] (built via [`Session::builder`]) owns the
//!   [`KernelEngine`] — PJRT artifacts or native packed kernels, thread
//!   and tile overrides — and an LRU **plan cache** keyed by
//!   `(expr, shapes, ranks, planner config)` with hit/miss counters
//!   ([`Session::cache_stats`]): recompiling an identical spec skips
//!   planning entirely and shares the cached [`Plan`];
//! - a [`Program`] ([`Session::compile`]) owns its plan, its persistent
//!   execution backend ([`crate::exec::Executor`]), and every recycled
//!   buffer.  [`Program::run`] executes and returns a fresh output;
//!   [`Program::run_into`] writes the output through a caller-provided
//!   tensor so steady-state reruns perform **zero tensor allocations**
//!   end to end; [`Program::schedule`] renders the §II-E intermediate
//!   program and [`Program::stats`] merges every store/scratch counter
//!   into one [`RunStats`].
//!
//! ## Execution backends
//!
//! Plans execute through a pluggable [`crate::exec::Executor`]: the
//! in-process simulated machine ([`ExecBackend::Sim`], the default),
//! the message-passing rank-thread backend ([`ExecBackend::Mp`]), or
//! the out-of-process backend ([`ExecBackend::Proc`]) driving
//! `deinsum rank-worker` child processes — or remote TCP peers via
//! [`SessionBuilder::rank_addrs`] / `DEINSUM_RANK_ADDR` — over a
//! versioned wire format.  Select per session with
//! [`SessionBuilder::backend`], or process-wide with
//! `DEINSUM_BACKEND=mp|proc`.  Outputs are bitwise identical across
//! backends for a fixed plan and inputs; distributed-transport
//! deadlines are tuned with [`SessionBuilder::peer_timeout`] /
//! `DEINSUM_PEER_TIMEOUT_MS`.
//!
//! ## Concurrency (0.6.0: `Rc` → `Arc`)
//!
//! As of 0.6.0 the handles are thread-safe: the session shares its
//! [`KernelEngine`] and cached [`Plan`]s by `Arc` (they were `Rc` in
//! 0.5.0), the plan cache sits behind a mutex, and the engine's per-term
//! kernel-config override moved into thread-local state — so `Session`
//! is `Send + Sync` and every `Program` is `Send`.  Many threads can
//! compile from one shared session and run their programs concurrently;
//! results stay bitwise identical to serial execution because per-element
//! accumulation orders never depend on scheduling.  The multi-tenant
//! worker pool built on top of this lives in [`crate::serve`].
//!
//! The deprecated `Coordinator` borrow-the-engine wrapper (0.4.0's
//! wiring, kept one release for migration) is **removed** in 0.6.0: the
//! handles are the only front door, and the execution core keeps
//! `Program`-owned state only.
//!
//! ```
//! use deinsum::{Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! // The paper's §II worked example: ijk,ja,ka,al->il on 8 ranks.
//! let shapes = vec![vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]];
//! let session = Session::builder().ranks(8).build()?;
//! let mut program = session.compile("ijk,ja,ka,al->il", &shapes)?;
//! let inputs: Vec<Tensor> =
//!     shapes.iter().enumerate().map(|(i, s)| Tensor::random(s, i as u64)).collect();
//! let report = program.run(&inputs)?;
//! assert_eq!(report.output.dims(), &[10, 10]);
//! # Ok(())
//! # }
//! ```
//!
//! ## Under the hood (the old wiring ritual)
//!
//! `compile` runs the same pipeline the free functions expose, in order:
//! [`EinsumSpec::parse`] validates the expression against the operand
//! shapes; [`crate::planner::plan`] decomposes it into FLOP-minimal
//! binary ops ([`crate::contraction`]), finds the I/O-minimal fusion and
//! per-term Cartesian grids with the SOAP model ([`crate::soap`]),
//! block-distributes operands ([`crate::dist`]) and infers the
//! redistribution moves ([`crate::redist`]); `run` drives the resulting
//! [`Plan`] through the execution core (the [`crate::coordinator`]
//! module) on the simulated machine ([`crate::sim`]), dispatching local
//! tile kernels through the engine ([`crate::runtime`]).  Before 0.5.0
//! every caller hand-wired those steps and borrowed the engine into a
//! `Coordinator` for its whole lifetime; that wrapper was deprecated in
//! 0.5.0 and removed in 0.6.0.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::baseline::plan_baseline;
use crate::coordinator::{
    run_plan, run_plan_batch, BatchRun, ExecState, LocalScratchStats, RunMetrics, RunReport,
};
use crate::einsum::EinsumSpec;
use crate::error::Result;
use crate::exec::{ExecBackend, ExecTuning};
use crate::planner::{plan as plan_schedule, Plan, PlannerConfig};
use crate::runtime::KernelEngine;
use crate::sim::{NetworkModel, StoreStats};
use crate::tensor::kernel::{KernelConfig, ScratchStats};
use crate::tensor::Tensor;

/// Hit/miss/eviction counters of a [`Session`]'s plan cache.  A repeated
/// [`Session::compile`] of an identical `(expr, shapes, ranks, planner)`
/// key is a `hit` and skips planning entirely.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Compiles served from the cache (planning skipped).
    pub hits: u64,
    /// Compiles that ran the planner.
    pub misses: u64,
    /// Cached plans dropped to respect the capacity bound (LRU order).
    pub evictions: u64,
}

/// Everything that identifies a plan: the expression, the operand
/// shapes, the rank count, the planner knobs (f64 compared by bits), and
/// whether the CTF-like baseline scheduler was requested.
#[derive(Debug, Clone, PartialEq, Eq)]
struct PlanKey {
    expr: String,
    shapes: Vec<Vec<usize>>,
    p: usize,
    s_bits: u64,
    fuse: bool,
    soap_grids: bool,
    baseline: bool,
}

/// LRU plan cache: MRU at the back of `entries`, evictions pop the
/// front.  Linear scan — capacities are tens of plans, and a hit saves a
/// full SOAP solve + grid search, so lookup cost is noise.
///
/// Concurrency protocol (the cache sits behind a session mutex): a
/// compile takes the lock for [`lookup`](Self::lookup), releases it to
/// run the planner on a miss — a SOAP solve must never block other
/// tenants' cache hits — and re-takes it for
/// [`insert`](Self::insert), which detects a racing insert of the same
/// key and shares the first plan so cache-hit pointer identity holds.
struct PlanCache {
    capacity: usize,
    entries: Vec<(PlanKey, Arc<Plan>)>,
    stats: PlanCacheStats,
}

impl PlanCache {
    fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            entries: Vec::new(),
            stats: PlanCacheStats::default(),
        }
    }

    /// Counted lookup: a present key is a hit (and becomes MRU); an
    /// absent key is a miss and the caller must plan + `insert`.
    fn lookup(&mut self, key: &PlanKey) -> Option<Arc<Plan>> {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == key) {
            self.stats.hits += 1;
            let entry = self.entries.remove(pos);
            let plan = Arc::clone(&entry.1);
            self.entries.push(entry);
            return Some(plan);
        }
        self.stats.misses += 1;
        None
    }

    /// Install a freshly-built plan.  If a concurrent compile of the
    /// same key won the race while this thread was planning, the earlier
    /// plan is kept (and returned) so hits keep sharing one allocation.
    fn insert(&mut self, key: PlanKey, plan: Arc<Plan>) -> Arc<Plan> {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            let entry = self.entries.remove(pos);
            let existing = Arc::clone(&entry.1);
            self.entries.push(entry);
            return existing;
        }
        if self.entries.len() >= self.capacity {
            self.entries.remove(0);
            self.stats.evictions += 1;
        }
        self.entries.push((key, Arc::clone(&plan)));
        plan
    }
}

/// Builder for a [`Session`]: rank count, network model, PJRT artifact
/// directory, kernel-config/thread overrides, planner knobs, and the
/// plan-cache capacity.
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    ranks: usize,
    network: NetworkModel,
    artifacts: Option<PathBuf>,
    kernel_config: Option<KernelConfig>,
    threads: Option<usize>,
    planner: PlannerConfig,
    plan_cache_capacity: usize,
    fault_plan: Option<crate::fault::FaultPlan>,
    backend: Option<ExecBackend>,
    peer_timeout: Option<Duration>,
    rank_addrs: Option<Vec<String>>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            ranks: 8,
            network: NetworkModel::aries(),
            artifacts: None,
            kernel_config: None,
            threads: None,
            planner: PlannerConfig::default(),
            plan_cache_capacity: 32,
            fault_plan: None,
            backend: None,
            peer_timeout: None,
            rank_addrs: None,
        }
    }
}

impl SessionBuilder {
    /// Default rank count for [`Session::compile`] (default 8; per-call
    /// overrides via [`Session::compile_on`]).
    pub fn ranks(mut self, p: usize) -> Self {
        self.ranks = p.max(1);
        self
    }

    /// α–β network model for the simulated machine (default
    /// [`NetworkModel::aries`]).
    pub fn network(mut self, net: NetworkModel) -> Self {
        self.network = net;
        self
    }

    /// Serve local kernels from AOT PJRT artifacts in `dir` (native
    /// fallback per op stays available).  [`SessionBuilder::build`]
    /// fails if the PJRT client cannot load; use
    /// [`SessionBuilder::build_or_native`] to degrade gracefully.
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Install explicit cache-blocking/threading knobs on the engine
    /// (otherwise `DEINSUM_MC/KC/NC` + thread env vars apply).
    pub fn kernel_config(mut self, cfg: KernelConfig) -> Self {
        self.kernel_config = Some(cfg);
        self
    }

    /// Override just the worker-thread count of the local kernels.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Planner knobs (analysis `S`, fusion, SOAP grids).  Part of the
    /// plan-cache key.
    pub fn planner(mut self, cfg: PlannerConfig) -> Self {
        self.planner = cfg;
        self
    }

    /// Maximum number of cached plans (default 32, minimum 1; least
    /// recently used plans are evicted).
    pub fn plan_cache_capacity(mut self, cap: usize) -> Self {
        self.plan_cache_capacity = cap;
        self
    }

    /// Install an explicit deterministic fault-injection plan
    /// ([`crate::fault::FaultPlan`]) on the session's engine, replacing
    /// the environment-seeded default (`DEINSUM_FAULT_SEED`).  The
    /// engine's dispatch methods and the run loop check their named
    /// sites against it; a [`crate::serve::Server`] built over the
    /// session inherits it for the `serve.*` sites.  Test-only seam —
    /// sessions without one pay a single branch per site check.
    pub fn fault_plan(mut self, plan: crate::fault::FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Pin the execution backend for every program of this session
    /// ([`ExecBackend::Sim`], [`ExecBackend::Mp`], or
    /// [`ExecBackend::Proc`]).  Unset, the process-wide
    /// `DEINSUM_BACKEND` environment variable decides
    /// ([`ExecBackend::from_env`], defaulting to the simulator).
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Bound on every coordinator↔rank wait inside the distributed
    /// backends (mp and proc).  A blown deadline is a fatal protocol
    /// error: the run fails typed and the executor is rebuilt on the
    /// next run.  Unset, `DEINSUM_PEER_TIMEOUT_MS` decides, defaulting
    /// to 60 s.
    pub fn peer_timeout(mut self, timeout: Duration) -> Self {
        self.peer_timeout = Some(timeout);
        self
    }

    /// Pre-existing TCP rank listeners for the proc backend, one
    /// `host:port` per rank in rank order (each a running
    /// `deinsum rank-worker --listen <addr>`).  Unset, the
    /// comma-separated `DEINSUM_RANK_ADDR` environment variable
    /// decides; with neither, the proc backend spawns
    /// `deinsum rank-worker` child processes over pipes.
    pub fn rank_addrs(mut self, addrs: Vec<String>) -> Self {
        self.rank_addrs = Some(addrs);
        self
    }

    /// Build the session.  Only the PJRT path can fail (missing or
    /// unloadable artifacts); a native session is infallible.
    pub fn build(self) -> Result<Session> {
        let mut engine = match &self.artifacts {
            Some(dir) => KernelEngine::pjrt(dir)?,
            None => KernelEngine::native(),
        };
        if let Some(cfg) = self.kernel_config {
            engine.set_config(cfg);
        }
        if let Some(t) = self.threads {
            let cfg = engine.base_config().with_threads(t);
            engine.set_config(cfg);
        }
        if let Some(plan) = self.fault_plan {
            engine.set_faults(crate::fault::Faults::plan(plan));
        }
        Ok(Session {
            engine: Arc::new(engine),
            network: self.network,
            ranks: self.ranks,
            planner: self.planner,
            cache: Mutex::new(PlanCache::new(self.plan_cache_capacity)),
            backend: self.backend.unwrap_or_else(ExecBackend::from_env),
            tuning: {
                let mut t = ExecTuning::default();
                if let Some(timeout) = self.peer_timeout {
                    t.peer_timeout = timeout;
                }
                if let Some(addrs) = self.rank_addrs {
                    t.rank_addrs = Some(addrs);
                }
                t
            },
        })
    }

    /// [`build`](Self::build), degrading to native kernels (with a
    /// stderr note) when the PJRT artifacts cannot be loaded — the
    /// pattern every CLI/example wants.
    pub fn build_or_native(self) -> Session {
        if self.artifacts.is_some() {
            let fallback = SessionBuilder { artifacts: None, ..self.clone() };
            match self.build() {
                Ok(s) => return s,
                Err(e) => {
                    eprintln!("warning: PJRT engine unavailable ({e}); using native kernels");
                    return fallback.build_or_native();
                }
            }
        }
        self.build().expect("native session build is infallible")
    }
}

/// A compile-once execution context: owns the [`KernelEngine`] shared by
/// every [`Program`] it compiles, plus the LRU plan cache.  `Send +
/// Sync` since 0.6.0: wrap it in an `Arc` and compile from as many
/// threads as the workload needs (the serving layer does exactly this).
/// See the [module docs](self) for the full story.
pub struct Session {
    engine: Arc<KernelEngine>,
    network: NetworkModel,
    ranks: usize,
    planner: PlannerConfig,
    cache: Mutex<PlanCache>,
    backend: ExecBackend,
    tuning: ExecTuning,
}

impl Session {
    /// Start configuring a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Compile `expr` over `shapes` on the session's default rank count.
    /// Identical `(expr, shapes, ranks, planner)` keys hit the plan
    /// cache and skip planning (the returned [`Program`] shares the
    /// cached [`Plan`] but owns fresh execution state).
    pub fn compile(&self, expr: &str, shapes: &[Vec<usize>]) -> Result<Program> {
        self.compile_on(expr, shapes, self.ranks)
    }

    /// [`compile`](Self::compile) with an explicit rank count (weak
    /// scaling sweeps compile the same expression at many `P`).
    pub fn compile_on(
        &self,
        expr: &str,
        shapes: &[Vec<usize>],
        ranks: usize,
    ) -> Result<Program> {
        let plan = self.cached_plan(self.key(expr, shapes, ranks, false), || {
            plan_schedule(&EinsumSpec::parse(expr, shapes)?, ranks, &self.planner)
        })?;
        Ok(self.program(plan))
    }

    /// Compile with the CTF-like baseline scheduler (no fusion, no SOAP
    /// grids) — the comparator of the paper's Fig. 5/6 rows.  Cached
    /// under its own key space.
    pub fn compile_baseline(&self, expr: &str, shapes: &[Vec<usize>]) -> Result<Program> {
        self.compile_baseline_on(expr, shapes, self.ranks)
    }

    /// [`compile_baseline`](Self::compile_baseline) with an explicit
    /// rank count.
    pub fn compile_baseline_on(
        &self,
        expr: &str,
        shapes: &[Vec<usize>],
        ranks: usize,
    ) -> Result<Program> {
        let plan = self.cached_plan(self.key(expr, shapes, ranks, true), || {
            plan_baseline(&EinsumSpec::parse(expr, shapes)?, ranks)
        })?;
        Ok(self.program(plan))
    }

    /// The single lookup → plan-outside-the-lock → insert dance both
    /// compile flavors share.  Parsing happens inside the miss path: a
    /// cache hit's key equality already proves the exact `(expr,
    /// shapes)` pair parsed successfully when the plan was first built.
    /// The cache lock is dropped around `build` (the planner run) so
    /// concurrent tenants' cache hits never queue behind a SOAP solve;
    /// racing same-key misses each run the planner once and the insert
    /// dedups to the first plan.
    fn cached_plan(
        &self,
        key: PlanKey,
        build: impl FnOnce() -> Result<Plan>,
    ) -> Result<Arc<Plan>> {
        let cached = crate::sync::lock(&self.cache).lookup(&key);
        match cached {
            Some(p) => Ok(p),
            None => {
                let built = Arc::new(build()?);
                Ok(crate::sync::lock(&self.cache).insert(key, built))
            }
        }
    }

    /// Plan-cache counters (the second compile of an identical spec is a
    /// counted hit).
    pub fn cache_stats(&self) -> PlanCacheStats {
        crate::sync::lock(&self.cache).stats
    }

    /// Number of plans currently cached.
    pub fn cached_plans(&self) -> usize {
        crate::sync::lock(&self.cache).entries.len()
    }

    /// The kernel engine every program of this session dispatches
    /// through (native packed kernels, or PJRT with native fallback).
    pub fn engine(&self) -> &KernelEngine {
        &self.engine
    }

    /// Default rank count for [`Session::compile`].
    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// The session's network model.
    pub fn network(&self) -> NetworkModel {
        self.network
    }

    /// The session's planner knobs (part of every cache key).
    pub fn planner_config(&self) -> PlannerConfig {
        self.planner
    }

    /// The execution backend every program of this session runs on.
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    fn key(&self, expr: &str, shapes: &[Vec<usize>], p: usize, baseline: bool) -> PlanKey {
        // Exhaustive destructuring: adding a PlannerConfig knob without
        // extending the cache key becomes a compile error here, not a
        // silent stale cache hit.
        let PlannerConfig { s_elements, fuse, soap_grids } = self.planner;
        PlanKey {
            expr: expr.to_string(),
            shapes: shapes.to_vec(),
            p,
            s_bits: s_elements.to_bits(),
            fuse,
            soap_grids,
            baseline,
        }
    }

    fn program(&self, plan: Arc<Plan>) -> Program {
        Program {
            engine: Arc::clone(&self.engine),
            network: self.network,
            plan,
            state: ExecState::with_backend(self.backend, self.tuning.clone()),
            runs: 0,
            batch_runs: 0,
            batch_members: 0,
        }
    }
}

/// Unified allocation/recycling counters for one [`Program`]: the
/// persistent backend's staging/redistribution destinations and compute
/// outputs ([`StoreStats`]), its per-rank local scratch
/// ([`LocalScratchStats`]), and the engine's packing/fold pool
/// ([`ScratchStats`] — shared by every program of the session).  The
/// steady-state invariant in one number: [`RunStats::allocs`] is flat
/// across reruns of a warm program.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RunStats {
    /// Completed executions of this program: every `run`/`run_into` call
    /// plus every successful member of a `run_batch_into` batch.
    pub runs: u64,
    /// Completed [`Program::run_batch_into`] invocations (one per fused
    /// batch, regardless of member count).
    pub batch_runs: u64,
    /// Members executed across every completed batch invocation — the
    /// counterpart of [`runs`](RunStats::runs) for sizing how much
    /// traffic rode the fused path.
    pub batch_members: u64,
    /// Staging/redistribution destination + compute-output counters of
    /// the program's persistent backend.
    pub store: StoreStats,
    /// Seq-intermediate / pre-reduction / permute / gather scratch
    /// counters of the backend's per-rank local scratch.
    pub local_scratch: LocalScratchStats,
    /// Packing/fold scratch-pool counters of the session engine
    /// (session-wide: shared across this session's programs).
    pub engine_scratch: ScratchStats,
}

impl RunStats {
    /// Total buffers heap-allocated across every counter — flat across
    /// steady-state reruns of a warm program (asserted in tests).  Note
    /// that `engine_scratch` is session-wide: interleaving *another*
    /// program with larger shapes on the same session can raise the
    /// shared pool's high-water mark and show up here; the
    /// [`store`](RunStats::store) and
    /// [`local_scratch`](RunStats::local_scratch) counters are strictly
    /// per-program.
    pub fn allocs(&self) -> u64 {
        self.store.dest_allocs
            + self.store.out_allocs
            + self.local_scratch.allocs
            + self.engine_scratch.allocs
    }

    /// Total whole-tensor recycles across every counter.
    pub fn reuses(&self) -> u64 {
        self.store.dest_reuses + self.store.out_reuses + self.local_scratch.reuses
    }

    /// Whole-tensor allocations strictly attributable to *this* program
    /// (store destinations + compute outputs + local scratch), excluding
    /// the session-wide engine packing pool whose high-water mark can
    /// move when another program runs.  This is the per-request figure
    /// the serving layer accounts ([`crate::serve::ServeStats`]) and the
    /// zero-steady-state-allocations acceptance tests assert.
    pub fn tensor_allocs(&self) -> u64 {
        self.store.dest_allocs + self.store.out_allocs + self.local_scratch.allocs
    }

    /// Whole-tensor recycles attributable to this program — the
    /// counterpart of [`tensor_allocs`](Self::tensor_allocs).  Equal to
    /// [`reuses`](Self::reuses) today (the engine pool contributes no
    /// per-program reuse counter), named separately so the serving
    /// layer's accounting reads symmetrically.
    pub fn tensor_reuses(&self) -> u64 {
        self.reuses()
    }
}

/// A compiled distributed program: the I/O-optimal [`Plan`] (possibly
/// shared with the session's cache), the persistent execution backend,
/// and every recycled buffer.  Re-running is the cheap operation the
/// whole stack is built around — see the [module docs](self).
///
/// `Send` since 0.6.0: a program can move to (or be created on) any
/// worker thread and run there while sibling programs of the same
/// session run elsewhere — per-program state is exclusive (`&mut self`),
/// and the shared engine is `Sync`.
pub struct Program {
    engine: Arc<KernelEngine>,
    network: NetworkModel,
    plan: Arc<Plan>,
    state: ExecState,
    runs: u64,
    batch_runs: u64,
    batch_members: u64,
}

impl Program {
    /// Execute on global input tensors (one per einsum operand, in
    /// order) and return the gathered output with the run's accounting.
    /// Repeated runs recycle every staging, redistribution, compute and
    /// scratch buffer; only the returned output tensor is freshly
    /// allocated (use [`run_into`](Self::run_into) to recycle that too).
    pub fn run(&mut self, inputs: &[Tensor]) -> Result<RunReport> {
        let (out, metrics) = run_plan(
            &self.engine,
            self.network,
            &mut self.state,
            &self.plan,
            inputs,
            None,
        )?;
        self.runs += 1;
        Ok(RunReport::from_parts(
            out.expect("run without dest returns an output"),
            metrics,
        ))
    }

    /// [`run`](Self::run) writing the gathered output through `dest`
    /// (shape-checked against [`output_dims`](Self::output_dims)): the
    /// fully recycled path — in steady state the whole run performs zero
    /// tensor allocations.
    ///
    /// ```
    /// # use deinsum::{Session, Tensor};
    /// # fn main() -> deinsum::Result<()> {
    /// let session = Session::builder().ranks(4).build()?;
    /// let shapes = vec![vec![8, 6], vec![6, 4]];
    /// let mut program = session.compile("ij,jk->ik", &shapes)?;
    /// let inputs = vec![Tensor::random(&[8, 6], 1), Tensor::random(&[6, 4], 2)];
    /// let mut out = Tensor::zeros(&program.output_dims());
    /// let metrics = program.run_into(&inputs, &mut out)?;
    /// assert_eq!(out.dims(), &[8, 4]);
    /// assert_eq!(metrics.per_term.len(), 1);
    /// # Ok(())
    /// # }
    /// ```
    pub fn run_into(&mut self, inputs: &[Tensor], dest: &mut Tensor) -> Result<RunMetrics> {
        let (_, metrics) = run_plan(
            &self.engine,
            self.network,
            &mut self.state,
            &self.plan,
            inputs,
            Some(dest),
        )?;
        self.runs += 1;
        Ok(metrics)
    }

    /// Execute a whole coalesced batch through **one** staged pass:
    /// every member's operands are staged into the persistent backend
    /// under batch-member store names, per-term kernel configuration and
    /// fault checks run once for the batch instead of once per member,
    /// and program inputs that alias one underlying buffer across
    /// members (requests sharing an `Arc<Vec<Tensor>>`) are staged
    /// exactly once.  Each member's output is gathered through its own
    /// [`BatchRun::dest`].
    ///
    /// Results are **bitwise identical** to calling
    /// [`run_into`](Self::run_into) back-to-back for each member, at
    /// every thread count and on every backend — each member executes
    /// the exact same kernel-call sequence, just with the per-term setup
    /// amortized.  Steady-state batches of a stable size perform zero
    /// tensor allocations, the same counter-asserted invariant as the
    /// serial path.
    ///
    /// The outer `Err` is a batch-level infrastructure failure (executor
    /// build, protocol violation, injected fault): no member completed.
    /// The inner per-member `Result`s carry individual admission
    /// failures — a member with mismatched input or dest shapes fails
    /// typed and is excluded without poisoning its batch-mates.
    ///
    /// ```
    /// # use deinsum::{BatchRun, Session, Tensor};
    /// # fn main() -> deinsum::Result<()> {
    /// let session = Session::builder().ranks(4).build()?;
    /// let shapes = vec![vec![8, 6], vec![6, 4]];
    /// let mut program = session.compile("ij,jk->ik", &shapes)?;
    /// let inputs = vec![Tensor::random(&[8, 6], 1), Tensor::random(&[6, 4], 2)];
    /// let mut d0 = Tensor::zeros(&program.output_dims());
    /// let mut d1 = Tensor::zeros(&program.output_dims());
    /// let mut batch =
    ///     vec![BatchRun::new(&inputs, &mut d0), BatchRun::new(&inputs, &mut d1)];
    /// let results = program.run_batch_into(&mut batch)?;
    /// assert!(results.iter().all(|r| r.is_ok()));
    /// assert!(d0.allclose(&d1, 0.0, 0.0)); // same inputs, same bytes
    /// # Ok(())
    /// # }
    /// ```
    pub fn run_batch_into(
        &mut self,
        batch: &mut [BatchRun<'_>],
    ) -> Result<Vec<Result<RunMetrics>>> {
        let results = run_plan_batch(
            &self.engine,
            self.network,
            &mut self.state,
            &self.plan,
            batch,
        )?;
        self.batch_runs += 1;
        self.batch_members += batch.len() as u64;
        self.runs += results.iter().filter(|r| r.is_ok()).count() as u64;
        Ok(results)
    }

    /// Render the generated schedule (the paper's §II-E "intermediate
    /// program": grids, distributions, compute, Allreduce, Redistribute).
    pub fn schedule(&self) -> String {
        self.plan.render()
    }

    /// The compiled plan (shared with the session cache when the compile
    /// was a hit).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The parsed einsum specification this program computes.
    pub fn spec(&self) -> &EinsumSpec {
        &self.plan.spec
    }

    /// Rank count the plan is scheduled for.
    pub fn ranks(&self) -> usize {
        self.plan.p
    }

    /// Global output dims (what a [`run_into`](Self::run_into) `dest`
    /// must have).
    pub fn output_dims(&self) -> Vec<usize> {
        self.plan.spec.output_shape()
    }

    /// Unified counters: machine store + local scratch + engine scratch
    /// + completed runs.
    pub fn stats(&self) -> RunStats {
        RunStats {
            runs: self.runs,
            batch_runs: self.batch_runs,
            batch_members: self.batch_members,
            store: self.state.store_stats(),
            local_scratch: self.state.local_scratch_stats(),
            engine_scratch: self.engine.scratch_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hits_share_the_plan_and_skip_planning() {
        let session = Session::builder().ranks(4).build().unwrap();
        let shapes = vec![vec![12, 10], vec![10, 8]];
        let p1 = session.compile("ij,jk->ik", &shapes).unwrap();
        let s = session.cache_stats();
        assert_eq!((s.hits, s.misses), (0, 1));
        let p2 = session.compile("ij,jk->ik", &shapes).unwrap();
        let s = session.cache_stats();
        assert_eq!((s.hits, s.misses), (1, 1), "identical spec must be a cache hit");
        // The hit shares the exact same Plan allocation.
        assert!(std::ptr::eq(p1.plan(), p2.plan()));
        // Different shapes miss.
        let shapes2 = vec![vec![14, 10], vec![10, 8]];
        let _p3 = session.compile("ij,jk->ik", &shapes2).unwrap();
        let s = session.cache_stats();
        assert_eq!((s.hits, s.misses), (1, 2), "different shapes must re-plan");
        assert_eq!(session.cached_plans(), 2);
    }

    #[test]
    fn cache_evicts_lru_at_capacity() {
        let session =
            Session::builder().ranks(2).plan_cache_capacity(2).build().unwrap();
        let mk = |n: usize| vec![vec![n, 6], vec![6, 4]];
        session.compile("ij,jk->ik", &mk(8)).unwrap();
        session.compile("ij,jk->ik", &mk(10)).unwrap();
        // Touch the first so the second becomes LRU, then insert a third.
        session.compile("ij,jk->ik", &mk(8)).unwrap();
        session.compile("ij,jk->ik", &mk(12)).unwrap();
        let s = session.cache_stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(session.cached_plans(), 2);
        // The touched entry survived; the LRU one re-plans.
        session.compile("ij,jk->ik", &mk(8)).unwrap();
        assert_eq!(session.cache_stats().hits, 2);
        session.compile("ij,jk->ik", &mk(10)).unwrap();
        assert_eq!(session.cache_stats().misses, 4, "evicted plan must re-plan");
    }

    #[test]
    fn handles_are_send_and_sync() {
        // The 0.6.0 contract: sessions are shareable across threads and
        // programs are movable to worker threads.  Compile-time only.
        fn is_send<T: Send>() {}
        fn is_sync<T: Sync>() {}
        is_send::<Session>();
        is_sync::<Session>();
        is_send::<Program>();
        is_send::<KernelEngine>();
        is_sync::<KernelEngine>();
    }

    #[test]
    fn builder_pins_backend_and_runs_on_it() {
        let session =
            Session::builder().ranks(2).backend(ExecBackend::Mp).build().unwrap();
        assert_eq!(session.backend(), ExecBackend::Mp);
        let shapes = vec![vec![8, 6], vec![6, 4]];
        let mut prog = session.compile("ij,jk->ik", &shapes).unwrap();
        let inputs = vec![Tensor::random(&[8, 6], 1), Tensor::random(&[6, 4], 2)];
        let rep = prog.run(&inputs).unwrap();
        assert_eq!(rep.output.dims(), &[8, 4]);
        // The pinned backend survives into the program's executor: a
        // second run must keep reusing it (counters keep accumulating).
        prog.run(&inputs).unwrap();
        assert!(prog.stats().store.dest_reuses > 0);
    }

    #[test]
    fn run_batch_into_is_bitwise_identical_to_serial_runs() {
        // Two fresh sessions of identical config compile identical
        // programs; one serves the members back-to-back with run_into,
        // the other fuses them with run_batch_into.  Outputs must match
        // bit for bit (allclose with zero tolerance).
        let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
        let member_inputs: Vec<Vec<Tensor>> = (0..3u64)
            .map(|i| {
                vec![
                    Tensor::random(&[12, 10, 8], 100 + i),
                    Tensor::random(&[10, 4], 200 + i),
                    Tensor::random(&[8, 4], 300 + i),
                ]
            })
            .collect();
        let serial: Vec<Tensor> = {
            let s = Session::builder().ranks(4).build().unwrap();
            let mut p = s.compile("ijk,ja,ka->ia", &shapes).unwrap();
            member_inputs
                .iter()
                .map(|inputs| {
                    let mut d = Tensor::zeros(&p.output_dims());
                    p.run_into(inputs, &mut d).unwrap();
                    d
                })
                .collect()
        };
        let s = Session::builder().ranks(4).build().unwrap();
        let mut p = s.compile("ijk,ja,ka->ia", &shapes).unwrap();
        let mut dests: Vec<Tensor> =
            (0..member_inputs.len()).map(|_| Tensor::zeros(&p.output_dims())).collect();
        let results = {
            let mut batch: Vec<BatchRun> = member_inputs
                .iter()
                .zip(dests.iter_mut())
                .map(|(inputs, d)| BatchRun::new(inputs, d))
                .collect();
            p.run_batch_into(&mut batch).unwrap()
        };
        assert!(results.iter().all(|r| r.is_ok()));
        for (got, want) in dests.iter().zip(&serial) {
            assert!(got.allclose(want, 0.0, 0.0), "batched output diverged");
        }
        let st = p.stats();
        assert_eq!((st.batch_runs, st.batch_members, st.runs), (1, 3, 3));
        // Batch metrics are per member: each carries the full term list.
        for r in &results {
            assert!(!r.as_ref().unwrap().per_term.is_empty());
        }
    }

    #[test]
    fn run_batch_into_steady_state_allocates_nothing() {
        let shapes = vec![vec![16, 12], vec![12, 8]];
        let s = Session::builder().ranks(4).build().unwrap();
        let mut p = s.compile("ij,jk->ik", &shapes).unwrap();
        let inputs_a = vec![Tensor::random(&[16, 12], 1), Tensor::random(&[12, 8], 2)];
        let inputs_b = vec![Tensor::random(&[16, 12], 3), Tensor::random(&[12, 8], 4)];
        let mut d0 = Tensor::zeros(&p.output_dims());
        let mut d1 = Tensor::zeros(&p.output_dims());
        let run = |p: &mut Program, d0: &mut Tensor, d1: &mut Tensor| {
            let mut batch =
                vec![BatchRun::new(&inputs_a, d0), BatchRun::new(&inputs_b, d1)];
            let results = p.run_batch_into(&mut batch).unwrap();
            assert!(results.iter().all(|r| r.is_ok()));
        };
        run(&mut p, &mut d0, &mut d1); // warmup allocates the buffer sets
        let warm = p.stats().tensor_allocs();
        for _ in 0..4 {
            run(&mut p, &mut d0, &mut d1);
        }
        let st = p.stats();
        assert_eq!(st.tensor_allocs(), warm, "steady-state batch allocated: {st:?}");
        assert_eq!(st.batch_runs, 5);
    }

    #[test]
    fn run_batch_member_validation_is_per_member() {
        // A shape-invalid member fails typed through its own inner
        // Result; batch-mates execute and land correct bytes.
        let shapes = vec![vec![8, 6], vec![6, 4]];
        let s = Session::builder().ranks(2).build().unwrap();
        let mut p = s.compile("ij,jk->ik", &shapes).unwrap();
        let inputs = vec![Tensor::random(&[8, 6], 7), Tensor::random(&[6, 4], 8)];
        let want = {
            let s2 = Session::builder().ranks(2).build().unwrap();
            let mut p2 = s2.compile("ij,jk->ik", &shapes).unwrap();
            p2.run(&inputs).unwrap().output
        };
        let mut good = Tensor::zeros(&p.output_dims());
        let mut bad = Tensor::zeros(&[3, 3]);
        let results = {
            let mut batch =
                vec![BatchRun::new(&inputs, &mut good), BatchRun::new(&inputs, &mut bad)];
            p.run_batch_into(&mut batch).unwrap()
        };
        assert!(results[0].is_ok());
        assert!(
            matches!(results[1], Err(crate::error::Error::Shape(_))),
            "bad dest must fail typed: {:?}",
            results[1]
        );
        assert!(good.allclose(&want, 0.0, 0.0), "batch-mate poisoned by invalid member");
        let st = p.stats();
        assert_eq!((st.batch_runs, st.batch_members, st.runs), (1, 2, 1));
    }

    #[test]
    fn baseline_and_deinsum_plans_cache_separately() {
        let session = Session::builder().ranks(4).build().unwrap();
        let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
        let d = session.compile("ijk,ja,ka->ia", &shapes).unwrap();
        let b = session.compile_baseline("ijk,ja,ka->ia", &shapes).unwrap();
        assert!(!std::ptr::eq(d.plan(), b.plan()));
        assert_eq!(session.cache_stats().misses, 2);
        session.compile_baseline("ijk,ja,ka->ia", &shapes).unwrap();
        assert_eq!(session.cache_stats().hits, 1);
    }
}
