//! Poison-tolerant synchronization helpers.
//!
//! Every `Mutex` in this crate guards state whose invariants are
//! re-established at well-defined points (counters, free lists, caches
//! keyed by value), so a panic while holding the lock never leaves the
//! data structurally broken — only *stale*, which every consumer already
//! tolerates.  Propagating `std`'s poison flag would instead let one
//! contained panic (a per-request `catch_unwind` in the serving layer, a
//! worker that the supervisor is about to restart) cascade `unwrap`
//! panics into every unrelated tenant touching the same pool or cache.
//! These helpers recover the guard unconditionally.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// `m.lock()` that shrugs off poisoning instead of panicking.
#[inline]
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// `cv.wait(guard)` that shrugs off poisoning.
#[inline]
pub(crate) fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|p| p.into_inner())
}

/// `cv.wait_timeout(guard, dur)` that shrugs off poisoning.
#[inline]
pub(crate) fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(41u32));
        let m2 = Arc::clone(&m);
        // Poison the mutex by panicking while holding it.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        let mut g = lock(&m);
        *g += 1;
        assert_eq!(*g, 42, "state survives poison recovery");
    }

    #[test]
    fn wait_timeout_times_out_cleanly() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock(&m);
        let (_g, res) = wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
