//! # Deinsum — practically I/O optimal multilinear algebra
//!
//! Reproduction of *Deinsum: Practically I/O Optimal Multilinear Algebra*
//! (Ziogas et al., 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! For the end-to-end dataflow narrative (einsum → SOAP planning →
//! Session/Program → execution backends → serving) see
//! `docs/ARCHITECTURE.md` in the repository root; every environment
//! knob is tabulated in `docs/TUNING.md` (completeness CI-enforced).
//!
//! The front door is two types ([`api`]): a [`Session`] owning the
//! kernel engine and an LRU plan cache, and a [`Program`] — an einsum
//! **compiled once** into an I/O-optimal distributed schedule, owning
//! its persistent simulated machine and every recycled buffer, re-run
//! cheaply as many times as the workload needs (CP-ALS sweeps, serving
//! loops).  The paper's §II worked example, end to end:
//!
//! ```
//! use deinsum::{Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let shapes = vec![vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]];
//! let session = Session::builder().ranks(8).build()?;
//! let mut program = session.compile("ijk,ja,ka,al->il", &shapes)?;
//! println!("{}", program.schedule()); // the §II-E intermediate program
//! let inputs: Vec<Tensor> =
//!     shapes.iter().enumerate().map(|(i, s)| Tensor::random(s, i as u64)).collect();
//! let report = program.run(&inputs)?;
//! assert_eq!(report.output.dims(), &[10, 10]);
//! # Ok(())
//! # }
//! ```
//!
//! Compiling an identical spec again is a counted plan-cache hit
//! ([`Session::cache_stats`]) that skips planning; rerunning a program
//! recycles every buffer ([`Program::stats`], [`RunStats`]).
//!
//! Under the hood, `compile`/`run` drive the pipeline the modules
//! expose (the [`api`] module docs walk the old hand-wiring):
//!
//! 1. decompose the n-ary contraction into FLOP-minimizing binary
//!    operations ([`contraction`], paper §II-A);
//! 2. derive tight I/O lower bounds and the matching tile sizes with the
//!    SOAP combinatorial model ([`soap`], §IV), including the paper's
//!    headline MTTKRP bound `rho = S^(2/3)/3`;
//! 3. block-distribute iteration spaces onto Cartesian process grids with
//!    input replication over sub-grids ([`grid`], [`dist`], §II-D, §V-B);
//! 4. infer the communication to redistribute intermediates between grids
//!    ([`redist`], §V-C);
//! 5. plan ([`planner`]) and execute ([`coordinator`]) the distributed
//!    program on a simulated multi-rank machine ([`sim`]) whose local tile
//!    kernels are AOT-compiled JAX/Pallas artifacts run through PJRT
//!    ([`runtime`]) with native fallbacks ([`tensor`]).
//!
//! The CTF-like comparator the paper evaluates against lives in
//! [`baseline`] (compiled via [`Session::compile_baseline`]); the Table
//! IV/V benchmark suite in [`bench_support`].
//!
//! ## The local compute engine
//!
//! Once communication is I/O-optimal, end-to-end time is decided by the
//! arithmetic intensity of the local tile kernels (paper §III-B, §V).
//! The native kernels therefore run on a packed compute engine
//! ([`tensor::kernel`]):
//!
//! - **Packing**: GEMM-shaped work packs `A` into `MC×KC` panels of
//!   8-row strips and `B` into `KC×NC` panels of 8-column strips
//!   (BLIS/Goto layout), with ragged edges zero-padded inside the packs
//!   so the microkernel stays branch-free.
//! - **Microkernel**: an 8×8 register-tiled accumulator block carried
//!   across the full `KC` reduction; no data-dependent branches, so the
//!   compiler auto-vectorizes the FMA loop.
//! - **Threading**: the macro loops run on the persistent runtime (see
//!   below) over disjoint output bands/tiles.  Thread count honors
//!   `RAYON_NUM_THREADS` / `DEINSUM_NUM_THREADS`, defaulting to all
//!   cores.
//! - **Scratch reuse**: every packing/fold buffer comes from a
//!   size-classed [`ScratchPool`]; steady-state coordinator steps perform
//!   zero heap allocations for intermediates (the pool's `allocs`
//!   counter is flat after warmup — asserted in tests).
//!
//! Knobs live in [`KernelConfig`] (`mc`/`kc`/`nc`/`threads`, env
//! overrides `DEINSUM_MC`/`KC`/`NC`, or
//! [`SessionBuilder::kernel_config`]/[`SessionBuilder::threads`]), which
//! the PJRT/native dispatcher ([`runtime::KernelEngine`]) carries and the
//! run loop retargets per term from SOAP-optimal tile sizes
//! ([`KernelConfig::from_tiles`] via `TermPlan::kernel_config`).
//!
//! ## The persistent runtime
//!
//! Every parallel macro loop dispatches to a crate-wide **persistent
//! work-stealing pool** ([`runtime::pool`]) instead of spawning threads
//! per macro step: workers are created lazily, park on a condition
//! variable between jobs, and claim tasks from per-participant deques
//! with stealing, so ragged tiles rebalance and a parallel region costs
//! a wakeup rather than a spawn.  On top of it:
//!
//! - the packed GEMM packs each `KC×NC` B panel **once** into shared
//!   scratch (a cooperative pool region; the job-completion protocol is
//!   the publish/consume fence) and fans out stealable A-panel ×
//!   macro-tile tasks, splitting macro tiles column-wise when M alone
//!   cannot feed every worker — wide-N and skinny shapes both
//!   load-balance;
//! - the fused MTTKRP forms its KC×R Khatri-Rao tile once per column
//!   tile (its "B panel") and contracts stealable row bands against it;
//! - every [`Program`] holds its simulated [`sim::Machine`] across runs:
//!   staging and redistribution destinations are recycled from the
//!   previous run (`redist::execute_into`, [`sim::StoreStats`]
//!   counters), the allreduce reduces in place, and each term
//!   reconfigures the engine with its SOAP-derived tiles automatically;
//! - **compute outputs are recycled too**: every local kernel has a
//!   `*_into` variant writing through a caller-provided tensor
//!   (`contract::einsum2_into` / `contract::mttkrp_into`,
//!   `runtime::KernelEngine::einsum2_into` / `mttkrp_into`), the machine
//!   hands each rank a store-recycled destination
//!   ([`sim::Machine::compute_step_into`], `out_allocs`/`out_reuses`
//!   counters), Seq-kernel intermediates, **pre-reduction buffers for
//!   indices private to one operand** (`contract::reduce_modes_into` —
//!   what used to be the one documented allocating exception), and the
//!   MTTKRP output-order permute recycle through the run loop's
//!   per-`(term, slot)` scratch table
//!   ([`coordinator::LocalScratchStats`]), and local inputs are borrowed
//!   from the store instead of deep-copied per rank per step;
//! - [`Program::run_into`] writes the gathered output through a
//!   caller-recycled tensor (permuted gathers stage through recycled
//!   scratch), so the **entire** steady-state run performs zero tensor
//!   allocations.
//!
//! Per-element reduction orders are fixed by the serial panel walk, so
//! results are **bitwise identical across thread counts** (asserted in
//! tests).  Steady-state invariant, counter-asserted end to end
//! ([`RunStats::allocs`] flat): packing, folds, staging, redistribution,
//! compute outputs, Seq intermediates, pre-reductions, permutes and the
//! gather all come from recycled buffers.  `cargo bench --bench hotpath`
//! tracks the win as `coordinator_steady_state` (with `allocs_per_run`)
//! and the plan cache as `program_compile_cached` vs `program_compile_cold`
//! in `BENCH_hotpath.json`.
//!
//! ## Distributed execution backends
//!
//! The run loop is generic over the [`Executor`] trait ([`exec`]) — the
//! full plan-execution surface (staging, redistribution, local compute,
//! allreduce, gather, recycling counters).  Three backends implement it:
//!
//! - **`sim`** ([`ExecBackend::Sim`], the default): the in-process
//!   simulated machine — sequential ranks over a shared store, measured
//!   compute plus α–β-modeled communication, zero-allocation steady
//!   state (counter-asserted and CI-gated).
//! - **`mp`** ([`ExecBackend::Mp`]): a message-passing backend — one OS
//!   thread per rank, each owning only its local store slice, with
//!   every redistribution and allreduce payload moving rank-to-rank
//!   over channels.  The in-process rehearsal of a multi-node MPI run:
//!   protocol violations (dead rank, timed-out collective) surface as
//!   typed [`Error::Protocol`] values, never panics, and a poisoned
//!   executor is rebuilt on the next run.
//! - **`proc`** ([`ExecBackend::Proc`]): out-of-process rank sites —
//!   every rank is a `deinsum rank-worker` child process spawned over
//!   stdin/stdout pipes, or a pre-started TCP listener named by
//!   `DEINSUM_RANK_ADDR` (comma-separated `host:port`, one per rank in
//!   rank order; start listeners with
//!   `deinsum rank-worker --listen host:0`).  Coordinator and workers
//!   speak a versioned, length-prefixed wire format (magic + protocol
//!   version handshake; a version skew is a typed error, never a
//!   misparse), and every read and write carries a deadline —
//!   [`SessionBuilder::peer_timeout`] / `DEINSUM_PEER_TIMEOUT_MS`,
//!   shared with mp, default 60 s.  Failure semantics match mp: a dead
//!   worker, a blown deadline, or a malformed frame surfaces as typed
//!   [`Error::Protocol`] carrying the rank and instruction site, the
//!   executor poisons (`healthy() == false`), and the next run rebuilds
//!   it — respawning children (with bounded reconnect retries) or
//!   redialing the configured listeners.  `DEINSUM_WORKER_BIN`
//!   overrides worker-binary discovery when the coordinator is not the
//!   `deinsum` CLI itself.
//!
//! Select per session with [`SessionBuilder::backend`], or process-wide
//! with `DEINSUM_BACKEND=mp|proc` (how CI runs the whole suite on the
//! mp and proc backends).  **Determinism contract**: block cuts,
//! accumulation orders, and per-term kernel configs are fixed by the
//! plan — never by the backend — so outputs are bitwise identical
//! across all three backends (pinned at P ∈ {1, 4, 8} in
//! `tests/backends.rs`):
//!
//! ```no_run
//! use deinsum::{ExecBackend, Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
//! let inputs: Vec<Tensor> =
//!     shapes.iter().enumerate().map(|(i, s)| Tensor::random(s, i as u64)).collect();
//! let mut outputs = Vec::new();
//! for backend in [ExecBackend::Sim, ExecBackend::Mp, ExecBackend::Proc] {
//!     let session = Session::builder().ranks(4).backend(backend).build()?;
//!     let mut program = session.compile("ijk,ja,ka->ia", &shapes)?;
//!     outputs.push(program.run(&inputs)?.output);
//! }
//! assert!(outputs[0].allclose(&outputs[1], 0.0, 0.0)); // bitwise identical
//! assert!(outputs[0].allclose(&outputs[2], 0.0, 0.0)); // ...across the process boundary too
//! # Ok(())
//! # }
//! ```
//!
//! (`no_run` because the proc leg spawns `deinsum rank-worker`
//! children, and rustdoc builds doctests outside the target directory
//! where worker-binary discovery looks; the executed equivalents —
//! including the bitwise pins — live in `tests/backends.rs`.)
//!
//! ## Serving
//!
//! Since 0.6.0 the handles are thread-safe (`Session: Send + Sync`,
//! `Program: Send` — the engine's config override is thread-local, its
//! scratch pool locks per size class, and plans are shared by `Arc`), so
//! many programs compiled from one session can run on concurrent
//! threads with bitwise-identical results.  The [`serve`] module builds
//! the multi-tenant layer on top: a [`Server`] with a fixed worker pool,
//! bounded per-worker queues, key-affinity routing that **coalesces**
//! identical `(expr, shapes)` traffic onto one warm program, and
//! per-tenant [`ServeStats`] (queue depth, p50/p99 latency, throughput,
//! warm-program hit rate).  A request moves its output buffer in and
//! gets it back filled — the recycled `run_into` path — so steady-state
//! serving performs zero tensor allocations per request:
//!
//! ```
//! use std::sync::Arc;
//! use deinsum::{ServeRequest, Server, Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let session = Session::builder().ranks(4).build()?;
//! let server = Server::builder(session).workers(2).build();
//! let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
//! let inputs: Vec<Tensor> =
//!     shapes.iter().enumerate().map(|(i, s)| Tensor::random(s, i as u64)).collect();
//! let ticket = server.submit(ServeRequest {
//!     tenant: "tenant-a".into(),
//!     expr: "ijk,ja,ka->ia".into(),
//!     shapes: shapes.clone(),
//!     inputs: Arc::new(inputs),
//!     dest: Tensor::zeros(&Server::output_dims("ijk,ja,ka->ia", &shapes)?),
//! })?;
//! let reply = ticket.wait()?;
//! assert_eq!(reply.output.dims(), &[12, 4]);
//! assert_eq!(server.tenant_stats("tenant-a").unwrap().completed, 1);
//! # Ok(())
//! # }
//! ```
//!
//! Since 0.9.0 a coalesced same-key batch is **fused into one batched
//! execution**: the worker drains the head request plus queued
//! same-key followers and drives them through
//! [`Program::run_batch_into`] — per-term engine configuration done
//! once for the whole batch, shared-`Arc` operands staged once, and
//! per-member outputs written through each request's own recycled
//! destination.  Batched results are **bitwise identical** to serving
//! the same requests back-to-back (same plan, same accumulation
//! orders — asserted on every backend in `tests/serving.rs`), replies
//! are fulfilled per ticket, and a shape-invalid member fails typed
//! without poisoning its batch-mates.  [`ServeStats::batched`] counts
//! fused members.  The batch entry is a first-class `Program` surface,
//! usable without a server:
//!
//! ```
//! use deinsum::{BatchRun, Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
//! let session = Session::builder().ranks(4).build()?;
//! let mut program = session.compile("ijk,ja,ka->ia", &shapes)?;
//! // Two requests' operands and recycled destinations, one fused run.
//! let a: Vec<Tensor> =
//!     shapes.iter().enumerate().map(|(i, s)| Tensor::random(s, i as u64)).collect();
//! let b: Vec<Tensor> =
//!     shapes.iter().enumerate().map(|(i, s)| Tensor::random(s, 10 + i as u64)).collect();
//! let (mut out_a, mut out_b) =
//!     (Tensor::zeros(&program.output_dims()), Tensor::zeros(&program.output_dims()));
//! let mut members = vec![BatchRun::new(&a, &mut out_a), BatchRun::new(&b, &mut out_b)];
//! let results = program.run_batch_into(&mut members)?;
//! assert!(results.iter().all(|r| r.is_ok())); // one typed Result per member
//! assert_eq!(program.stats().batch_members, 2);
//! # Ok(())
//! # }
//! ```
//!
//! `cargo bench --bench hotpath` tracks serving throughput as
//! `serve_throughput_1w` / `serve_throughput_8w` plus the single-key
//! fused leg `serve_throughput_batched`, and `examples/serving.rs`
//! drives a closed-loop mixed MTTKRP/TTMc load.
//!
//! ## Robustness
//!
//! Since 0.7.0 the serving stack treats failure as traffic with a typed
//! answer at every layer, and every accepted ticket **resolves** —
//! filled or failed, never hung:
//!
//! - **Admission control**: [`Server::try_submit`] sheds on a full
//!   queue with [`Error::QueueFull`] instead of blocking, and
//!   [`Server::submit_with_deadline`] bounds both the backpressure wait
//!   and the request's queue residency with
//!   [`Error::DeadlineExceeded`].  A shut-down server answers
//!   [`Error::ServerShutdown`].
//! - **Bounded waits**: [`Ticket::wait_timeout`] gives up after a bound
//!   with [`Error::DeadlineExceeded`]; the worker still fulfills the
//!   abandoned slot, so nothing leaks.
//! - **Containment, retry, supervision**: planner/kernel panics are
//!   contained to the request; transient failures
//!   ([`Error::is_retryable`]) are retried with exponential backoff up
//!   to [`ServerBuilder::max_retries`]; a worker that dies outside
//!   containment is restarted by a supervisor with a fresh warm-program
//!   LRU, its in-flight requests requeued or failed with
//!   [`Error::WorkerLost`].  [`ServeStats`] exposes the
//!   `shed`/`timeouts`/`retries`/`restarts` counters.
//! - **Rehearsal**: the deterministic [`fault`] injection seam
//!   ([`FaultPlan`], threaded via [`SessionBuilder::fault_plan`] /
//!   `ServerBuilder::fault_plan`, env-armed by `DEINSUM_FAULT_SEED`)
//!   drives every recovery path in `tests/faults.rs` and a CI chaos
//!   leg.  Library mutexes are poison-tolerant throughout: a contained
//!   panic never wedges an unrelated thread on a poisoned lock.
//!
//! ```
//! use std::sync::Arc;
//! use std::time::Duration;
//! use deinsum::{Error, ServeRequest, Server, Session, Tensor};
//! # fn main() -> deinsum::Result<()> {
//! let session = Session::builder().ranks(2).build()?;
//! let server = Server::builder(session).workers(1).build();
//! let shapes = vec![vec![8, 6], vec![6, 4]];
//! let request = ServeRequest {
//!     tenant: "latency-sensitive".into(),
//!     expr: "ij,jk->ik".into(),
//!     shapes: shapes.clone(),
//!     inputs: Arc::new(vec![Tensor::random(&[8, 6], 1), Tensor::random(&[6, 4], 2)]),
//!     dest: Tensor::zeros(&Server::output_dims("ij,jk->ik", &shapes)?),
//! };
//! // Non-blocking admission + bounded wait: every outcome is typed.
//! match server.try_submit(request) {
//!     Ok(ticket) => match ticket.wait_timeout(Duration::from_secs(30)) {
//!         Ok(reply) => assert_eq!(reply.output.dims(), &[8, 4]),
//!         Err(Error::DeadlineExceeded) => { /* give up; the worker still resolves the slot */ }
//!         Err(e) => return Err(e),
//!     },
//!     Err(Error::QueueFull) => { /* shed: back off and resubmit later */ }
//!     Err(e) => return Err(e),
//! }
//! # Ok(())
//! # }
//! ```
//!
//! ## Correctness & fuzzing
//!
//! The compile pipeline is held to one invariant, enforced by a
//! deterministic differential fuzzer ([`fuzz`]): **every generated
//! einsum either plans and runs bitwise-identical to a naive dense
//! oracle, or is rejected with a typed [`Error`] — never a panic, at
//! any rank count.**  The harness generates random einsum chains (2–5
//! operands, shared/permuted/reduced indices, degenerate extents 0 and
//! 1, skinny/fat aspect ratios) from a SplitMix64 stream, evaluates
//! each with an independent odometer loop nest (no shared kernel code),
//! and compares against `Session::compile` + `run`/`run_into` (dirty
//! recycled destinations) at rank counts {1, 4, 8}.  Inputs are small
//! integers, so f32 arithmetic is exact and "bitwise identical" holds
//! across any summation order.  Rejections must be deterministic across
//! reruns and thread counts, and never retryable.
//!
//! Run a local campaign with the CLI:
//!
//! ```text
//! deinsum fuzz --seed 20260808 --cases 500 --ranks 1,4,8
//! ```
//!
//! Any BUG (panic or oracle mismatch) is greedily shrunk — drop
//! operands, drop indices, halve extents — and reported with a
//! one-line repro; re-running with those env vars regenerates the
//! failing case:
//!
//! ```text
//! DEINSUM_FUZZ_SEED=<n> DEINSUM_FUZZ_CASE=<k> deinsum fuzz
//! ```
//!
//! CI runs a fixed-seed 500-case campaign on the 8-thread leg and
//! uploads the shrunk repro corpus as an artifact on failure;
//! `tests/fuzz.rs` pins a 64-case corpus, rejection determinism, and
//! the shrinker contract.

// Every public item must carry documentation; CI's docs job promotes
// this to a hard error (`RUSTDOCFLAGS: -D missing_docs`).
#![warn(missing_docs)]

pub mod api;
pub mod baseline;
pub mod bench_support;
pub mod contraction;
pub mod coordinator;
pub mod dist;
pub mod einsum;
pub mod error;
pub mod exec;
pub mod fault;
pub mod fuzz;
pub mod grid;
pub mod planner;
pub mod redist;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod soap;
mod sync;
pub mod tensor;

pub use api::{PlanCacheStats, Program, RunStats, Session, SessionBuilder};
pub use coordinator::{BatchRun, RunMetrics, RunReport};
pub use error::{Error, Result};
pub use exec::{rank_worker, ExecBackend, Executor};
pub use fault::{FaultKind, FaultPlan};
pub use serve::{ServeReply, ServeRequest, ServeStats, Server, ServerBuilder, Ticket};
pub use tensor::kernel::{KernelConfig, ScratchPool, ScratchStats};
pub use tensor::Tensor;
