//! # Deinsum — practically I/O optimal multilinear algebra
//!
//! Reproduction of *Deinsum: Practically I/O Optimal Multilinear Algebra*
//! (Ziogas et al., 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! Given an arbitrary einsum over dense tensors, the library:
//!
//! 1. decomposes the n-ary contraction into FLOP-minimizing binary
//!    operations ([`contraction`], paper §II-A);
//! 2. derives tight I/O lower bounds and the matching tile sizes with the
//!    SOAP combinatorial model ([`soap`], §IV), including the paper's
//!    headline MTTKRP bound `rho = S^(2/3)/3`;
//! 3. block-distributes iteration spaces onto Cartesian process grids with
//!    input replication over sub-grids ([`grid`], [`dist`], §II-D, §V-B);
//! 4. infers the communication to redistribute intermediates between grids
//!    ([`redist`], §V-C);
//! 5. plans ([`planner`]) and executes ([`coordinator`]) the distributed
//!    program on a simulated multi-rank machine ([`sim`]) whose local tile
//!    kernels are AOT-compiled JAX/Pallas artifacts run through PJRT
//!    ([`runtime`]) with native fallbacks ([`tensor`]).
//!
//! The CTF-like comparator the paper evaluates against lives in
//! [`baseline`]; the Table IV/V benchmark suite in [`bench_support`].
//!
//! ## The local compute engine
//!
//! Once communication is I/O-optimal, end-to-end time is decided by the
//! arithmetic intensity of the local tile kernels (paper §III-B, §V).
//! The native kernels therefore run on a packed compute engine
//! ([`tensor::kernel`]):
//!
//! - **Packing**: GEMM-shaped work packs `A` into `MC×KC` panels of
//!   8-row strips and `B` into `KC×NC` panels of 8-column strips
//!   (BLIS/Goto layout), with ragged edges zero-padded inside the packs
//!   so the microkernel stays branch-free.
//! - **Microkernel**: an 8×8 register-tiled accumulator block carried
//!   across the full `KC` reduction; no data-dependent branches, so the
//!   compiler auto-vectorizes the FMA loop.
//! - **Threading**: the M macro-loop (and the transpose / fused-MTTKRP
//!   unit spaces) split across `std::thread::scope` workers operating on
//!   disjoint output bands.  Thread count honors `RAYON_NUM_THREADS` /
//!   `DEINSUM_NUM_THREADS`, defaulting to all cores.
//! - **Scratch reuse**: every packing/fold buffer comes from a
//!   size-classed [`ScratchPool`]; steady-state coordinator steps perform
//!   zero heap allocations for intermediates (the pool's `allocs`
//!   counter is flat after warmup — asserted in tests).
//!
//! Knobs live in [`KernelConfig`] (`mc`/`kc`/`nc`/`threads`, env
//! overrides `DEINSUM_MC`/`KC`/`NC`), which the PJRT/native dispatcher
//! ([`runtime::KernelEngine`]) carries and the planner can derive from
//! SOAP-optimal tile sizes via [`KernelConfig::from_tiles`].

pub mod baseline;
pub mod bench_support;
pub mod contraction;
pub mod coordinator;
pub mod dist;
pub mod einsum;
pub mod error;
pub mod grid;
pub mod planner;
pub mod redist;
pub mod runtime;
pub mod sim;
pub mod soap;
pub mod tensor;

pub use error::{Error, Result};
pub use tensor::kernel::{KernelConfig, ScratchPool, ScratchStats};
pub use tensor::Tensor;
