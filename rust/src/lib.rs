//! # Deinsum — practically I/O optimal multilinear algebra
//!
//! Reproduction of *Deinsum: Practically I/O Optimal Multilinear Algebra*
//! (Ziogas et al., 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! Given an arbitrary einsum over dense tensors, the library:
//!
//! 1. decomposes the n-ary contraction into FLOP-minimizing binary
//!    operations ([`contraction`], paper §II-A);
//! 2. derives tight I/O lower bounds and the matching tile sizes with the
//!    SOAP combinatorial model ([`soap`], §IV), including the paper's
//!    headline MTTKRP bound `rho = S^(2/3)/3`;
//! 3. block-distributes iteration spaces onto Cartesian process grids with
//!    input replication over sub-grids ([`grid`], [`dist`], §II-D, §V-B);
//! 4. infers the communication to redistribute intermediates between grids
//!    ([`redist`], §V-C);
//! 5. plans ([`planner`]) and executes ([`coordinator`]) the distributed
//!    program on a simulated multi-rank machine ([`sim`]) whose local tile
//!    kernels are AOT-compiled JAX/Pallas artifacts run through PJRT
//!    ([`runtime`]) with native fallbacks ([`tensor`]).
//!
//! The CTF-like comparator the paper evaluates against lives in
//! [`baseline`]; the Table IV/V benchmark suite in [`bench_support`].

pub mod baseline;
pub mod bench_support;
pub mod contraction;
pub mod coordinator;
pub mod dist;
pub mod einsum;
pub mod error;
pub mod grid;
pub mod planner;
pub mod redist;
pub mod runtime;
pub mod sim;
pub mod soap;
pub mod tensor;

pub use error::{Error, Result};
pub use tensor::Tensor;
