//! `deinsum` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   plan  <einsum> --shapes 64x64x64,64x24,64x24 [--ranks P]   print the schedule (§II-E)
//!   run   <einsum> --shapes ... [--ranks P] [--backend sim|mp|proc]
//!                                                              execute on a backend (default:
//!                                                              DEINSUM_BACKEND, else sim)
//!   bench [--ranks P] [--size-factor F] [--filter NAME] [--backend sim|mp|proc]
//!                                                              Table IV suite, Fig. 5 rows
//!   bounds [--s S]                                             §IV-E I/O lower bounds
//!   fuzz  [--seed N] [--cases N] [--ranks 1,4,8] [--corpus F]  differential campaign vs the
//!                                                              dense oracle (src/fuzz);
//!                                                              DEINSUM_FUZZ_SEED/_CASE set =
//!                                                              single-case repro mode
//!   rank-worker [--listen HOST:PORT]                           serve one rank of the proc
//!                                                              backend: over stdin/stdout
//!                                                              (spawned by a coordinator) or
//!                                                              as a TCP listener for
//!                                                              DEINSUM_RANK_ADDR peers
//!
//! All einsum work goes through the [`Session`]/`Program` front door
//! (`--artifacts DIR` serves local kernels from PJRT, degrading to the
//! native engine with a warning).  CLI parsing is hand-rolled (no clap
//! in the offline vendored registry).

use std::process::ExitCode;

use deinsum::bench_support::{self, header, row};
use deinsum::fuzz;
use deinsum::soap::{self, Statement};
use deinsum::tensor::Tensor;
use deinsum::{ExecBackend, Session};

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>, String> {
    s.split(',')
        .map(|shape| {
            shape
                .split('x')
                .map(|d| d.parse::<usize>().map_err(|e| format!("bad dim '{d}': {e}")))
                .collect()
        })
        .collect()
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < argv.len() {
        if let Some(name) = argv[i].strip_prefix("--") {
            let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 1;
                argv[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(argv[i].clone());
        }
        i += 1;
    }
    Args { positional, flags }
}

fn ranks_flag(args: &Args) -> usize {
    args.flags.get("ranks").map(|s| s.parse().unwrap_or(8)).unwrap_or(8)
}

fn backend_flag(args: &Args) -> Result<Option<ExecBackend>, String> {
    match args.flags.get("backend").map(String::as_str) {
        None => Ok(None),
        Some("sim") => Ok(Some(ExecBackend::Sim)),
        Some("mp") => Ok(Some(ExecBackend::Mp)),
        Some("proc") => Ok(Some(ExecBackend::Proc)),
        Some(other) => Err(format!("bad --backend '{other}' (expected sim|mp|proc)")),
    }
}

fn session_from_flags(args: &Args) -> Result<Session, String> {
    let mut b = Session::builder().ranks(ranks_flag(args));
    if let Some(dir) = args.flags.get("artifacts") {
        b = b.artifacts(dir);
    }
    if let Some(backend) = backend_flag(args)? {
        b = b.backend(backend);
    }
    Ok(b.build_or_native())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!(
            "usage: deinsum <plan|run|bench|bounds|fuzz|rank-worker> [args]  (see README)"
        );
        return ExitCode::FAILURE;
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    let res = match cmd.as_str() {
        "plan" => cmd_plan(&args),
        "run" => cmd_run(&args),
        "bench" => cmd_bench(&args),
        "bounds" => cmd_bounds(&args),
        "fuzz" => cmd_fuzz(&args),
        "rank-worker" => cmd_rank_worker(&args),
        other => Err(format!("unknown command '{other}'")),
    };
    match res {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_plan(args: &Args) -> Result<(), String> {
    let expr = args.positional.first().ok_or("missing einsum string")?;
    let shapes = parse_shapes(args.flags.get("shapes").ok_or("--shapes required")?)?;
    // Planning needs no kernel engine: skip the artifacts flag (and any
    // PJRT-load warning) and compile on a plain native session.
    let session = Session::builder().ranks(ranks_flag(args)).build_or_native();
    let program = session.compile(expr, &shapes).map_err(|e| e.to_string())?;
    println!("{}", program.schedule());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let expr = args.positional.first().ok_or("missing einsum string")?;
    let shapes = parse_shapes(args.flags.get("shapes").ok_or("--shapes required")?)?;
    let session = session_from_flags(args)?;
    let mut program = session.compile(expr, &shapes).map_err(|e| e.to_string())?;
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 7 + i as u64))
        .collect();
    let rep = program.run(&inputs).map_err(|e| e.to_string())?;
    println!("output {:?}  |out| = {:.6e}", rep.output.dims(), rep.output.norm());
    println!(
        "time: compute {:.6}s + comm {:.6}s = {:.6}s",
        rep.time.compute,
        rep.time.comm,
        rep.time.total()
    );
    println!(
        "comm: {} p2p msgs, {} p2p bytes, {} allreduces, {} allreduce bytes",
        rep.comm.p2p_msgs, rep.comm.p2p_bytes, rep.comm.allreduces, rep.comm.allreduce_bytes
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let p = ranks_flag(args);
    let sf: usize =
        args.flags.get("size-factor").map(|s| s.parse().unwrap_or(16)).unwrap_or(16);
    let filter = args.flags.get("filter").cloned().unwrap_or_default();
    let session = session_from_flags(args)?;
    println!("{}", header());
    let mut points = Vec::new();
    for def in bench_support::suite(sf) {
        if !filter.is_empty() && !def.name.contains(&filter) {
            continue;
        }
        let (pt, _, _) =
            bench_support::run_point(&def, p, &session).map_err(|e| e.to_string())?;
        println!("{}", row(&pt));
        points.push(pt);
    }
    println!("geomean speedup: {:.2}x", bench_support::geomean(&points));
    Ok(())
}

fn cmd_fuzz(args: &Args) -> Result<(), String> {
    let ranks: Vec<usize> = match args.flags.get("ranks") {
        Some(s) => s
            .split(',')
            .map(|r| r.parse::<usize>().map_err(|e| format!("bad rank '{r}': {e}")))
            .collect::<Result<_, _>>()?,
        None => fuzz::DEFAULT_RANKS.to_vec(),
    };
    if ranks.is_empty() || ranks.contains(&0) {
        return Err("--ranks needs a comma-separated list of positive rank counts".into());
    }

    // Repro mode: DEINSUM_FUZZ_SEED / DEINSUM_FUZZ_CASE (the pair a
    // shrunk corpus prints) pin one generated case instead of a sweep.
    if let Some(case) = fuzz::env_case() {
        println!("repro {}: {} shapes {:?}", case.repro(), case.expr, case.shapes);
        let outcome = fuzz::classify(&case, &ranks);
        println!("{}", outcome.signature());
        return if outcome.is_bug() {
            Err(format!("BUG reproduced: {}", outcome.signature()))
        } else {
            Ok(())
        };
    }

    let seed: u64 = match args.flags.get("seed") {
        Some(s) => s.parse().map_err(|e| format!("bad --seed '{s}': {e}"))?,
        None => 20260808,
    };
    let cases: u64 = match args.flags.get("cases") {
        Some(s) => s.parse().map_err(|e| format!("bad --cases '{s}': {e}"))?,
        None => 500,
    };
    let report = fuzz::campaign(seed, cases, &ranks);
    println!(
        "fuzz seed {seed}: {} cases at ranks {ranks:?} — {} oracle-identical, {} typed-reject, {} bugs",
        report.cases,
        report.matches,
        report.rejects,
        report.bugs.len()
    );
    for b in &report.bugs {
        eprintln!("BUG: {}", b.detail);
        eprintln!("  original: {} shapes {:?}", b.case.expr, b.case.shapes);
        eprintln!("  shrunk:   {} shapes {:?}", b.shrunk.expr, b.shrunk.shapes);
        eprintln!("  repro:    {}", b.case.repro());
    }
    // The corpus (clean summary or shrunk repro blocks) is written even
    // on failure — CI uploads it as the campaign artifact.
    if let Some(path) = args.flags.get("corpus") {
        std::fs::write(path, report.corpus()).map_err(|e| format!("write {path}: {e}"))?;
        println!("# wrote {path}");
    }
    if report.bugs.is_empty() {
        Ok(())
    } else {
        Err(format!("{} BUG case(s) — shrunk repros above", report.bugs.len()))
    }
}

fn cmd_rank_worker(args: &Args) -> Result<(), String> {
    // stdout is the wire in pipe mode: nothing else may print there.
    let listen = args.flags.get("listen").map(String::as_str);
    deinsum::rank_worker(listen).map_err(|e| e.to_string())
}

fn cmd_bounds(args: &Args) -> Result<(), String> {
    let s: f64 = args.flags.get("s").map(|x| x.parse().unwrap_or(1e6)).unwrap_or(1e6);
    println!("S = {s:.3e} elements (fast memory)");
    let gemm = Statement::gemm(1e12, 1e12, 1e12).io_bound(s);
    println!(
        "GEMM:   rho = {:.4e}  (closed form sqrt(S)/2 = {:.4e}), X0 = {:.4e} (3S = {:.4e})",
        gemm.rho,
        soap::gemm_rho_closed_form(s),
        gemm.x0,
        3.0 * s
    );
    let mt = Statement::mttkrp3(1e12, 1e12, 1e12, 1e12).io_bound(s);
    println!(
        "MTTKRP: rho = {:.4e}  (paper S^(2/3)/3  = {:.4e}), X0 = {:.4e} (5S/2 = {:.4e})",
        mt.rho,
        soap::mttkrp_rho_closed_form(s),
        mt.x0,
        2.5 * s
    );
    println!(
        "MTTKRP improvement over Ballard et al.: {:.2}x (paper: 3^(5/3) ~ 6.24x)",
        soap::mttkrp_improvement_factor()
    );
    Ok(())
}
