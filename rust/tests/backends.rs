//! Cross-backend execution pins (tier-1): the simulated machine and the
//! message-passing backend must produce **bitwise identical** outputs
//! for the same plan and inputs — block cuts, accumulation orders, and
//! per-term kernel configs are fixed by the plan, never by the backend.
//!
//! Every pin runs `run` plus a dirty-destination `run_into` on both
//! backends at several rank counts, including the paper's kernels
//! (MTTKRP, TTMc), a permuted gather, an allreduce-bearing two-term
//! split, and degenerate distributions (P=1 grids, extent-0/extent-1
//! blocks, edge-rank clipped padding surviving dirty store recycling).

use deinsum::planner::PlannerConfig;
use deinsum::{ExecBackend, Session, Tensor};

/// Compile + `run` + dirty-destination `run_into` on one backend.
fn run_once(
    expr: &str,
    shapes: &[Vec<usize>],
    p: usize,
    cfg: PlannerConfig,
    backend: ExecBackend,
    inputs: &[Tensor],
) -> deinsum::Result<Tensor> {
    let session = Session::builder()
        .ranks(p)
        .planner(cfg)
        .backend(backend)
        .build()?;
    let mut prog = session.compile(expr, shapes)?;
    let rep = prog.run(inputs)?;
    // Dirty recycled destination: run_into must fully overwrite.
    let mut dest = Tensor::random(&prog.output_dims(), 0x0D15_EA5E);
    prog.run_into(inputs, &mut dest)?;
    assert!(
        rep.output.allclose(&dest, 0.0, 0.0),
        "{expr} P={p} {}: run vs dirty run_into must be bitwise identical",
        backend.name()
    );
    Ok(rep.output)
}

/// Run `expr` on both backends at `p` ranks: either both accept — and
/// their outputs are bitwise identical — or both reject with the same
/// typed error message.  Returns the output when accepted.
fn pin_bitwise_or_reject(
    expr: &str,
    shapes: &[Vec<usize>],
    p: usize,
    cfg: PlannerConfig,
) -> Option<Tensor> {
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 1000 + i as u64))
        .collect();
    let sim = run_once(expr, shapes, p, cfg, ExecBackend::Sim, &inputs);
    let mp = run_once(expr, shapes, p, cfg, ExecBackend::Mp, &inputs);
    match (sim, mp) {
        (Ok(a), Ok(b)) => {
            assert!(
                a.allclose(&b, 0.0, 0.0),
                "{expr} P={p}: sim vs mp must be bitwise identical"
            );
            Some(b)
        }
        (Err(a), Err(b)) => {
            assert_eq!(
                a.to_string(),
                b.to_string(),
                "{expr} P={p}: backends must reject identically"
            );
            None
        }
        (sim, mp) => panic!(
            "{expr} P={p}: backends disagree on acceptance (sim: {:?}, mp: {:?})",
            sim.map(|_| "accepted").map_err(|e| e.to_string()),
            mp.map(|_| "accepted").map_err(|e| e.to_string()),
        ),
    }
}

/// [`pin_bitwise_or_reject`] for expressions that must be accepted.
fn pin_bitwise(expr: &str, shapes: &[Vec<usize>], p: usize, cfg: PlannerConfig) -> Tensor {
    pin_bitwise_or_reject(expr, shapes, p, cfg)
        .unwrap_or_else(|| panic!("{expr} P={p}: expected both backends to accept"))
}

#[test]
fn mttkrp_bitwise_across_backends() {
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijk,ja,ka->ia",
            &[vec![16, 20, 12], vec![20, 6], vec![12, 6]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn ttmc_bitwise_across_backends() {
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijklm,jb,kc,ld,me->ibcde",
            &[vec![8, 6, 6, 6, 6], vec![6, 3], vec![6, 3], vec![6, 3], vec![6, 3]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn permuted_gather_bitwise_across_backends() {
    // Output order 'ai' differs from the MTTKRP kernel's natural
    // (mode, r) order, forcing the permuted-gather staging path.
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijk,ja,ka->ai",
            &[vec![16, 20, 12], vec![20, 6], vec![12, 6]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn allreduce_and_redistribution_bitwise_across_backends() {
    // A small analysis S forces the two-term [MTTKRP, MM] split: the
    // plan carries an inter-term redistribution, and the term grids
    // reduce over sub-grids (real allreduce traffic on the mp backend).
    let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijk,ja,ka,al->il",
            &[vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]],
            p,
            cfg,
        );
    }
}

#[test]
fn degenerate_extents_bitwise_across_backends() {
    // Extent-1 and extent-0 blocks through staging, redistribution and
    // gather: the degenerate distributions the fuzzer generates, pinned
    // on both backends at P=1 (trivial grids) and P ∈ {4, 8}.
    for p in [1, 4, 8] {
        pin_bitwise(
            "ij,jk->ik",
            &[vec![1, 5], vec![5, 1]],
            p,
            PlannerConfig::default(),
        );
        // Extent 0: accepted with an empty output, or rejected typed —
        // but identically on both backends.
        if let Some(empty) = pin_bitwise_or_reject(
            "ij,jk->ik",
            &[vec![0, 4], vec![4, 3]],
            p,
            PlannerConfig::default(),
        ) {
            assert_eq!(empty.dims(), &[0, 3]);
        }
        pin_bitwise(
            "ijk,ja,ka->ia",
            &[vec![4, 1, 3], vec![1, 2], vec![3, 2]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn edge_rank_clipped_padding_survives_dirty_recycling() {
    // Prime-ish extents leave the edge ranks with clipped blocks whose
    // buffers carry zero padding; reruns recycle those buffers dirty, so
    // the padding must be re-established every run on both backends.
    let shapes = [vec![9, 7, 5], vec![7, 3], vec![5, 3]];
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 42 + i as u64))
        .collect();
    let mut outputs: Vec<Tensor> = Vec::new();
    for backend in [ExecBackend::Sim, ExecBackend::Mp] {
        let session =
            Session::builder().ranks(8).backend(backend).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka->ia", &shapes).unwrap();
        let first = prog.run(&inputs).unwrap().output;
        for run in 0u64..3 {
            let mut dest = Tensor::random(&prog.output_dims(), 7 + run);
            prog.run_into(&inputs, &mut dest).unwrap();
            assert!(
                first.allclose(&dest, 0.0, 0.0),
                "{}: rerun {run} over dirty recycled buffers must be bitwise stable",
                backend.name()
            );
        }
        outputs.push(first);
    }
    assert!(outputs[0].allclose(&outputs[1], 0.0, 0.0), "sim vs mp");
}

#[test]
fn mp_tensor_counters_stay_flat_across_reruns() {
    // The mp backend is not zero-alloc asserted at the engine-pool level
    // (rank kernels hit the shared pool concurrently), but its
    // tensor-level counters — per-rank store destinations, compute
    // outputs, local scratch — must go flat once warm, same as sim.
    let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
    let shapes = [vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]];
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 9 + i as u64))
        .collect();
    let session = Session::builder()
        .ranks(8)
        .planner(cfg)
        .backend(ExecBackend::Mp)
        .build()
        .unwrap();
    let mut prog = session.compile("ijk,ja,ka,al->il", &shapes).unwrap();
    assert!(!prog.plan().moves.is_empty(), "want redistribution in the plan");
    let first = prog.run(&inputs).unwrap();
    prog.run(&inputs).unwrap();
    let warm = prog.stats();
    assert!(warm.store.dest_allocs > 0);
    assert!(warm.store.out_allocs > 0);
    for _ in 0..3 {
        let rep = prog.run(&inputs).unwrap();
        assert!(rep.output.allclose(&first.output, 0.0, 0.0));
    }
    let after = prog.stats();
    assert_eq!(
        after.tensor_allocs(),
        warm.tensor_allocs(),
        "warm mp reruns must not allocate store/scratch tensors ({warm:?} -> {after:?})"
    );
    assert!(after.store.dest_reuses > warm.store.dest_reuses);
    assert!(after.store.out_reuses > warm.store.out_reuses);
}
