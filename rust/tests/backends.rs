//! Cross-backend execution pins (tier-1): the simulated machine, the
//! message-passing backend, and the out-of-process backend must produce
//! **bitwise identical** outputs for the same plan and inputs — block
//! cuts, accumulation orders, and per-term kernel configs are fixed by
//! the plan, never by the backend.
//!
//! Every pin runs `run` plus a dirty-destination `run_into` on all
//! three backends at several rank counts, including the paper's kernels
//! (MTTKRP, TTMc), a permuted gather, an allreduce-bearing two-term
//! split, and degenerate distributions (P=1 grids, extent-0/extent-1
//! blocks, edge-rank clipped padding surviving dirty store recycling).
//! The proc backend additionally pins its failure semantics: a killed
//! rank-worker process yields a typed error (no hang, no panic) and the
//! run loop's rebuild seam reconnects on the next run.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use deinsum::planner::PlannerConfig;
use deinsum::{Error, ExecBackend, Session, Tensor};

/// Every executor backend, in comparison order (sim is the anchor).
const BACKENDS: [ExecBackend; 3] =
    [ExecBackend::Sim, ExecBackend::Mp, ExecBackend::Proc];

/// Compile + `run` + dirty-destination `run_into` on one backend.
fn run_once(
    expr: &str,
    shapes: &[Vec<usize>],
    p: usize,
    cfg: PlannerConfig,
    backend: ExecBackend,
    inputs: &[Tensor],
) -> deinsum::Result<Tensor> {
    let session = Session::builder()
        .ranks(p)
        .planner(cfg)
        .backend(backend)
        .build()?;
    let mut prog = session.compile(expr, shapes)?;
    let rep = prog.run(inputs)?;
    // Dirty recycled destination: run_into must fully overwrite.
    let mut dest = Tensor::random(&prog.output_dims(), 0x0D15_EA5E);
    prog.run_into(inputs, &mut dest)?;
    assert!(
        rep.output.allclose(&dest, 0.0, 0.0),
        "{expr} P={p} {}: run vs dirty run_into must be bitwise identical",
        backend.name()
    );
    Ok(rep.output)
}

/// Run `expr` on every backend at `p` ranks: either all accept — and
/// their outputs are bitwise identical to the simulator's — or all
/// reject with the same typed error message.  Returns the output when
/// accepted.
fn pin_bitwise_or_reject(
    expr: &str,
    shapes: &[Vec<usize>],
    p: usize,
    cfg: PlannerConfig,
) -> Option<Tensor> {
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 1000 + i as u64))
        .collect();
    let sim = run_once(expr, shapes, p, cfg, ExecBackend::Sim, &inputs);
    for backend in [ExecBackend::Mp, ExecBackend::Proc] {
        let other = run_once(expr, shapes, p, cfg, backend, &inputs);
        match (&sim, other) {
            (Ok(a), Ok(b)) => assert!(
                a.allclose(&b, 0.0, 0.0),
                "{expr} P={p}: sim vs {} must be bitwise identical",
                backend.name()
            ),
            (Err(a), Err(b)) => assert_eq!(
                a.to_string(),
                b.to_string(),
                "{expr} P={p}: sim vs {} must reject identically",
                backend.name()
            ),
            (sim, other) => panic!(
                "{expr} P={p}: backends disagree on acceptance (sim: {:?}, {}: {:?})",
                sim.as_ref().map(|_| "accepted").map_err(|e| e.to_string()),
                backend.name(),
                other.map(|_| "accepted").map_err(|e| e.to_string()),
            ),
        }
    }
    sim.ok()
}

/// [`pin_bitwise_or_reject`] for expressions that must be accepted.
fn pin_bitwise(expr: &str, shapes: &[Vec<usize>], p: usize, cfg: PlannerConfig) -> Tensor {
    pin_bitwise_or_reject(expr, shapes, p, cfg)
        .unwrap_or_else(|| panic!("{expr} P={p}: expected every backend to accept"))
}

#[test]
fn mttkrp_bitwise_across_backends() {
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijk,ja,ka->ia",
            &[vec![16, 20, 12], vec![20, 6], vec![12, 6]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn ttmc_bitwise_across_backends() {
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijklm,jb,kc,ld,me->ibcde",
            &[vec![8, 6, 6, 6, 6], vec![6, 3], vec![6, 3], vec![6, 3], vec![6, 3]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn permuted_gather_bitwise_across_backends() {
    // Output order 'ai' differs from the MTTKRP kernel's natural
    // (mode, r) order, forcing the permuted-gather staging path.
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijk,ja,ka->ai",
            &[vec![16, 20, 12], vec![20, 6], vec![12, 6]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn allreduce_and_redistribution_bitwise_across_backends() {
    // A small analysis S forces the two-term [MTTKRP, MM] split: the
    // plan carries an inter-term redistribution, and the term grids
    // reduce over sub-grids (real allreduce traffic on the distributed
    // backends).
    let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
    for p in [1, 4, 8] {
        pin_bitwise(
            "ijk,ja,ka,al->il",
            &[vec![10, 10, 10], vec![10, 10], vec![10, 10], vec![10, 10]],
            p,
            cfg,
        );
    }
}

#[test]
fn degenerate_extents_bitwise_across_backends() {
    // Extent-1 and extent-0 blocks through staging, redistribution and
    // gather: the degenerate distributions the fuzzer generates, pinned
    // on every backend at P=1 (trivial grids) and P ∈ {4, 8}.
    for p in [1, 4, 8] {
        pin_bitwise(
            "ij,jk->ik",
            &[vec![1, 5], vec![5, 1]],
            p,
            PlannerConfig::default(),
        );
        // Extent 0: accepted with an empty output, or rejected typed —
        // but identically on every backend.
        if let Some(empty) = pin_bitwise_or_reject(
            "ij,jk->ik",
            &[vec![0, 4], vec![4, 3]],
            p,
            PlannerConfig::default(),
        ) {
            assert_eq!(empty.dims(), &[0, 3]);
        }
        pin_bitwise(
            "ijk,ja,ka->ia",
            &[vec![4, 1, 3], vec![1, 2], vec![3, 2]],
            p,
            PlannerConfig::default(),
        );
    }
}

#[test]
fn edge_rank_clipped_padding_survives_dirty_recycling() {
    // Prime-ish extents leave the edge ranks with clipped blocks whose
    // buffers carry zero padding; reruns recycle those buffers dirty, so
    // the padding must be re-established every run on every backend.
    let shapes = [vec![9, 7, 5], vec![7, 3], vec![5, 3]];
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 42 + i as u64))
        .collect();
    let mut outputs: Vec<Tensor> = Vec::new();
    for backend in BACKENDS {
        let session =
            Session::builder().ranks(8).backend(backend).build().unwrap();
        let mut prog = session.compile("ijk,ja,ka->ia", &shapes).unwrap();
        let first = prog.run(&inputs).unwrap().output;
        for run in 0u64..3 {
            let mut dest = Tensor::random(&prog.output_dims(), 7 + run);
            prog.run_into(&inputs, &mut dest).unwrap();
            assert!(
                first.allclose(&dest, 0.0, 0.0),
                "{}: rerun {run} over dirty recycled buffers must be bitwise stable",
                backend.name()
            );
        }
        outputs.push(first);
    }
    for (backend, out) in BACKENDS.iter().zip(&outputs).skip(1) {
        assert!(outputs[0].allclose(out, 0.0, 0.0), "sim vs {}", backend.name());
    }
}

/// Shared body of the counters pin: the distributed backends are not
/// zero-alloc asserted at the engine-pool level (rank kernels hit the
/// shared pool concurrently), but their tensor-level counters —
/// per-rank store destinations, compute outputs, local scratch — must
/// go flat once warm, same as sim.
fn tensor_counters_stay_flat_on(backend: ExecBackend) {
    let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
    let shapes = [vec![16, 16, 16], vec![16, 8], vec![16, 8], vec![8, 16]];
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 9 + i as u64))
        .collect();
    let session = Session::builder()
        .ranks(8)
        .planner(cfg)
        .backend(backend)
        .build()
        .unwrap();
    let mut prog = session.compile("ijk,ja,ka,al->il", &shapes).unwrap();
    assert!(!prog.plan().moves.is_empty(), "want redistribution in the plan");
    let first = prog.run(&inputs).unwrap();
    prog.run(&inputs).unwrap();
    let warm = prog.stats();
    assert!(warm.store.dest_allocs > 0);
    assert!(warm.store.out_allocs > 0);
    for _ in 0..3 {
        let rep = prog.run(&inputs).unwrap();
        assert!(rep.output.allclose(&first.output, 0.0, 0.0));
    }
    let after = prog.stats();
    assert_eq!(
        after.tensor_allocs(),
        warm.tensor_allocs(),
        "warm {} reruns must not allocate store/scratch tensors ({warm:?} -> {after:?})",
        backend.name()
    );
    assert!(after.store.dest_reuses > warm.store.dest_reuses);
    assert!(after.store.out_reuses > warm.store.out_reuses);
}

#[test]
fn mp_tensor_counters_stay_flat_across_reruns() {
    tensor_counters_stay_flat_on(ExecBackend::Mp);
}

#[test]
fn proc_tensor_counters_stay_flat_across_reruns() {
    tensor_counters_stay_flat_on(ExecBackend::Proc);
}

/// Spawn one `deinsum rank-worker --listen 127.0.0.1:0` child via the
/// real CLI and parse the `listening <addr>` line for its ephemeral
/// port.
fn spawn_listen_worker(listen: &str) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_deinsum"))
        .args(["rank-worker", "--listen", listen])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn rank-worker");
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("worker banner");
    let addr = line
        .trim()
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
        .to_string();
    (child, addr)
}

#[test]
fn killed_rank_worker_is_typed_and_rebuild_reconnects() {
    // Two real rank-worker processes in TCP listen mode.
    let (child0, addr0) = spawn_listen_worker("127.0.0.1:0");
    let (child1, addr1) = spawn_listen_worker("127.0.0.1:0");
    let mut children = vec![child0, child1];
    let session = Session::builder()
        .ranks(2)
        .backend(ExecBackend::Proc)
        .rank_addrs(vec![addr0, addr1.clone()])
        // Also bounds the dead-address reconnect below: that run fails
        // only after the full connect window, so keep it short.
        .peer_timeout(Duration::from_secs(2))
        .build()
        .unwrap();
    let shapes = [vec![8, 6], vec![6, 4]];
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 77 + i as u64))
        .collect();
    let mut prog = session.compile("ij,jk->ik", &shapes).unwrap();
    let first = prog.run(&inputs).unwrap().output;

    // Kill rank 1's process mid-life: the next run must surface a typed
    // error under the peer deadline — no hang, no panic.
    children[1].kill().expect("kill rank 1");
    children[1].wait().expect("reap rank 1");
    let err = prog.run(&inputs).unwrap_err();
    assert!(
        matches!(err, Error::Protocol { .. }),
        "killed worker must be a typed protocol error, got: {err}"
    );

    // The poisoned executor is rebuilt on the next run; with rank 1
    // still dead the reconnect itself fails typed (never hangs).
    let err = prog.run(&inputs).unwrap_err();
    assert!(
        matches!(err, Error::Protocol { .. }),
        "reconnect to a dead worker must stay typed, got: {err}"
    );

    // Revive rank 1 at its old address (SO_REUSEADDR lets the listener
    // rebind immediately): the rebuild seam reconnects and the program
    // completes bitwise-identically.
    let (child1b, addr1b) = spawn_listen_worker(&addr1);
    children[1] = child1b;
    assert_eq!(addr1b, addr1, "revived worker must reuse the address");
    let again = prog.run(&inputs).unwrap().output;
    assert!(
        first.allclose(&again, 0.0, 0.0),
        "post-rebuild run must be bitwise identical"
    );

    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
}
