//! Property tests for the packed compute engine: the new packed GEMM,
//! threaded fused MTTKRP, and parallel transpose against the naive
//! elementwise oracles, across randomized odd shapes — non-multiples of
//! every block size, degenerate extent-1 dims, empty free sets — and
//! across serial/threaded configs (hand-rolled generator: the offline
//! registry has no proptest; failing seeds print and reproduce).

use deinsum::tensor::kernel::{self, KernelConfig, ScratchPool};
use deinsum::tensor::{contract, transpose, Tensor};

/// Tiny deterministic PRNG (xorshift64*).
struct Rng(u64);
impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
}

fn stress_cfgs() -> Vec<KernelConfig> {
    vec![
        // Tiny blocks force many ragged macro/micro edges.
        KernelConfig { mc: 16, kc: 8, nc: 16, threads: 1 }.normalized(),
        KernelConfig { mc: 16, kc: 24, nc: 16, threads: 3 }.normalized(),
        KernelConfig::default().serial(),
        KernelConfig::default().with_threads(4),
    ]
}

fn gemm_oracle(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aik = a[i * k + p];
            for j in 0..n {
                c[i * n + j] += aik * b[p * n + j];
            }
        }
    }
    c
}

#[test]
fn property_packed_gemm_matches_oracle() {
    let pool = ScratchPool::new();
    let mut rng = Rng::new(0x6E44);
    let cfgs = stress_cfgs();
    for trial in 0..60 {
        // Odd shapes around the MR/NR=8 and block boundaries; extent-1
        // dims model empty free sets after folding.
        let m = rng.range(1, 70);
        let k = rng.range(1, 90);
        let n = rng.range(1, 70);
        let a = Tensor::random(&[m, k], 1000 + trial);
        let b = Tensor::random(&[k, n], 2000 + trial);
        let want = gemm_oracle(a.data(), b.data(), m, k, n);
        for cfg in &cfgs {
            let mut c = vec![0.0f32; m * n];
            kernel::gemm_into_with(cfg, &pool, a.data(), b.data(), &mut c, m, k, n);
            for (i, (&g, &w)) in c.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 + 1e-3 * w.abs(),
                    "trial {trial} ({m},{k},{n}) cfg {cfg:?} elem {i}: {g} vs {w}"
                );
            }
        }
    }
}

#[test]
fn property_gemm_degenerate_extent_one() {
    // m=1 / n=1 / k=1 boundaries (empty free or contracted sets after
    // folding) against the oracle, all configs.
    let pool = ScratchPool::new();
    for &(m, k, n) in
        &[(1usize, 1usize, 1usize), (1, 50, 1), (1, 1, 40), (40, 1, 1), (1, 33, 27), (27, 33, 1)]
    {
        let a = Tensor::random(&[m, k], 7);
        let b = Tensor::random(&[k, n], 8);
        let want = gemm_oracle(a.data(), b.data(), m, k, n);
        for cfg in &stress_cfgs() {
            let mut c = vec![0.0f32; m * n];
            kernel::gemm_into_with(cfg, &pool, a.data(), b.data(), &mut c, m, k, n);
            let got = Tensor::from_vec(&[m, n], c).unwrap();
            let want_t = Tensor::from_vec(&[m, n], want.clone()).unwrap();
            assert!(got.allclose(&want_t, 1e-4, 1e-4), "({m},{k},{n}) cfg {cfg:?}");
        }
    }
}

/// Elementwise MTTKRP oracle straight from the einsum.
fn mttkrp_oracle(x: &Tensor, factors: &[&Tensor], mode: usize) -> Tensor {
    let order = x.order();
    let rest: Vec<usize> = (0..order).filter(|&m| m != mode).collect();
    let r = factors[rest[0]].dims()[1];
    let mut out = Tensor::zeros(&[x.dims()[mode], r]);
    let dims = x.dims().to_vec();
    let total: usize = dims.iter().product();
    let strides = deinsum::tensor::strides_of(&dims);
    for flat in 0..total {
        let mut rem = flat;
        let mut idx = vec![0usize; order];
        for d in 0..order {
            idx[d] = rem / strides[d];
            rem %= strides[d];
        }
        for c in 0..r {
            let mut v = x.data()[flat];
            for &m in &rest {
                v *= factors[m].at(&[idx[m], c]);
            }
            *out.at_mut(&[idx[mode], c]) += v;
        }
    }
    out
}

#[test]
fn property_fused_mttkrp_matches_oracle() {
    let pool = ScratchPool::new();
    let mut rng = Rng::new(0x3771);
    let cfgs = stress_cfgs();
    for trial in 0..25 {
        let order = rng.range(2, 4);
        let dims: Vec<usize> = (0..order)
            .map(|_| if rng.range(0, 4) == 0 { 1 } else { rng.range(2, 13) })
            .collect();
        let r = rng.range(1, 9);
        let x = Tensor::random(&dims, 3000 + trial);
        let fs: Vec<Tensor> =
            (0..order).map(|m| Tensor::random(&[dims[m], r], 4000 + trial * 7 + m as u64)).collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..order {
            let want = mttkrp_oracle(&x, &frefs, mode);
            for cfg in &cfgs {
                let got = contract::mttkrp_with(cfg, &pool, &x, &frefs, mode).unwrap();
                assert!(
                    got.allclose(&want, 1e-3, 1e-3),
                    "trial {trial} dims {dims:?} r {r} mode {mode} cfg {cfg:?}: rel {}",
                    got.rel_error(&want)
                );
            }
        }
    }
}

#[test]
fn mttkrp_large_engages_threaded_bands() {
    // Above the parallel cutoff: threaded result must equal serial and
    // the two-step oracle.
    let pool = ScratchPool::new();
    let x = Tensor::random(&[80, 40, 40], 1);
    let fs: Vec<Tensor> = (0..3).map(|m| Tensor::random(&[x.dims()[m], 24], 2 + m as u64)).collect();
    let frefs: Vec<&Tensor> = fs.iter().collect();
    for mode in 0..3 {
        let serial =
            contract::mttkrp_with(&KernelConfig::default().serial(), &pool, &x, &frefs, mode)
                .unwrap();
        let threaded =
            contract::mttkrp_with(&KernelConfig::default().with_threads(4), &pool, &x, &frefs, mode)
                .unwrap();
        assert!(serial.allclose(&threaded, 1e-5, 1e-5), "mode {mode}");
        let two = contract::mttkrp_two_step(&x, &frefs, mode).unwrap();
        assert!(serial.allclose(&two, 1e-2, 1e-3), "mode {mode} vs two-step");
    }
}

#[test]
fn property_einsum2_into_bitwise_identical_to_allocating() {
    // The recycled-output variant shares the allocating path's dispatch
    // and arithmetic order, so results must be *bitwise* identical — at
    // odd shapes, across thread counts, into dirty destinations.
    let pool = ScratchPool::new();
    let mut rng = Rng::new(0x51A7);
    for trial in 0..40 {
        let (i, j, k) = (rng.range(1, 33), rng.range(1, 45), rng.range(1, 29));
        let a = rng.range(1, 17);
        let x = Tensor::random(&[i, j, k], 6000 + trial);
        let y = Tensor::random(&[j, k, a], 7000 + trial);
        // Rotate through output orders incl. permuted layouts.
        let outs: [&[char]; 3] = [&['i', 'a'], &['a', 'i'], &['i']];
        let out_idx = outs[(trial % 3) as usize];
        for threads in [1usize, 8] {
            let cfg = KernelConfig::default().with_threads(threads);
            let want = contract::einsum2_with(
                &cfg, &pool, &x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], out_idx,
            )
            .unwrap();
            let mut dest = Tensor::random(want.dims(), 8000 + trial);
            contract::einsum2_into_with(
                &cfg, &pool, &x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], out_idx, &mut dest,
            )
            .unwrap();
            assert_eq!(
                dest, want,
                "trial {trial} ({i},{j},{k},{a}) ->{out_idx:?} threads {threads}"
            );
        }
    }
}

#[test]
fn property_mttkrp_into_bitwise_identical_to_allocating() {
    let pool = ScratchPool::new();
    let mut rng = Rng::new(0x91B3);
    for trial in 0..25 {
        let order = rng.range(2, 4);
        let dims: Vec<usize> = (0..order)
            .map(|_| if rng.range(0, 4) == 0 { 1 } else { rng.range(2, 13) })
            .collect();
        let r = rng.range(1, 9);
        let x = Tensor::random(&dims, 9000 + trial);
        let fs: Vec<Tensor> = (0..order)
            .map(|m| Tensor::random(&[dims[m], r], 9500 + trial * 7 + m as u64))
            .collect();
        let frefs: Vec<&Tensor> = fs.iter().collect();
        for mode in 0..order {
            for threads in [1usize, 8] {
                let cfg = KernelConfig::default().with_threads(threads);
                let want = contract::mttkrp_with(&cfg, &pool, &x, &frefs, mode).unwrap();
                let mut dest = Tensor::random(want.dims(), 9900 + trial);
                contract::mttkrp_with_into(&cfg, &pool, &x, &frefs, mode, &mut dest)
                    .unwrap();
                assert_eq!(
                    dest, want,
                    "trial {trial} dims {dims:?} r {r} mode {mode} threads {threads}"
                );
            }
        }
    }
}

/// Elementwise permute oracle.
fn permute_oracle(t: &Tensor, perm: &[usize]) -> Tensor {
    let src_dims = t.dims();
    let dst_dims: Vec<usize> = perm.iter().map(|&p| src_dims[p]).collect();
    let mut out = Tensor::zeros(&dst_dims);
    let strides = deinsum::tensor::strides_of(src_dims);
    for flat in 0..t.len() {
        let mut rem = flat;
        let mut idx = vec![0usize; src_dims.len()];
        for d in 0..src_dims.len() {
            idx[d] = rem / strides[d];
            rem %= strides[d];
        }
        let dst_idx: Vec<usize> = perm.iter().map(|&p| idx[p]).collect();
        *out.at_mut(&dst_idx) = t.data()[flat];
    }
    out
}

#[test]
fn property_parallel_transpose_matches_oracle() {
    let mut rng = Rng::new(0x7245);
    for trial in 0..30 {
        let order = rng.range(2, 5);
        let dims: Vec<usize> = (0..order)
            .map(|_| if rng.range(0, 4) == 0 { 1 } else { rng.range(2, 40) })
            .collect();
        // random permutation via repeated swaps
        let mut perm: Vec<usize> = (0..order).collect();
        for i in (1..order).rev() {
            perm.swap(i, rng.range(0, i));
        }
        let t = Tensor::random(&dims, 5000 + trial);
        let want = permute_oracle(&t, &perm);
        for threads in [1usize, 4] {
            let got =
                transpose::permute_with(&KernelConfig::default().with_threads(threads), &t, &perm);
            assert_eq!(
                got, want,
                "trial {trial} dims {dims:?} perm {perm:?} threads {threads}"
            );
        }
    }
}

#[test]
fn property_gemm_extent_zero_tiles_are_no_ops() {
    // Zero-size tiles are what over-partitioning produces locally (a
    // grid factor larger than a small extent leaves trailing ranks with
    // empty blocks — exactly the shapes the fuzzer's degenerate-extent
    // seeds drive through the planner's P=8 fallback).  The packed GEMM
    // must early-return — never index OOB or touch the accumulator: an
    // empty reduction (k = 0) under accumulate semantics leaves C
    // exactly as it was.
    let pool = ScratchPool::new();
    for cfg in &stress_cfgs() {
        for &(m, k, n) in &[(0usize, 5usize, 7usize), (5, 0, 7), (5, 7, 0), (0, 0, 0)] {
            let a = Tensor::random(&[m, k], 31);
            let b = Tensor::random(&[k, n], 32);
            let mut c = vec![9.0f32; m * n];
            kernel::gemm_into_with(cfg, &pool, a.data(), b.data(), &mut c, m, k, n);
            assert!(
                c.iter().all(|&v| v == 9.0),
                "({m},{k},{n}) cfg {cfg:?}: zero-size GEMM wrote to C"
            );
        }
    }
}

#[test]
fn property_einsum2_into_extent_zero_overwrites_dirty_dest() {
    // An extent-0 contracted index is an empty sum: a recycled dirty
    // destination must come back all-zero (fully overwritten), in both
    // the natural-layout accumulate path and the permuted-output path,
    // at every config.
    let pool = ScratchPool::new();
    let x = Tensor::zeros(&[3, 0, 4]); // ijk with j = 0
    let y = Tensor::zeros(&[0, 4, 2]); // jka
    for cfg in &stress_cfgs() {
        for out_idx in [&['i', 'a'] as &[char], &['a', 'i']] {
            let want = contract::einsum2_with(
                cfg, &pool, &x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], out_idx,
            )
            .unwrap();
            assert!(want.data().iter().all(|&v| v == 0.0), "->{out_idx:?} cfg {cfg:?}");
            let mut dest = Tensor::random(want.dims(), 77);
            contract::einsum2_into_with(
                cfg, &pool, &x, &['i', 'j', 'k'], &y, &['j', 'k', 'a'], out_idx, &mut dest,
            )
            .unwrap();
            assert_eq!(dest, want, "->{out_idx:?} cfg {cfg:?}: dirty dest survived");
        }
    }
    // Extent-0 *free* index: the output itself is empty, not zero-filled.
    let xe = Tensor::zeros(&[0, 3]); // ij with i = 0
    let ye = Tensor::zeros(&[3, 2]); // ja
    let serial = KernelConfig::default().serial();
    let (xi, yi, oi): (&[char], &[char], &[char]) = (&['i', 'j'], &['j', 'a'], &['i', 'a']);
    let out = contract::einsum2_with(&serial, &pool, &xe, xi, &ye, yi, oi).unwrap();
    assert_eq!(out.dims(), &[0, 2]);
    assert!(out.is_empty());
}

#[test]
fn property_einsum2_extent_one_folds_match_oracle() {
    // All-singleton and mixed extent-1 shapes: the folds that collapse
    // empty free/contracted sets must stay exact (bitwise vs the _into
    // twin, value-correct vs hand computation).
    let pool = ScratchPool::new();
    for cfg in &stress_cfgs() {
        let x = Tensor::from_vec(&[1, 1, 1], vec![3.0]).unwrap();
        let y = Tensor::from_vec(&[1, 1, 1], vec![5.0]).unwrap();
        let (xi, yi): (&[char], &[char]) = (&['i', 'j', 'k'], &['j', 'k', 'a']);
        let oi: &[char] = &['i', 'a'];
        let got = contract::einsum2_with(cfg, &pool, &x, xi, &y, yi, oi).unwrap();
        assert_eq!(got.dims(), &[1, 1], "cfg {cfg:?}");
        assert_eq!(got.data(), &[15.0], "cfg {cfg:?}");

        // Extent-1 contracted dim next to a real one: ij,jk->ki with
        // j = 1 degenerates to a permuted outer product.
        let a = Tensor::from_vec(&[2, 1], vec![2.0, -1.0]).unwrap();
        let b = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 4.0]).unwrap();
        let (ai, bi, ki): (&[char], &[char], &[char]) = (&['i', 'j'], &['j', 'k'], &['k', 'i']);
        let got = contract::einsum2_with(cfg, &pool, &a, ai, &b, bi, ki).unwrap();
        assert_eq!(got.dims(), &[3, 2], "cfg {cfg:?}");
        assert_eq!(got.data(), &[2.0, -1.0, 4.0, -2.0, 8.0, -4.0], "cfg {cfg:?}");
        let mut dest = Tensor::random(&[3, 2], 55);
        contract::einsum2_into_with(cfg, &pool, &a, ai, &b, bi, ki, &mut dest).unwrap();
        assert_eq!(dest, got, "cfg {cfg:?}: _into twin diverged");
    }
}

#[test]
fn property_mttkrp_into_extent_zero_zeroes_dest() {
    // Both degenerate MTTKRP shapes: an empty mode-0 fiber count (empty
    // output) and an empty rest mode (empty reduction — the dirty dest
    // must be zero-filled, not left stale).
    let pool = ScratchPool::new();
    let r = 5usize;
    for cfg in &stress_cfgs() {
        for dims in [vec![0usize, 4, 3], vec![4, 0, 3]] {
            let x = Tensor::zeros(&dims);
            let fs: Vec<Tensor> = dims.iter().map(|&d| Tensor::random(&[d, r], 3)).collect();
            let frefs: Vec<&Tensor> = fs.iter().collect();
            let mut dest = Tensor::random(&[dims[0], r], 9);
            contract::mttkrp_with_into(cfg, &pool, &x, &frefs, 0, &mut dest).unwrap();
            assert_eq!(dest.dims(), &[dims[0], r], "dims {dims:?} cfg {cfg:?}");
            assert!(
                dest.data().iter().all(|&v| v == 0.0),
                "dims {dims:?} cfg {cfg:?}: dirty dest survived an empty reduction"
            );
        }
    }
}

#[test]
fn property_transpose_extent_zero_is_empty() {
    // Permuting a tensor with a 0-extent mode must produce the permuted
    // (still empty) shape without touching any element.
    for threads in [1usize, 4] {
        let cfg = KernelConfig::default().with_threads(threads);
        let t = Tensor::zeros(&[3, 0, 2]);
        let got = transpose::permute_with(&cfg, &t, &[2, 0, 1]);
        assert_eq!(got.dims(), &[2, 3, 0], "threads {threads}");
        assert!(got.is_empty(), "threads {threads}");
    }
}

#[test]
fn transpose_above_parallel_cutoff_matches_oracle() {
    // Forcefully large tensors so the threaded paths run: both the
    // inner-run fast path and the blocked 2D path.
    for (dims, perm) in [
        (vec![40usize, 50, 40], vec![1usize, 0, 2]), // inner mode fixed
        (vec![40, 50, 40], vec![2, 1, 0]),           // blocked path
        (vec![300, 300], vec![1, 0]),                // big matrix transpose
    ] {
        let t = Tensor::random(&dims, 17);
        let want = permute_oracle(&t, &perm);
        let got = transpose::permute_with(&KernelConfig::default().with_threads(8), &t, &perm);
        assert_eq!(got, want, "{dims:?} {perm:?}");
    }
}
