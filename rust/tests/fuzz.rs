//! Differential-fuzzing acceptance suite for the plan-or-typed-reject
//! invariant (ROADMAP item 4): a fixed-seed corpus driven through
//! `Session::compile` and `run`/`run_into` (dirty recycled destinations)
//! at rank counts {1, 4, 8} against the naive dense oracle, plus
//! rejection-determinism and shrinker regressions.
//!
//! The CI thread matrix (`DEINSUM_NUM_THREADS={1,8}`) runs this file
//! under both the serial and the 8-worker kernel paths, so the signature
//! assertions pin rejection stability across thread counts as well as
//! across reruns: classification is a pure function of
//! `(expr, shapes, P)` — the compile path never consults the kernel
//! thread count before accepting or rejecting.

use deinsum::fuzz::{self, FuzzCase};

/// The fixed campaign seed CI and the corpus tests share (also the
/// `deinsum fuzz` default).
const CORPUS_SEED: u64 = 20260808;

#[test]
fn corpus_plans_bitwise_or_rejects_typed() {
    let report = fuzz::campaign(CORPUS_SEED, 64, fuzz::DEFAULT_RANKS);
    assert!(report.bugs.is_empty(), "invariant violated:\n{}", report.corpus());
    assert_eq!(report.matches + report.rejects, report.cases);
    // The corpus must exercise both arms of the invariant, or the
    // campaign is vacuous.
    assert!(report.matches > 0, "no case matched the oracle bitwise");
    assert!(report.rejects > 0, "no case was typed-rejected");
}

#[test]
fn rejections_are_deterministic_and_never_retryable() {
    for k in 0..64u64 {
        let case = fuzz::generate(CORPUS_SEED, k);
        let first = fuzz::classify(&case, fuzz::DEFAULT_RANKS);
        let second = fuzz::classify(&case, fuzz::DEFAULT_RANKS);
        assert_eq!(
            first.signature(),
            second.signature(),
            "case {k} ({}) classified differently across reruns",
            case.expr
        );
        assert!(!first.is_bug(), "case {k}: {}", first.signature());
        for r in first.rejections() {
            assert!(!r.message.is_empty(), "case {k} P={}: empty rejection", r.ranks);
            assert!(
                !r.retryable,
                "case {k} P={}: rejection '{}' must never burn serve retry budget",
                r.ranks,
                r.message
            );
        }
    }
}

#[test]
fn hostile_expressions_reject_typed_at_every_rank_count() {
    // Hand-picked adversarial expressions the generator's grammar cannot
    // emit: each must produce the same typed rejection at P in {1,4,8}.
    let hostile: &[(&str, &[&[usize]])] = &[
        (",j->j", &[&[], &[3]]),                // empty operand
        ("ij,jk->ik,", &[&[2, 3], &[3, 2]]),    // trailing comma in output
        ("ii->i", &[&[2, 2]]),                  // trace (repeated index)
        ("ij,jk->ik", &[&[2, 0], &[0, 2]]),     // extent-0 contraction
        ("ij,ij->", &[&[2, 2], &[2, 2]]),       // rank-0 output
        ("ij,jk->il", &[&[2, 3], &[3, 2]]),     // unbound output index
        ("ij,jk->ik", &[&[2, 3], &[4, 2]]),     // extent conflict on j
    ];
    for (expr, shapes) in hostile {
        let shapes: Vec<Vec<usize>> = shapes.iter().map(|s| s.to_vec()).collect();
        let case = FuzzCase { seed: 0, case: 0, expr: expr.to_string(), shapes };
        let outcome = fuzz::classify(&case, fuzz::DEFAULT_RANKS);
        assert!(
            matches!(outcome, fuzz::Outcome::Reject(_)),
            "{expr}: expected typed reject at every rank count, got {}",
            outcome.signature()
        );
        assert_eq!(outcome.rejections().len(), fuzz::DEFAULT_RANKS.len(), "{expr}");
        for r in outcome.rejections() {
            assert!(!r.retryable, "{expr} P={}: '{}'", r.ranks, r.message);
        }
    }
}

#[test]
fn planted_bug_shrinks_to_minimal_and_reproduces_from_env_pair() {
    // Plant a synthetic failure predicate — any case with a contracted
    // index of extent >= 2, mimicking an accumulation defect — and pin
    // the acceptance contract end to end: the minimizer reaches <= 2
    // operands with single-digit extents, and the printed
    // `DEINSUM_FUZZ_SEED`/`DEINSUM_FUZZ_CASE` pair regenerates the
    // unshrunk ancestor through the same env-var path the CLI repro
    // mode (`deinsum fuzz`) uses.
    fn ops_of(c: &FuzzCase) -> Vec<&str> {
        c.expr.split_once("->").map(|(lhs, _)| lhs.split(',').collect()).unwrap_or_default()
    }
    let mut is_bug = |c: &FuzzCase| {
        let Some((_, rhs)) = c.expr.split_once("->") else { return false };
        ops_of(c)
            .iter()
            .zip(&c.shapes)
            .any(|(op, sh)| op.chars().zip(sh).any(|(i, &e)| !rhs.contains(i) && e >= 2))
    };
    let case = (0..64)
        .map(|k| fuzz::generate(0xF00D, k))
        .find(|c| ops_of(c).len() >= 3 && is_bug(c))
        .expect("corpus contains a 3+-operand contracted case");
    let shrunk = fuzz::shrink(&case, &mut is_bug);
    assert!(is_bug(&shrunk), "shrinking must preserve the planted failure");
    assert!(ops_of(&shrunk).len() <= 2, "minimal case has <= 2 operands: {}", shrunk.expr);
    assert!(
        shrunk.shapes.iter().flatten().all(|&e| e <= 9),
        "single-digit extents: {:?}",
        shrunk.shapes
    );

    // The one-line repro names the *ancestor* pair; round-trip it
    // through the env-var entry point.
    assert_eq!(
        shrunk.repro(),
        format!("DEINSUM_FUZZ_SEED={} DEINSUM_FUZZ_CASE={}", case.seed, case.case)
    );
    std::env::set_var("DEINSUM_FUZZ_SEED", case.seed.to_string());
    std::env::set_var("DEINSUM_FUZZ_CASE", case.case.to_string());
    let regen = fuzz::env_case().expect("env pair parses back");
    std::env::remove_var("DEINSUM_FUZZ_SEED");
    std::env::remove_var("DEINSUM_FUZZ_CASE");
    assert_eq!(regen, case, "env repro must regenerate the ancestor bit-for-bit");
}
