//! Integration tests over the real AOT artifacts: the PJRT CPU client
//! loads HLO text lowered from the JAX/Pallas kernels and the results
//! must match the native Rust oracles bit-for-bit up to f32 tolerance.
//!
//! Requires `make artifacts` (skipped gracefully when absent, e.g. in a
//! bare checkout).

use deinsum::runtime::{Engine, KernelEngine};
use deinsum::tensor::{contract, Tensor};
use deinsum::Session;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_lists_all_ops() {
    let Some(dir) = artifacts_dir() else { return };
    let m = deinsum::runtime::Manifest::load(&dir).unwrap();
    assert_eq!(m.format, "hlo-text-v1");
    for op in ["gemm", "mttkrp", "krp", "ttmc"] {
        assert!(
            m.variants.iter().any(|v| v.op == op),
            "missing op {op} in manifest"
        );
    }
    for v in &m.variants {
        assert!(dir.join(&v.file).exists(), "missing artifact {}", v.file);
        assert!(!v.output.is_empty());
    }
}

#[test]
fn pjrt_gemm_exact_variant_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let v = engine
        .manifest()
        .variants
        .iter()
        .find(|v| v.op == "gemm" && v.m == Some(64) && v.k == Some(64) && v.n == Some(64))
        .expect("gemm_64 variant");
    let a = Tensor::random(&[64, 64], 1);
    let b = Tensor::random(&[64, 64], 2);
    let got = engine.execute(v, &[&a, &b]).unwrap();
    let want = contract::gemm(&a, &b).unwrap();
    assert!(
        got.allclose(&want, 1e-3, 1e-3),
        "PJRT gemm diverges from native: rel {}",
        got.rel_error(&want)
    );
    assert_eq!(engine.stats().compiles, 1);
}

#[test]
fn pjrt_executable_cache_reused() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let v = engine
        .manifest()
        .variants
        .iter()
        .find(|v| v.op == "gemm" && v.m == Some(64))
        .unwrap()
        .clone();
    let a = Tensor::random(&[64, 64], 3);
    let b = Tensor::random(&[64, 64], 4);
    engine.execute(&v, &[&a, &b]).unwrap();
    engine.execute(&v, &[&a, &b]).unwrap();
    assert_eq!(engine.stats().compiles, 1, "second call must hit the cache");
}

#[test]
fn pjrt_fused_mttkrp_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let v = engine
        .manifest()
        .variants
        .iter()
        .find(|v| v.op == "mttkrp" && v.dims.as_deref() == Some(&[64, 64, 64][..]))
        .expect("mttkrp 64^3 variant");
    let x = Tensor::random(&[64, 64, 64], 5);
    let f1 = Tensor::random(&[64, 24], 6);
    let f2 = Tensor::random(&[64, 24], 7);
    let got = engine.execute(v, &[&x, &f1, &f2]).unwrap();
    let want = contract::mttkrp(&x, &[&x, &f1, &f2], 0).unwrap();
    assert!(
        got.allclose(&want, 1e-2, 1e-2),
        "PJRT fused MTTKRP diverges: rel {}",
        got.rel_error(&want)
    );
}

#[test]
fn kernel_engine_buckets_ragged_mttkrp() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = KernelEngine::pjrt(&dir).unwrap();
    // 60^3 pads up to the 64^3 bucket (zero padding is exact).
    let x = Tensor::random(&[60, 60, 60], 8);
    let f1 = Tensor::random(&[60, 24], 9);
    let f2 = Tensor::random(&[60, 24], 10);
    let got = engine.mttkrp(&x, &[&x, &f1, &f2], 0).unwrap();
    let want = contract::mttkrp(&x, &[&x, &f1, &f2], 0).unwrap();
    assert!(got.allclose(&want, 1e-2, 1e-2), "rel {}", got.rel_error(&want));
    let st = engine.stats();
    assert!(st.pjrt_padded >= 1, "expected a padded PJRT dispatch: {st:?}");
}

#[test]
fn kernel_engine_falls_back_when_no_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = KernelEngine::pjrt(&dir).unwrap();
    // A shape far from any bucket (pad ratio too large) -> native path.
    let a = Tensor::random(&[7, 3], 11);
    let b = Tensor::random(&[3, 5], 12);
    let got = engine.gemm(&a, &b).unwrap();
    let want = contract::gemm(&a, &b).unwrap();
    assert!(got.allclose(&want, 1e-4, 1e-4));
    assert!(engine.stats().native >= 1);
}

#[test]
fn pjrt_krp_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = KernelEngine::pjrt(&dir).unwrap();
    let u0 = Tensor::random(&[128, 24], 13);
    let u1 = Tensor::random(&[128, 24], 14);
    let got = engine.krp_flat(&u0, &u1).unwrap();
    let k = contract::krp_chain(&[&u0, &u1]).unwrap();
    let want = k.reshape(&[128 * 128, 24]).unwrap();
    assert!(got.allclose(&want, 1e-4, 1e-4));
    assert_eq!(engine.stats().pjrt_exact, 1);
}

#[test]
fn pjrt_ttmc_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = KernelEngine::pjrt(&dir).unwrap();
    let x = Tensor::random(&[16, 16, 16, 16, 16], 15);
    let fs: Vec<Tensor> = (0..5).map(|m| Tensor::random(&[16, 24], 16 + m as u64)).collect();
    let frefs: Vec<&Tensor> = fs.iter().collect();
    let got = engine.ttmc(&x, &frefs, 0).unwrap();
    let want = contract::ttmc(&x, &frefs, 0).unwrap();
    assert!(got.allclose(&want, 1e-2, 1e-2), "rel {}", got.rel_error(&want));
    assert_eq!(engine.stats().pjrt_exact, 1);
}

#[test]
fn distributed_run_on_pjrt_engine_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    // Full three-layer round trip through the front door: an
    // artifacts-backed session compiles and runs the program with
    // PJRT-served local kernels on every rank, vs the all-native run.
    let shapes = vec![vec![128, 128, 128], vec![128, 24], vec![128, 24]];
    let inputs = vec![
        Tensor::random(&[128, 128, 128], 21),
        Tensor::random(&[128, 24], 22),
        Tensor::random(&[128, 24], 23),
    ];
    let pjrt = Session::builder().ranks(8).artifacts(&dir).build().unwrap();
    let native = Session::builder().ranks(8).build().unwrap();
    let rep_p =
        pjrt.compile("ijk,ja,ka->ia", &shapes).unwrap().run(&inputs).unwrap();
    let rep_n =
        native.compile("ijk,ja,ka->ia", &shapes).unwrap().run(&inputs).unwrap();
    assert!(
        rep_p.output.allclose(&rep_n.output, 1e-2, 1e-2),
        "PJRT vs native distributed runs diverge: rel {}",
        rep_p.output.rel_error(&rep_n.output)
    );
    let st = pjrt.engine().stats();
    assert!(
        st.pjrt_exact + st.pjrt_padded > 0,
        "PJRT engine never used: {st:?}"
    );
}
