//! Concurrency coverage for the 0.6.0 multi-tenant layer: programs
//! compiled from one shared `Session` running on many threads must be
//! bitwise identical to serial execution with flat per-program tensor
//! allocations, the plan cache must survive concurrent access, and a
//! `Server` must sustain concurrent `run_into` traffic with zero
//! steady-state tensor allocations per request.
//!
//! The CI chaos leg re-runs this suite with `DEINSUM_FAULT_SEED` set,
//! which arms the env-seeded fault plan on every server built here
//! (strided transient run failures, worker panics, latency — see
//! `deinsum::fault`).  Under that flag the *exactness* asserts (zero
//! errors, flat allocations, warm hit rates) are relaxed — injected
//! faults legitimately consume retry budgets and drop warm programs —
//! but the load-bearing invariants hold unconditionally: every accepted
//! ticket resolves (`completed + errors == submitted`, nothing hangs)
//! and every *successful* reply is bitwise identical to the fault-free
//! serial reference.

use std::sync::Arc;

use deinsum::{ServeRequest, Server, Session, Tensor};

/// True on the CI chaos leg: servers built without an explicit
/// `fault_plan` inherit the `DEINSUM_FAULT_SEED`-seeded plan, so
/// injected faults are expected traffic.
fn faults_active() -> bool {
    std::env::var("DEINSUM_FAULT_SEED").is_ok()
}

/// A mixed workload: MTTKRP all three modes (one with a permuted
/// output), a TTMc-shaped chain, plain and transposed GEMM, and a
/// 2MM chain — eight distinct program keys.
fn mixed_workload() -> Vec<(&'static str, Vec<Vec<usize>>)> {
    let n = 12usize;
    let r = 4usize;
    vec![
        ("ijk,ja,ka->ia", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ka->ja", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ja->ka", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ja,ka->ai", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijkl,jb,kc,ld->ibcd", vec![vec![6, 6, 6, 6], vec![6, 3], vec![6, 3], vec![6, 3]]),
        ("ij,jk->ik", vec![vec![16, 12], vec![12, 8]]),
        ("ij,jk->ki", vec![vec![16, 12], vec![12, 8]]),
        ("ij,jk,kl->il", vec![vec![10, 8], vec![8, 12], vec![12, 6]]),
    ]
}

fn inputs_for(shapes: &[Vec<usize>], seed: u64) -> Arc<Vec<Tensor>> {
    Arc::new(
        shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, seed + i as u64))
            .collect(),
    )
}

#[test]
fn concurrent_programs_from_one_session_match_serial_bitwise() {
    let session = Arc::new(Session::builder().ranks(4).build().unwrap());
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 1000 + 100 * i as u64)).collect();

    // Serial reference: one program per key, run once.
    let serial: Vec<Tensor> = work
        .iter()
        .zip(&inputs)
        .map(|((expr, shapes), ins)| {
            session.compile(expr, shapes).unwrap().run(ins).unwrap().output
        })
        .collect();

    // Concurrent: one thread per key, each compiling its own program
    // from the SAME session (all compiles are now cache hits sharing the
    // serial pass's plans), re-running it with recycled outputs.  Every
    // rerun must be bitwise identical to serial, and per-program tensor
    // allocations must be flat after warmup.
    std::thread::scope(|s| {
        for (((expr, shapes), ins), want) in work.iter().zip(&inputs).zip(&serial) {
            let session = Arc::clone(&session);
            s.spawn(move || {
                let mut prog = session.compile(expr, shapes).unwrap();
                let mut out = Tensor::zeros(&prog.output_dims());
                for _ in 0..2 {
                    prog.run_into(ins, &mut out).unwrap();
                }
                assert!(out.allclose(want, 0.0, 0.0), "{expr}: warmup diverged from serial");
                // RunStats::tensor_allocs deliberately excludes the
                // session-wide engine packing pool, whose high-water
                // mark depends on which programs ran concurrently.
                let warm = prog.stats().tensor_allocs();
                for _ in 0..3 {
                    prog.run_into(ins, &mut out).unwrap();
                    assert!(
                        out.allclose(want, 0.0, 0.0),
                        "{expr}: concurrent rerun diverged from serial"
                    );
                }
                assert_eq!(
                    prog.stats().tensor_allocs(),
                    warm,
                    "{expr}: steady-state rerun allocated tensors under concurrency"
                );
            });
        }
    });
    let cs = session.cache_stats();
    assert_eq!(cs.misses, work.len() as u64, "serial pass planned each key exactly once");
    assert_eq!(cs.hits, work.len() as u64, "every concurrent compile must hit the cache");
}

#[test]
fn plan_cache_survives_concurrent_compile_stress() {
    // Loom-free stress: 8 threads hammer the shared cache with a mix of
    // hits and misses.  Invariants: every compile is counted exactly
    // once (hits + misses == total), capacity is respected, and every
    // returned program is runnable.
    let session = Arc::new(
        Session::builder().ranks(2).plan_cache_capacity(4).build().unwrap(),
    );
    let specs: Vec<(String, Vec<Vec<usize>>)> = (0..6)
        .map(|i| ("ij,jk->ik".to_string(), vec![vec![8 + 2 * i, 6], vec![6, 4]]))
        .collect();
    let threads = 8usize;
    let iters = 12usize;
    std::thread::scope(|s| {
        for t in 0..threads {
            let session = Arc::clone(&session);
            let specs = &specs;
            s.spawn(move || {
                for i in 0..iters {
                    let (expr, shapes) = &specs[(t + i) % specs.len()];
                    let mut prog = session.compile(expr, shapes).unwrap();
                    if i == 0 {
                        // Each thread also executes once: compiled
                        // handles must be immediately usable.
                        let ins: Vec<Tensor> = shapes
                            .iter()
                            .map(|sh| Tensor::random(sh, t as u64))
                            .collect();
                        let rep = prog.run(&ins).unwrap();
                        assert_eq!(rep.output.dims(), prog.output_dims());
                    }
                }
            });
        }
    });
    let cs = session.cache_stats();
    assert_eq!(
        cs.hits + cs.misses,
        (threads * iters) as u64,
        "every compile is exactly one counted hit or miss: {cs:?}"
    );
    // 6 distinct keys in a 4-entry cache: evictions must have happened,
    // and the cache never exceeds its bound.
    assert!(session.cached_plans() <= 4);
    assert!(cs.misses >= 6, "each distinct key planned at least once: {cs:?}");
}

#[test]
fn server_with_8_workers_sustains_concurrent_traffic_with_zero_steady_state_allocs() {
    // The acceptance pin: an 8-worker server serving mixed traffic from
    // two tenants over programs compiled from ONE session returns
    // bitwise-identical outputs vs serial execution, and once every
    // program is warm, requests perform zero tensor allocations
    // (counter-asserted through the server's own accounting).
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 5000 + 100 * i as u64)).collect();

    // Serial reference on an independent session (identical settings →
    // identical plans → bitwise-identical outputs).
    let reference: Vec<Tensor> = {
        let s = Session::builder().ranks(4).build().unwrap();
        work.iter()
            .zip(&inputs)
            .map(|((expr, shapes), ins)| {
                s.compile(expr, shapes).unwrap().run(ins).unwrap().output
            })
            .collect()
    };

    let session = Session::builder().ranks(4).build().unwrap();
    let server = Server::builder(session).workers(8).queue_capacity(32).build();
    let submit_round = |tenant: &str| -> Vec<deinsum::Ticket> {
        work.iter()
            .zip(&inputs)
            .map(|((expr, shapes), ins)| {
                server
                    .submit(ServeRequest {
                        tenant: tenant.into(),
                        expr: (*expr).into(),
                        shapes: shapes.clone(),
                        inputs: Arc::clone(ins),
                        dest: Tensor::zeros(
                            &Server::output_dims(expr, shapes).unwrap(),
                        ),
                    })
                    .unwrap()
            })
            .collect()
    };

    // Under the chaos leg, injected faults may legitimately exhaust a
    // request's retry budget: accept only the typed retryable classes.
    let chaos = faults_active();
    let wait_one = |ticket: deinsum::Ticket| -> Option<deinsum::ServeReply> {
        match ticket.wait() {
            Ok(reply) => Some(reply),
            Err(e) if chaos && e.is_retryable() => None,
            Err(e) => panic!("request failed outside injected-fault classes: {e}"),
        }
    };

    // Warmup: two rounds so every key's owning worker holds a warm
    // program and every recycled path (including permuted gathers) has
    // its buffers.
    for _ in 0..2 {
        for ticket in submit_round("warmup") {
            wait_one(ticket);
        }
    }
    let warm = server.stats();
    if !chaos {
        assert_eq!(warm.errors, 0, "warmup must succeed: {warm:?}");
        assert_eq!(warm.completed, 2 * work.len() as u64);
        assert_eq!(
            warm.program_misses,
            work.len() as u64,
            "each key instantiates exactly one program (key-affinity routing): {warm:?}"
        );
    }

    // Steady state: three interleaved rounds from two tenants, all in
    // flight together.
    let mut all_tickets = Vec::new();
    for _ in 0..3 {
        for tenant in ["tenant-a", "tenant-b"] {
            all_tickets.push((tenant, submit_round(tenant)));
        }
    }
    for (_, tickets) in all_tickets {
        for (ticket, want) in tickets.into_iter().zip(&reference) {
            if let Some(reply) = wait_one(ticket) {
                assert!(
                    reply.output.allclose(want, 0.0, 0.0),
                    "served output diverged from serial reference"
                );
            }
        }
    }

    let after = server.stats();
    // Unconditional: every accepted ticket resolved, nothing hangs.
    assert_eq!(after.submitted, 8 * work.len() as u64);
    assert_eq!(after.completed + after.errors, after.submitted, "zero lost tickets");
    assert_eq!(after.in_flight, 0);
    assert!(after.p50_latency_s <= after.p99_latency_s);
    if !chaos {
        assert_eq!(after.errors, 0);
        assert_eq!(after.completed, warm.completed + 6 * work.len() as u64);
        assert_eq!(
            after.tensor_allocs, warm.tensor_allocs,
            "steady-state serving must perform zero tensor allocations per request \
             ({warm:?} -> {after:?})"
        );
        assert!(after.tensor_reuses > warm.tensor_reuses, "requests must recycle buffers");
        assert_eq!(after.program_misses, warm.program_misses, "no program re-instantiation");
        assert!(after.throughput_rps > 0.0);
        assert!(after.hit_rate() > 0.8, "steady state must be warm-program hits: {after:?}");
    }

    // Per-tenant accounting: both tenants saw all three rounds.
    for tenant in ["tenant-a", "tenant-b"] {
        let ts = server.tenant_stats(tenant).unwrap();
        assert_eq!(
            ts.completed + ts.errors,
            3 * work.len() as u64,
            "{tenant}: every request resolved: {ts:?}"
        );
        assert_eq!(ts.in_flight, 0);
        if !chaos {
            assert_eq!(ts.completed, 3 * work.len() as u64, "{tenant}: {ts:?}");
            assert_eq!(ts.errors, 0);
        }
    }
    assert_eq!(server.tenants(), vec!["tenant-a", "tenant-b", "warmup"]);
}

#[test]
fn bounded_queue_applies_backpressure_without_losing_requests() {
    // One worker, tiny queue: submitters block instead of erroring or
    // dropping; every request completes exactly once.
    let session = Session::builder().ranks(2).build().unwrap();
    let server =
        Arc::new(Server::builder(session).workers(1).queue_capacity(2).build());
    let shapes = vec![vec![8, 6], vec![6, 4]];
    let ins = inputs_for(&shapes, 77);
    let chaos = faults_active();
    std::thread::scope(|s| {
        for t in 0..4 {
            let server = Arc::clone(&server);
            let shapes = shapes.clone();
            let ins = Arc::clone(&ins);
            s.spawn(move || {
                for _ in 0..4 {
                    let ticket = server
                        .submit(ServeRequest {
                            tenant: format!("client-{t}"),
                            expr: "ij,jk->ik".into(),
                            shapes: shapes.clone(),
                            inputs: Arc::clone(&ins),
                            dest: Tensor::zeros(&[8, 4]),
                        })
                        .unwrap();
                    match ticket.wait() {
                        Ok(_) => {}
                        Err(e) if chaos && e.is_retryable() => {}
                        Err(e) => panic!("request failed outside injected faults: {e}"),
                    }
                }
            });
        }
    });
    let st = server.stats();
    assert_eq!(st.submitted, 16);
    assert_eq!(st.completed + st.errors, 16, "zero lost tickets: {st:?}");
    if !chaos {
        assert_eq!((st.completed, st.errors), (16, 0));
    }
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.in_flight, 0);
    assert_eq!(server.tenants().len(), 4);
}

#[test]
fn programs_can_move_across_threads() {
    // Program: Send — compile on one thread, run on another, hand the
    // result back.  (Compile-time guarantee exercised at runtime.)
    let session = Session::builder().ranks(2).build().unwrap();
    let shapes = vec![vec![10, 8], vec![8, 6]];
    let mut prog = session.compile("ij,jk->ik", &shapes).unwrap();
    let ins = inputs_for(&shapes, 31);
    let here = prog.run(&ins).unwrap().output;
    let there = std::thread::spawn(move || {
        let out = prog.run(&ins).unwrap().output;
        (prog, out)
    })
    .join()
    .unwrap();
    assert!(here.allclose(&there.1, 0.0, 0.0));
    // And back again.
    let mut prog = there.0;
    let ins2 = inputs_for(&shapes, 31);
    assert!(prog.run(&ins2).unwrap().output.allclose(&here, 0.0, 0.0));
}
